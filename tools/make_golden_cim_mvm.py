#!/usr/bin/env python
"""Regenerate the committed cim_mvm golden-vector fixtures.

Each fixture in ``tests/golden/cim_mvm/`` is one .npz holding the
inputs, the crossbar params and the expected int32 output of one kernel
entry point.  Expectations come from the pure-jnp oracle
(``ref.cim_mvm_ref``) — the semantic ground truth — and are
cross-checked against the Pallas interpreter before being written, so a
fixture can only ever encode agreed-upon semantics.

The point of committing them: the conformance suite replays these on
*any* platform (TPU/GPU compiled routes included) without needing
hypothesis or a tracked RNG — a bit-for-bit contract across backends
and releases.  Inputs are crc32-seeded from the case name, mirroring
``cimsim.functional.make_weights`` (stable across processes and
PYTHONHASHSEED).

Usage:  PYTHONPATH=src python tools/make_golden_cim_mvm.py
"""
from __future__ import annotations

import pathlib
import sys
import zlib

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp                                   # noqa: E402

from repro.kernels.cim_mvm import CimMvmParams            # noqa: E402
from repro.kernels.cim_mvm import ops                     # noqa: E402
from repro.kernels.cim_mvm import ref                     # noqa: E402

OUT_DIR = pathlib.Path(__file__).resolve().parent.parent / "tests" / \
    "golden" / "cim_mvm"

#: (name, kind, params, shape) — shape is (M, R, C) for cim_mvm /
#: cim_mvm_signed and (T, M, R, C) for cim_mvm_tiles.  Params cover the
#: preset families plus a hard-saturating ADC.
CASES = [
    ("mvm_isaac", "cim_mvm", CimMvmParams(8, 8, 1, 2, 8, 8), (5, 40, 9)),
    ("mvm_puma", "cim_mvm", CimMvmParams(8, 8, 8, 2, 128, 8), (3, 130, 17)),
    ("mvm_saturating", "cim_mvm", CimMvmParams(8, 8, 8, 8, 128, 4),
     (4, 128, 8)),
    ("tiles_isaac", "cim_mvm_tiles", CimMvmParams(8, 8, 1, 2, 8, 8),
     (3, 6, 20, 12)),
    ("tiles_saturating", "cim_mvm_tiles", CimMvmParams(8, 8, 1, 2, 8, 4),
     (2, 4, 16, 8)),
    ("signed_wide_adc", "cim_mvm_signed", CimMvmParams(8, 8, 1, 2, 8, 16),
     (7, 50, 11)),
]


def _rng(name: str, tag: str) -> np.random.Generator:
    return np.random.default_rng(zlib.crc32(f"{name}\x00{tag}".encode()))


def _inputs(name: str, kind: str, params: CimMvmParams, shape):
    if kind == "cim_mvm_tiles":
        t, m, r, c = shape
        x = _rng(name, "x").integers(0, 1 << params.act_bits, (t, m, r))
        w = _rng(name, "w").integers(0, 1 << params.weight_bits, (t, r, c))
    elif kind == "cim_mvm_signed":
        m, r, c = shape
        half_a, half_w = 1 << (params.act_bits - 1), \
            1 << (params.weight_bits - 1)
        x = _rng(name, "x").integers(-half_a, half_a, (m, r))
        w = _rng(name, "w").integers(-half_w, half_w, (r, c))
    else:
        m, r, c = shape
        x = _rng(name, "x").integers(0, 1 << params.act_bits, (m, r))
        w = _rng(name, "w").integers(0, 1 << params.weight_bits, (r, c))
    return x.astype(np.int32), w.astype(np.int32)


def _expected(kind: str, x: np.ndarray, w: np.ndarray,
              params: CimMvmParams) -> np.ndarray:
    kw = dict(act_bits=params.act_bits, weight_bits=params.weight_bits,
              dac_bits=params.dac_bits, cell_bits=params.cell_bits,
              parallel_row=params.parallel_row, adc_bits=params.adc_bits)
    if kind == "cim_mvm_tiles":
        return np.asarray(ref.cim_mvm_ref_tiles(jnp.asarray(x),
                                                jnp.asarray(w), **kw))
    if kind == "cim_mvm_signed":
        ox, ow = 1 << (params.act_bits - 1), 1 << (params.weight_bits - 1)
        y_u = np.asarray(ref.cim_mvm_ref(jnp.asarray(x + ox),
                                         jnp.asarray(w + ow), **kw),
                         np.int64)
        sx = (x.astype(np.int64) + ox).sum(axis=-1, keepdims=True)
        sw = (w.astype(np.int64) + ow).sum(axis=0, keepdims=True)
        return (y_u - ow * sx - ox * sw
                + x.shape[-1] * ox * ow).astype(np.int32)
    return np.asarray(ref.cim_mvm_ref(jnp.asarray(x), jnp.asarray(w), **kw))


def main() -> None:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    entry = {"cim_mvm": ops.cim_mvm, "cim_mvm_tiles": ops.cim_mvm_tiles,
             "cim_mvm_signed": ops.cim_mvm_signed}
    for name, kind, params, shape in CASES:
        x, w = _inputs(name, kind, params, shape)
        y = _expected(kind, x, w, params)
        # cross-check: the Pallas interpreter must agree before we
        # enshrine the expectation
        y_interp = np.asarray(entry[kind](jnp.asarray(x), jnp.asarray(w),
                                          params, mode="interpret"))
        np.testing.assert_array_equal(y, y_interp)
        path = OUT_DIR / f"{name}.npz"
        np.savez_compressed(
            path, kind=np.array(kind), x=x, w=w, y=y,
            params=np.array([params.act_bits, params.weight_bits,
                             params.dac_bits, params.cell_bits,
                             params.parallel_row, params.adc_bits],
                            np.int32))
        print(f"wrote {path.relative_to(OUT_DIR.parent.parent.parent)} "
              f"({path.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
