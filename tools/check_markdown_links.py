#!/usr/bin/env python
"""Markdown link checker (no external deps).

Scans the given markdown files/directories for inline links and images
``[text](target)`` and verifies every *relative* target resolves to an
existing file or directory (anchors are stripped; ``http(s)``/``mailto``
links are skipped — CI must not depend on the network).  Exits non-zero
listing every broken link.

    python tools/check_markdown_links.py README.md docs
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

#: [text](target) / ![alt](target) — target up to the first unescaped ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def iter_md_files(paths):
    for p in map(Path, paths):
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        else:
            yield p


def check_file(path: Path):
    """Yield (line_number, target) for every broken relative link."""
    text = path.read_text(encoding="utf-8")
    in_fence = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
        if in_fence:
            continue
        for m in _LINK.finditer(line):
            target = m.group(1)
            if target.startswith(_SKIP):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                yield lineno, target


def main(argv) -> int:
    paths = argv or ["README.md", "docs"]
    broken = []
    n_files = 0
    for md in iter_md_files(paths):
        if not md.exists():
            broken.append((md, 0, "<file missing>"))
            continue
        n_files += 1
        for lineno, target in check_file(md):
            broken.append((md, lineno, target))
    for md, lineno, target in broken:
        print(f"BROKEN {md}:{lineno}: {target}", file=sys.stderr)
    print(f"checked {n_files} markdown file(s): "
          f"{'FAIL, ' + str(len(broken)) + ' broken link(s)' if broken else 'all links resolve'}")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
