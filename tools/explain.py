#!/usr/bin/env python
"""Compile-provenance CLI — "why does my network run the way it runs".

Compiles a workload for an architecture preset and prints the per-node
provenance table (``repro.obs.explain.ExplainReport``): the scheduling
tier each operator compiled under, its crossbar binding and grid, the
duplication the search paid for, which schedule segment it landed in,
plus the plan-level decisions (pipeline, ping-pong, cache provenance,
compile wall seconds) as metadata.

    python tools/explain.py --workload resnet18 --arch isaac-baseline
    python tools/explain.py --workload vgg7 --arch puma --level MVM \
        --format json

Pass ``--fault-prob`` to route through the fault-aware compiler and see
the retired-line provenance on top.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.abstraction import PRESETS, get_arch          # noqa: E402
from repro.core.mapping import BitBinding                     # noqa: E402
from repro.obs.explain import explain_compile                 # noqa: E402
from repro.workloads import WORKLOADS, get_workload           # noqa: E402


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="Per-node compile provenance for one workload/arch")
    ap.add_argument("--workload", default="resnet18",
                    choices=sorted(WORKLOADS))
    ap.add_argument("--arch", default="isaac-baseline",
                    choices=sorted(PRESETS))
    ap.add_argument("--level", default=None,
                    help="clamp the scheduling tier (CM/MVM/VVM aliases "
                         "accepted by the compiler; default: chip mode)")
    ap.add_argument("--binding", default="B->XBC",
                    choices=[b.value for b in BitBinding])
    ap.add_argument("--no-pipeline", action="store_true",
                    help="disable inter-operator pipelining")
    ap.add_argument("--no-duplication", action="store_true",
                    help="disable the duplication search")
    ap.add_argument("--fault-prob", type=float, default=None,
                    help="stuck-cell probability: route through the "
                         "fault-aware compiler")
    ap.add_argument("--seed", type=int, default=0,
                    help="fault-model seed (with --fault-prob)")
    ap.add_argument("--format", default="markdown",
                    choices=["markdown", "json"])
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    fault_model = None
    if args.fault_prob is not None:
        from repro.cimsim.faults import FaultModel
        fault_model = FaultModel(seed=args.seed,
                                 stuck_cell_rate=args.fault_prob)
    report = explain_compile(
        get_workload(args.workload), get_arch(args.arch),
        level=args.level,
        binding=BitBinding(args.binding),
        use_pipeline=not args.no_pipeline,
        use_duplication=not args.no_duplication,
        fault_model=fault_model)
    if args.format == "json":
        print(report.to_json(indent=2))
    else:
        print(report.to_markdown())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
