"""Compile a transformer (ViT) onto every published CIM chip abstraction
and compare schedules — the paper's §4.4 scenario, runnable end to end.

Shows the arch-applicability split: Q/K/V/O + MLP Gemms map to
crossbars, QK^T / AV MatMuls stay on the ALU (weight-stationary
constraint — DESIGN.md §4).

  PYTHONPATH=src python examples/compile_vit_cim.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.cimsim import perf
from repro.core import baselines, compiler
from repro.core.abstraction import get_arch
from repro.workloads import get_workload


def main():
    vit = get_workload("vit", n_layers=4)   # 4-layer ViT for a quick run
    n_cim = len(vit.cim_nodes)
    n_alu = len(vit.nodes) - n_cim
    print(f"ViT graph: {n_cim} crossbar-mappable Gemms, "
          f"{n_alu} ALU ops (incl. QK^T/AV MatMuls)\n")

    for preset in ("isaac-baseline", "puma", "jia-issc21"):
        arch = get_arch(preset)
        res = compiler.compile_graph(vit, arch)
        ours = perf.estimate(res.plan)
        noopt = perf.estimate(baselines.no_opt(vit, arch))
        counts = res.program.op_counts()
        cim_ops = sum(v for k, v in counts.items() if k.startswith("cim."))
        print(f"{preset:15s} mode={arch.mode.value:3s} "
              f"segments={ours.n_segments:3d} cim_ops={cim_ops:8d} "
              f"latency={ours.latency_cycles:10.0f}cy "
              f"speedup={noopt.latency_cycles/ours.latency_cycles:6.1f}x "
              f"peak_xbs={ours.peak_active_xbs:.0f}")


if __name__ == "__main__":
    main()
