"""End-to-end driver: train a ~100M-param reduced LM for a few hundred
steps on the host devices, with checkpoints + auto-resume.

  PYTHONPATH=src python examples/train_lm.py --steps 200

(Equivalent to `python -m repro.launch.train --arch gemma2-2b --reduced`;
this script sizes the model up to ~100M params and shows the loss curve.)
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec
from repro.data import TokenStream, make_batch_iterator
from repro.launch.mesh import make_host_mesh
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workdir", default="/tmp/repro_train_lm")
    ap.add_argument("--arch", default="qwen1.5-4b")
    args = ap.parse_args()

    # ~100M params: widen the reduced config of the chosen family
    cfg = dataclasses.replace(
        reduced(get_config(args.arch)),
        name="lm-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
        head_dim=64, d_ff=2048, vocab=32_768,
    )
    shape = ShapeSpec("train", "train", seq_len=256, global_batch=8)
    mesh = make_host_mesh()
    stream = TokenStream(cfg.vocab, shape.global_batch, shape.seq_len, seed=0)
    data = make_batch_iterator(stream)
    tcfg = TrainerConfig(workdir=args.workdir, num_steps=args.steps,
                         save_every=50, log_every=10, lr=3e-4)
    trainer = Trainer(cfg, shape, mesh, tcfg, data, data_state=stream.state)
    result = trainer.train()
    print("done:", result)
    print(f"metrics: {args.workdir}/metrics.jsonl")


if __name__ == "__main__":
    main()
