"""Quickstart: compile a small CNN through the full CIM-MLC stack and
execute the generated meta-operator flow in the functional simulator.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.cimsim import perf
from repro.cimsim.functional import simulate
from repro.core import baselines, compiler
from repro.core.abstraction import get_arch
from repro.workloads import get_workload


def main():
    # 1. a workload graph (ONNX-isomorphic IR) and a CIM chip abstraction
    graph = get_workload("tiny_cnn")
    arch = get_arch("isaac-baseline")
    print(f"workload: {graph.name} ({len(graph.nodes)} nodes)")
    print(f"chip: {arch.name}, mode={arch.mode.value}, "
          f"{arch.chip.n_cores} cores x {arch.core.n_xbs} crossbars "
          f"of {arch.xb.xb_size}")

    # 2. multi-level compilation (CG -> MVM -> VVM for a WLM chip)
    result = compiler.compile_graph(graph, arch)
    print("\n--- meta-operator flow (head) ---")
    print(result.program.to_text(max_lines=24))
    print("\nop counts:", dict(result.program.op_counts()))

    # 3. schedule quality vs baselines (same performance simulator)
    ours = perf.estimate(result.plan)
    noopt = perf.estimate(baselines.no_opt(graph, arch))
    poly = perf.estimate(baselines.poly_schedule(graph, arch))
    print(f"\nlatency: ours={ours.latency_cycles:.0f} cycles, "
          f"no-opt={noopt.latency_cycles:.0f} "
          f"({noopt.latency_cycles/ours.latency_cycles:.1f}x), "
          f"poly={poly.latency_cycles:.0f} "
          f"({poly.latency_cycles/ours.latency_cycles:.1f}x)")
    print(f"peak active crossbars: {ours.peak_active_xbs:.0f} "
          f"(staggered) vs {noopt.peak_active_xbs:.0f}")

    # 4. the flow computes the right numbers: interpret it and compare
    # with the int8 reference forward pass
    sim_out, ref_out, stats = simulate(graph, arch)
    ok = all(np.array_equal(sim_out[t], ref_out[t]) for t in graph.outputs)
    print(f"\nfunctional simulation: {stats.cim_reads} CIM reads, "
          f"{stats.dcom_ops} DCOM ops -> matches reference: {ok}")
    assert ok


if __name__ == "__main__":
    main()
