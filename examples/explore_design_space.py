"""Explore the cross-tier CIM design space — now as a multi-workload
campaign with successive halving.

Default run (``--mode campaign``): sweep several workloads against one
design space through a single shared job queue and compile cache, using
the multi-fidelity successive-halving searcher (analytic proxy → prefix
compile → full compile), then report per-workload Pareto frontiers and
the cross-workload robust points.  On one comparison workload the script
also runs exhaustive enumeration and demonstrates that halving pays a
small fraction of the full-fidelity compiles (>= 5x fewer) while
returning the same best-latency configuration, then runs the seeded
adaptive (ask/tell) searcher on the same space and prints its
scorecard next to the campaign's.

``--mode sweep`` keeps the original single-workload exhaustive sweep
with the warm-cache rerun demonstration.

    PYTHONPATH=src python examples/explore_design_space.py \
        --workloads resnet18,vgg7,tiny_cnn --arch isaac-baseline --workers 4

See docs/DSE.md for the guide.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.abstraction import PRESETS, get_arch          # noqa: E402
from repro.dse import (CompileCache, DesignSpace,             # noqa: E402
                       adaptive_search, campaign_scorecard, pareto_frontier,
                       run_campaign, search_scorecard, successive_halving)
from repro.dse.cache import default_cache_dir                 # noqa: E402
from repro.dse.runner import sweep                            # noqa: E402
from repro.workloads import WORKLOADS, get_workload           # noqa: E402

OBJECTIVES = ("latency_cycles", "peak_power", "crossbars_used")


def build_space(arch_name: str) -> DesignSpace:
    arch = get_arch(arch_name)
    xr, xc = arch.xb.xb_size
    return DesignSpace(
        arch,
        arch_axes={"xb.xb_size": [(xr, xc), (xr * 2, xc * 2)]},
    )


def load(name: str, in_hw: int):
    kw = {"in_hw": in_hw} if name.startswith(("resnet", "vgg")) else {}
    return get_workload(name, **kw)


def print_frontier(front, header: str) -> None:
    print(f"\n{header} ({len(front)} points, "
          f"minimizing {', '.join(OBJECTIVES)}):")
    hdr = f"{'latency':>12} {'peak_pwr':>9} {'xbs':>6}   configuration"
    print(hdr)
    print("-" * len(hdr))
    for r in front:
        m = r.metrics
        print(f"{m['latency_cycles']:12.1f} {m['peak_power']:9.1f} "
              f"{int(m['crossbars_used']):6d}   {r.point.label()}")


def run_campaign_demo(args, space, cache) -> int:
    graphs = {}
    for name in args.workloads.split(","):
        name = name.strip()
        graphs[name] = load(name, args.in_hw)
    points = space.points()
    print(f"workloads={','.join(graphs)} arch={args.arch} "
          f"points={len(points)} workers={args.workers} eta={args.eta}")
    print(f"cache: {cache.root}")

    t0 = time.perf_counter()
    camp = run_campaign(graphs, space, cache=cache, workers=args.workers,
                        eta=args.eta, robust_tol=args.robust_tol)
    camp_s = time.perf_counter() - t0
    print(f"\ncampaign finished in {camp_s:.2f}s")
    print(camp.summary())
    print()
    print(campaign_scorecard(camp).to_markdown())
    for name, w in camp.workloads.items():
        print_frontier(w.frontier, f"{name} Pareto frontier")

    # --- exhaustive-vs-halving demonstration on one workload -------------
    ref = args.compare_workload or next(iter(graphs))
    graph = graphs.get(ref) or load(ref, args.in_hw)
    print(f"\n=== exhaustive vs successive halving on {ref} ===")
    t0 = time.perf_counter()
    exhaustive = sweep(graph, space, cache=cache, workers=args.workers)
    ex_s = time.perf_counter() - t0
    ok = [r for r in exhaustive if r.ok]
    best_ex = min(ok, key=lambda r: (r.metrics["latency_cycles"], r.index))
    t0 = time.perf_counter()
    sr = successive_halving(graph, space, cache=cache, workers=args.workers,
                            eta=args.eta)
    sh_s = time.perf_counter() - t0
    for log in sr.rungs:
        print(f"  rung {log.rung} [{log.fidelity:6s}] evaluated "
              f"{log.evaluated:3d} -> promoted {log.promoted}")
    reduction = len(exhaustive) / max(sr.full_evals, 1)
    print(f"  exhaustive: {len(exhaustive)} full compiles in {ex_s:.2f}s")
    print(f"  halving:    {sr.full_evals} full compiles in {sh_s:.2f}s "
          f"-> {reduction:.1f}x fewer full-fidelity compiles")
    print(f"  exhaustive best: {best_ex.point.label()} "
          f"({best_ex.metrics['latency_cycles']:.0f} cycles)")
    assert sr.best is not None and sr.best.point == best_ex.point, \
        "halving diverged from the exhaustive best point"
    print("  halving returns the same best point: OK")
    assert reduction >= 5, \
        f"halving should compile >=5x fewer points (got {reduction:.1f}x)"

    # --- adaptive searcher on the same workload --------------------------
    print(f"\n=== adaptive (learned, budgeted) search on {ref} ===")
    t0 = time.perf_counter()
    asr = adaptive_search(graph, space, cache=cache, workers=args.workers,
                          seed=args.seed, batch=16,
                          prefix_keep=max(8, len(exhaustive) // 3),
                          full_keep=max(4, len(exhaustive) // 8))
    ad_s = time.perf_counter() - t0
    print(search_scorecard(asr, name=ref).to_markdown())
    gap = (asr.best.metrics["latency_cycles"]
           / best_ex.metrics["latency_cycles"] - 1.0)
    print(f"  adaptive: {asr.full_evals} full compiles in {ad_s:.2f}s; "
          f"best within {gap:.1%} of the exhaustive best")
    assert asr.best is not None, "adaptive found no feasible point"
    assert asr.full_evals * 3 <= len(exhaustive), \
        "adaptive should compile at most a third of the space at full fidelity"
    print(f"cache entries on disk: {cache.stats()['disk_entries']}")
    return 0


def run_sweep_demo(args, space, cache) -> int:
    graph = load(args.workloads.split(",")[0].strip(), args.in_hw)
    points = space.points()
    print(f"workload={graph.name} arch={args.arch} "
          f"points={len(points)} workers={args.workers}")
    print(f"cache: {cache.root}")

    t0 = time.perf_counter()
    results = sweep(graph, space, cache=cache, workers=args.workers)
    cold_s = time.perf_counter() - t0
    ok = [r for r in results if r.ok]
    n_hit = sum(r.cached for r in results)
    print(f"sweep 1: {len(ok)}/{len(results)} points in {cold_s:.2f}s "
          f"({n_hit} cache hits)")
    for r in results:
        if not r.ok:
            print(f"  infeasible: {r.point.label()}: {r.error}")

    if not args.no_warm_rerun:
        cache.drop_memory()      # force the disk path, not process memory
        t0 = time.perf_counter()
        rerun = sweep(graph, space, cache=cache, workers=args.workers)
        warm_s = time.perf_counter() - t0
        speedup = cold_s / max(warm_s, 1e-9)
        print(f"sweep 2 (warm cache): {warm_s:.2f}s -> {speedup:.1f}x "
              f"{'faster' if speedup >= 1 else 'SLOWER'} than sweep 1")
        assert all(r.cached for r in rerun if r.ok), \
            "warm sweep recompiled points that should have been cached"
        assert [r.metrics for r in rerun] == [r.metrics for r in results], \
            "warm sweep diverged from cold sweep"

    front = pareto_frontier(ok, OBJECTIVES)
    print_frontier(front, f"Pareto frontier ({len(ok)} feasible points)")
    best = front[0]
    print(f"\nlowest-latency config: {best.point.label()} "
          f"({best.metrics['latency_cycles']:.0f} cycles)")
    print(f"cache entries on disk: {cache.stats()['disk_entries']}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--mode", default="campaign",
                    choices=("campaign", "sweep"))
    ap.add_argument("--workloads", default="resnet18,vgg7,tiny_cnn",
                    help="comma-separated workload names "
                         f"(from {sorted(WORKLOADS)} or lmblock:<cfg>)")
    ap.add_argument("--compare-workload", default="resnet18",
                    help="workload for the exhaustive-vs-halving section")
    ap.add_argument("--in-hw", type=int, default=32,
                    help="input resolution for conv workloads")
    ap.add_argument("--arch", default="isaac-baseline",
                    choices=sorted(PRESETS))
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width for the job queue")
    ap.add_argument("--eta", type=int, default=3,
                    help="successive-halving promotion factor")
    ap.add_argument("--seed", type=int, default=0,
                    help="adaptive-search RNG seed (pins the trajectory)")
    ap.add_argument("--robust-tol", type=float, default=0.10,
                    help="robust-point tolerance (relative to per-workload "
                         "best)")
    ap.add_argument("--cache-dir", default=None,
                    help=f"compile cache root (default {default_cache_dir()})")
    ap.add_argument("--fresh", action="store_true",
                    help="clear the cache first (forces a cold run)")
    ap.add_argument("--no-warm-rerun", action="store_true",
                    help="sweep mode: skip the warm-cache demonstration")
    args = ap.parse_args(argv)

    space = build_space(args.arch)
    cache = CompileCache(args.cache_dir)
    if args.fresh:
        cache.clear()
    if args.mode == "campaign":
        return run_campaign_demo(args, space, cache)
    return run_sweep_demo(args, space, cache)


if __name__ == "__main__":
    sys.exit(main())
