"""Explore the cross-tier CIM design space for one workload.

Sweeps the scheduling level (CM/XBM/WLM), the bit-dimension binding,
the CG pipeline/duplication switches and a set of Abs-arch axes
(crossbar geometry by default) over a ResNet-style graph, then prints
the Pareto frontier over (latency, peak power, crossbars used).

Every compiled point lands in the content-addressed compile cache, so
re-running the same sweep is near-free; the script demonstrates this by
re-sweeping from disk and reporting the warm/cold speedup.

    PYTHONPATH=src python examples/explore_design_space.py \
        --workload resnet18 --in-hw 32 --arch isaac-baseline --workers 4
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.abstraction import PRESETS, get_arch          # noqa: E402
from repro.dse import (CompileCache, DesignSpace,             # noqa: E402
                       pareto_frontier)
from repro.dse.cache import default_cache_dir                 # noqa: E402
from repro.dse.runner import sweep                            # noqa: E402
from repro.workloads import WORKLOADS, get_workload           # noqa: E402

OBJECTIVES = ("latency_cycles", "peak_power", "crossbars_used")


def build_space(arch_name: str) -> DesignSpace:
    arch = get_arch(arch_name)
    xr, xc = arch.xb.xb_size
    return DesignSpace(
        arch,
        arch_axes={"xb.xb_size": [(xr, xc), (xr * 2, xc * 2)]},
    )


def run_sweep(graph, space, cache, workers):
    t0 = time.perf_counter()
    results = sweep(graph, space, cache=cache, workers=workers)
    return results, time.perf_counter() - t0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--workload", default="resnet18",
                    choices=sorted(WORKLOADS))
    ap.add_argument("--in-hw", type=int, default=32,
                    help="input resolution for conv workloads")
    ap.add_argument("--arch", default="isaac-baseline",
                    choices=sorted(PRESETS))
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width for the sweep")
    ap.add_argument("--cache-dir", default=None,
                    help=f"compile cache root (default {default_cache_dir()})")
    ap.add_argument("--fresh", action="store_true",
                    help="clear the cache first (forces a cold sweep)")
    ap.add_argument("--no-warm-rerun", action="store_true",
                    help="skip the warm-cache demonstration pass")
    args = ap.parse_args(argv)

    kw = {"in_hw": args.in_hw} if args.workload.startswith(
        ("resnet", "vgg")) else {}
    graph = get_workload(args.workload, **kw)
    space = build_space(args.arch)
    points = space.points()
    cache = CompileCache(args.cache_dir)
    if args.fresh:
        cache.clear()

    print(f"workload={graph.name} arch={args.arch} "
          f"points={len(points)} workers={args.workers}")
    print(f"cache: {cache.root}")

    results, cold_s = run_sweep(graph, space, cache, args.workers)
    ok = [r for r in results if r.ok]
    n_hit = sum(r.cached for r in results)
    print(f"sweep 1: {len(ok)}/{len(results)} points in {cold_s:.2f}s "
          f"({n_hit} cache hits)")
    for r in results:
        if not r.ok:
            print(f"  infeasible: {r.point.label()}: {r.error}")

    if not args.no_warm_rerun:
        cache.drop_memory()      # force the disk path, not process memory
        rerun, warm_s = run_sweep(graph, space, cache, args.workers)
        speedup = cold_s / max(warm_s, 1e-9)
        print(f"sweep 2 (warm cache): {warm_s:.2f}s -> {speedup:.1f}x "
              f"{'faster' if speedup >= 1 else 'SLOWER'} than sweep 1")
        assert all(r.cached for r in rerun if r.ok), \
            "warm sweep recompiled points that should have been cached"
        assert [r.metrics for r in rerun] == [r.metrics for r in results], \
            "warm sweep diverged from cold sweep"

    front = pareto_frontier(ok, OBJECTIVES)
    print(f"\nPareto frontier ({len(front)} of {len(ok)} feasible points, "
          f"minimizing {', '.join(OBJECTIVES)}):")
    hdr = f"{'latency':>12} {'peak_pwr':>9} {'xbs':>6}   configuration"
    print(hdr)
    print("-" * len(hdr))
    for r in front:
        m = r.metrics
        print(f"{m['latency_cycles']:12.1f} {m['peak_power']:9.1f} "
              f"{int(m['crossbars_used']):6d}   {r.point.label()}")

    best = front[0]
    print(f"\nlowest-latency config: {best.point.label()} "
          f"({best.metrics['latency_cycles']:.0f} cycles)")
    # hit/miss counters live in per-worker caches under a process pool,
    # so report only what is globally meaningful here
    print(f"cache entries on disk: {cache.stats()['disk_entries']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
