"""Batched serving example: prefill + decode over a request queue with
slot-based batching (reduced config on the host devices).

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import lm
from repro.serving import BatchServer, Request


def main():
    cfg = reduced(ARCHS["qwen1.5-4b"])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchServer(cfg, params, batch_slots=4, max_len=96)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 8 + i % 5),
                    max_new_tokens=12)
            for i in range(10)]
    t0 = time.time()
    done = server.serve(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done)
    for r in done[:4]:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> {r.output}")
    print(f"\n{len(done)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
