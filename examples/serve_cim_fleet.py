"""Serve two workloads co-resident on one CIM chip — the multi-tenant
fleet end to end.

ResNet-18 and a ViT share the ISAAC-like Table-3 chip: the tenancy
planner partitions the crossbar pool by traffic share (each tenant gets
a feasible ``CIMArch`` sub-view), the engine pool warm-loads one
trace-lowered executable per tenant, and the deadline-aware batcher
drains an interleaved request trace into bucketed batches.

The demo asserts the property that makes the fleet trustworthy: every
tenant's outputs are bit-exact against a standalone single-workload
``CimBatchService`` running on the whole chip — co-tenancy, partition
compiles, bucket padding and batching change *when* work runs, never
what it computes.

  PYTHONPATH=src python examples/serve_cim_fleet.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.cimsim.functional import make_input
from repro.core.abstraction import get_arch
from repro.serving import (CimBatchService, CimFleet, CimRequest,
                           TenantSpec, plan_tenancy)
from repro.workloads import get_workload


def main():
    arch = get_arch("isaac-baseline")
    resnet = get_workload("resnet18", in_hw=16)
    vit = get_workload("vit", n_layers=2)
    tenants = [TenantSpec("resnet18", resnet, traffic=3.0),
               TenantSpec("vit", vit, traffic=1.0)]

    plan = plan_tenancy(tenants, arch)
    print(plan.summary(), "\n")
    plan.validate()                      # crossbar budget provably respected

    t0 = time.time()
    fleet = CimFleet(tenants, arch, plan=plan, max_wait_s=0.0)
    print(f"fleet warm-up (compile + lower + pack): {time.time() - t0:.1f}s")

    # an interleaved trace, 3:1 resnet:vit like the traffic shares
    graphs = {"resnet18": resnet, "vit": vit}
    trace = []
    for i in range(12):
        model = "vit" if i % 4 == 3 else "resnet18"
        trace.append(CimRequest(rid=i, model=model,
                                inputs=make_input(graphs[model], i)))

    t0 = time.time()
    done = fleet.serve(trace, now=0.0)
    print(f"served {len(done)} requests in {time.time() - t0:.1f}s")
    print(fleet.stats().summary(), "\n")

    # ---- bit-exactness vs the standalone single-workload service ------
    for model, graph in graphs.items():
        svc = CimBatchService(graph, arch, max_batch=8)
        mine = [r for r in done if r.model == model]
        refs = [CimRequest(rid=r.rid, inputs=r.inputs) for r in mine]
        svc.serve(refs)
        for a, b in zip(mine, refs):
            for t in graph.outputs:
                np.testing.assert_array_equal(a.outputs[t], b.outputs[t])
        print(f"{model}: {len(mine)} fleet outputs bit-exact vs standalone "
              "CimBatchService on the full chip")
    print("\nco-tenancy changed scheduling, not semantics ✓")


if __name__ == "__main__":
    main()
