"""Sharding rule resolution + HLO roofline walker."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import roofline
from repro.configs import get_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import lm


def test_spec_divisibility_fallback():
    mesh = make_host_mesh()           # (n,1) over real devices
    rules = {"big": ("data",), "odd": ("data",), None: None}
    n = mesh.shape["data"]
    sp = shd.spec_for((n * 4, 7), ("big", "odd"), mesh, rules)
    assert sp == P("data") or sp == P("data", None)
    # odd dim falls back to replication
    sp2 = shd.spec_for((7,), ("odd",), mesh, rules) if n > 1 else P()
    if n > 1:
        assert sp2 == P()


def test_param_shardings_cover_tree():
    mesh = make_host_mesh()
    cfg = get_config("gemma2-2b")
    specs = lm.param_specs(cfg)
    axes = lm.logical_axes(cfg)
    rules = shd.param_rules(cfg, mesh, "train")
    sh = shd.tree_shardings(specs, axes, mesh, rules)
    n_spec = len(jax.tree.leaves(specs))
    n_sh = len(jax.tree.leaves(
        sh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)))
    assert n_spec == n_sh


def test_expert_rule_adaptive():
    mesh = make_host_mesh()
    mix = get_config("mixtral-8x7b")          # 8 experts
    dsk = get_config("deepseek-v2-lite-16b")  # 64 experts
    r_mix = shd.param_rules(mix, mesh, "train")
    r_dsk = shd.param_rules(dsk, mesh, "train")
    m = mesh.shape["model"]
    if mix.n_experts % m == 0:
        assert r_mix["expert"] == ("model",)
    else:
        assert r_mix["mlp_e"] == ("model",)
    assert (dsk.n_experts % m == 0) == (r_dsk["expert"] == ("model",))


MINI_HLO = """\
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,8]{1,0} all-gather(%d), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%i, %ag)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[8,8]{1,0}) tuple(%z, %a)
  %w0 = (s32[], f32[8,8]{1,0}) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"3"}}
  %ar = f32[8,8]{1,0} all-reduce(%a), channel_id=2, replica_groups=[1,8]<=[8], to_apply=%add
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w0), index=1
}
"""


def test_hlo_walker_trip_counts_and_collectives():
    res = roofline.parse_collectives(MINI_HLO, 8)
    # dot: 2*8*8*8 = 1024 flops, x3 trips
    assert res["walked_flops"] == 3 * 1024
    # all-gather in loop: 8*8*4 bytes * (4-1)/4 wire * 3 trips
    ag = 256 * 0.75 * 3
    # all-reduce outside: 2 * 256 * (8-1)/8
    ar = 2 * 256 * 7 / 8
    assert res["by_kind"]["all-gather"] == pytest.approx(ag)
    assert res["by_kind"]["all-reduce"] == pytest.approx(ar)
    assert res["total_bytes"] == pytest.approx(ag + ar)


def test_model_flops_and_terms():
    cfg = get_config("mixtral-8x7b")
    total, active = roofline.model_params(cfg)
    assert total > 4.5e10                     # ~46.7B
    assert 1.0e10 < active < 1.5e10           # ~12.9B active
    from repro.configs.base import SHAPES
    shape = SHAPES["train_4k"]
    useful = roofline.model_flops(cfg, shape) / 256
    rec = {"walked_flops": useful * 3, "walked_hbm_bytes": 1e11,
           "collective_bytes": 1e10}
    t = roofline.terms(rec, cfg, shape, 256)
    assert t["bottleneck"] in ("compute", "memory", "collective")
    assert 0 <= t["roofline_frac"] <= 1.0 + 1e-9
    assert t["useful_flops_frac"] == pytest.approx(1 / 3)
