"""Multi-tenant CIM serving fleet: tenancy planner budget invariants,
deadline-aware bucketed batching, fleet-vs-standalone bit-exactness,
CimBatchService edge cases, and the compile-cache size cap."""
import os

import numpy as np
import pytest

from repro.cimsim.functional import make_input
from repro.core.abstraction import get_arch
from repro.serving import (CimBatchService, CimFleet, CimRequest,
                           DynamicBatcher, ServiceStats, TenantSpec,
                           bucket_for, plan_tenancy)
from repro.workloads import get_workload

ISAAC = get_arch("isaac-baseline")
CHIP8 = ISAAC.subarch(8, "isaac-8c")        # small planner playground
CNN = get_workload("tiny_cnn")
MLP = get_workload("tiny_mlp")


def _tenants(traffic_cnn=3.0, traffic_mlp=1.0):
    return [TenantSpec("cnn", CNN, traffic=traffic_cnn),
            TenantSpec("mlp", MLP, traffic=traffic_mlp)]


def _mixed_trace(n, models=("cnn", "mlp")):
    graphs = {"cnn": CNN, "mlp": MLP}
    return [CimRequest(rid=i, model=models[i % len(models)],
                       inputs=make_input(graphs[models[i % len(models)]], i))
            for i in range(n)]


# ---------------------------------------------------------------- planner

def test_plan_respects_chip_budget_across_mixes():
    chip_xbs = CHIP8.chip.n_cores * CHIP8.core.n_xbs
    for tc, tm in ((1, 1), (10, 1), (1, 10), (7, 3), (100, 1)):
        plan = plan_tenancy(_tenants(tc, tm), CHIP8)
        assert plan.cores_used <= CHIP8.chip.n_cores
        assert plan.xbs_used <= chip_xbs
        plan.validate()                  # raises on any violation
        for t in plan.tenants.values():
            assert t.cores >= 1
            assert t.xbs == t.cores * CHIP8.core.n_xbs


def test_hot_tenant_gets_replicas():
    plan = plan_tenancy(_tenants(8.0, 1.0), CHIP8)
    hot, cold = plan.tenants["cnn"], plan.tenants["mlp"]
    assert hot.resident and cold.resident
    assert hot.replicas >= 2             # duplicated copies for the hot model
    assert hot.replicas >= cold.replicas
    assert hot.cores >= hot.replicas * hot.footprint_cores


def test_over_capacity_tenant_is_time_multiplexed():
    # resnet18's footprint dwarfs a 4-core slice of the ISAAC chip, so it
    # must fall back to weight-rewrite time multiplexing while the tiny
    # tenant stays resident
    chip4 = ISAAC.subarch(4, "isaac-4c")
    big = get_workload("resnet18", in_hw=16)
    plan = plan_tenancy([TenantSpec("resnet", big, traffic=1.0),
                         TenantSpec("mlp", MLP, traffic=1.0)], chip4)
    assert not plan.tenants["resnet"].resident
    assert plan.tenants["mlp"].resident
    assert plan.cores_used <= 4
    plan.validate()


def test_planner_input_validation():
    with pytest.raises(ValueError, match="unique"):
        plan_tenancy([TenantSpec("a", MLP), TenantSpec("a", MLP)], CHIP8)
    with pytest.raises(ValueError, match="at least one"):
        plan_tenancy([], CHIP8)
    with pytest.raises(ValueError, match="traffic"):
        TenantSpec("a", MLP, traffic=0.0)
    two_core = ISAAC.subarch(2)
    with pytest.raises(ValueError, match="cores"):
        plan_tenancy([TenantSpec(str(i), MLP) for i in range(3)], two_core)


def test_subarch_view():
    sub = ISAAC.subarch(12)
    assert sub.chip.n_cores == 12
    assert sub.xb == ISAAC.xb            # crossbar tier untouched
    assert sub.core == ISAAC.core
    assert sub.mode == ISAAC.mode
    with pytest.raises(ValueError):
        ISAAC.subarch(0)
    with pytest.raises(ValueError):
        ISAAC.subarch(ISAAC.chip.n_cores + 1)


def test_validate_catches_corrupt_plan():
    plan = plan_tenancy(_tenants(), CHIP8)
    plan.tenants["cnn"].cores = CHIP8.chip.n_cores + 5
    plan.tenants["cnn"].xbs = plan.tenants["cnn"].cores * CHIP8.core.n_xbs
    with pytest.raises(AssertionError):
        plan.validate()


# ---------------------------------------------------------------- batcher

def test_bucket_for_ladder():
    buckets = (1, 2, 4, 8)
    assert [bucket_for(n, buckets) for n in (1, 2, 3, 5, 8, 20)] == \
        [1, 2, 4, 8, 8, 8]


def test_batcher_release_policy():
    b = DynamicBatcher(buckets=(1, 2, 4), max_wait_s=1.0, est_batch_s=0.1)
    assert b.release_reason(now=0.0) is None            # empty queue
    for i in range(2):
        b.submit(CimRequest(rid=i, arrival_s=0.0))
    assert b.release_reason(now=0.5) is None            # young, no deadline
    assert b.release_reason(now=1.5) == "age"
    b.submit(CimRequest(rid=2, arrival_s=0.5))
    b.submit(CimRequest(rid=3, arrival_s=0.5))
    assert b.release_reason(now=0.6) == "full"          # 4 >= max bucket
    batch = b.next_batch(now=0.6)
    assert batch.reason == "full" and batch.bucket == 4 and len(batch) == 4
    assert len(b) == 0
    # deadline pressure: slack smaller than estimated service time
    b.submit(CimRequest(rid=4, arrival_s=0.0, deadline_s=0.15))
    assert b.release_reason(now=0.1) == "deadline"


def test_batcher_pops_edf_order_and_drains():
    b = DynamicBatcher(buckets=(1, 2), max_wait_s=10.0)
    b.submit(CimRequest(rid=0, arrival_s=0.0, deadline_s=9.0))
    b.submit(CimRequest(rid=1, arrival_s=0.1, deadline_s=1.0))
    b.submit(CimRequest(rid=2, arrival_s=0.2))          # no deadline: last
    batches = b.drain(now=0.3)
    order = [r.rid for batch in batches for r in batch.requests]
    assert order == [1, 0, 2]                           # EDF, then arrival
    assert [batch.reason for batch in batches] == ["full", "flush"]
    assert b.drain(now=1.0) == []                       # empty queue: no-op
    with pytest.raises(ValueError):
        DynamicBatcher(buckets=(4, 2))                  # unsorted ladder


def test_request_positional_payload_binding():
    # the pre-common.py signatures: payload right after rid, clock fields
    # keyword-only so they can never silently swallow a payload
    from repro.serving import Request
    r = CimRequest(3, {"x": np.zeros(2)})
    assert r.rid == 3 and "x" in r.inputs and r.arrival_s == 0.0
    q = Request(1, np.arange(5), 16)
    assert q.prompt.shape == (5,) and q.max_new_tokens == 16
    with pytest.raises(TypeError):
        CimRequest(3, {"x": np.zeros(2)}, "cnn", None, 1.0)  # clock field


def test_service_stats_latency_window_is_bounded():
    from repro.serving.common import LATENCY_WINDOW
    s = ServiceStats()
    for _ in range(3):
        s.record([1.0] * LATENCY_WINDOW, batch_s=1.0, misses=2)
    assert s.requests == 3 * LATENCY_WINDOW      # counters stay all-time
    assert s.deadline_misses == 6                # misses stay all-time
    # tails + windowed misses stay windowed
    assert len(s.window_latencies_s) == LATENCY_WINDOW
    assert len(s.window_missed) == LATENCY_WINDOW
    assert s.window_deadline_misses == 2         # only the last batch's
    m = s.merge(s)
    assert len(m.window_latencies_s) == LATENCY_WINDOW
    assert m.deadline_misses == 12 and m.window_deadline_misses == 2


def test_fleet_rejects_mismatched_plan():
    plan = plan_tenancy(_tenants(), CHIP8)
    with pytest.raises(ValueError, match="plan tenants"):
        CimFleet([TenantSpec("other", MLP)], CHIP8, plan=plan)
    with pytest.raises(ValueError, match="built for arch"):
        CimFleet(_tenants(), ISAAC.subarch(16), plan=plan)
    # same names but different substance (graph swapped) must not pass
    swapped = [TenantSpec("cnn", MLP, traffic=3.0),
               TenantSpec("mlp", MLP, traffic=1.0)]
    with pytest.raises(ValueError, match="different spec"):
        CimFleet(swapped, CHIP8, plan=plan)


def test_batcher_unknown_service_time_releases_deadlined_work():
    b = DynamicBatcher(buckets=(1, 4), max_wait_s=100.0, est_batch_s=None)
    b.submit(CimRequest(rid=0, arrival_s=0.0))
    assert b.release_reason(now=0.0) is None     # no deadline: wait
    b.submit(CimRequest(rid=1, arrival_s=0.0, deadline_s=1e9))
    assert b.release_reason(now=0.0) == "deadline"   # unknown est: go now


def test_service_stats_tails_and_merge():
    s = ServiceStats()
    s.record([i / 100.0 for i in range(1, 101)], batch_s=1.0)
    assert s.p50_latency_s == pytest.approx(0.50)
    assert s.p95_latency_s == pytest.approx(0.95)
    t = ServiceStats()
    t.record([10.0], batch_s=2.0, misses=1)
    m = s.merge(t)
    assert m.requests == 101 and m.batches == 2
    assert m.deadline_misses == 1 and m.serve_s == 3.0
    assert ServiceStats().p95_latency_s == 0.0


# ------------------------------------------------------------------ fleet

def test_fleet_bit_exact_vs_standalone_reference():
    tenants = _tenants()
    fleet = CimFleet(tenants, CHIP8, max_wait_s=0.0)
    done = fleet.serve(_mixed_trace(10), now=0.0)
    assert len(done) == 10
    for name, g in (("cnn", CNN), ("mlp", MLP)):
        mine = [r for r in done if r.model == name]
        # reference 1: standalone service on the tenant's own sub-arch
        sub = CimBatchService(g, fleet.plan.subarch(name), max_batch=8)
        # reference 2: standalone service on the whole chip
        full = CimBatchService(g, CHIP8, max_batch=8)
        for ref in (sub, full):
            refs = [CimRequest(rid=r.rid, inputs=r.inputs) for r in mine]
            ref.serve(refs)
            for a, b in zip(mine, refs):
                for t in g.outputs:
                    np.testing.assert_array_equal(a.outputs[t],
                                                  b.outputs[t])


def test_fleet_interpreter_fallback_parity():
    # use_executor=False drives the same batcher/padding path through the
    # op-by-op interpreter; outputs must be identical
    tenants = _tenants()
    fast = CimFleet(tenants, CHIP8, max_wait_s=0.0)
    slow = CimFleet(tenants, CHIP8, max_wait_s=0.0, use_executor=False)
    a = fast.serve(_mixed_trace(6), now=0.0)
    b = slow.serve(_mixed_trace(6), now=0.0)
    graphs = {"cnn": CNN, "mlp": MLP}
    for ra, rb in zip(a, b):
        assert ra.rid == rb.rid
        for t in graphs[ra.model].outputs:
            np.testing.assert_array_equal(ra.outputs[t], rb.outputs[t])


def test_fleet_stats_and_deadline_accounting():
    fleet = CimFleet(_tenants(), CHIP8, max_wait_s=0.0)
    reqs = _mixed_trace(4)
    for r in reqs:
        r.deadline_s = -1.0          # already past at dispatch time
    done = fleet.serve(reqs, now=0.0)
    st = fleet.stats()
    agg = st.aggregate
    assert agg.requests == 4
    assert agg.deadline_misses == 4
    assert agg.p50_latency_s > 0.0
    assert all(r.latency_s > 0 for r in done)
    assert "deadline misses" in fleet.summary()


def test_fleet_routing_and_step():
    fleet = CimFleet(_tenants(), CHIP8, buckets=(1, 2), max_wait_s=10.0)
    with pytest.raises(KeyError):
        fleet.submit("nope", {})
    fleet.submit("cnn", make_input(CNN, 0), now=0.0)
    assert fleet.pending == 1
    assert fleet.step(now=0.0) == []         # young + partial: keep waiting
    fleet.submit("cnn", make_input(CNN, 1), now=0.0)
    done = fleet.step(now=0.0)               # bucket 2 is full now
    assert len(done) == 2 and fleet.pending == 0


# ----------------------------------------------- CimBatchService edge cases

def test_service_empty_flush_is_noop():
    svc = CimBatchService(MLP, CHIP8, max_batch=4)
    assert svc.serve([]) == []
    assert svc.stats.requests == 0 and svc.stats.batches == 0
    assert svc.dispatch([]) == 0.0
    fleet = CimFleet(_tenants(), CHIP8)
    assert fleet.drain(now=0.0) == []        # empty queues: no batches
    assert fleet.stats().aggregate.batches == 0


def test_service_batch_larger_than_max_batch_splits():
    svc = CimBatchService(MLP, CHIP8, max_batch=4)
    reqs = [CimRequest(rid=i, inputs=make_input(MLP, i)) for i in range(11)]
    done = svc.serve(reqs)
    assert len(done) == 11
    assert svc.stats.batches == 3            # 4 + 4 + 3
    assert svc.stats.requests == 11
    ref = CimBatchService(MLP, CHIP8, max_batch=4, use_executor=False)
    refs = [CimRequest(rid=i, inputs=make_input(MLP, i)) for i in range(11)]
    ref.serve(refs)
    for a, b in zip(done, refs):
        for t in MLP.outputs:
            np.testing.assert_array_equal(a.outputs[t], b.outputs[t])


def test_serve_padded_matches_unpadded():
    svc = CimBatchService(MLP, CHIP8, max_batch=8)
    reqs = [CimRequest(rid=i, inputs=make_input(MLP, i)) for i in range(3)]
    svc.serve_padded(reqs, bucket=8)         # 3 real rows + 5 pad rows
    plain = [CimRequest(rid=i, inputs=make_input(MLP, i)) for i in range(3)]
    svc.serve(plain)
    for a, b in zip(reqs, plain):
        for t in MLP.outputs:
            np.testing.assert_array_equal(a.outputs[t], b.outputs[t])


# ------------------------------------------------------- compile-cache cap

def test_compile_cache_lru_eviction(tmp_path):
    from repro.core import compiler
    from repro.dse import CompileCache

    probe = CompileCache(tmp_path)           # measure one entry's size
    g = MLP
    archs = [CHIP8.replace(act_bits=b) for b in (2, 3, 4, 5)]
    keys = []
    for arch in archs:
        res = compiler.compile_graph(g, arch)
        keys.append(compiler.compile_key(g, arch))
        probe.put(keys[-1], res)
    entry_bytes = probe.disk_bytes() // len(archs)
    probe.clear()

    cache = CompileCache(tmp_path, max_bytes=int(entry_bytes * 2.5))
    results = [compiler.compile_graph(g, a) for a in archs]
    for key, res in zip(keys[:3], results[:3]):
        cache.put(key, res)
    # oldest of the three must have been evicted to fit the ~2.5-entry cap
    assert cache.stats()["disk_entries"] == 2
    assert cache.evictions == 1
    assert not cache.contains(keys[0])
    assert cache.get(keys[0]) is None        # counts a miss, not a crash
    assert cache.contains(keys[2])           # newest always survives

    # touch entry 1 (LRU refresh), then insert entry 3: entry 2 evicts
    os.utime(cache._pkl(keys[1]), (9e9, 9e9))
    os.utime(cache._json(keys[1]), (9e9, 9e9))
    cache.put(keys[3], results[3])
    assert cache.contains(keys[1])           # recently accessed: kept
    assert not cache.contains(keys[2])
    assert cache.contains(keys[3])
    assert cache.disk_bytes() <= int(entry_bytes * 2.5)

    # uncapped handle on the same dir never evicts
    free = CompileCache(tmp_path)
    free.put(keys[0], results[0])
    assert free.evictions == 0
    assert "evictions" in free.stats()


def test_compile_cache_memory_hits_protect_entries_from_eviction(tmp_path):
    # memory-layer hits never touch the files; the in-process access log
    # must still count them as recency or the hottest entry evicts first
    from repro.core import compiler
    from repro.dse import CompileCache

    g = MLP
    archs = [CHIP8.replace(act_bits=b) for b in (2, 3, 4)]
    results = [compiler.compile_graph(g, a) for a in archs]
    keys = [compiler.compile_key(g, a) for a in archs]
    probe = CompileCache(tmp_path)
    probe.put(keys[0], results[0])
    entry = probe.disk_bytes()
    probe.clear()

    cache = CompileCache(tmp_path, max_bytes=int(entry * 2.5))
    cache.put(keys[0], results[0])
    cache.put(keys[1], results[1])
    # age both entries on disk, then hit entry 0 through the memory layer
    for k in keys[:2]:
        os.utime(cache._pkl(k), (1, 1))
        os.utime(cache._json(k), (1, 1))
    assert cache.get(keys[0]) is not None        # memory hit, no file I/O
    cache.put(keys[2], results[2])               # forces one eviction
    assert cache.contains(keys[0])               # hot entry survives
    assert not cache.contains(keys[1])           # cold one evicted
    assert cache.get(keys[1]) is None            # memory layer purged too


# ------------------------------------------------------- campaign handoff

def test_points_from_campaign_duck_typed():
    from repro.serving import points_from_campaign

    class _Best:
        def __init__(self):
            from repro.dse import DesignPoint
            self.point = DesignPoint(level="WLM", binding="B->XBC",
                                     use_pipeline=True, use_duplication=True)

    class _Outcome:
        best = _Best()

    class _Campaign:
        workloads = {"cnn": _Outcome()}

    pts = points_from_campaign(_Campaign())
    assert set(pts) == {"cnn"}
    assert pts["cnn"]["use_pipeline"] is True
    # tenants without a feasible best are skipped
    class _NoBest:
        best = None
    _Campaign.workloads["mlp"] = _NoBest()
    assert set(points_from_campaign(_Campaign())) == {"cnn"}
