"""Multi-fidelity search: Graph.prefix fidelity slices, proxy metrics,
successive halving vs exhaustive ground truth, campaign determinism."""
import numpy as np
import pytest

from repro.cimsim.functional import simulate
from repro.core import compiler
from repro.core.abstraction import (CellType, ChipTier, CIMArch,
                                    ComputingMode, CoreTier, CrossbarTier,
                                    get_arch)
from repro.dse import (AdaptiveSearch, CompileCache, DesignSpace,
                       HalvingSearch, Rung, adaptive_search, run_campaign,
                       successive_halving, sweep)
from repro.dse.runner import EvalJob, run_jobs
from repro.dse.search import rung_prefix_graph
from repro.workloads import get_workload, resnet18

SIM_ARCH = CIMArch(
    name="test-wlm", mode=ComputingMode.WLM,
    chip=ChipTier(core_number=(4, 1), alu_ops_per_cycle=64, l0_bw_bits=1024),
    core=CoreTier(xb_number=(2, 1), l1_bw_bits=1024),
    xb=CrossbarTier(xb_size=(32, 32), dac_bits=1, adc_bits=8,
                    cell_type=CellType.SRAM, cell_precision=2,
                    parallel_row=8),
)


def _space():
    return DesignSpace(get_arch("toy"),
                       arch_axes={"xb.xb_size": [(32, 128), (64, 128)]})


def _best(results):
    ok = [r for r in results if r.ok]
    return min(ok, key=lambda r: (r.metrics["latency_cycles"], r.index))


# ------------------------------------------------------------- Graph.prefix
def test_prefix_structure():
    g = get_workload("tiny_cnn")
    p = g.prefix(3)
    assert [n.name for n in p.nodes] == [n.name for n in g.nodes[:3]]
    assert p.name != g.name                      # distinct compile-cache keys
    # dangling tensors became outputs; every output has an inferred shape
    assert p.outputs == ["conv2.out"]
    assert all(t in p.shapes for t in p.outputs)
    # nodes are copies: compiling the prefix never annotates the original
    compiler.compile_graph(p, get_arch("toy"))
    assert all(not n.sched for n in g.nodes)
    # degenerate requests
    assert g.prefix(len(g.nodes)) is g
    assert g.prefix(10_000) is g
    with pytest.raises(ValueError):
        g.prefix(0)


def test_prefix_keeps_graph_outputs_and_split_tails():
    g = get_workload("tiny_mlp")
    p = g.prefix(1)
    assert p.outputs == ["fc1.out"]
    assert list(p.inputs) == ["input"]
    full = g.prefix(len(g.nodes))
    assert full.outputs == g.outputs


@pytest.mark.parametrize("n_nodes", [2, 5])
def test_prefix_compiles_and_simulates_bit_exact(n_nodes):
    g = get_workload("tiny_cnn").prefix(n_nodes)
    sim_out, ref_out, stats = simulate(g, SIM_ARCH)
    for t in g.outputs:
        np.testing.assert_array_equal(sim_out[t], ref_out[t])
    assert stats.cim_reads > 0
    m = compiler.compile_graph(g, SIM_ARCH).metrics()
    assert m["latency_cycles"] > 0


def test_prefix_stage_count_grows_with_fidelity():
    # latency is NOT monotone in prefix size (the duplication budget
    # redistributes), but scheduled CIM stages are
    g = get_workload("tiny_cnn")
    arch = get_arch("toy")
    stages = [compiler.compile_graph(g.prefix(n), arch).metrics()
              ["n_stages"] for n in (1, 3, len(g.nodes))]
    assert stages[0] <= stages[1] <= stages[2]
    assert stages[0] >= 1


# ------------------------------------------------------------ proxy metrics
def test_proxy_metrics_deterministic_and_knob_sensitive():
    g = get_workload("tiny_cnn")
    arch = get_arch("toy")
    m1 = compiler.proxy_metrics(g, arch)
    assert m1 == compiler.proxy_metrics(g, arch)
    assert m1["fidelity"] == "proxy"
    for key in ("latency_cycles", "peak_power", "crossbars_used"):
        assert m1[key] >= 0
    nopipe = compiler.proxy_metrics(g, arch, use_pipeline=False)
    assert nopipe["latency_cycles"] >= m1["latency_cycles"]
    nodup = compiler.proxy_metrics(g, arch, use_duplication=False)
    assert nodup["crossbars_used"] <= m1["crossbars_used"]


def test_proxy_metrics_raises_like_compile():
    g = get_workload("tiny_cnn")
    arch = get_arch("puma")            # XBM chip: WLM must be rejected
    with pytest.raises(ValueError):
        compiler.proxy_metrics(g, arch, level="WLM")


# ------------------------------------------------------ successive halving
def test_halving_finds_exhaustive_best_tiny(tmp_path):
    space = _space()
    for wl in ("tiny_cnn", "tiny_mlp"):
        g = get_workload(wl)
        cache = CompileCache(tmp_path / wl)
        exhaustive = sweep(g, space, cache=cache)
        sr = successive_halving(g, space, cache=cache)
        assert sr.best is not None
        assert sr.best.point == _best(exhaustive).point
        assert sr.best.metrics == _best(exhaustive).metrics
        # acceptance: <= 1/3 the full-fidelity compiles of exhaustive
        assert sr.full_evals * 3 <= len(exhaustive)
        # the ladder was actually multi-fidelity
        fidelities = [log.fidelity for log in sr.rungs]
        assert fidelities == ["proxy", "prefix", "full"]
        assert sr.rungs[0].evaluated == len(space.points())
        assert sr.rungs[0].full_evals == 0


def test_halving_deterministic_across_worker_counts(tmp_path):
    g = get_workload("tiny_cnn")
    space = _space()
    a = successive_halving(g, space, cache=CompileCache(tmp_path / "a"))
    b = successive_halving(g, space, cache=CompileCache(tmp_path / "b"),
                           workers=4)
    assert [r.point for r in a.results] == [r.point for r in b.results]
    assert [r.metrics for r in a.results] == [r.metrics for r in b.results]
    assert a.full_evals == b.full_evals


def test_halving_reuses_cache_across_reruns(tmp_path):
    g = get_workload("tiny_cnn")
    space = _space()
    cache = CompileCache(tmp_path / "c")
    first = successive_halving(g, space, cache=cache)
    cache.drop_memory()
    again = successive_halving(g, space, cache=cache)
    assert all(r.cached for r in again.results if r.ok), \
        "promoted points must pay nothing twice"
    assert [r.metrics for r in again.results] == \
        [r.metrics for r in first.results]


def test_halving_custom_ladder_and_validation():
    g = get_workload("tiny_mlp")
    space = _space()
    sr = successive_halving(g, space,
                            ladder=(Rung("proxy"), Rung("full")), eta=4)
    assert [log.fidelity for log in sr.rungs] == ["proxy", "full"]
    with pytest.raises(ValueError):
        HalvingSearch(g, space, ladder=(Rung("proxy"),))   # no full rung
    with pytest.raises(ValueError):
        HalvingSearch(g, space, eta=1)
    with pytest.raises(ValueError):
        Rung("nonsense")


def test_halving_reports_infeasible_without_aborting():
    g = get_workload("tiny_cnn")
    toy = get_arch("toy")
    arch = toy.replace(chip=toy.chip.__class__(core_number=(1, 1)))
    # B->XB on a 1-core chip is infeasible (4 bit slices, 2 crossbars):
    # the proxy rung must filter those without killing the search
    sr = successive_halving(g, DesignSpace(arch))
    assert sr.best is not None
    assert sr.best.point.binding == "B->XBC"


# ----------------------------------------------------------------- campaign
def _campaign_graphs():
    return {"tiny_cnn": get_workload("tiny_cnn"),
            "tiny_mlp": get_workload("tiny_mlp")}


def _flat(camp):
    return {name: [(r.point, r.metrics, r.error) for r in w.results]
            for name, w in camp.workloads.items()}


def test_campaign_order_independent_across_workers(tmp_path):
    space = _space()
    camp1 = run_campaign(_campaign_graphs(), space,
                         cache=CompileCache(tmp_path / "w1"), workers=1)
    camp4 = run_campaign(_campaign_graphs(), space,
                         cache=CompileCache(tmp_path / "w4"), workers=4)
    assert _flat(camp1) == _flat(camp4)
    assert [(rp.point, rp.max_regret) for rp in camp1.robust] == \
        [(rp.point, rp.max_regret) for rp in camp4.robust]
    assert camp1.full_evals == camp4.full_evals


def test_campaign_halving_beats_exhaustive_cost(tmp_path):
    space = _space()
    cache = CompileCache(tmp_path / "c")
    camp = run_campaign(_campaign_graphs(), space, cache=cache)
    ex = run_campaign(_campaign_graphs(), space, cache=cache,
                      mode="exhaustive")
    assert camp.full_evals * 3 <= ex.full_evals
    # same per-workload winner as the exhaustive campaign
    for name, w in camp.workloads.items():
        assert w.best.point == ex.workloads[name].best.point
    # frontier members are full-fidelity feasible results
    for w in camp.workloads.values():
        assert w.frontier and all(r.ok for r in w.frontier)


def test_campaign_robust_points_are_near_optimal_everywhere(tmp_path):
    space = _space()
    camp = run_campaign(_campaign_graphs(), space,
                        cache=CompileCache(tmp_path / "c"),
                        mode="exhaustive", robust_tol=0.25)
    assert camp.robust, "exhaustive tiny campaign should find robust points"
    for rp in camp.robust:
        assert rp.max_regret <= 0.25
        assert set(rp.regret) == set(camp.workloads)
        for name, w in camp.workloads.items():
            floor = w.best.metrics["latency_cycles"]
            got = next(r.metrics["latency_cycles"] for r in w.results
                       if r.ok and r.point == rp.point)
            assert got <= floor * 1.25 + 1e-9


def test_campaign_accepts_graph_sequences(tmp_path):
    space = _space()
    camp = run_campaign([get_workload("tiny_mlp")], space,
                        cache=CompileCache(tmp_path / "c"))
    assert list(camp.workloads) == ["tiny_mlp"]
    with pytest.raises(ValueError):
        run_campaign(_campaign_graphs(), space, mode="bogus")


# ----------------------------------------------------------------- adaptive
def test_adaptive_deterministic_end_to_end(tmp_path):
    """Same seed -> same ask sequence -> same best point (any workers)."""
    g = get_workload("tiny_cnn")
    space = _space()
    kw = dict(seed=7, batch=12, prefix_keep=6, full_keep=3)
    a = adaptive_search(g, space, cache=CompileCache(tmp_path / "a"), **kw)
    b = adaptive_search(g, space, cache=CompileCache(tmp_path / "b"), **kw)
    assert a.ask_log == b.ask_log
    assert a.best is not None
    assert a.best.point == b.best.point
    assert a.best.metrics == b.best.metrics
    assert [r.point for r in a.results] == [r.point for r in b.results]
    # the pool path must not perturb the search either
    c = adaptive_search(g, space, cache=CompileCache(tmp_path / "c"),
                        workers=4, **kw)
    assert c.ask_log == a.ask_log and c.best.point == a.best.point
    # and the seed actually feeds the generator: the ask sequence is
    # reproducible from AdaptiveSearch's own rng, not global numpy state
    np.random.seed(0)
    d = adaptive_search(g, space, cache=CompileCache(tmp_path / "d"), **kw)
    assert d.ask_log == a.ask_log


def test_adaptive_full_budget_matches_exhaustive_best(tmp_path):
    """With every knob opened up, adaptive degenerates to exhaustive."""
    g = get_workload("tiny_mlp")
    space = _space()
    n = len(space.points())
    cache = CompileCache(tmp_path / "c")
    exhaustive = sweep(g, space, cache=cache)
    ar = adaptive_search(g, space, cache=cache, seed=0, batch=n,
                         prefix_keep=n, full_keep=n)
    assert ar.proxy_evals == n
    assert ar.best.point == _best(exhaustive).point
    assert ar.best.metrics == _best(exhaustive).metrics


def test_adaptive_spends_less_than_exhaustive(tmp_path):
    g = get_workload("tiny_cnn")
    space = _space()
    n = len(space.points())
    ar = adaptive_search(g, space, cache=CompileCache(tmp_path / "c"),
                         seed=3, batch=12, prefix_keep=6, full_keep=3)
    assert ar.best is not None
    assert ar.full_evals * 3 <= n
    assert ar.prefix_evals <= 6 and ar.full_evals <= 3
    assert [r.fidelity for r in ar.rungs][-2:] == ["prefix", "full"]
    assert ar.rungs[0].fidelity == "proxy"
    assert ar.ask_rounds == len(ar.ask_log) >= 1


def test_adaptive_handles_infeasible_points(tmp_path):
    g = get_workload("tiny_cnn")
    toy = get_arch("toy")
    arch = toy.replace(chip=toy.chip.__class__(core_number=(1, 1)))
    # B->XB on a 1-core chip is infeasible: the model must learn around
    # it and still land on a feasible B->XBC winner
    ar = adaptive_search(g, DesignSpace(arch), seed=1, batch=4,
                         prefix_keep=4, full_keep=2)
    assert ar.best is not None
    assert ar.best.point.binding == "B->XBC"


def test_adaptive_validation():
    g = get_workload("tiny_mlp")
    space = _space()
    with pytest.raises(ValueError):
        AdaptiveSearch(g, space, gamma=1.5)
    with pytest.raises(ValueError):
        AdaptiveSearch(g, space, explore=-0.1)
    with pytest.raises(ValueError):
        AdaptiveSearch(g, space, prefix_keep=4, full_keep=8)
    s = AdaptiveSearch(g, space)
    with pytest.raises(RuntimeError):
        s.observe([None])
    with pytest.raises(RuntimeError):
        s.search_result()


def test_adaptive_campaign_mode(tmp_path):
    space = _space()
    knobs = dict(batch=16, prefix_keep=8, full_keep=4)
    camp = run_campaign(_campaign_graphs(), space,
                        cache=CompileCache(tmp_path / "c1"),
                        mode="adaptive", seed=5, adaptive=knobs)
    again = run_campaign(_campaign_graphs(), space,
                         cache=CompileCache(tmp_path / "c2"),
                         mode="adaptive", seed=5, adaptive=knobs)
    assert _flat(camp) == _flat(again)        # seeded end to end
    assert camp.mode == "adaptive"
    assert camp.full_evals * 3 <= camp.exhaustive_evals
    for w in camp.workloads.values():
        assert w.best is not None
        assert [r.fidelity for r in w.rungs][0] == "proxy"
    # the winners hand off to the serving fleet unchanged
    from repro.serving.engine import points_from_campaign
    assert set(points_from_campaign(camp)) == set(camp.workloads)


# ------------------------------------------------------ batched prefix rung
def test_batched_prefix_rung_bit_exact_small_resnet():
    """Screened batch compiles == one-at-a-time prefix compiles."""
    g = resnet18(in_hw=32)
    pg = rung_prefix_graph(g, 0.5)
    assert pg is not g
    space = DesignSpace(get_arch("isaac-baseline"),
                        levels=("WLM", "XBM"), duplication=(True,))
    points = space.points()
    base = space.arch
    batched = run_jobs([EvalJob(index=i, graph=pg, point=p, arch=base,
                                screen=True)
                        for i, p in enumerate(points)])
    one_at_a_time = run_jobs([EvalJob(index=i, graph=pg, point=p, arch=base)
                              for i, p in enumerate(points)])
    assert len(batched) == len(one_at_a_time) == len(points)
    for bt, oo in zip(batched, one_at_a_time):
        assert bt.index == oo.index and bt.point == oo.point
        assert bt.metrics == oo.metrics        # bit-exact scores
        assert bt.error == oo.error


def test_batched_rung_masks_infeasibility_like_compile(tmp_path):
    """Screened-out points carry the compiler's exact error strings."""
    g = get_workload("tiny_cnn")
    toy = get_arch("toy")
    arch = toy.replace(chip=toy.chip.__class__(core_number=(1, 1)))
    points = DesignSpace(arch).points()
    screened = run_jobs([EvalJob(index=i, graph=g, point=p, arch=arch,
                                 screen=True) for i, p in enumerate(points)])
    compiled = run_jobs([EvalJob(index=i, graph=g, point=p, arch=arch)
                         for i, p in enumerate(points)])
    assert any(r.error for r in screened)      # the space has bad points
    for sc, cp in zip(screened, compiled):
        assert sc.error == cp.error            # identical strings
        assert sc.metrics == cp.metrics
    # and the strings are the scalar proxy's raise messages
    for sc in screened:
        if sc.error is None:
            continue
        with pytest.raises(Exception) as ei:
            kwargs = sc.point.compile_kwargs()
            kwargs.pop("expand", None)
            compiler.proxy_metrics(g, sc.point.arch_for(arch), **kwargs)
        assert sc.error == f"{type(ei.value).__name__}: {ei.value}"
