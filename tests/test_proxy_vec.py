"""Batched proxy cost model: bit-equivalence against the scalar oracle.

The contract under test (dse/proxy_vec.py): for every design point the
batched structure-of-arrays path returns *exactly* the dict scalar
``compiler.proxy_metrics`` returns — same floats, bit for bit — and for
every point the scalar path raises on, the batched path returns a masked
entry whose error string equals the scalar raise.  The suite sweeps
chips (CM/XBM/WLM), both bit bindings, both CG switches, multi-segment
(over-capacity) workloads and degenerate arch parameters.
"""
import numpy as np
import pytest

from hypcompat import given, settings, st

from repro.core import compiler
from repro.core.abstraction import get_arch
from repro.dse import (CompileCache, DesignSpace, EvalJob, NodeTensor,
                       proxy_metrics_batch, run_campaign, run_jobs)
from repro.dse.runner import _eval_job
from repro.dse.space import DesignPoint
from repro.workloads import get_workload

CHIPS = ("toy", "puma", "jia-issc21", "jain-jssc21")


def scalar_outcome(graph, base_arch, point):
    """(metrics, error) exactly as the pre-batching job runner saw it."""
    try:
        arch = point.arch_for(base_arch)
        return compiler.proxy_metrics(graph, arch,
                                      **point.compile_kwargs()), None
    except Exception as e:
        return None, f"{type(e).__name__}: {e}"


def assert_batch_equals_scalar(graph, base_arch, points):
    batch = proxy_metrics_batch(graph, points, base_arch)
    assert len(batch) == len(points)
    for i, pt in enumerate(points):
        expected, error = scalar_outcome(graph, base_arch, pt)
        if error is None:
            assert bool(batch.feasible[i]), (pt.label(), batch.errors[i])
            assert batch.metrics(i) == expected, pt.label()
            assert batch.errors[i] is None
        else:
            assert not batch.feasible[i], pt.label()
            assert batch.metrics(i) is None
            assert batch.errors[i] == error, pt.label()
    return batch


# ------------------------------------------------------ cross-chip sweeps
@pytest.mark.parametrize("chip", CHIPS)
@pytest.mark.parametrize("workload", ["tiny_cnn", "tiny_mlp"])
def test_batched_matches_scalar_bit_exact(workload, chip):
    """Every (level x binding x pipeline x duplication x cell precision)
    combination the space enumerates, on every published chip mode."""
    graph = get_workload(workload)
    arch = get_arch(chip)
    space = DesignSpace(arch, arch_axes={"xb.cell_precision": [1, 2, 4]})
    points = space.points()
    assert points, "space collapsed"
    assert_batch_equals_scalar(graph, arch, points)


def test_batched_matches_scalar_multi_segment():
    """An over-capacity workload (multi-segment schedule: nonzero rewrite
    cycles, crossbars clamped to the pool) must agree too."""
    graph = get_workload("tiny_cnn")
    toy = get_arch("toy")
    arch = toy.replace(chip=toy.chip.__class__(core_number=(1, 1)))
    space = DesignSpace(arch)
    points = space.points()
    batch = assert_batch_equals_scalar(graph, arch, points)
    rewrites = [batch.metrics(i)["rewrite_cycles"]
                for i in range(len(points)) if batch.feasible[i]]
    assert any(r > 0 for r in rewrites), \
        "test intended to cover the multi-segment path"


def test_batched_matches_scalar_resnet_arch_axes():
    """A cross-tier arch sweep on a real workload (the benchmark shape):
    xb geometry, cell precision, DAC width, core/chip counts."""
    graph = get_workload("resnet18", in_hw=32)
    arch = get_arch("isaac-baseline")
    space = DesignSpace(
        arch,
        levels=("CM", "WLM"), pipeline=(True,),
        arch_axes={"xb.xb_size": [(64, 64), (128, 128)],
                   "xb.cell_precision": [2, 4],
                   "chip.core_number": [(8, 8), (32, 32)]})
    assert_batch_equals_scalar(graph, arch, space.points())


# --------------------------------------------------- masked infeasibility
def test_infeasible_points_masked_with_scalar_error_strings():
    graph = get_workload("tiny_cnn")
    arch = get_arch("puma")                # XBM chip
    points = [
        # level above the chip's computing mode
        DesignPoint("WLM", "B->XBC", True, True),
        # B->XBC with fewer physical columns than bit slices
        DesignPoint("XBM", "B->XBC", True, True,
                    (("xb.xb_size", (32, 2)),)),
        # B->XB whose VXB column unit spans more crossbars than the chip
        DesignPoint("XBM", "B->XB", True, True,
                    (("chip.core_number", (1, 1)),
                     ("core.xb_number", (1, 1)),
                     ("xb.cell_precision", 1))),
        # unknown override tier (arch_for raises KeyError)
        DesignPoint("XBM", "B->XBC", True, True,
                    (("bogus.tier", 1),)),
        # a feasible point mixed in
        DesignPoint("XBM", "B->XBC", True, True),
    ]
    batch = assert_batch_equals_scalar(graph, arch, points)
    assert list(batch.feasible) == [False, False, False, False, True]
    assert batch.errors[0].startswith("ValueError: chip puma")
    assert "bit slices" in batch.errors[1]
    assert "VXB column unit" in batch.errors[2]
    assert batch.errors[3].startswith("KeyError")


def test_enum_valued_and_invalid_point_fields_match_scalar():
    """DesignPoint declares string level/binding, but the scalar paths
    normalize via ComputingMode(...)/BitBinding(...) and so accept enum
    values (and raise on invalid ones, level before binding before the
    mode-allows check).  The batched path must agree on all of it."""
    from repro.core.abstraction import ComputingMode
    from repro.core.mapping import BitBinding
    graph = get_workload("tiny_cnn")
    arch = get_arch("puma")
    points = [
        DesignPoint("XBM", BitBinding.B_TO_XB, True, True),
        DesignPoint(ComputingMode.XBM, "B->XBC", True, True),
        DesignPoint(ComputingMode.WLM, BitBinding.B_TO_XB, True, True),
        DesignPoint("bogus", "B->XBC", True, True),
        DesignPoint("XBM", "sideways", True, True),
        DesignPoint("bogus", "sideways", True, True),   # level error wins
        DesignPoint("WLM", "sideways", True, True),     # binding error wins
    ]
    jobs = [EvalJob(index=i, graph=graph, point=p, arch=arch, proxy=True)
            for i, p in enumerate(points)]
    got = run_jobs(jobs)
    ref = [_eval_job(j, None) for j in jobs]
    assert [(r.metrics, r.error) for r in got] == \
        [(r.metrics, r.error) for r in ref]
    assert got[0].ok and got[1].ok          # enum fields evaluate, feasibly
    assert "ComputingMode" in got[3].error
    assert "BitBinding" in got[4].error


def test_degenerate_arch_params_take_the_oracle_path():
    """Zero DAC bits / zero bandwidths raise zero-divisions node by node
    in the scalar path; the batched path must reproduce them verbatim
    (it routes such points through the oracle itself)."""
    graph = get_workload("tiny_cnn")
    arch = get_arch("toy")
    points = [
        DesignPoint("WLM", "B->XBC", True, True, (("xb.dac_bits", 0),)),
        DesignPoint("WLM", "B->XBC", True, True,
                    (("core.l1_bw_bits", 0.0),)),
        DesignPoint("WLM", "B->XBC", True, True),
    ]
    batch = assert_batch_equals_scalar(graph, arch, points)
    assert not batch.feasible[0] and not batch.feasible[1]
    assert batch.feasible[2]


# ------------------------------------------------------- runner rewiring
def test_run_jobs_proxy_path_equals_per_job_scalar():
    """run_jobs' batched proxy grouping is a drop-in for the per-job
    scalar evaluation it replaced: same metrics, same error strings,
    same ordering."""
    graph = get_workload("tiny_cnn")
    arch = get_arch("toy")
    points = DesignSpace(arch).points() + [
        DesignPoint("WLM", "B->XBC", True, True,
                    (("xb.xb_size", (32, 4)), ("xb.cell_precision", 1)))]
    jobs = [EvalJob(index=i, graph=graph, point=p, arch=arch, proxy=True,
                    tag="t")
            for i, p in enumerate(points)]
    got = run_jobs(jobs)
    ref = sorted((_eval_job(j, None) for j in jobs), key=lambda r: r.index)
    assert [(r.index, r.metrics, r.error, r.tag) for r in got] == \
        [(r.index, r.metrics, r.error, r.tag) for r in ref]


def test_proxy_memo_skips_recomputation(monkeypatch):
    """A threaded-through memo answers repeated proxy jobs without a
    second batched evaluation (campaigns thread one across rounds)."""
    from repro.dse import proxy_vec
    calls = {"n": 0}
    real = proxy_vec.proxy_metrics_batch

    def counting(graph, space, base_arch=None, **kw):
        calls["n"] += 1
        return real(graph, space, base_arch, **kw)

    monkeypatch.setattr(proxy_vec, "proxy_metrics_batch", counting)
    graph = get_workload("tiny_mlp")
    arch = get_arch("toy")
    points = DesignSpace(arch).points()
    # duplicate jobs inside one invocation: one batch, every job answered
    jobs = [EvalJob(index=i, graph=graph, point=points[i % 3], arch=arch,
                    proxy=True) for i in range(9)]
    memo: dict = {}
    first = run_jobs(jobs, proxy_memo=memo)
    assert calls["n"] == 1
    assert sum(1 for k in memo if k[0] != "__pin__") == 3
    # the memo pins the (graph, arch) pair so its id-keys stay valid
    assert memo[("__pin__", id(graph), id(arch))] == (graph, arch)
    # second invocation with the same memo: no new batched evaluation
    again = run_jobs(jobs, proxy_memo=memo)
    assert calls["n"] == 1
    assert [(r.metrics, r.error) for r in again] == \
        [(r.metrics, r.error) for r in first]


# ------------------------------------------------ node tensor + reporting
def test_node_tensor_matches_graph_queries():
    from repro.core.cg_opt import fused_epilogue_elems
    from repro.core.graph import n_mvm, weight_matrix_shape
    graph = get_workload("tiny_cnn")
    nt = NodeTensor.from_graph(graph)
    assert nt.names == [n.name for n in graph.cim_nodes]
    for i, node in enumerate(graph.cim_nodes):
        r, c = weight_matrix_shape(node)
        assert (nt.r[i], nt.c[i]) == (r, c)
        assert nt.windows[i] == n_mvm(node, graph.shapes)
        elems = fused_epilogue_elems(node, graph)
        assert list(nt.epi_elems[i][:len(elems)]) == elems
        assert not nt.epi_elems[i][len(elems):].any()


def test_cache_stats_count_metric_only_hits(tmp_path):
    graph = get_workload("tiny_mlp")
    arch = get_arch("toy")
    cache = CompileCache(tmp_path / "c")
    key = compiler.compile_key(graph, arch)
    assert cache.get(key) is None                      # miss
    compiler.compile_graph(graph, arch, cache=cache)   # miss then put
    assert cache.get(key) is not None                  # full hit
    cache.drop_memory()
    assert cache.get_metrics(key) is not None          # metric-only hit
    s = cache.stats()
    assert s["hits"] == 1 and s["metrics_hits"] == 1
    assert s["misses"] >= 2 and s["disk_entries"] == 1


def test_campaign_summary_surfaces_cache_stats(tmp_path):
    arch = get_arch("toy")
    space = DesignSpace(arch, arch_axes={"xb.xb_size": [(32, 128),
                                                        (64, 128)]})
    graphs = {"tiny_cnn": get_workload("tiny_cnn"),
              "tiny_mlp": get_workload("tiny_mlp")}
    cache = CompileCache(tmp_path / "c")
    camp = run_campaign(graphs, space, cache=cache)
    assert camp.cache_stats is not None
    assert set(camp.cache_stats) == {"hits", "metrics_hits", "misses",
                                     "disk_entries", "evictions",
                                     "foreign_hits"}
    assert "compile cache:" in camp.summary()
    assert "metric-only hits" in camp.summary()
    # uncached campaigns don't invent stats
    camp2 = run_campaign({"tiny_mlp": graphs["tiny_mlp"]}, space)
    assert camp2.cache_stats is None
    assert "compile cache:" not in camp2.summary()


# --------------------------------------------------- property-based sweep
@given(rows=st.sampled_from([16, 32, 64, 128]),
       cols=st.sampled_from([16, 32, 64, 128]),
       cell=st.sampled_from([1, 2, 4, 8]),
       dac=st.sampled_from([1, 2, 8]),
       par=st.sampled_from([4, 16, 1024]),
       cores=st.sampled_from([(1, 1), (2, 2), (4, 2)]),
       xbs=st.sampled_from([(1, 1), (2, 2)]),
       workload=st.sampled_from(["tiny_cnn", "tiny_mlp"]))
@settings(max_examples=30, deadline=None)
def test_batched_equivalence_property(rows, cols, cell, dac, par, cores,
                                      xbs, workload):
    graph = get_workload(workload)
    toy = get_arch("toy")
    arch = toy.replace(
        chip=toy.chip.__class__(core_number=cores),
        core=toy.core.__class__(xb_number=xbs, l1_bw_bits=1024.0),
        xb=toy.xb.__class__(xb_size=(rows, cols), dac_bits=dac,
                            cell_type=toy.xb.cell_type,
                            cell_precision=cell,
                            parallel_row=min(par, rows)))
    assert_batch_equals_scalar(graph, arch, DesignSpace(arch).points())


def test_empty_inputs_and_graphs_without_cim_nodes():
    from repro.core.graph import Graph, Node
    arch = get_arch("toy")
    graph = get_workload("tiny_cnn")
    empty = proxy_metrics_batch(graph, [], arch)
    assert len(empty) == 0 and empty.metrics_list() == []
    # a DCOM-only graph compiles to an empty placement list: the scalar
    # path returns the degenerate bundle, the batch must match it
    nocim = Graph("nocim", [Node("r", "Relu", ["input"], ["out"])],
                  {"input": (4, 4, 4)}, ["out"])
    assert_batch_equals_scalar(nocim, arch, DesignSpace(arch).points())


def test_batched_proxy_arrays_are_consistent_with_metrics():
    graph = get_workload("tiny_cnn")
    arch = get_arch("toy")
    points = DesignSpace(arch).points()
    batch = proxy_metrics_batch(graph, points, arch)
    ok = np.flatnonzero(batch.feasible)
    assert ok.size
    for i in ok[:4]:
        m = batch.metrics(int(i))
        assert m["latency_cycles"] == batch.latency_cycles[i]
        assert m["crossbars_used"] == batch.crossbars_used[i]
        assert m["fidelity"] == "proxy"
    assert batch.metrics_list()[int(ok[0])] == batch.metrics(int(ok[0]))
