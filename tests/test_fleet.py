"""Cross-chip fleet serving: 2-D placement, cluster bit-exactness vs
independent single-chip fleets, drift-driven re-planning, the overload
degradation ladder, Chrome-trace round-trips and synthetic traffic
determinism."""
import copy
import json

import numpy as np
import pytest

from repro.cimsim.functional import make_input
from repro.core.abstraction import get_arch
from repro.serving import (AdmissionError, CimCluster, CimFleet,
                           CimRequest, FleetPlan, ReplanPolicy, TenantSpec,
                           TraceRecorder, TrafficModel, load_trace,
                           plan_fleet, synthetic_trace,
                           validate_chrome_trace)
from repro.workloads import get_workload

ISAAC = get_arch("isaac-baseline")
CNN = get_workload("tiny_cnn")
MLP = get_workload("tiny_mlp")
GRAPHS = {"cnn": CNN, "mlp": MLP}


def _chips(n0=8, n1=8):
    return {"c0": ISAAC.subarch(n0, f"isaac-{n0}c-a"),
            "c1": ISAAC.subarch(n1, f"isaac-{n1}c-b")}


def _tenants(tc=3.0, tm=1.0, pc=1, pm=0):
    return [TenantSpec("cnn", CNN, traffic=tc, priority=pc),
            TenantSpec("mlp", MLP, traffic=tm, priority=pm)]


def _requests(n, rid_base=0):
    out = []
    for i in range(n):
        model = ("cnn", "mlp")[i % 2]
        rid = rid_base + i
        out.append(CimRequest(rid=rid, model=model,
                              inputs=make_input(GRAPHS[model], rid)))
    return out


# ------------------------------------------------------------- placement

def test_plan_fleet_budget_and_routes():
    chips = _chips()
    for tc, tm in ((1, 1), (10, 1), (1, 10)):
        plan = plan_fleet(_tenants(tc, tm), chips)
        plan.validate()                     # budgets + route consistency
        for tenant, row in plan.routes.items():
            assert abs(sum(row.values()) - 1.0) < 1e-9
        assert set(plan.routes) == {"cnn", "mlp"}


def test_plan_fleet_heterogeneous_chips_spans_hot_tenant():
    # a hot tenant with more offered load than one chip's share should
    # span chips (replicas on both), while the planner keeps every
    # per-chip budget honest
    chips = _chips(8, 8)
    plan = plan_fleet(_tenants(20.0, 1.0), chips)
    plan.validate()
    assert len(plan.routes["cnn"]) >= 1
    assert plan.total_replicas("cnn") >= 2


def test_from_split_rejects_multi_chip_tenant():
    chips = _chips()
    with pytest.raises(ValueError, match="multiple chips"):
        FleetPlan.from_split({"c0": [_tenants()[0]],
                              "c1": [_tenants()[0]]}, chips)


# ------------------------------------------------------- bit-exactness

def test_cluster_bitexact_vs_independent_single_chip_fleets():
    # acceptance criterion: an N-chip cluster must produce bit-exact
    # outputs vs N independent single-chip fleets given the same tenant
    # split — placement/routing must never touch numerics
    chips = _chips()
    cnn_spec, mlp_spec = _tenants()
    split = {"c0": [cnn_spec], "c1": [mlp_spec]}
    plan = FleetPlan.from_split(split, chips)
    cluster = CimCluster(_tenants(), chips, plan=plan, max_wait_s=0.0)

    reqs = _requests(12)
    done = cluster.serve(copy.deepcopy(reqs), now=0.0)
    assert len(done) == len(reqs)
    by_rid = {r.rid: r for r in done}

    f0 = CimFleet([cnn_spec], chips["c0"], max_wait_s=0.0)
    f1 = CimFleet([mlp_spec], chips["c1"], max_wait_s=0.0)
    for r in copy.deepcopy(reqs):
        ref = (f0 if r.model == "cnn" else f1).serve([r], now=0.0)[0]
        got = by_rid[ref.rid]
        assert got.outputs is not None and ref.outputs is not None
        for t in ref.outputs:
            np.testing.assert_array_equal(got.outputs[t], ref.outputs[t])


def test_cluster_routes_same_object_back_to_caller():
    chips = _chips()
    cluster = CimCluster(_tenants(), chips, max_wait_s=0.0)
    req = cluster.submit("mlp", make_input(MLP, 7), now=0.0)
    assert req.outputs is None
    cluster.drain(now=0.0)
    assert req.outputs is not None          # caller's object was served


# ------------------------------------------------- drift + re-planning

def test_cluster_replans_under_traffic_drift():
    chips = _chips()
    cluster = CimCluster(
        _tenants(3.0, 1.0), chips, max_wait_s=0.0,
        policy=ReplanPolicy(min_requests=8, drift_threshold=0.4))
    assumed = cluster.plan.assumed_shares
    assert assumed["cnn"] > assumed["mlp"]  # planned for a cnn-heavy mix
    clock, rid = 0.0, 0
    for _ in range(4):                      # actual traffic is all-mlp
        for i in range(12):
            cluster.submit("mlp", make_input(MLP, rid), now=clock + i * 0.5)
            rid += 1
        done = cluster.drain(now=clock + 6.0)
        assert len(done) == 12              # nothing dropped across replans
        assert all(r.outputs is not None for r in done)
        clock += 6.0
        cluster.control(now=clock)
    assert cluster.migrations >= 1
    # the re-planned fleet now assumes an mlp-heavy mix
    shares = cluster.plan.assumed_shares
    assert shares["mlp"] > shares["cnn"]


def test_cluster_migration_carries_pending_requests():
    chips = _chips()
    cluster = CimCluster(
        _tenants(), chips, max_wait_s=0.0,
        policy=ReplanPolicy(min_requests=4, drift_threshold=0.3))
    # queue work, then force a drift re-plan *before* dispatching it
    held = [cluster.submit("mlp", make_input(MLP, i), now=0.1 * i)
            for i in range(8)]
    cluster.control(now=2.0)
    assert cluster.migrations >= 1          # plan flipped to all-mlp mix
    assert cluster.pending == len(held)     # nothing dropped by migration
    cluster.drain(now=3.0)
    assert all(r.outputs is not None for r in held)


# ------------------------------------------------- degradation ladder

def test_overload_degrades_then_rejects_typed():
    # 2x-planned traffic on a small chip: the ladder must demote the
    # lowest-priority tenant first, then reject with a typed error —
    # and every accepted request must still be served (no deadlock, no
    # silent drop)
    chips = {"c0": ISAAC.subarch(6, "isaac-6c")}
    cluster = CimCluster(_tenants(1.0, 1.0, pc=1, pm=0), chips,
                         max_wait_s=0.0, max_queue=4)
    accepted, rejected = [], 0
    for i in range(40):
        try:
            accepted.append(cluster.submit("cnn", make_input(CNN, i),
                                           now=0.0))
        except AdmissionError as e:
            rejected += 1
            assert e.model == "cnn" and e.limit == 4
            assert e.pending >= e.limit
    assert cluster.demotions >= 1           # ladder step 1: demote mlp
    assert "mlp" in cluster.demoted
    assert not cluster.plan.chips["c0"].tenants["mlp"].resident
    assert rejected > 0                     # ladder exhausted: typed reject
    done = cluster.drain(now=1.0)
    assert len(done) == len(accepted)       # accepted work all served
    assert all(r.outputs is not None for r in done)


def test_lowest_priority_tenant_is_never_shed_for_equal_priority():
    chips = {"c0": ISAAC.subarch(6, "isaac-6c")}
    cluster = CimCluster(_tenants(1.0, 1.0, pc=0, pm=0), chips,
                         max_wait_s=0.0, max_queue=2)
    with pytest.raises(AdmissionError):     # no strictly-lower victim
        for i in range(10):
            cluster.submit("cnn", make_input(CNN, i), now=0.0)
    assert cluster.demotions == 0


# ------------------------------------------------------- observability

def test_trace_roundtrip_and_schema(tmp_path):
    chips = _chips()
    tr = TraceRecorder()
    cluster = CimCluster(
        _tenants(), chips, max_wait_s=0.0, trace=tr,
        policy=ReplanPolicy(min_requests=4, drift_threshold=0.3))
    clock = 0.0
    for rnd in range(3):
        for r in _requests(8, rid_base=rnd * 8):
            cluster.submit_request(r, now=clock + 0.1)
        cluster.drain(now=clock + 1.0)
        clock += 1.0
        cluster.control(now=clock)
    assert len(tr) > 0
    phases = {ev["ph"] for ev in tr.events}
    assert {"X", "C", "M"} <= phases        # spans, counters, metadata
    cats = {ev.get("cat") for ev in tr.events}
    assert "batcher" in cats and "engine" in cats
    path = tr.save(tmp_path / "trace.json")
    loaded = load_trace(path)               # validates on load
    assert loaded["traceEvents"] == json.loads(
        path.read_text())["traceEvents"]
    # schema guard: Perfetto-required fields on every event
    for ev in loaded["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError, match="missing field"):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "X"}]})
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "Z", "ts": 0, "pid": 1, "tid": 0}]})
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 0}]})
    with pytest.raises(ValueError, match="never registered"):
        validate_chrome_trace({"traceEvents": [
            {"name": "process_name", "ph": "M", "ts": 0, "pid": 1,
             "tid": 0, "args": {"name": "chip:c0"}},
            {"name": "x", "ph": "i", "ts": 0, "pid": 9, "tid": 0}]})


# ------------------------------------------------------- traffic model

def test_synthetic_trace_is_deterministic_and_shaped():
    model = TrafficModel(users=1e6, diurnal_amp=0.6, bursts_per_day=4)
    a = synthetic_trace(GRAPHS, 64, 3600.0, shares={"cnn": 1, "mlp": 1},
                        model=model, seed=11, deadline_s=0.5)
    b = synthetic_trace(GRAPHS, 64, 3600.0, shares={"cnn": 1, "mlp": 1},
                        model=model, seed=11, deadline_s=0.5)
    assert [(r.rid, r.model, r.arrival_s) for r in a] == \
        [(r.rid, r.model, r.arrival_s) for r in b]
    arrivals = [r.arrival_s for r in a]
    assert arrivals == sorted(arrivals)
    assert 0.0 <= arrivals[0] and arrivals[-1] < 3600.0
    assert all(r.deadline_s == pytest.approx(r.arrival_s + 0.5) for r in a)
    assert {r.model for r in a} == {"cnn", "mlp"}


def test_synthetic_trace_share_drift_is_honored():
    # callable shares: first half all-cnn, second half all-mlp
    def shares(t_s):
        return {"cnn": 1.0, "mlp": 0.0} if t_s < 500.0 \
            else {"cnn": 0.0, "mlp": 1.0}
    model = TrafficModel(diurnal_amp=0.0, bursts_per_day=0.0)
    trace = synthetic_trace(GRAPHS, 40, 1000.0, shares=shares,
                            model=model, seed=3)
    for r in trace:
        assert r.model == ("cnn" if r.arrival_s < 500.0 else "mlp")


def test_traffic_model_validation_and_rates():
    with pytest.raises(ValueError, match="diurnal_amp"):
        TrafficModel(diurnal_amp=1.5)
    with pytest.raises(ValueError, match="burst_mult"):
        TrafficModel(burst_mult=0.5)
    m = TrafficModel(users=864_000.0, req_per_user_day=1.0)
    assert m.mean_rps == pytest.approx(10.0)
    peak_t = m.peak_hour / 24.0 * m.day_s
    assert m.diurnal(peak_t) == pytest.approx(1.0 + m.diurnal_amp)
    assert m.rps(peak_t, burst=True) == \
        pytest.approx(m.rps(peak_t) * m.burst_mult)


# ------------------------------------------- fault injection / failover

def _fault_cluster(faults, chips=None, trace=None, **kw):
    from repro.serving import FaultSchedule
    return CimCluster(_tenants(), chips or _chips(),
                      faults=FaultSchedule(faults), trace=trace,
                      max_wait_s=0.0, **kw)


def test_chip_kill_mid_run_loses_no_accepted_requests():
    from repro.serving import ChipFault
    tr = TraceRecorder()
    cluster = _fault_cluster([ChipFault(at_s=3.0, chip="c0", kind="kill")],
                             trace=tr)
    submitted, t = [], 0.0
    for i in range(24):
        model = ("cnn", "mlp")[i % 2]
        submitted.append(cluster.submit(
            model, make_input(GRAPHS[model], i), now=t))
        t += 0.5
        if i % 6 == 5:
            cluster.step(now=t)
    cluster.drain(now=t)
    # acceptance: zero accepted requests lost across the kill
    assert all(r.outputs is not None for r in submitted)
    assert cluster.chip_kills == 1 and cluster.failed == {"c0"}
    assert "c0" not in cluster.fleets and "c0" not in cluster.archs
    kills = [e for e in tr.events if e.get("name") == "chip_kill"]
    assert len(kills) == 1 and kills[0]["args"]["survivors"] == 1
    assert "1 kills" in cluster.summary()


def test_chip_degrade_slowdown_compounds_and_survives_replan():
    from repro.serving import ChipFault
    tr = TraceRecorder()
    cluster = _fault_cluster(
        [ChipFault(at_s=1.0, chip="c1", kind="degrade", degrade_factor=2.0),
         ChipFault(at_s=2.0, chip="c1", kind="degrade", degrade_factor=1.5)],
        trace=tr,
        policy=ReplanPolicy(min_requests=4, drift_threshold=0.3))
    for i in range(12):
        cluster.submit("mlp", make_input(MLP, i), now=0.5 * i)
    cluster.drain(now=8.0)
    assert cluster.chip_degrades == 2
    assert cluster.fleets["c1"].slowdown == pytest.approx(3.0)
    # a drift-driven re-plan rebuilds the chip's fleet: the slowdown is
    # cluster-held state and must survive the rebuild
    cluster.control(now=9.0)
    assert cluster.migrations >= 1
    assert cluster.fleets["c1"].slowdown == pytest.approx(3.0)
    assert [e["args"]["factor"] for e in tr.events
            if e.get("name") == "chip_degrade"] == [2.0, 3.0]


def test_kill_last_chip_rejects_typed():
    from repro.serving import AdmissionError, ChipFault
    cluster = _fault_cluster([ChipFault(at_s=2.0, chip="c0", kind="kill")],
                             chips={"c0": ISAAC.subarch(8, "isaac-8c")})
    cluster.submit("mlp", make_input(MLP, 0), now=0.0)
    with pytest.raises(AdmissionError) as ei:
        cluster.submit("mlp", make_input(MLP, 1), now=5.0)
    assert ei.value.model == "*" and ei.value.limit == 0


def test_failover_ladder_demotes_then_propagates_planner_error():
    from repro.serving import ChipFault
    # the survivor has 1 core for 2 tenants: the failover re-plan is
    # infeasible at any residency, so the ladder demotes everyone and
    # the planner's error surfaces (not a silent drop)
    chips = {"c0": ISAAC.subarch(8, "isaac-8c-a"),
             "c1": ISAAC.subarch(1, "isaac-1c")}
    cluster = _fault_cluster([ChipFault(at_s=2.0, chip="c0", kind="kill")],
                             chips=chips)
    cluster.submit("mlp", make_input(MLP, 0), now=0.0)
    with pytest.raises(ValueError, match="cores"):
        cluster.submit("mlp", make_input(MLP, 1), now=5.0)
    assert cluster.demoted == {"cnn", "mlp"}


def test_transient_kernel_error_bounded_retry():
    from repro.serving import TransientKernelError
    fleet = CimFleet(_tenants(), ISAAC.subarch(8, "isaac-8c"),
                     max_wait_s=0.0, max_retries=2)
    engine = fleet.pool["mlp"]
    real = engine.serve_padded
    fails = {"n": 2}

    def flaky(requests, bucket):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise TransientKernelError("injected")
        return real(requests, bucket)

    engine.serve_padded = flaky
    req = fleet.submit("mlp", make_input(MLP, 0), now=0.0)
    fleet.drain(now=0.0)
    assert req.outputs is not None and fleet.retries == 2
    # budget exhausted: the typed error stays loud
    fails["n"] = 10
    fleet.submit("mlp", make_input(MLP, 1), now=1.0)
    with pytest.raises(TransientKernelError):
        fleet.drain(now=1.0)


def test_evict_pending_counts_deadline_misses_exactly_once():
    fleet = CimFleet(_tenants(), ISAAC.subarch(8, "isaac-8c"),
                     max_wait_s=0.0)
    late = [fleet.submit("mlp", make_input(MLP, i), now=0.0,
                         deadline_s=1.0) for i in range(3)]
    ok = fleet.submit("mlp", make_input(MLP, 3), now=0.0, deadline_s=99.0)
    evicted = fleet.evict_pending(now=5.0)   # all 4 past eviction clock
    assert len(evicted) == 4
    stats = fleet.stats().tenants["mlp"]
    assert stats.deadline_misses == 3        # ok's deadline not passed
    assert stats.window_deadline_misses == 3
    # re-admission and completion must not double count
    for r in evicted:
        fleet.requeue(r)
    fleet.drain(now=5.0)
    assert all(r.outputs is not None for r in late + [ok])
    stats = fleet.stats().tenants["mlp"]
    assert stats.deadline_misses == 3
    # eviction again after completion: nothing new to count
    assert fleet.evict_pending(now=9.0) == []
    assert fleet.stats().tenants["mlp"].deadline_misses == 3


def test_degrade_ladder_skips_already_multiplexed_tenant():
    # the lowest-priority tenant is time-multiplexed from the start
    # (zero resident replicas): the ladder must pass over it and demote
    # the lowest *resident* victim instead, then reject typed once no
    # victim remains
    big = get_workload("resnet18", in_hw=16)
    tenants = [TenantSpec("big", big, traffic=0.2, priority=0),
               TenantSpec("mlp", MLP, traffic=1.0, priority=1),
               TenantSpec("cnn", CNN, traffic=1.0, priority=2)]
    cluster = CimCluster(tenants, {"c0": ISAAC.subarch(6, "isaac-6c")},
                         max_wait_s=0.0, max_queue=3)
    assert cluster.plan.total_replicas("big") == 0
    rejected = 0
    for i in range(20):
        try:
            cluster.submit("cnn", make_input(CNN, i), now=0.0)
        except AdmissionError:
            rejected += 1
    assert "mlp" in cluster.demoted          # resident victim demoted
    assert "big" not in cluster.demoted      # never a ladder victim
    assert cluster.demotions == 1 and rejected > 0
