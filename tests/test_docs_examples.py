"""The docs stay true: every fenced ``python`` block in the guides
(docs/DSE.md, docs/SERVING.md, docs/FLEET.md, docs/KERNELS.md,
docs/FAULTS.md, docs/OBSERVABILITY.md) executes, and every relative
markdown link in README.md / docs/ resolves.

Blocks run in file order inside one shared namespace (like a reader
pasting them into one session), with the compile cache pointed at a
temporary directory.
"""
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
_FENCED = re.compile(r"```python\n(.*?)```", re.S)


def python_blocks(path: Path):
    return _FENCED.findall(path.read_text(encoding="utf-8"))


def test_dse_doc_snippets_execute(tmp_path, monkeypatch):
    import tempfile
    monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", str(tmp_path / "cache"))
    # tempfile caches its directory at first use (pytest already used it),
    # so patch the cache itself: the snippets' mkdtemp lands under tmp_path
    monkeypatch.setattr(tempfile, "tempdir", str(tmp_path))
    blocks = python_blocks(REPO / "docs" / "DSE.md")
    assert len(blocks) >= 5, "docs/DSE.md lost its executable snippets"
    ns: dict = {}
    for i, block in enumerate(blocks):
        code = compile(block, f"docs/DSE.md[python block {i}]", "exec")
        exec(code, ns)   # noqa: S102 — executing our own documentation
    # the guide's narrative claims, re-checked here explicitly
    assert ns["sr"].full_evals * 3 <= len(ns["points"])
    assert ns["camp"].full_evals <= ns["camp"].exhaustive_evals // 3
    assert ns["asr"].ask_log == ns["rerun"].ask_log     # seeded determinism
    assert ns["agg"]["foreign_hits"] > 0                # shared-store reuse


def test_serving_doc_snippets_execute(tmp_path, monkeypatch):
    import tempfile
    monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", str(tmp_path / "cache"))
    monkeypatch.setattr(tempfile, "tempdir", str(tmp_path))
    blocks = python_blocks(REPO / "docs" / "SERVING.md")
    assert len(blocks) >= 5, "docs/SERVING.md lost its executable snippets"
    ns: dict = {}
    for i, block in enumerate(blocks):
        code = compile(block, f"docs/SERVING.md[python block {i}]", "exec")
        exec(code, ns)   # noqa: S102 — executing our own documentation
    # the guide's narrative claims, re-checked here explicitly
    assert ns["plan"].cores_used <= ns["arch"].chip.n_cores
    assert ns["fleet"].stats().aggregate.requests >= 9


def test_fleet_doc_snippets_execute(tmp_path, monkeypatch):
    import tempfile
    monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", str(tmp_path / "cache"))
    monkeypatch.setattr(tempfile, "tempdir", str(tmp_path))
    blocks = python_blocks(REPO / "docs" / "FLEET.md")
    assert len(blocks) >= 5, "docs/FLEET.md lost its executable snippets"
    ns: dict = {}
    for i, block in enumerate(blocks):
        block = block.replace("/tmp/fleet_trace.json",
                              str(tmp_path / "fleet_trace.json"))
        code = compile(block, f"docs/FLEET.md[python block {i}]", "exec")
        exec(code, ns)   # noqa: S102 — executing our own documentation
    # the guide's narrative claims, re-checked here explicitly
    assert ns["cluster"].migrations >= 1          # drift section replans
    assert len(ns["served"]) == len(ns["accepted"])   # ladder never drops
    assert len(ns["trace"]["traceEvents"]) > 0


def test_kernels_doc_snippets_execute(tmp_path, monkeypatch):
    import tempfile
    monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", str(tmp_path / "cache"))
    monkeypatch.setattr(tempfile, "tempdir", str(tmp_path))
    blocks = python_blocks(REPO / "docs" / "KERNELS.md")
    assert len(blocks) >= 5, "docs/KERNELS.md lost its executable snippets"
    ns: dict = {}
    for i, block in enumerate(blocks):
        code = compile(block, f"docs/KERNELS.md[python block {i}]", "exec")
        exec(code, ns)   # noqa: S102 — executing our own documentation
    # the guide's narrative claims, re-checked here explicitly
    assert ns["route"].mode in ("compiled", "interpret", "xla")
    assert ns["exe"].stats.streamed and ns["exe"].stats.swaps > 0


def test_faults_doc_snippets_execute(tmp_path, monkeypatch):
    import tempfile
    monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", str(tmp_path / "cache"))
    monkeypatch.setattr(tempfile, "tempdir", str(tmp_path))
    blocks = python_blocks(REPO / "docs" / "FAULTS.md")
    assert len(blocks) >= 5, "docs/FAULTS.md lost its executable snippets"
    ns: dict = {}
    for i, block in enumerate(blocks):
        code = compile(block, f"docs/FAULTS.md[python block {i}]", "exec")
        exec(code, ns)   # noqa: S102 — executing our own documentation
    # the guide's narrative claims, re-checked here explicitly
    assert ns["remapped"] == 1.0          # exact top-1 recovery
    assert ns["unmitigated"] < 1.0        # the unmitigated map degrades
    assert ns["budget_error"].retire_cols > 0
    assert ns["lost"] == 0                # chip kill drops nothing
    assert ns["cluster"].chip_kills == 1


def test_observability_doc_snippets_execute(tmp_path, monkeypatch):
    import tempfile
    monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", str(tmp_path / "cache"))
    monkeypatch.setattr(tempfile, "tempdir", str(tmp_path))
    blocks = python_blocks(REPO / "docs" / "OBSERVABILITY.md")
    assert len(blocks) >= 5, \
        "docs/OBSERVABILITY.md lost its executable snippets"
    ns: dict = {}
    for i, block in enumerate(blocks):
        block = block.replace("/tmp/cim_timeline.json",
                              str(tmp_path / "cim_timeline.json"))
        code = compile(block, f"docs/OBSERVABILITY.md[python block {i}]",
                       "exec")
        exec(code, ns)   # noqa: S102 — executing our own documentation
    # the guide's narrative claims, re-checked here explicitly
    assert "requests_total" in ns["prom"]
    assert any(k.startswith("requests_total") for k in ns["flat"])
    assert ns["coverage"] == 1.0          # explain covers every node
    assert any(k.startswith("executor_dispatches_total")
               for k in ns["profile"])
    assert {"compiler", "executor", "chip:isaac-8c"} <= ns["tracks"]
    assert ns["disabled_ok"]              # off is really off


def test_architecture_doc_mentions_every_package():
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text(encoding="utf-8")
    src = REPO / "src" / "repro"
    missing = [p.name for p in sorted(src.iterdir())
               if p.is_dir() and not p.name.startswith("__")
               and p.name not in text]
    assert not missing, f"docs/ARCHITECTURE.md does not mention: {missing}"


def test_markdown_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_markdown_links.py"),
         str(REPO / "README.md"), str(REPO / "docs")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr + proc.stdout
