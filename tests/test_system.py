"""End-to-end behaviour tests for the paper's system.

The headline claims, verified against our own simulator (§4.2):
  * multi-level scheduling beats the Poly-Schedule-style baseline on the
    ISAAC-like Table-3 chip;
  * CIM-MLC generalizes across all three published accelerators
    (CM / XBM / WLM chips) without code changes;
  * the staggered MVM pipeline cuts PUMA's peak power by a large factor
    (paper: -75%);
  * the compiled meta-operator flow *computes the right numbers*
    (functional simulator == int8 reference).
"""
import numpy as np

from repro.cimsim import perf
from repro.cimsim.functional import simulate
from repro.core import baselines, compiler
from repro.core.abstraction import ComputingMode, get_arch
from repro.workloads import get_workload


def test_beats_poly_schedule_on_isaac_baseline():
    arch = get_arch("isaac-baseline")
    speedups = []
    for wl in ("vgg7", "resnet18"):
        g = get_workload(wl)
        ours = perf.estimate(compiler.compile_graph(g, arch).plan)
        poly = perf.estimate(baselines.poly_schedule(g, arch))
        speedups.append(poly.latency_cycles / ours.latency_cycles)
    assert all(s > 1.0 for s in speedups)
    assert max(speedups) > 1.5


def test_generalizes_across_published_chips():
    for preset, wl in (("jia-issc21", "vgg7"), ("puma", "vgg7"),
                       ("jain-jssc21", "tiny_cnn")):
        arch = get_arch(preset)
        g = get_workload(wl)
        res = compiler.compile_graph(g, arch)
        assert res.program.op_counts()          # non-empty flow
        rep = perf.estimate(res.plan)
        nat = perf.estimate(baselines.native(g, arch))
        assert rep.latency_cycles <= nat.latency_cycles + 1e-6


def test_puma_peak_power_reduction():
    arch = get_arch("puma")
    g = get_workload("vgg16")
    ours = perf.estimate(compiler.compile_graph(g, arch).plan)
    nat = perf.estimate(baselines.native(g, arch))
    reduction = 1 - ours.peak_active_xbs / nat.peak_active_xbs
    assert reduction >= 0.5       # paper: 75%


def test_flow_is_numerically_correct_end_to_end():
    small = get_arch("isaac-baseline").replace(mode=ComputingMode.WLM)
    g = get_workload("tiny_cnn")
    sim_out, ref_out, _ = simulate(g, small)
    np.testing.assert_array_equal(sim_out["fc.out"], ref_out["fc.out"])
