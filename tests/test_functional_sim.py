"""Functional simulator: interpreting the meta-operator flow reproduces
the int8 fake-quant reference bit-exactly (when the ADC is exact)."""
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.cimsim.functional import (make_input, make_weights,
                                     reference_forward, simulate)
from repro.core.abstraction import (CellType, ChipTier, CIMArch,
                                    ComputingMode, CoreTier, CrossbarTier)
from repro.core.graph import Graph, Node
from repro.workloads import get_workload

SMALL = CIMArch(
    name="test-wlm", mode=ComputingMode.WLM,
    chip=ChipTier(core_number=(4, 1), alu_ops_per_cycle=64, l0_bw_bits=1024),
    core=CoreTier(xb_number=(2, 1), l1_bw_bits=1024),
    xb=CrossbarTier(xb_size=(32, 32), dac_bits=1, adc_bits=8,
                    cell_type=CellType.SRAM, cell_precision=2,
                    parallel_row=8),
)
MODES = [("wlm", SMALL), ("xbm", SMALL.replace(mode=ComputingMode.XBM)),
         ("cm", SMALL.replace(mode=ComputingMode.CM))]


@pytest.mark.parametrize("wl", ["tiny_mlp", "tiny_cnn"])
@pytest.mark.parametrize("mode_name,arch", MODES)
def test_sim_matches_reference(wl, mode_name, arch):
    g = get_workload(wl)
    sim_out, ref_out, stats = simulate(g, arch)
    for t in g.outputs:
        np.testing.assert_array_equal(sim_out[t], ref_out[t])
    assert stats.cim_reads > 0


def test_sim_counts_scale_with_mode():
    g = get_workload("tiny_cnn")
    _, _, s_cm = simulate(g, SMALL.replace(mode=ComputingMode.CM))
    _, _, s_xbm = simulate(g, SMALL.replace(mode=ComputingMode.XBM))
    # XBM exposes per-crossbar reads -> strictly more CIM ops than CM
    assert s_xbm.cim_reads > s_cm.cim_reads
    assert s_xbm.cim_writes > 0 and s_cm.cim_writes == 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), depth=st.integers(1, 3),
       hw=st.sampled_from([4, 6, 8]))
def test_sim_property_random_graphs(seed, depth, hw):
    rnd = np.random.default_rng(seed)
    nodes = []
    tin, cin = "input", 3
    for i in range(depth):
        cout = int(rnd.choice([2, 4, 8]))
        nodes.append(Node(f"c{i}", "Conv", [tin], [f"c{i}.out"],
                          {"weight_shape": (cout, cin, 3, 3),
                           "stride": 1, "pad": 1}))
        nodes.append(Node(f"r{i}", "Relu", [f"c{i}.out"], [f"r{i}.out"]))
        tin, cin = f"r{i}.out", cout
    nodes.append(Node("fl", "Flatten", [tin], ["fl.out"]))
    nodes.append(Node("fc", "Gemm", ["fl.out"], ["fc.out"],
                      {"weight_shape": (cin * hw * hw, 5)}))
    g = Graph(f"rand{seed}", nodes, {"input": (3, hw, hw)}, ["fc.out"])
    sim_out, ref_out, _ = simulate(g, SMALL, seed=seed)
    np.testing.assert_array_equal(sim_out["fc.out"], ref_out["fc.out"])


@pytest.mark.parametrize("mode_name,arch", MODES)
def test_sim_split_graph_end_to_end(mode_name, arch):
    """Split-bearing graphs execute end-to-end and match the reference."""
    nodes = [
        Node("fc1", "Gemm", ["input"], ["fc1.out"],
             {"weight_shape": (16, 12)}),
        Node("sp", "Split", ["fc1.out"], ["sp.a", "sp.b"],
             {"axis": -1, "parts": [4, 8]}),
        Node("ra", "Relu", ["sp.a"], ["ra.out"]),
        Node("rb", "Relu", ["sp.b"], ["rb.out"]),
        Node("cat", "Concat", ["ra.out", "rb.out"], ["cat.out"],
             {"axis": -1}),
        Node("fc2", "Gemm", ["cat.out"], ["fc2.out"],
             {"weight_shape": (12, 5)}),
    ]
    g = Graph("splitnet", nodes, {"input": (16,)}, ["fc2.out"])
    assert g.shapes["sp.a"] == (4,) and g.shapes["sp.b"] == (8,)
    sim_out, ref_out, stats = simulate(g, arch)
    np.testing.assert_array_equal(sim_out["fc2.out"], ref_out["fc2.out"])
    assert stats.cim_reads > 0


def test_reference_shift_calibration_idempotent():
    g = get_workload("tiny_mlp")
    w = make_weights(g, 1)
    x = make_input(g, 1)
    out1, shifts = reference_forward(g, w, x)
    out2, _ = reference_forward(g, w, x, shifts=shifts)
    np.testing.assert_array_equal(out1["fc2.out"], out2["fc2.out"])
