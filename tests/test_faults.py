"""Fault-injection stack: seeded device fault maps, interpreter-vs-
executor bit-exactness under faults, fault-aware remapping (line
retirement + exact top-1 recovery on resnet18), the typed fault budget,
and the executor-backed robustness metric for DSE."""
import numpy as np
import pytest

from repro.cimsim.executor import lower
from repro.cimsim.faults import (FaultMap, FaultModel, accuracy_under_faults,
                                 fault_aware_compile)
from repro.cimsim.functional import (FunctionalSimulator, calibrate_shifts,
                                     make_input, make_weights)
from repro.core import compiler
from repro.core.abstraction import get_arch
from repro.core.graph import Graph
from repro.core.mapping import FaultBudgetError, retired_geometry
from repro.kernels.cim_mvm import cim_mvm_params
from repro.workloads import get_workload

ISAAC = get_arch("isaac-baseline")

#: the acceptance fault map: a seeded 1% stuck-at map (whole-bitline
#: stuck-at faults — 1% of cells — plus a sprinkle of dead rows, both
#: line-correlated so retirement can recover them exactly)
STUCK_1PCT = FaultModel(seed=7, stuck_col_rate=0.01, dead_row_rate=0.005)


def _resnet18_prefix(in_hw=8, n_classes=16):
    """The real resnet18 node list cut after the first residual add
    (conv1 -> pool -> basic block) — genuine resnet18 layer shapes at a
    cost the oracle interpreter can afford in tier-1."""
    full = get_workload("resnet18", in_hw=in_hw, n_classes=n_classes)
    cut = next(i for i, n in enumerate(full.nodes)
               if n.op_type == "Add") + 1
    nodes = full.nodes[:cut]
    return Graph("resnet18-prefix", nodes, full.inputs,
                 [nodes[-1].outputs[0]])


# ------------------------------------------------------ device tier

def test_fault_map_seeded_and_deterministic():
    span = (0, 64, 0, 12)       # 12 logical cols x S slices fits 128
    w = np.random.default_rng(0).integers(-128, 128, (64, 12)) \
        .astype(np.int32)
    a = FaultMap(STUCK_1PCT, ISAAC)
    b = FaultMap(STUCK_1PCT, ISAAC)
    np.testing.assert_array_equal(a.apply_tile("n", span, w),
                                  b.apply_tile("n", span, w))
    assert a.token == b.token
    # a different seed is a different map (and a different cache token)
    c = FaultMap(FaultModel(seed=8, stuck_col_rate=0.01,
                            dead_row_rate=0.005), ISAAC)
    assert c.token != a.token
    assert not np.array_equal(c.apply_tile("n", span, w),
                              a.apply_tile("n", span, w))
    # remapped and direct placements are distinct cache identities
    assert FaultMap(STUCK_1PCT, ISAAC, remap=True).token != a.token


def test_clean_model_is_identity():
    fm = FaultMap(FaultModel(seed=1), ISAAC)
    assert not FaultModel(seed=1).any_faults
    w = np.arange(32 * 16, dtype=np.int32).reshape(32, 16) - 200
    np.testing.assert_array_equal(fm.apply_tile("n", (0, 32, 0, 16), w), w)
    assert fm.tile_offset("n", (0, 32, 0, 16)) is None


def test_resnet18_interpreter_executor_bit_exact_under_faults():
    """Acceptance: with the seeded 1% stuck-at map on resnet18, the
    oracle interpreter and the trace-lowered executor agree bit for bit
    — and the faults demonstrably perturb the output."""
    g = _resnet18_prefix()
    p = cim_mvm_params(ISAAC)
    weights, inputs = make_weights(g, 0), make_input(g, 0)
    shifts = calibrate_shifts(g, weights, inputs, p)
    res = compiler.compile_graph(g, ISAAC, expand=True)
    sim = FunctionalSimulator(res.plan, res.program, weights, shifts,
                              params=p, faults=FaultMap(STUCK_1PCT, ISAAC))
    sim_out = sim.run(inputs)
    res2 = compiler.compile_graph(g, ISAAC)
    exe = lower(res2.plan, res2.program, params=p,
                faults=FaultMap(STUCK_1PCT, ISAAC), cache=False)
    exe_out = exe.run(inputs, weights, shifts)
    clean = lower(res2.plan, res2.program, params=p, cache=False) \
        .run(inputs, weights, shifts)
    for t in g.outputs:
        np.testing.assert_array_equal(sim_out[t], exe_out[t])
        assert not np.array_equal(clean[t], exe_out[t])


def test_lower_cache_distinguishes_fault_maps():
    g = get_workload("tiny_mlp")
    p = cim_mvm_params(ISAAC)
    weights, inputs = make_weights(g, 0), make_input(g, 0)
    shifts = calibrate_shifts(g, weights, inputs, p)
    res = compiler.compile_graph(g, ISAAC)
    out = {}
    for tag, fm in (("clean", None),
                    ("a", FaultMap(STUCK_1PCT, ISAAC)),
                    ("a2", FaultMap(STUCK_1PCT, ISAAC)),
                    ("b", FaultMap(FaultModel(seed=9, stuck_col_rate=0.02),
                                   ISAAC))):
        exe = lower(res.plan, res.program, params=p, faults=fm)
        out[tag] = exe.run(inputs, weights, shifts)[g.outputs[0]]
    # same map hits the trace cache and reproduces; different maps and
    # the clean trace never collide on one cached program
    np.testing.assert_array_equal(out["a"], out["a2"])
    assert not np.array_equal(out["a"], out["clean"])
    assert not np.array_equal(out["a"], out["b"])


# ---------------------------------------------------- compiler tier

def test_retired_geometry_shrinks_and_raises_typed():
    arch = retired_geometry(ISAAC, 8, 16)
    assert arch.xb.xb_size[0] == ISAAC.xb.xb_size[0] - 8
    assert arch.xb.xb_size[1] == ISAAC.xb.xb_size[1] - 16
    assert arch.xb.parallel_row <= arch.xb.xb_size[0]
    with pytest.raises(FaultBudgetError) as ei:
        retired_geometry(ISAAC, ISAAC.xb.xb_size[0], 0)
    assert ei.value.retire_rows == ISAAC.xb.xb_size[0]


def test_fault_aware_compile_exhaustion_raises_budget_error():
    # half the bitlines stuck: no retirement budget can find clean
    # column groups, so the remapping loop must fail *typed*
    hopeless = FaultModel(seed=2, stuck_col_rate=0.5)
    with pytest.raises(FaultBudgetError):
        fault_aware_compile(get_workload("tiny_mlp"), ISAAC, hopeless,
                            max_rounds=3)


def test_resnet18_remap_recovers_exact_top1():
    """Acceptance: on exact-ADC isaac, fault-aware remapping restores
    exact top-1 agreement with the fault-free reference, while the
    unmitigated map demonstrably degrades it."""
    g = get_workload("resnet18", in_hw=32, n_classes=16)
    fc = fault_aware_compile(g, ISAAC, STUCK_1PCT)
    assert fc.retired_rows > 0 or fc.retired_cols > 0
    assert fc.result.plan.notes["fault_retired"] == {
        "rows": fc.retired_rows, "cols": fc.retired_cols,
        "attempts": fc.attempts}
    unmitigated = accuracy_under_faults(g, ISAAC, STUCK_1PCT, n_inputs=4)
    remapped = accuracy_under_faults(g, ISAAC, STUCK_1PCT, n_inputs=4,
                                     remap=True)
    assert unmitigated < 1.0
    assert remapped == 1.0


# --------------------------------------------------------- DSE tier

def test_evaluate_point_exposes_fault_metric(tmp_path):
    from repro.dse import CompileCache, DesignPoint
    from repro.dse.runner import evaluate_point
    g = get_workload("tiny_mlp")
    point = DesignPoint(level="WLM", binding="B->XBC",
                        use_pipeline=True, use_duplication=True)
    cache = CompileCache(tmp_path / "c")
    model = FaultModel(seed=4, stuck_col_rate=0.02)
    m1, cached1 = evaluate_point(g, ISAAC, point, cache=cache,
                                 fault_model=model)
    assert not cached1
    assert 0.0 <= m1["fault_top1"] <= 1.0
    # the robustness metric is executor-backed and re-derived even when
    # the compile itself is a cache hit
    m2, cached2 = evaluate_point(g, ISAAC, point, cache=cache,
                                 fault_model=model)
    assert cached2
    assert m2["fault_top1"] == m1["fault_top1"]
    assert "fault_top1" not in evaluate_point(g, ISAAC, point)[0]
