"""Meta-operator flow generation: BNF syntax, structure, loop expansion."""
import re

from repro.core import compiler, mop
from repro.core.abstraction import get_arch
from repro.workloads import get_workload


def test_walkthrough_flow_cm():
    """§3.4 CM codegen: parallel read_core per copy, then the ReLU DCOM."""
    res = compiler.compile_graph(get_workload("conv_relu_toy"),
                                 get_arch("toy"), level="CM")
    text = res.program.to_text()
    assert "parallel {" in text
    assert text.count("cim.read_core") == 2     # duplication = 2
    assert "relu(" in text


def test_walkthrough_flow_xbm_and_wlm():
    g = get_workload("conv_relu_toy")
    arch = get_arch("toy")
    xbm = compiler.compile_graph(g, arch, level="XBM").program
    assert xbm.op_counts()["cim.write_xb"] == 4      # dup 4 x 1 xb
    # 1024 windows over 4 copies -> 256 read blocks (paper: "256 similar
    # code segments")
    assert xbm.op_counts()["cim.read_xb"] == 256 * 4
    wlm = compiler.compile_graph(g, arch, level="WLM").program
    assert wlm.op_counts()["cim.read_row"] > 0


def test_loop_expansion_preserves_counts():
    g = get_workload("tiny_cnn")
    arch = get_arch("toy")
    res = compiler.compile_graph(g, arch)
    compact = res.program
    expanded = compact.expand()
    assert compact.op_counts() == expanded.op_counts()
    assert expanded.max_parallel_width() >= 1
    expanded.validate()


def test_bnf_syntax_shape():
    res = compiler.compile_graph(get_workload("tiny_mlp"), get_arch("toy"))
    for line in res.program.to_text().splitlines():
        line = line.strip()
        if not line or line.startswith("//") or line in ("}",):
            continue
        assert re.match(
            r"^(parallel \{|repeat x\d+ \{|\}|[\w\.]+\(.*\)?)", line), line


def test_user_extensible_dcom():
    mop.register_dcom("my_custom_op")
    op = mop.dcom("my_custom_op", src=0, dst=8, len=4)
    assert op.family == "DCOM"
    assert "my_custom_op(" in op.to_text()
