"""Per-kernel tests: shape/dtype/precision sweeps of the Pallas cim_mvm
kernel against the pure-jnp oracle (ref.py), plus exactness/saturation
contracts."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st

from repro.kernels.cim_mvm import cim_mvm, CimMvmParams, cim_mvm_params
from repro.kernels.cim_mvm.ops import cim_mvm_signed
from repro.core.abstraction import get_arch

RNG = np.random.default_rng(42)

SHAPES = [(1, 27, 32), (7, 100, 5), (64, 128, 128), (33, 300, 130),
          (128, 1152, 256), (2, 8, 1)]
PARAMS = [
    CimMvmParams(8, 8, 1, 2, 8, 8),       # ISAAC-like
    CimMvmParams(8, 8, 8, 2, 128, 8),     # PUMA-like
    CimMvmParams(8, 8, 1, 1, 32, 6),      # Jain-like
    CimMvmParams(4, 4, 2, 2, 16, 12),     # wide-ADC low precision
    CimMvmParams(8, 8, 4, 4, 64, 16),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("params", PARAMS)
def test_kernel_matches_oracle(shape, params):
    m, r, c = shape
    x = RNG.integers(0, 2 ** params.act_bits, (m, r)).astype(np.int32)
    w = RNG.integers(0, 2 ** params.weight_bits, (r, c)).astype(np.int32)
    y_kernel = np.asarray(cim_mvm(jnp.asarray(x), jnp.asarray(w), params,
                                  mode="interpret"))
    y_oracle = np.asarray(cim_mvm(jnp.asarray(x), jnp.asarray(w), params,
                                  mode="xla"))
    np.testing.assert_array_equal(y_kernel, y_oracle)


def test_deprecated_boolean_kwargs_warn_and_match():
    """use_kernel=/interpret= still work, warn, and keep their meaning."""
    p = CimMvmParams(8, 8, 1, 2, 8, 8)
    x = jnp.asarray(RNG.integers(0, 256, (5, 40)).astype(np.int32))
    w = jnp.asarray(RNG.integers(0, 256, (40, 7)).astype(np.int32))
    with pytest.warns(DeprecationWarning, match="deprecated"):
        y_legacy = np.asarray(cim_mvm(x, w, p, use_kernel=False))
    np.testing.assert_array_equal(y_legacy,
                                  np.asarray(cim_mvm(x, w, p, mode="xla")))
    with pytest.warns(DeprecationWarning):
        y_interp = np.asarray(cim_mvm(x, w, p, interpret=True))
    np.testing.assert_array_equal(y_interp, y_legacy)
    with pytest.raises(ValueError, match="not both"):
        cim_mvm(x, w, p, use_kernel=False, mode="xla")


@pytest.mark.parametrize("params", [p for p in PARAMS if p.exact])
def test_exact_adc_is_integer_matmul(params):
    x = RNG.integers(0, 2 ** params.act_bits, (17, 96)).astype(np.int64)
    w = RNG.integers(0, 2 ** params.weight_bits, (96, 40)).astype(np.int64)
    y = np.asarray(cim_mvm(jnp.asarray(x, jnp.int32),
                           jnp.asarray(w, jnp.int32), params))
    np.testing.assert_array_equal(y, x @ w)


def test_saturating_adc_underestimates():
    p = CimMvmParams(8, 8, 8, 8, 128, 4)   # tiny ADC, huge analog range
    assert not p.exact
    x = RNG.integers(1, 256, (4, 128)).astype(np.int64)
    w = RNG.integers(1, 256, (128, 8)).astype(np.int64)
    y = np.asarray(cim_mvm(jnp.asarray(x, jnp.int32),
                           jnp.asarray(w, jnp.int32), p)).astype(np.int64)
    assert (y <= x @ w).all()
    assert (y < x @ w).any()


def test_signed_offset_encoding_exact():
    p = CimMvmParams(8, 8, 1, 2, 8, 16)
    x = RNG.integers(-128, 128, (9, 200)).astype(np.int32)
    w = RNG.integers(-128, 128, (200, 33)).astype(np.int32)
    y = np.asarray(cim_mvm_signed(jnp.asarray(x), jnp.asarray(w), p))
    np.testing.assert_array_equal(y, x.astype(np.int64) @ w.astype(np.int64))


def test_params_from_arch():
    p = cim_mvm_params(get_arch("isaac-baseline"))
    assert p.parallel_row == 8 and p.cell_bits == 2 and p.dac_bits == 1
    assert p.exact        # 8 rows x 1b x 3 max = 24 < 2^8


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 33), r=st.integers(1, 200), c=st.integers(1, 150),
       pr=st.sampled_from([4, 8, 32, 128]),
       db=st.sampled_from([1, 2, 4]), cb=st.sampled_from([1, 2, 4]))
def test_kernel_property_sweep(m, r, c, pr, db, cb):
    params = CimMvmParams(act_bits=8, weight_bits=8, dac_bits=db,
                          cell_bits=cb, parallel_row=pr, adc_bits=20)
    rng = np.random.default_rng(m * 1000 + r * 10 + c)
    x = rng.integers(0, 256, (m, r)).astype(np.int64)
    w = rng.integers(0, 256, (r, c)).astype(np.int64)
    y = np.asarray(cim_mvm(jnp.asarray(x, jnp.int32),
                           jnp.asarray(w, jnp.int32), params))
    np.testing.assert_array_equal(y, x @ w)     # wide ADC -> exact
