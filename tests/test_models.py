"""Per-architecture smoke tests (reduced configs, CPU): one forward /
train step, output shapes, no NaNs; prefill->decode consistency; SSD and
blockwise-attention oracles (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.configs import ARCHS, reduced
from repro.models import lm, ssm
from repro.models.layers import AttnSpec, attention, decode_attention

B, S = 2, 64


def _batch(cfg, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.vision_stub:
        batch["vision_embeds"] = jnp.ones(
            (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16) * 0.1
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S))
    if cfg.enc_dec:
        batch["enc_embeds"] = jnp.ones((B, 16, cfg.d_model),
                                       jnp.bfloat16) * 0.1
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_loss(name):
    cfg = reduced(ARCHS[name])
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    x = lm.forward(params, cfg, batch)
    assert x.shape == (B, S, cfg.d_model)
    assert not np.isnan(np.asarray(x, np.float32)).any()
    loss = jax.jit(lambda p, b: lm.lm_loss(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    # one SGD-flavored step decreases nothing structurally — just check
    # grads exist and are finite for every leaf
    grads = jax.grad(lambda p: lm.lm_loss(p, cfg, batch, remat=False))(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_prefill_decode_consistency(name):
    cfg = dataclasses.replace(reduced(ARCHS[name]), dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab)
    fb = dict(_batch(cfg), tokens=toks)
    if cfg.vision_stub:
        fb["positions3"] = jnp.broadcast_to(jnp.arange(S + 1)[None, None],
                                            (3, B, S + 1))
    x = lm.forward(params, cfg, fb)
    ref_logits = lm.logits_fn(params, cfg, x[:, S - 1:S + 1])

    pb = dict(fb, tokens=toks[:, :S])
    if cfg.vision_stub:
        pb["positions3"] = fb["positions3"][:, :, :S]
    lp, cache = lm.prefill(params, cfg, pb)
    db = {"tokens": toks[:, S:S + 1]}
    if cfg.mrope:
        db["positions3"] = jnp.full((3, B, 1), S)
    ld, cache2 = lm.decode_step(params, cfg, cache, db, jnp.int32(S))

    scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
    assert float(jnp.max(jnp.abs(lp[:, 0] - ref_logits[:, 0]))) / scale < 2e-2
    assert float(jnp.max(jnp.abs(ld[:, 0] - ref_logits[:, 1]))) / scale < 5e-2
    # greedy tokens agree
    np.testing.assert_array_equal(np.argmax(np.asarray(ld[:, 0]), -1),
                                  np.argmax(np.asarray(ref_logits[:, 1]), -1))
    # cache structure is stable across steps
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 pytest.fail("cache shape changed"), cache, cache2)


def _naive_attention(q, k, v, spec):
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(d))
    if spec.logit_softcap:
        s = jnp.tanh(s / spec.logit_softcap) * spec.logit_softcap
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((sq, k.shape[1]), bool)
    if spec.causal:
        m &= kp <= qp
    if spec.window is not None:
        m &= kp > qp - spec.window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, v.shape[-1]).astype(q.dtype)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(3, 65), hq=st.sampled_from([2, 4]),
       ratio=st.sampled_from([1, 2]), window=st.sampled_from([None, 5, 16]),
       cap=st.sampled_from([None, 20.0]), causal=st.booleans())
def test_blockwise_attention_matches_naive(s, hq, ratio, window, cap, causal):
    rng = np.random.default_rng(s * 7 + hq)
    hkv = hq // ratio
    d = 8
    q = jnp.asarray(rng.normal(size=(2, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, s, hkv, d)), jnp.float32)
    spec = AttnSpec(causal=causal, window=window, logit_softcap=cap,
                    q_block=16, kv_block=16)
    out = attention(q, k, v, spec)
    ref = _naive_attention(q, k, v, spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 80), h=st.sampled_from([1, 4]),
       chunk=st.sampled_from([8, 16, 32]))
def test_ssd_chunked_matches_sequential(s, h, chunk):
    rng = np.random.default_rng(s * 13 + h)
    p, n, bt = 4, 8, 2
    x = jnp.asarray(rng.normal(size=(bt, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.normal(size=(bt, s, h)), jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.normal(size=(h,)) * 0.3, jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(bt, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(bt, s, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    y1 = ssm.ssd_scan(x, dt, A, Bm, C, D, chunk=chunk)
    y2 = ssm.ssd_reference(x, dt, A, Bm, C, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-4)


def test_decode_attention_matches_blockwise_last_row():
    rng = np.random.default_rng(0)
    s, hq, hkv, d = 33, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(2, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, s, hkv, d)), jnp.float32)
    spec = AttnSpec(causal=True, logit_softcap=50.0)
    full = attention(q, k, v, spec)
    dec = decode_attention(q[:, -1:], k, v, jnp.int32(s), spec)
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(dec),
                               atol=1e-5, rtol=1e-5)
