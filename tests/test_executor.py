"""Trace-lowered batched executor: bit-exact against the op-by-op
interpreter across chip modes, ADC regimes and batch sizes."""
import subprocess
import sys

import numpy as np
import pytest

from repro.cimsim.executor import LoweredExecutable, lower
from repro.cimsim.functional import (FunctionalSimulator, calibrate_shifts,
                                     compile_and_verify, make_input,
                                     make_weights, simulate)
from repro.core import compiler
from repro.core.abstraction import (CellType, ChipTier, CIMArch,
                                    ComputingMode, CoreTier, CrossbarTier)
from repro.kernels.cim_mvm import cim_mvm_params
from repro.workloads import get_workload

SMALL = CIMArch(
    name="test-wlm", mode=ComputingMode.WLM,
    chip=ChipTier(core_number=(4, 1), alu_ops_per_cycle=64, l0_bw_bits=1024),
    core=CoreTier(xb_number=(2, 1), l1_bw_bits=1024),
    xb=CrossbarTier(xb_size=(32, 32), dac_bits=1, adc_bits=8,
                    cell_type=CellType.SRAM, cell_precision=2,
                    parallel_row=8),
)
#: a 4-bit ADC saturates (exact_adc_bits needs 5 here) -> the executor
#: must take the tile-batched oracle path, not the matmul shortcut
SATURATING = SMALL.replace(name="test-sat",
                           xb=CrossbarTier(xb_size=(32, 32), dac_bits=1,
                                           adc_bits=4,
                                           cell_type=CellType.SRAM,
                                           cell_precision=2,
                                           parallel_row=8))
MODES = [ComputingMode.WLM, ComputingMode.XBM, ComputingMode.CM]


def _both(graph, arch):
    """(interpreter outputs, executor outputs, executable) for one cell."""
    params = cim_mvm_params(arch)
    weights = make_weights(graph, 0)
    inputs = make_input(graph, 0)
    shifts = calibrate_shifts(graph, weights, inputs, params)
    res = compiler.compile_graph(graph, arch, expand=True)
    sim = FunctionalSimulator(res.plan, res.program, weights, shifts,
                              params=params)
    sim_out = sim.run(inputs)
    exe = lower(res.plan, res.program, params=params)
    exe_out = exe.run(inputs, weights, shifts)
    return sim_out, exe_out, exe


@pytest.mark.parametrize("wl", ["tiny_mlp", "tiny_cnn"])
@pytest.mark.parametrize("mode", MODES)
def test_executor_matches_interpreter(wl, mode):
    g = get_workload(wl)
    sim_out, exe_out, exe = _both(g, SMALL.replace(mode=mode))
    for t in g.outputs:
        np.testing.assert_array_equal(sim_out[t], exe_out[t])
    assert exe.stats.cim_reads > 0
    if exe.stats.streamed:
        # multi-segment plan: weight-update streaming rides the tile
        # path (the pool models crossbar residency), still bit-exact
        assert exe.stats.segments > 1 and exe.stats.swaps > 0
        assert exe.stats.matmul_nodes == 0
    else:
        assert exe.stats.matmul_nodes == exe.stats.cim_nodes  # exact ADC


@pytest.mark.parametrize("wl", ["tiny_mlp", "tiny_cnn"])
@pytest.mark.parametrize("mode", MODES)
def test_executor_matches_interpreter_saturating_adc(wl, mode):
    assert not cim_mvm_params(SATURATING).exact
    g = get_workload(wl)
    sim_out, exe_out, exe = _both(g, SATURATING.replace(mode=mode))
    for t in g.outputs:
        np.testing.assert_array_equal(sim_out[t], exe_out[t])
    assert exe.stats.matmul_nodes == 0     # tile-batched oracle path


def test_executor_batch_axis_consistency():
    g = get_workload("tiny_cnn")
    arch = SMALL
    params = cim_mvm_params(arch)
    weights = make_weights(g, 0)
    shifts = calibrate_shifts(g, weights, make_input(g, 0), params)
    res = compiler.compile_graph(g, arch)
    exe = lower(res.plan, res.program, params=params)
    packed = exe.pack(weights)
    xs = [make_input(g, s) for s in range(5)]
    singles = [exe.run(x, packed=packed, shifts=shifts) for x in xs]
    batched = exe.run_batch(
        {"input": np.stack([x["input"] for x in xs])},
        packed=packed, shifts=shifts)
    for t in g.outputs:
        np.testing.assert_array_equal(
            batched[t], np.stack([s[t] for s in singles]))


def test_executor_split_graph():
    from repro.core.graph import Graph, Node
    nodes = [
        Node("fc1", "Gemm", ["input"], ["fc1.out"],
             {"weight_shape": (16, 12)}),
        Node("sp", "Split", ["fc1.out"], ["sp.a", "sp.b"],
             {"axis": -1, "parts": [4, 8]}),
        Node("ra", "Relu", ["sp.a"], ["ra.out"]),
        Node("rb", "Relu", ["sp.b"], ["rb.out"]),
        Node("cat", "Concat", ["ra.out", "rb.out"], ["cat.out"],
             {"axis": -1}),
        Node("fc2", "Gemm", ["cat.out"], ["fc2.out"],
             {"weight_shape": (12, 5)}),
    ]
    g = Graph("splitnet", nodes, {"input": (16,)}, ["fc2.out"])
    sim_out, exe_out, _ = _both(g, SMALL)
    np.testing.assert_array_equal(sim_out["fc2.out"], exe_out["fc2.out"])


@pytest.mark.parametrize("arch", [SMALL, SATURATING],
                         ids=["exact", "saturating"])
def test_executor_float_and_matmul_dcom_ops(arch):
    """Attention-style graph: MatMul (transpose_b), Softmax, LayerNorm
    and Gelu lowerings (incl. the float pure_callback path) stay
    bit-exact vs the interpreter."""
    from repro.core.graph import Graph, Node
    nodes = [
        Node("fc1", "Gemm", ["input"], ["fc1.out"],
             {"weight_shape": (16, 16)}),
        Node("sm", "Softmax", ["fc1.out"], ["sm.out"]),
        Node("mm", "MatMul", ["sm.out", "fc1.out"], ["mm.out"],
             {"transpose_b": True}),
        Node("ln", "LayerNorm", ["mm.out"], ["ln.out"]),
        Node("ge", "Gelu", ["ln.out"], ["ge.out"]),
        Node("fc2", "Gemm", ["ge.out"], ["fc2.out"],
             {"weight_shape": (4, 5)}),
    ]
    g = Graph("attn_toy", nodes, {"input": (4, 16)}, ["fc2.out"])
    sim_out, exe_out, _ = _both(g, arch)
    np.testing.assert_array_equal(sim_out["fc2.out"], exe_out["fc2.out"])


def test_executor_simulate_entry_point():
    g = get_workload("tiny_cnn")
    sim_out, ref_out, _ = simulate(g, SMALL)
    exe_out, ref_out2, stats = simulate(g, SMALL, use_executor=True)
    for t in g.outputs:
        np.testing.assert_array_equal(sim_out[t], exe_out[t])
        np.testing.assert_array_equal(ref_out[t], ref_out2[t])
    assert stats.cim_reads > 0


def test_compile_and_verify_batched():
    g = get_workload("tiny_cnn")
    rep = compile_and_verify(g, SMALL, batch=3)
    assert rep.ok and rep.batch == 3
    assert set(rep.max_abs_err) == set(g.outputs)
    rep_sat = compile_and_verify(g, SATURATING, batch=2)
    assert rep_sat.ok                      # reference shares ADC semantics
    rep_interp = compile_and_verify(g, SMALL, batch=2, use_executor=False)
    assert rep_interp.ok


def test_compile_and_verify_falls_back_on_lowering_error(monkeypatch):
    """A flow the executor refuses (LoweringError) still verifies, op by
    op — the documented fallback."""
    from repro.cimsim import executor as executor_mod

    def refuse(*args, **kwargs):
        raise executor_mod.LoweringError("forced for test")

    monkeypatch.setattr(executor_mod, "lower", refuse)
    rep = compile_and_verify(get_workload("tiny_mlp"), SMALL, batch=2)
    assert rep.ok and rep.lower_s == 0.0    # interpreter path was used


def test_lower_cache_reuses_executable():
    g = get_workload("tiny_mlp")
    res1 = compiler.compile_graph(g, SMALL)
    res2 = compiler.compile_graph(g, SMALL)
    assert res1.key is not None and res1.key == res2.key
    e1 = lower(res1.plan, res1.program)
    e2 = lower(res2.plan, res2.program)
    assert e1 is e2
    assert isinstance(lower(res1.plan, res1.program, cache=False),
                      LoweredExecutable)
    # params are part of the key
    e3 = lower(res1.plan, res1.program,
               params=cim_mvm_params(SATURATING))
    assert e3 is not e1


def test_plan_key_distinguishes_baseline_policies():
    """Baseline-policy plans (different placements, same knobs) must not
    alias the compiler's plan in the executor cache."""
    from repro.core import baselines
    g = get_workload("tiny_mlp")
    compiled = compiler.compile_graph(g, SMALL)
    native = baselines.native(g, SMALL)
    assert compiler.compile_key_for_plan(native) != \
        compiler.compile_key_for_plan(compiled.plan)


def test_executor_swappable_weights_and_shifts():
    """One lowered executable serves any weight/shift set (no re-trace)."""
    g = get_workload("tiny_mlp")
    params = cim_mvm_params(SMALL)
    res = compiler.compile_graph(g, SMALL)
    exe = lower(res.plan, res.program, params=params)
    x = make_input(g, 0)
    for seed in (0, 1):
        w = make_weights(g, seed)
        sh = calibrate_shifts(g, w, x, params)
        res_e = compiler.compile_graph(g, SMALL, expand=True)
        sim = FunctionalSimulator(res_e.plan, res_e.program, w, sh,
                                  params=params)
        np.testing.assert_array_equal(
            exe.run(x, w, sh)["fc2.out"], sim.run(x)["fc2.out"])


def test_make_weights_stable_across_processes():
    """Weight seeding must not depend on the per-process str-hash salt."""
    snippet = (
        "from repro.cimsim.functional import make_weights\n"
        "from repro.workloads import get_workload\n"
        "import zlib\n"
        "w = make_weights(get_workload('tiny_mlp'), seed=3)\n"
        "print({k: zlib.crc32(v.tobytes()) for k, v in sorted(w.items())})\n"
    )
    digests = []
    for salt in ("0", "1"):
        out = subprocess.run(
            [sys.executable, "-c", snippet], check=True, text=True,
            capture_output=True,
            env={"PYTHONPATH": "src", "PYTHONHASHSEED": salt,
                 "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"},
            cwd=str(__import__("pathlib").Path(__file__).resolve().parent.parent))
        digests.append(out.stdout.strip())
    assert digests[0] == digests[1]


def test_cim_batch_service_matches_interpreter():
    from repro.serving.cim_service import CimBatchService, CimRequest
    g = get_workload("tiny_mlp")
    fast = CimBatchService(g, SMALL, max_batch=4)
    slow = CimBatchService(g, SMALL, max_batch=4, use_executor=False)
    reqs = [CimRequest(rid=i, inputs=make_input(g, i)) for i in range(6)]
    reqs2 = [CimRequest(rid=i, inputs=make_input(g, i)) for i in range(6)]
    fast.serve(reqs)
    slow.serve(reqs2)
    for a, b in zip(reqs, reqs2):
        for t in g.outputs:
            np.testing.assert_array_equal(a.outputs[t], b.outputs[t])
    assert fast.stats.requests == 6 and fast.stats.batches == 2


def test_cim_batch_service_falls_back_on_lowering_error(monkeypatch):
    from repro.cimsim import executor as executor_mod
    from repro.serving.cim_service import CimBatchService, CimRequest

    def refuse(*args, **kwargs):
        raise executor_mod.LoweringError("forced for test")

    monkeypatch.setattr(executor_mod, "lower", refuse)
    g = get_workload("tiny_mlp")
    svc = CimBatchService(g, SMALL, max_batch=4)
    assert not svc.use_executor            # degraded to the interpreter
    reqs = [CimRequest(rid=i, inputs=make_input(g, i)) for i in range(2)]
    svc.serve(reqs)
    assert all(r.outputs is not None for r in reqs)


def test_campaign_verify_best():
    from repro.dse import DesignSpace, run_campaign
    g = get_workload("tiny_mlp")
    space = DesignSpace(SMALL, levels=("CM", "WLM"), bindings=("B->XBC",),
                        pipeline=(True,), duplication=(True,))
    camp = run_campaign({"tiny_mlp": g}, space, verify_best=True,
                        mode="exhaustive")
    rep = camp.workloads["tiny_mlp"].verify
    assert rep is not None and rep.ok
