"""DSE engine: compile cache identity, Pareto correctness, sweep
determinism across worker counts, knob-space validity."""
import pytest

from repro.core import compiler
from repro.core.abstraction import ComputingMode, get_arch
from repro.core.mapping import BitBinding
from repro.dse import (CompileCache, DesignPoint, DesignSpace,
                       apply_arch_overrides, dominates, pareto_frontier,
                       sweep)
from repro.dse.runner import SweepResult
from repro.workloads import get_workload


@pytest.fixture
def cache(tmp_path):
    return CompileCache(tmp_path / "cache")


# ---------------------------------------------------------------- cache
def test_cache_hit_bit_identical_to_fresh_compile(cache):
    g = get_workload("tiny_cnn")
    arch = get_arch("toy")
    fresh = compiler.compile_graph(g, arch)
    cached_miss = compiler.compile_graph(g, arch, cache=cache)
    assert cache.stats()["disk_entries"] == 1

    # a disk hit (memory layer dropped) must reproduce the result bit for bit
    cache.drop_memory()
    hit = compiler.compile_graph(g, arch, cache=cache)
    assert hit.program.to_text() == fresh.program.to_text()
    assert hit.text == cached_miss.text
    assert hit.report() == fresh.report()
    assert hit.metrics() == fresh.metrics()
    assert [p.node.name for p in hit.plan.placements] == \
        [p.node.name for p in fresh.plan.placements]


def test_cache_key_sensitivity():
    g1, g2 = get_workload("tiny_cnn"), get_workload("tiny_mlp")
    arch = get_arch("toy")
    k = compiler.compile_key(g1, arch)
    assert k == compiler.compile_key(g1, arch)            # stable
    assert k != compiler.compile_key(g2, arch)            # graph-sensitive
    assert k != compiler.compile_key(g1, arch.replace(act_bits=4))
    assert k != compiler.compile_key(g1, arch, level="CM")
    assert k != compiler.compile_key(g1, arch, use_pipeline=False)
    assert k != compiler.compile_key(g1, arch, binding=BitBinding.B_TO_XB)


def test_cache_metrics_fast_path(cache):
    g = get_workload("tiny_mlp")
    arch = get_arch("toy")
    key = compiler.compile_key(g, arch)
    assert cache.get_metrics(key) is None
    result = compiler.compile_graph(g, arch, cache=cache)
    cache.drop_memory()
    m = cache.get_metrics(key)
    assert m == result.metrics()
    assert m["latency_cycles"] > 0


def test_global_cache_hook(cache):
    g = get_workload("tiny_mlp")
    arch = get_arch("toy")
    prev = compiler.set_compile_cache(cache)
    try:
        r1 = compiler.compile_graph(g, arch)
        r2 = compiler.compile_graph(g, arch)
        assert r2 is r1                 # memory-layer hit returns the object
    finally:
        compiler.set_compile_cache(prev)


# ---------------------------------------------------------------- pareto
def test_pareto_frontier_hand_computed():
    # 2-knob space by hand: (latency, power) — minimize both.
    rows = [
        {"latency_cycles": 10.0, "peak_power": 8.0, "crossbars_used": 1},
        {"latency_cycles": 5.0, "peak_power": 9.0, "crossbars_used": 1},
        {"latency_cycles": 6.0, "peak_power": 9.5, "crossbars_used": 1},
        {"latency_cycles": 5.0, "peak_power": 9.0, "crossbars_used": 1},
        {"latency_cycles": 20.0, "peak_power": 1.0, "crossbars_used": 1},
    ]
    front = pareto_frontier(rows)
    # (6, 9.5) dominated by (5, 9); duplicate (5, 9) collapses;
    # (10, 8), (5, 9), (20, 1) are mutually non-dominated.
    assert front == [rows[1], rows[0], rows[4]]
    assert dominates((5.0, 9.0, 1), (6.0, 9.5, 1))
    assert not dominates((5.0, 9.0, 1), (5.0, 9.0, 1))
    assert not dominates((20.0, 1.0, 1), (5.0, 9.0, 1))


def test_pareto_on_real_sweep_is_nondominated():
    g = get_workload("tiny_cnn")
    res = sweep(g, DesignSpace(get_arch("toy")))
    ok = [r for r in res if r.ok]
    front = pareto_frontier(ok)
    assert 1 <= len(front) <= len(ok)
    objs = ("latency_cycles", "peak_power", "crossbars_used")

    def vec(r):
        return tuple(r.metrics[o] for o in objs)

    for f in front:
        assert not any(dominates(vec(o), vec(f)) for o in ok)
    # every non-frontier point is dominated by (or equal to) some frontier one
    fronts = {vec(f) for f in front}
    for o in ok:
        if vec(o) not in fronts:
            assert any(dominates(vec(f), vec(o)) for f in front)


# ---------------------------------------------------------------- space
def test_space_clamps_and_filters():
    arch = get_arch("puma")            # XBM chip: WLM requests clamp to XBM
    space = DesignSpace(arch)
    pts = space.points()
    assert all(ComputingMode(p.level).rank <= arch.mode.rank for p in pts)
    assert len(pts) == len(set(pts))   # clamping deduplicates
    # 2 effective levels x 2 bindings x 2 pipeline x 2 duplication
    assert len(pts) == 16


def test_arch_overrides_nested_and_clamped():
    arch = get_arch("isaac-baseline")
    out = apply_arch_overrides(arch, {"xb.xb_size": (64, 64),
                                      "chip.core_number": (8, 8),
                                      "act_bits": 4})
    assert out.xb.xb_size == (64, 64)
    assert out.chip.n_cores == 64
    assert out.act_bits == 4
    assert out.xb.parallel_row <= 64   # clamped to the shrunk row count
    assert arch.xb.xb_size == (128, 128)   # base untouched


# ---------------------------------------------------------------- runner
def _toy_space():
    return DesignSpace(get_arch("toy"),
                       arch_axes={"xb.xb_size": [(32, 128), (64, 128)]})


def test_sweep_deterministic_across_worker_counts(tmp_path):
    g = get_workload("tiny_cnn")
    space = _toy_space()
    serial = sweep(g, space)
    pooled = sweep(g, space, cache=CompileCache(tmp_path / "c"), workers=4)
    assert len(serial) == len(pooled) == 48
    assert [r.point for r in serial] == [r.point for r in pooled]
    assert [r.metrics for r in serial] == [r.metrics for r in pooled]
    # and a warm re-run (any worker count) returns identical metrics
    warm = sweep(g, space, cache=CompileCache(tmp_path / "c"), workers=2)
    assert all(r.cached for r in warm if r.ok)
    assert [r.metrics for r in warm] == [r.metrics for r in serial]


def test_sweep_reports_infeasible_points_without_aborting():
    g = get_workload("tiny_cnn")
    # a 1-core chip's 2 crossbars cannot hold the 4 bit slices of one
    # B->XB column unit: those points must fail *individually*
    toy = get_arch("toy")
    space = DesignSpace(toy.replace(
        chip=toy.chip.__class__(core_number=(1, 1))))
    res = sweep(g, space)
    assert all(isinstance(r, SweepResult) for r in res)
    by_binding = {}
    for r in res:
        by_binding.setdefault(r.point.binding, []).append(r)
    assert all(r.ok for r in by_binding["B->XBC"])
    assert all(not r.ok and "crossbar" in r.error
               for r in by_binding["B->XB"])


def test_sweep_level_beats_or_matches_coarser(tmp_path):
    """Sanity: finer scheduling levels never lose to coarser ones."""
    g = get_workload("tiny_cnn")
    arch = get_arch("toy")
    pts = [DesignPoint(level=lv, binding="B->XBC", use_pipeline=True,
                       use_duplication=True) for lv in ("CM", "XBM", "WLM")]
    res = sweep(g, pts, base_arch=arch)
    lat = {r.point.level: r.metrics["latency_cycles"] for r in res}
    assert lat["WLM"] <= lat["XBM"] <= lat["CM"] * (1 + 1e-9)


def test_design_point_label_roundtrip():
    p = DesignPoint(level="XBM", binding="B->XB", use_pipeline=False,
                    use_duplication=True,
                    arch_overrides=(("xb.cell_precision", 4),))
    assert p.mode is ComputingMode.XBM
    assert p.bit_binding is BitBinding.B_TO_XB
    assert "XBM" in p.label() and "cell_precision" in p.label()
    kw = p.compile_kwargs()
    assert kw["use_pipeline"] is False and kw["level"] is ComputingMode.XBM


# ---------------------------------------------------------- shared store
def _fill(cache, keys, result, nbytes=0):
    for i, k in enumerate(keys):
        cache.put(k, result, metrics={"latency_cycles": float(i)})


def _compile_once():
    g = get_workload("tiny_mlp")
    arch = get_arch("toy")
    return g, arch, compiler.compile_graph(g, arch)


def test_cache_cross_owner_hit_accounting(tmp_path):
    """Disk hits on another campaign's entries count as foreign_hits."""
    from repro.dse import shared_stats
    root = tmp_path / "shared"
    g, arch, _ = _compile_once()
    a = CompileCache(root, owner="campA")
    compiler.compile_graph(g, arch, cache=a)
    key = compiler.compile_key(g, arch)

    b = CompileCache(root, owner="campB")
    assert b.get_metrics(key) is not None
    assert b.stats()["foreign_hits"] == 1
    b.get_metrics(key)                       # memory-layer re-hit
    assert b.stats()["foreign_hits"] == 1    # counted once per key
    assert b.get(key) is not None
    assert b.stats()["foreign_hits"] == 1

    # the writer's own entries are never foreign, even from disk
    a.drop_memory()
    assert a.get_metrics(key) is not None
    assert a.stats()["foreign_hits"] == 0

    # per-owner bundles aggregate through the store itself
    a.publish_stats()
    b.publish_stats()
    agg = shared_stats(root)
    assert agg["owners"] == 2
    assert agg["foreign_hits"] == 1
    assert agg["metrics_hits"] >= 2
    # live counters supersede a stale published bundle
    b.get_metrics(compiler.compile_key(g, arch.replace(act_bits=4)))
    assert b.shared_stats()["misses"] == agg["misses"] + 1


def test_cache_eviction_waits_for_store_lock(tmp_path):
    """Eviction is serialized through the store lock (the 2-writer race)."""
    import threading
    import time
    root = tmp_path / "c"
    _, _, result = _compile_once()
    a = CompileCache(root, max_bytes=1)
    _fill(a, [f"{i:02x}aaaa" for i in range(4)], result)

    b = CompileCache(root)
    held = threading.Event()
    release = threading.Event()

    def holder():
        with b.lock():
            held.set()
            release.wait(10)

    t_hold = threading.Thread(target=holder)
    t_hold.start()
    assert held.wait(10)
    done = []
    t_evict = threading.Thread(target=lambda: (a._evict(), done.append(1)))
    t_evict.start()
    time.sleep(0.3)
    assert not done, "eviction must block while another handle holds the lock"
    release.set()
    t_evict.join(10)
    t_hold.join(10)
    assert done and a.evictions > 0


def test_cache_eviction_two_writers_keep_inflight_entries(tmp_path):
    """Concurrent capped writers never evict each other's fresh entries."""
    import os
    import threading
    root = tmp_path / "shared"
    _, _, result = _compile_once()
    probe = CompileCache(root)
    probe.put("00probe", result, metrics={"latency_cycles": 0.0})
    entry_bytes = probe.disk_bytes()
    probe.clear()

    cap = 3 * entry_bytes
    a = CompileCache(root, max_bytes=cap, evict_grace_s=60.0, owner="wa")
    b = CompileCache(root, max_bytes=cap, evict_grace_s=60.0, owner="wb")
    failures = []

    def writer(cache, tag):
        for i in range(8):
            key = f"{i:02x}{tag}"
            cache.put(key, result, metrics={"latency_cycles": float(i)})
            if cache.get_metrics(key) is None:    # in-flight re-read
                failures.append(key)

    threads = [threading.Thread(target=writer, args=(a, "wa")),
               threading.Thread(target=writer, args=(b, "wb"))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not failures, f"evicted in-flight entries: {failures}"

    # age everything past the grace window: the next capped put prunes
    old = __import__("time").time() - 120
    for p in (root / f"v{compiler.COMPILE_KEY_SCHEMA}").glob("*/*.*"):
        os.utime(p, (old, old))
    c = CompileCache(root, max_bytes=cap, evict_grace_s=60.0, owner="wc")
    c.put("ffnewest", result, metrics={"latency_cycles": 99.0})
    assert c.evictions > 0
    assert c.disk_bytes() <= cap
    assert c.get_metrics("ffnewest") is not None   # newest entry survives


def test_cache_stats_shape_and_disk_accounting(tmp_path):
    """_stats bundles never count toward entry size or entry count."""
    root = tmp_path / "c"
    _, _, result = _compile_once()
    cache = CompileCache(root, owner="x")
    cache.put("00abc", result)
    before = cache.disk_bytes()
    cache.publish_stats()
    assert cache.disk_bytes() == before
    s = cache.stats()
    assert s["disk_entries"] == 1
    for k in ("hits", "metrics_hits", "misses", "evictions",
              "foreign_hits"):
        assert k in s


# ---------------------------------------------------------------- report
def test_scorecards_render_and_roundtrip(tmp_path):
    import json
    from repro.dse import (campaign_scorecard, run_campaign,
                           search_scorecard, successive_halving)
    g = get_workload("tiny_mlp")
    space = _toy_space()
    cache = CompileCache(tmp_path / "c")
    sr = successive_halving(g, space, cache=cache)
    card = search_scorecard(sr, "tiny_mlp")
    md = card.to_markdown()
    assert "tiny_mlp" in md and "|proxy" in md and "full" in md
    data = json.loads(card.to_json())
    assert data["meta"]["full_evals"] == sr.full_evals
    assert len(data["rows"]) == len(sr.rungs)

    camp = run_campaign({"tiny_mlp": g}, space, cache=cache)
    ccard = campaign_scorecard(camp)
    cmd = ccard.to_markdown()
    assert "tiny_mlp" in cmd and "cache_foreign_hits" in cmd
    cdata = json.loads(ccard.to_json())
    assert cdata["meta"]["mode"] == "halving"
    assert cdata["rows"][0]["workload"] == "tiny_mlp"
    assert cdata["rows"][0]["full_evals"] == camp.full_evals


def test_cache_lock_timeout_stale_break_and_diagnostics(tmp_path):
    """Spin-lock hardening: a bounded wait raises the typed timeout
    while a live holder keeps the lock; an abandoned marker older than
    ``stale_s`` is broken instead of wedging the store forever."""
    import os
    import threading
    import time
    from repro.dse import CacheLockTimeout
    a = CompileCache(tmp_path / "c", owner="holder")
    b = CompileCache(tmp_path / "c", owner="waiter")
    held, release = threading.Event(), threading.Event()

    def holder():
        with a.lock(force_spin=True):
            held.set()
            release.wait(10)

    t = threading.Thread(target=holder)
    t.start()
    assert held.wait(10)
    t0 = time.monotonic()
    with pytest.raises(CacheLockTimeout):
        with b.lock(timeout_s=0.2, force_spin=True):
            pass
    assert time.monotonic() - t0 >= 0.2      # waited the full budget
    release.set()
    t.join(10)
    # stale break: a marker left by a dead process is aged out
    marker = a._base / ".lock.excl"
    marker.write_text("dead pid=0")
    old = time.time() - 100
    os.utime(marker, (old, old))
    with b.lock(timeout_s=2.0, stale_s=30.0, force_spin=True):
        assert marker.read_text().startswith("waiter")   # holder identity
    assert not marker.exists()
    # the flock path honors the same bounded wait
    import fcntl
    with open(a._base / ".lock", "a+b") as f:
        fcntl.flock(f.fileno(), fcntl.LOCK_EX)
        with pytest.raises(CacheLockTimeout):
            with b.lock(timeout_s=0.2):
                pass
        fcntl.flock(f.fileno(), fcntl.LOCK_UN)
    with b.lock(timeout_s=2.0):
        pass


def test_cache_lock_two_process_spin_contention(tmp_path):
    """Two real processes contending on the O_EXCL spin path: the
    waiter acquires only after the holder releases, never concurrently
    (the pre-hardening lock could spin forever or break a live lock)."""
    import subprocess
    import sys
    import time
    root = tmp_path / "c"
    code = (
        "import sys, time\n"
        "sys.path.insert(0, sys.argv[1])\n"
        "from repro.dse import CompileCache\n"
        "c = CompileCache(sys.argv[2], owner='proc-holder')\n"
        "with c.lock(force_spin=True):\n"
        "    open(sys.argv[2] + '/held', 'w').write('1')\n"
        "    time.sleep(1.0)\n"
    )
    src = str(__import__("pathlib").Path(__file__).resolve()
              .parent.parent / "src")
    root.mkdir()
    proc = subprocess.Popen([sys.executable, "-c", code, src, str(root)])
    try:
        deadline = time.monotonic() + 20
        while not (root / "held").exists():
            assert proc.poll() is None, "holder process died early"
            assert time.monotonic() < deadline, "holder never started"
            time.sleep(0.01)
        c = CompileCache(root, owner="waiter")
        t0 = time.monotonic()
        with c.lock(timeout_s=30.0, force_spin=True):
            # the holder slept 1s under the lock; acquiring before it
            # released would mean the spin lock was broken while live
            assert time.monotonic() - t0 > 0.2
            assert proc.wait(timeout=10) == 0
    finally:
        proc.kill()
