import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def pytest_collection_modifyitems(config, items):
    """Auto-skip ``accel``-marked tests unless the backend registry says
    the active platform can lower a compiled pallas_call for real."""
    from repro.kernels import backend

    if backend.supports("cim_mvm", "compiled"):
        return
    skip = pytest.mark.skip(
        reason=f"no compiled pallas_call route on "
               f"{backend.detect_platform()!r} (accel-only test)")
    for item in items:
        if "accel" in item.keywords:
            item.add_marker(skip)
