import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def pytest_collection_modifyitems(config, items):
    """Auto-skip ``accel``-marked tests unless the backend registry says
    the active platform can lower a compiled pallas_call for real."""
    from repro.kernels import backend

    if backend.supports("cim_mvm", "compiled"):
        return
    skip = pytest.mark.skip(
        reason=f"no compiled pallas_call route on "
               f"{backend.detect_platform()!r} (accel-only test)")
    for item in items:
        if "accel" in item.keywords:
            item.add_marker(skip)


#: per-test wall-clock ceiling used when pytest-timeout is unavailable
#: (CI installs the plugin and passes --timeout; this fallback keeps a
#: hung test from wedging a plain local `pytest` run forever)
_FALLBACK_TIMEOUT_S = 900


@pytest.fixture(autouse=True)
def _test_deadline(request):
    import signal
    if request.config.pluginmanager.hasplugin("timeout") or \
            not hasattr(signal, "SIGALRM"):
        yield                     # plugin active (or no SIGALRM): defer
        return

    def _expired(signum, frame):
        pytest.fail(f"test exceeded the {_FALLBACK_TIMEOUT_S}s fallback "
                    "ceiling (install pytest-timeout for the CI-grade "
                    "per-test timeout)", pytrace=False)

    previous = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(_FALLBACK_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, previous)
