"""Optional-hypothesis shim.

Import ``given``/``settings``/``st`` from here instead of ``hypothesis``.
When hypothesis is installed (requirements-dev.txt pins it) the real
objects pass straight through; when it's absent, property tests are
collected but skipped instead of crashing the whole module at import
time (the seed's ``ModuleNotFoundError: hypothesis``).
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy constructor call, returns a placeholder."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
