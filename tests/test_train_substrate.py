"""Substrate: optimizer, data pipeline, checkpointing (atomicity, elastic
restore), fault-tolerant trainer, serving loop."""
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.checkpoint import (CheckpointManager, latest_checkpoint,
                              restore_checkpoint, restore_resharded,
                              save_checkpoint)
from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeSpec
from repro.data import TokenStream
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw
from repro.optim.adamw import compress_int8, decompress_int8
from repro.serving import BatchServer, Request
from repro.train import Trainer, TrainerConfig


# ---------------------------------------------------------------- optimizer
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.ones((8,)) * 5.0}
    opt = adamw.adamw_init(params)
    def loss(p):
        return jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw.adamw_update(g, opt, params, lr=0.1,
                                         weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 100.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_int8_compression_error_feedback(seed):
    """With error feedback, the accumulated dequantized sum tracks the
    true gradient sum (error does not accumulate unboundedly)."""
    rng = np.random.default_rng(seed)
    err = jnp.zeros((32,))
    true_sum = np.zeros((32,))
    deq_sum = np.zeros((32,))
    for _ in range(20):
        g = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
        q, scale, err = compress_int8(g, err)
        deq_sum += np.asarray(decompress_int8(q, scale))
        true_sum += np.asarray(g)
    resid = np.abs(true_sum - deq_sum).max()
    assert resid <= float(np.abs(np.asarray(err)).max()) + 1e-4


# ---------------------------------------------------------------- data
def test_tokenstream_deterministic_and_resumable():
    s1 = TokenStream(vocab=512, batch=2, seq_len=16, seed=7)
    batches = [s1.next_batch() for _ in range(3)]
    s2 = TokenStream(vocab=512, batch=2, seq_len=16, seed=7)
    s2.state.step = 2                      # resume mid-stream
    np.testing.assert_array_equal(batches[2]["tokens"],
                                  s2.next_batch()["tokens"])
    assert batches[0]["tokens"].max() < 512
    # labels are next-token shifted
    np.testing.assert_array_equal(batches[0]["tokens"][:, 1:],
                                  batches[0]["labels"][:, :-1])


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": np.arange(6).reshape(2, 3), "b": (np.ones(4),)}
    p = save_checkpoint(tmp_path, 3, tree, extra={"step": 3})
    got, extra = restore_checkpoint(p, tree)
    np.testing.assert_array_equal(got["a"], tree["a"])
    assert extra["step"] == 3
    # a crashed writer leaves only a .tmp- staging dir -> ignored
    (tmp_path / "step_00000009.tmp-dead").mkdir()
    assert latest_checkpoint(tmp_path).endswith("step_00000003")


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, save_every=1, keep=2)
    for step in range(1, 6):
        mgr.maybe_save(step, {"x": np.full(3, step)})
    dirs = sorted(d.name for d in Path(tmp_path).iterdir())
    assert dirs == ["step_00000004", "step_00000005"]


def test_elastic_restore_reshard(tmp_path):
    mesh = make_host_mesh()
    tree = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    p = save_checkpoint(tmp_path, 1, tree, extra={"step": 1})
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P())}
    got, _ = restore_resharded(p, tree, sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])
    assert got["w"].sharding.is_equivalent_to(sh["w"], 2)


# ---------------------------------------------------------------- trainer
def _tiny_trainer(tmp_path, steps=6, arch="gemma2-2b", save_every=2):
    cfg = reduced(ARCHS[arch])
    shape = ShapeSpec("t", "train", 32, 4)
    mesh = make_host_mesh()
    stream = TokenStream(cfg.vocab, 4, 32, seed=1)
    from repro.data import make_batch_iterator
    data = make_batch_iterator(stream)
    tcfg = TrainerConfig(workdir=str(tmp_path), num_steps=steps,
                         save_every=save_every, log_every=2, lr=1e-3)
    return Trainer(cfg, shape, mesh, tcfg, data, data_state=stream.state), \
        stream


def test_trainer_end_to_end_and_resume(tmp_path):
    trainer, stream = _tiny_trainer(tmp_path, steps=4)
    res = trainer.train()
    assert res["steps"] == 4 and np.isfinite(res["final_loss"])
    # resume: a new trainer picks up at step 4 (checkpoint at step 4)
    trainer2, stream2 = _tiny_trainer(tmp_path, steps=6)
    res2 = trainer2.train()
    assert res2["steps"] == 6
    lines = [json.loads(l) for l in
             (Path(tmp_path) / "metrics.jsonl").read_text().splitlines()]
    assert any(l.get("event") == "done" for l in lines)
    # data stream resumed past the already-consumed batches
    assert stream2.state.step >= 4


def test_trainer_loss_decreases(tmp_path):
    trainer, _ = _tiny_trainer(tmp_path, steps=30, save_every=100)
    trainer.train()
    lines = [json.loads(l) for l in
             (Path(tmp_path) / "metrics.jsonl").read_text().splitlines()
             if "loss" in json.loads(l)]
    first, last = lines[0]["loss"], lines[-1]["loss"]
    assert last < first      # markov stream is learnable


# ---------------------------------------------------------------- serving
def test_batch_server_greedy_decode():
    cfg = dataclasses.replace(reduced(ARCHS["qwen1.5-4b"]),
                              dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchServer(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=np.arange(5 + i) % cfg.vocab,
                    max_new_tokens=4) for i in range(3)]
    done = server.serve(reqs)
    assert all(len(r.output) == 4 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.output)
    # determinism: same prompt twice -> same greedy output
    r2 = server.serve([Request(rid=9, prompt=np.arange(5) % cfg.vocab,
                               max_new_tokens=4)])[0]
    assert r2.output == done[0].output
