"""CIM-MLC core: abstraction, mapping, multi-level scheduler invariants."""
import math

import pytest
from hypcompat import given, settings, st

from repro.core import baselines, compiler
from repro.core.abstraction import (CellType, ChipTier, ComputingMode,
                                    CoreTier, CrossbarTier, get_arch,
                                    PRESETS)
from repro.core.graph import Graph, Node
from repro.core.mapping import bind
from repro.cimsim import perf
from repro.workloads import get_workload


def test_presets_load():
    for name in PRESETS:
        arch = get_arch(name)
        assert arch.chip.n_cores >= 1
        assert arch.core.n_xbs >= 1
        assert arch.xb.parallel_row <= arch.xb.rows


def test_mode_ordering():
    assert ComputingMode.WLM.allows(ComputingMode.CM)
    assert ComputingMode.WLM.allows(ComputingMode.XBM)
    assert not ComputingMode.CM.allows(ComputingMode.XBM)


def test_t_xb_read_isaac():
    arch = get_arch("isaac-baseline")
    # 8 input phases (8b act / 1b DAC) x 16 row groups (128 rows / 8)
    assert arch.t_xb_read() == 8 * 16
    assert arch.t_xb_read(rows_used=8) == 8


@settings(max_examples=50, deadline=None)
@given(r=st.integers(1, 5000), c=st.integers(1, 3000),
       rows=st.sampled_from([32, 128, 256, 1152]),
       cols=st.sampled_from([64, 128, 256]),
       cell=st.sampled_from([1, 2, 4]))
def test_bind_covers_matrix(r, c, rows, cols, cell):
    arch = get_arch("isaac-baseline",
                    xb=CrossbarTier(xb_size=(rows, cols), cell_precision=cell,
                                    parallel_row=8))
    m = bind((r, c), arch)
    slices = math.ceil(8 / cell)
    cols_per_xb = cols // slices
    assert m.grid_r == math.ceil(r / rows)
    assert m.grid_c == math.ceil(c / cols_per_xb)
    assert 1 <= m.rows_used_last <= rows
    # total capacity >= matrix bits
    assert m.grid_r * rows >= r and m.grid_c * cols_per_xb >= c


def test_eq1_walkthrough_dup_2_to_4():
    """§3.4: 2 cores x 2 xbs, matrix fits one crossbar -> CM dup 2, XBM 4."""
    arch = get_arch("toy")
    g = get_workload("conv_relu_toy")
    res_cm = compiler.compile_graph(g, arch, level="CM")
    res_xbm = compiler.compile_graph(g, arch, level="XBM")
    (p_cm,) = res_cm.plan.placements
    (p_xbm,) = res_xbm.plan.placements
    assert p_cm.mapping.n_xbs == 1          # 27x(32x4slices=128cols) fits
    assert p_cm.dup == 2                    # one copy per core
    assert p_xbm.dup == 4                   # packs both crossbars per core


@pytest.mark.parametrize("preset,wl", [
    ("isaac-baseline", "vgg7"), ("isaac-baseline", "resnet18"),
    ("puma", "vgg7"), ("jia-issc21", "vgg7"), ("jain-jssc21", "tiny_cnn"),
])
def test_budget_and_ordering_invariants(preset, wl):
    arch = get_arch(preset)
    g = get_workload(wl)
    res = compiler.compile_graph(g, arch)
    plan = res.plan
    budget = plan.notes["cg_budget"]
    phys_xbs = arch.chip.n_cores * arch.core.n_xbs
    slot_budget = budget * arch.core.n_xbs
    for seg in plan.segments:
        # XBM+ packing shares cores at crossbar granularity (Eq. 1), so
        # the hard resource bound is crossbar slots, not whole cores
        assert sum(p.dup * p.mapping.n_xbs for p in seg.placements) \
            <= slot_budget
        assert all(p.dup >= 1 for p in seg.placements)
        assert sum(p.dup * p.mapping.n_xbs for p in seg.placements) \
            <= phys_xbs
    ours = perf.estimate(plan)
    noopt = perf.estimate(baselines.no_opt(g, arch))
    poly = perf.estimate(baselines.poly_schedule(g, arch))
    assert ours.latency_cycles <= noopt.latency_cycles + 1e-6
    assert ours.latency_cycles <= poly.latency_cycles + 1e-6
    assert ours.peak_active_xbs <= phys_xbs


def test_multilevel_monotone_isaac_resnet18():
    arch = get_arch("isaac-baseline")
    g = get_workload("resnet18")
    lat = {}
    for level in ("CM", "XBM", "WLM"):
        lat[level] = perf.estimate(
            compiler.compile_graph(g, arch, level=level).plan).latency_cycles
    assert lat["XBM"] <= lat["CM"] + 1e-6
    assert lat["WLM"] <= lat["XBM"] + 1e-6


def test_stagger_reduces_peak_power():
    arch = get_arch("puma")
    g = get_workload("vgg7")
    ours = perf.estimate(compiler.compile_graph(g, arch).plan)
    nat = perf.estimate(baselines.native(g, arch))
    assert ours.peak_active_xbs < nat.peak_active_xbs


def test_level_above_mode_rejected():
    arch = get_arch("jia-issc21")      # CM-only chip
    g = get_workload("tiny_mlp")
    with pytest.raises(ValueError):
        compiler.compile_graph(g, arch, level="XBM")


def test_sram_vs_reram_segmentation_cost():
    """ReRAM writes are ~100x SRAM writes: a model that does not fit must
    cost more (per inference) on the ReRAM variant of the same chip."""
    g = get_workload("vgg7")
    small = get_arch("isaac-baseline",
                     chip=ChipTier(core_number=(4, 2), alu_ops_per_cycle=1024,
                                   l0_bw_bits=8192))
    reram = perf.estimate(compiler.compile_graph(g, small).plan)
    sram_arch = small.replace(
        xb=CrossbarTier(xb_size=(128, 128), dac_bits=1, adc_bits=8,
                        cell_type=CellType.SRAM, cell_precision=2,
                        parallel_row=8))
    sram = perf.estimate(compiler.compile_graph(g, sram_arch).plan)
    assert reram.n_segments > 1      # does not fit -> reloads happen
    assert sram.latency_cycles < reram.latency_cycles


def test_graph_topology_and_shapes():
    g = get_workload("resnet18")
    seen = set()
    for n in g.nodes:
        for t in n.inputs:
            p = g.producer(t)
            assert p is None or p.name in seen
        seen.add(n.name)
    assert g.shapes["fc.out"] == (1000,)
    g2 = Graph.from_dict(g.to_dict())
    assert [n.name for n in g2.nodes] == [n.name for n in g.nodes]


@settings(max_examples=20, deadline=None)
@given(cores=st.sampled_from([4, 16, 64, 256]),
       xbs=st.sampled_from([1, 2, 8]),
       seed=st.integers(0, 100))
def test_duplication_budget_property(cores, xbs, seed):
    import random
    rnd = random.Random(seed)
    arch = get_arch("isaac-baseline",
                    chip=ChipTier(core_number=(cores, 1),
                                  alu_ops_per_cycle=1024, l0_bw_bits=8192),
                    core=CoreTier(xb_number=(xbs, 1), alu_ops_per_cycle=1024,
                                  l1_bw_bits=8192))
    nodes = []
    tin = "input"
    cin = 3
    for i in range(rnd.randint(1, 6)):
        cout = rnd.choice([8, 16, 32, 64])
        nodes.append(Node(f"c{i}", "Conv", [tin], [f"c{i}.out"],
                          {"weight_shape": (cout, cin, 3, 3), "stride": 1,
                           "pad": 1}))
        tin, cin = f"c{i}.out", cout
    g = Graph("rand", nodes, {"input": (3, 16, 16)}, [tin])
    plan = compiler.compile_graph(g, arch).plan
    slot_budget = plan.notes["cg_budget"] * arch.core.n_xbs
    for seg in plan.segments:
        assert sum(p.dup * p.mapping.n_xbs for p in seg.placements) \
            <= slot_budget
