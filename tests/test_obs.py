"""Stack-wide telemetry: registry determinism, zero-overhead-disabled
semantics, compile provenance coverage, and the merged Perfetto
timeline (compiler + executor + DSE + fleet + fault events)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.cimsim import executor
from repro.cimsim.faults import FaultModel, fault_aware_compile
from repro.cimsim.functional import make_input, make_weights
from repro.core import compiler
from repro.core.abstraction import get_arch
from repro.dse import CompileCache, DesignSpace, adaptive_search
from repro.dse.report import search_scorecard
from repro.obs import MetricsRegistry, hooks, metrics, trace
from repro.obs.explain import explain_compile
from repro.obs.trace import (TraceRecorder, load_trace,
                             validate_chrome_trace)
from repro.serving import CimFleet, CimRequest, TenantSpec
from repro.workloads import get_workload

TOY = get_arch("toy")
ISAAC = get_arch("isaac-baseline")
MLP = get_workload("tiny_mlp")


@pytest.fixture
def telemetry():
    """Enable the registry + process-wide trace; always torn down."""
    reg = metrics.enable()
    tr = trace.install()
    try:
        yield reg, tr
    finally:
        metrics.disable()
        trace.uninstall()


def _compile_run(batch=2, seed=0):
    res = compiler.compile_graph(MLP, TOY)
    exe = executor.lower(res.plan, res.program)
    w = make_weights(MLP, seed)
    singles = [make_input(MLP, seed + i) for i in range(batch)]
    x = {t: np.stack([s[t] for s in singles]) for t in singles[0]}
    return exe.run_batch(x, w)


# ------------------------------------------------------------- registry

def test_registry_instruments_and_deterministic_snapshots():
    def feed(reg):
        reg.counter("requests_total", route="xla").inc()
        reg.counter("requests_total", route="xla").inc(2)
        reg.counter("requests_total", route="pallas").inc()
        reg.gauge("pool_bytes", chip="c0").set(512)
        for v in (0.002, 0.04, 3.0):
            reg.histogram("dispatch_s").observe(v)
        return reg
    a, b = feed(MetricsRegistry()), feed(MetricsRegistry())
    assert a.to_json() == b.to_json()       # byte-identical exposition
    snap = a.snapshot()
    assert snap["counters"]['requests_total{route="xla"}'] == 3
    assert snap["counters"]['requests_total{route="pallas"}'] == 1
    assert snap["gauges"]['pool_bytes{chip="c0"}'] == 512
    h = snap["histograms"]["dispatch_s"]
    assert h["count"] == 3 and h["buckets"]["+Inf"] == 3
    assert h["buckets"]["0.01"] == 1        # cumulative le-buckets
    assert h["buckets"]["0.1"] == 2
    with pytest.raises(ValueError, match="cannot decrease"):
        a.counter("requests_total", route="xla").inc(-1)
    assert len(a) == 4                      # 3 counter/gauge series + 1 hist


def test_registry_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("compiles_total", cached=False).inc(2)
    reg.gauge("depth").set(1.5)
    reg.histogram("lat_s", bounds=(0.1, 1.0)).observe(0.05)
    text = reg.to_prometheus()
    assert "# TYPE compiles_total counter" in text
    assert 'compiles_total{cached="False"} 2' in text
    assert "# TYPE depth gauge" in text and "depth 1.5" in text
    assert "# TYPE lat_s histogram" in text
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert 'lat_s_bucket{le="+Inf"} 1' in text
    assert "lat_s_count 1" in text


def test_registry_absorbs_legacy_stat_bundles(tmp_path):
    reg = MetricsRegistry()
    cache = CompileCache(tmp_path / "cc")
    compiler.compile_graph(MLP, TOY, cache=cache)
    compiler.compile_graph(MLP, TOY, cache=cache)
    reg.absorb("compile_cache", cache.stats(), owner="me")
    flat = reg.flat("compile_cache_")
    assert flat['compile_cache_hits{owner="me"}'] == 1
    assert flat['compile_cache_misses{owner="me"}'] == 1
    # executor stats: numeric + bool fields surface, strings are skipped
    res = compiler.compile_graph(MLP, TOY)
    exe = executor.lower(res.plan, res.program)
    reg.absorb("executor", dataclasses.asdict(exe.stats))
    flat = reg.flat("executor_")
    assert flat["executor_cim_nodes"] == 2
    assert flat["executor_streamed"] in (0.0, 1.0)
    assert "executor_kernel_mode" not in flat


def test_flat_prefix_filter():
    reg = MetricsRegistry()
    reg.counter("dse_rounds_total").inc()
    reg.counter("compile_cache_hits_total").inc()
    reg.counter("other_total").inc()
    both = reg.flat(prefix=("compile_cache_", "dse_"))
    assert set(both) == {"compile_cache_hits_total", "dse_rounds_total"}


# ---------------------------------------------- disabled-by-default

def test_disabled_by_default_bitexact_and_zero_events():
    assert metrics.active() is None and trace.get_trace() is None
    executor.clear_lower_cache()
    base = _compile_run()

    reg = metrics.enable()
    tr = trace.install()
    try:
        executor.clear_lower_cache()
        on = _compile_run()
        assert len(reg) > 0 and len(tr) > 0
        n_events = len(tr.events)
        snap = reg.to_json()
    finally:
        metrics.disable()
        trace.uninstall()

    executor.clear_lower_cache()
    off = _compile_run()
    for t in base:
        np.testing.assert_array_equal(base[t], on[t])
        np.testing.assert_array_equal(base[t], off[t])
    # disabled runs add zero events and zero counters to the old sinks
    assert len(tr.events) == n_events
    assert reg.to_json() == snap


# ------------------------------------------------------ one timeline

def test_unified_timeline_roundtrip(tmp_path, telemetry):
    reg, tr = telemetry
    executor.clear_lower_cache()

    # compiler + executor + fault events on the reserved tracks
    _compile_run()
    fault_aware_compile(MLP, TOY, FaultModel(seed=0, stuck_cell_rate=0.02))

    # a DSE rung batch on the dse track
    space = DesignSpace(TOY, arch_axes={"xb.xb_size": [(32, 128),
                                                       (64, 128)]})
    adaptive_search(MLP, space, cache=CompileCache(tmp_path / "cc"),
                    seed=3, batch=2)

    # serving events merge in by handing the fleet the same recorder
    fleet = CimFleet([TenantSpec("mlp", MLP, traffic=1.0)],
                     ISAAC.subarch(8, "isaac-8c"), max_wait_s=0.0,
                     trace=tr)
    reqs = [CimRequest(rid=i, model="mlp", inputs=make_input(MLP, i))
            for i in range(3)]
    assert len(fleet.serve(reqs, now=0.0)) == 3

    validate_chrome_trace(tr.to_dict())
    labels = {ev["args"]["name"]: ev["pid"] for ev in tr.events
              if ev["ph"] == "M" and ev["name"] == "process_name"}
    # distinct Perfetto process rows per tier, plus the serving chip row
    assert {"compiler", "executor", "dse", "chip:isaac-8c"} <= set(labels)
    assert len(set(labels.values())) == len(labels)
    by_pid = {}
    for ev in tr.events:
        if ev["ph"] != "M":
            by_pid.setdefault(ev["pid"], set()).add(ev.get("cat"))
    assert "compile" in by_pid[labels["compiler"]]
    assert "faults" in by_pid[labels["compiler"]]
    assert "executor" in by_pid[labels["executor"]]
    assert "dse" in by_pid[labels["dse"]]
    assert "engine" in by_pid[labels["chip:isaac-8c"]]
    # tenants get their own tids under each track
    exec_tids = {ev["tid"] for ev in tr.events
                 if ev["pid"] == labels["executor"] and ev["ph"] != "M"}
    assert exec_tids and 0 not in exec_tids

    # the compile→dispatch flow arrow shares one id across tracks
    flows = [ev for ev in tr.events if ev["ph"] in ("s", "f")]
    ids = {}
    for ev in flows:
        ids.setdefault(ev["id"], set()).add(ev["ph"])
    assert any(phases == {"s", "f"} for phases in ids.values())

    path = tr.save(tmp_path / "timeline.json")
    loaded = load_trace(path)               # validates on load
    assert loaded["traceEvents"] == tr.to_dict()["traceEvents"]
    # registry saw every tier too
    flat = reg.flat()
    assert any(k.startswith("compiles_total") for k in flat)
    assert any(k.startswith("executor_dispatches_total") for k in flat)
    assert any(k.startswith("dse_jobs_total") for k in flat)
    assert any(k.startswith("fault_compile_attempts_total") for k in flat)


def test_trace_save_is_atomic(tmp_path):
    tr = TraceRecorder()
    tr.complete("compiler", "g", "compile:g", "compile", 0.0, 0.1)
    p = tr.save(tmp_path / "t.json")
    first = p.read_text()
    tr.complete("compiler", "g", "compile:g", "compile", 0.2, 0.1)
    tr.save(p)                              # overwrite in place
    assert p.read_text() != first
    load_trace(p)
    leftovers = [q for q in p.parent.iterdir() if q.suffix == ".tmp"]
    assert leftovers == []                  # temp file renamed, not leaked


def test_validate_counter_and_flow_shapes():
    def ev(**kw):
        base = {"name": "x", "ts": 0, "pid": 1, "tid": 0}
        base.update(kw)
        return {"traceEvents": [
            {"name": "process_name", "ph": "M", "ts": 0, "pid": 1,
             "tid": 0, "args": {"name": "p"}}, base]}
    with pytest.raises(ValueError, match="counter event needs args"):
        validate_chrome_trace(ev(ph="C", args={}))
    with pytest.raises(ValueError, match="must be a number"):
        validate_chrome_trace(ev(ph="C", args={"depth": "high"}))
    with pytest.raises(ValueError, match="must be a number"):
        validate_chrome_trace(ev(ph="C", args={"up": True}))
    validate_chrome_trace(ev(ph="C", args={"depth": 3}))
    with pytest.raises(ValueError, match="needs an 'id'"):
        validate_chrome_trace(ev(ph="s", args={}))
    validate_chrome_trace(ev(ph="f", **{"id": 7, "bp": "e"}))
    tr = TraceRecorder()
    with pytest.raises(ValueError, match="flow phase"):
        tr.flow("X", "c", "t", "n", "cat", 0.0, 1)


def test_serving_shim_reexports_obs_trace():
    import repro.serving.trace as shim
    from repro.obs import trace as obs_trace
    assert shim.TraceRecorder is obs_trace.TraceRecorder
    assert shim.validate_chrome_trace is obs_trace.validate_chrome_trace


# ------------------------------------------------------------ explain

def test_explain_covers_every_resnet18_node():
    report = explain_compile(get_workload("resnet18"), ISAAC)
    assert report.coverage == 1.0           # acceptance bar: 100 %
    assert len(report.rows) == report.meta["nodes"]
    for row in report.rows:
        assert set(report.columns) <= set(row)
    cim = [r for r in report.rows if r["tier"] != "digital"]
    assert len(cim) == report.meta["cim_nodes"]
    assert all(r["xbs"] > 0 and r["grid"] != "-" for r in cim)
    assert report.meta["cache_hit"] is False
    assert report.meta["compile_wall_s"] > 0
    assert report.meta["key"]
    md = report.to_markdown()
    assert "|node" in md and "conv1" in md
    parsed = json.loads(report.to_json())
    assert parsed["meta"]["workload"] == "resnet18"


def test_explain_fault_provenance_and_cache_hit(tmp_path):
    fm = FaultModel(seed=0, stuck_cell_rate=0.02)
    report = explain_compile(MLP, TOY, fault_model=fm)
    assert report.meta["fault_retire_attempts"] >= 1
    assert report.coverage == 1.0
    cache = CompileCache(tmp_path / "cc")
    explain_compile(MLP, TOY, cache=cache)
    again = explain_compile(MLP, TOY, cache=cache)
    assert again.meta["cache_hit"] is True


def test_hooks_capture_compile_provenance_events():
    seen = []
    unsub = hooks.subscribe(lambda kind, payload: seen.append(kind))
    try:
        assert hooks.subscribed()
        compiler.compile_graph(MLP, TOY)
    finally:
        unsub()
    kinds = set(seen)
    assert {"mapping.bind", "mapping.place", "cg.plan",
            "compile.done"} <= kinds
    n = len(seen)
    compiler.compile_graph(MLP, TOY)        # after unsubscribe: silence
    assert len(seen) == n and not hooks.subscribed()


# ------------------------------------------------- satellite counters

def test_cache_and_dse_counters_reach_scorecards(tmp_path, telemetry):
    reg, _ = telemetry
    cache = CompileCache(tmp_path / "cc")
    compiler.compile_graph(MLP, TOY, cache=cache)
    compiler.compile_graph(MLP, TOY, cache=cache)
    flat = reg.flat()
    assert flat['compile_cache_hits_total{layer="memory"}'] == 1
    assert flat["compile_cache_misses_total"] == 1

    space = DesignSpace(TOY, arch_axes={"xb.xb_size": [(32, 128),
                                                       (64, 128)]})
    result = adaptive_search(MLP, space,
                             cache=CompileCache(tmp_path / "dse"),
                             seed=1, batch=2)
    flat = reg.flat()
    assert flat['dse_ask_rounds_total{workload="tiny_mlp"}'] \
        == result.ask_rounds
    assert flat['dse_promotions_total{workload="tiny_mlp"}'] >= 1
    card = search_scorecard(result, "tiny_mlp")
    obs_keys = [k for k in card.meta if k.startswith("obs_")]
    assert any("dse_ask_rounds_total" in k for k in obs_keys)
    assert any("compile_cache_" in k for k in obs_keys)
    metrics.disable()
    clean = search_scorecard(result, "tiny_mlp")
    assert not any(k.startswith("obs_") for k in clean.meta)
