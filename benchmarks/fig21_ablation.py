"""Figure 21 — multi-level ablation on the ISAAC-like Table-3 baseline,
ResNet series.

(a) CG-grained arms (pipeline / duplication / P&D) vs no-opt
    [paper: pipeline 2.3-4.7x, duplication 25.4->3.1x, P&D up to 123x]
(b) +MVM-grained over CG-P&D                [paper: ~1.8x R50, 1.4x R101]
(c) +VVM-grained over +MVM                  [paper: ~10% R50]
(d) normalized peak power: CG vs +MVM       [paper: CG 5-16x, MVM -85%]
"""
from __future__ import annotations

from cim_common import get_arch, run_policy, smoke_subset

NETS = ("resnet18", "resnet34", "resnet50", "resnet101")


def rows():
    arch = get_arch("isaac-baseline")
    out = []
    for wl in smoke_subset(NETS):
        noopt = run_policy(wl, arch, "no_opt")
        pipe = run_policy(wl, arch, "cg_pipe")
        dup = run_policy(wl, arch, "cg_dup")
        pd = run_policy(wl, arch, "ours", level="CM")
        mvm = run_policy(wl, arch, "ours", level="XBM")
        vvm = run_policy(wl, arch, "ours", level="WLM")
        base = noopt.latency_cycles
        out += [
            (f"fig21a_{wl}_cg_pipeline_x", base / pipe.latency_cycles, ""),
            (f"fig21a_{wl}_cg_duplication_x", base / dup.latency_cycles, ""),
            (f"fig21a_{wl}_cg_pd_x", base / pd.latency_cycles, ""),
            (f"fig21b_{wl}_mvm_over_cg_x",
             pd.latency_cycles / mvm.latency_cycles, ""),
            (f"fig21c_{wl}_vvm_over_mvm_x",
             mvm.latency_cycles / vvm.latency_cycles, ""),
            (f"fig21d_{wl}_peak_power_cg_vs_noopt_x",
             pd.peak_active_xbs / max(noopt.peak_active_xbs, 1), ""),
            (f"fig21d_{wl}_peak_power_mvm_reduction_pct",
             100 * (1 - mvm.peak_active_xbs / max(pd.peak_active_xbs, 1)),
             "paper up to 85%"),
        ]
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.3f},{note}")
