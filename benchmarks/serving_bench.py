"""Multi-tenant serving benchmark: one CIM fleet vs per-model sequential
services on the same mixed request trace.

The baseline is the pre-fleet deployment: one standalone
``CimBatchService`` per model (each generously given the *whole* chip),
processing the trace in arrival order and batching only consecutive
same-model runs — all a sequential per-model frontend can do without
reordering traffic.  The fleet routes the same trace through per-tenant
deadline-aware batchers over planner-assigned crossbar partitions, so
interleaved arrivals still fill bucketed batches and ride the
executor's sublinear batch cost.

Both sides are driven on a synthetic burst clock (all requests arrive
at t=0; the clock advances by each measured dispatch): makespan gives
throughput, per-request completion times give p50/p95 tails.  Dispatch
measurements are steady-state (first use of a batch shape warms the jit
cache untimed), and the two systems' outputs are asserted bit-exact
against each other request by request.

Emits ``BENCH_serving.json`` next to this script (override with
``REPRO_BENCH_SERVING_JSON``; under ``REPRO_BENCH_SMOKE=1`` nothing is
written unless the override is set).  The committed JSON is the
regression anchor: multi-tenant throughput must stay >= 2x sequential.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List

import numpy as np

from cim_common import SMOKE, get_arch, get_workload
from repro.cimsim.functional import make_input
from repro.serving import (CimBatchService, CimFleet, CimRequest,
                           TenantSpec, plan_tenancy)
from repro.serving.common import percentile


def _mixed_trace(tenants, n: int) -> List[CimRequest]:
    """Traffic-weighted fair interleave (Bresenham): the arrival pattern
    of many concurrent users — per-model runs stay short, which is
    exactly what starves a sequential per-model frontend of batches."""
    credits = {t.name: 0.0 for t in tenants}
    share = {t.name: t.traffic / sum(t.traffic for t in tenants)
             for t in tenants}
    graphs = {t.name: t.graph for t in tenants}
    out = []
    for i in range(n):
        for name in credits:
            credits[name] += share[name]
        pick = max(credits, key=lambda k: credits[k])
        credits[pick] -= 1.0
        out.append(CimRequest(rid=i, model=pick,
                              inputs=make_input(graphs[pick], i)))
    return out


def _run_sequential(services: Dict[str, CimBatchService],
                    trace: List[CimRequest], max_batch: int):
    """Arrival-order serving through per-model services; returns
    (makespan_s, completion latencies).  Only consecutive same-model
    runs batch (queueing *within* the burst is charged via the clock)."""
    clock, lat = 0.0, []
    i = 0
    while i < len(trace):
        j = i
        while (j < len(trace) and trace[j].model == trace[i].model
               and j - i < max_batch):
            j += 1
        batch = trace[i:j]
        dt = services[batch[0].model].dispatch(batch)
        clock += dt
        lat.extend([clock] * len(batch))
        i = j
    return clock, lat


def _run_fleet(fleet: CimFleet, trace: List[CimRequest]):
    """Burst-clock fleet serving; returns (makespan_s, latencies)."""
    for r in trace:
        fleet.submit_request(r, now=0.0)
    clock, lat = 0.0, []
    while fleet.pending:
        before = {n: fleet.pool[n].stats.serve_s for n in fleet.pool.names}
        done = fleet.step(now=clock, force=True)
        assert done, "fleet.step(force=True) must make progress"
        step_s = sum(fleet.pool[n].stats.serve_s - before[n]
                     for n in fleet.pool.names)
        clock += step_s
        lat.extend(clock - r.arrival_s for r in done)
    return clock, lat


def _measure_cell(tag: str, tenants: List[TenantSpec], arch,
                  n_requests: int, max_batch: int = 8) -> dict:
    plan = plan_tenancy(tenants, arch)
    fleet = CimFleet(tenants, arch, plan=plan, max_wait_s=0.0,
                     buckets=tuple(b for b in (1, 2, 4, 8)
                                   if b <= max_batch))
    services = {t.name: CimBatchService(t.graph, arch, max_batch=max_batch)
                for t in tenants}

    fleet_trace = _mixed_trace(tenants, n_requests)
    seq_trace = _mixed_trace(tenants, n_requests)

    fleet_s, fleet_lat = _run_fleet(fleet, fleet_trace)
    seq_s, seq_lat = _run_sequential(services, seq_trace, max_batch)

    graphs = {t.name: t.graph for t in tenants}
    bit_exact = True
    for a, b in zip(sorted(fleet_trace, key=lambda r: r.rid),
                    sorted(seq_trace, key=lambda r: r.rid)):
        for t in graphs[a.model].outputs:
            if not np.array_equal(a.outputs[t], b.outputs[t]):
                bit_exact = False
    agg = fleet.stats().aggregate
    return {
        "cell": tag,
        "tenants": [{"name": t.name, "traffic": t.traffic,
                     "resident": plan.tenants[t.name].resident,
                     "replicas": plan.tenants[t.name].replicas,
                     "cores": plan.tenants[t.name].cores}
                    for t in tenants],
        "arch": arch.name,
        "n_requests": n_requests,
        "fleet_makespan_s": round(fleet_s, 4),
        "seq_makespan_s": round(seq_s, 4),
        "speedup": round(seq_s / fleet_s, 2) if fleet_s > 0 else None,
        "fleet_rps": round(n_requests / fleet_s, 1) if fleet_s > 0 else None,
        "seq_rps": round(n_requests / seq_s, 1) if seq_s > 0 else None,
        "fleet_p50_ms": round(percentile(fleet_lat, 50) * 1e3, 3),
        "fleet_p95_ms": round(percentile(fleet_lat, 95) * 1e3, 3),
        "seq_p50_ms": round(percentile(seq_lat, 50) * 1e3, 3),
        "seq_p95_ms": round(percentile(seq_lat, 95) * 1e3, 3),
        "fleet_batches": agg.batches,
        "xbs_used": plan.xbs_used,
        "xbs_chip": arch.chip.n_cores * arch.core.n_xbs,
        "bit_exact": bit_exact,
    }


def cells() -> list:
    chip12 = get_arch("isaac-baseline").subarch(12, "isaac-12c")
    out = [_measure_cell(
        "tiny_cnn+tiny_mlp+toy/isaac-12c",
        [TenantSpec("tiny_cnn", get_workload("tiny_cnn"), traffic=2.0),
         TenantSpec("tiny_mlp", get_workload("tiny_mlp"), traffic=1.0),
         TenantSpec("conv_toy", get_workload("conv_relu_toy"),
                    traffic=1.0)],
        chip12, n_requests=24 if SMOKE else 64)]
    if not SMOKE:
        # conv workloads, where executor batch cost is strongly sublinear
        # (committed BENCH_simulator.json: resnet18@16 batch8 = 1.87x
        # batch1).  Compute-bound f32-exact matmul stacks (ViT on CPU)
        # scale ~linearly with batch, so the fleet's win there is
        # co-residency and routing, not batching — the bit-exactness of
        # that case is covered by examples/serve_cim_fleet.py.
        out.append(_measure_cell(
            "resnet18@16+vgg7@16+tiny_cnn/isaac",
            [TenantSpec("resnet18", get_workload("resnet18", in_hw=16),
                        traffic=2.0),
             TenantSpec("vgg7", get_workload("vgg7", in_hw=16),
                        traffic=1.0),
             TenantSpec("tiny_cnn", get_workload("tiny_cnn"),
                        traffic=1.0)],
            get_arch("isaac-baseline"), n_requests=48))
    return out


def rows():
    data = {"schema": 1, "smoke": SMOKE, "cells": cells()}
    path = os.environ.get("REPRO_BENCH_SERVING_JSON")
    if path or not SMOKE:
        path = Path(path) if path else \
            Path(__file__).resolve().parent / "BENCH_serving.json"
        path.write_text(json.dumps(data, indent=2) + "\n", encoding="utf-8")
    out = []
    for c in data["cells"]:
        tag = c["cell"].split("/")[0].replace("+", "_").replace("@", "")
        out.append((f"serve_fleet_{tag}_rps", c["fleet_rps"],
                    "multi-tenant fleet"))
        out.append((f"serve_seq_{tag}_rps", c["seq_rps"],
                    "sequential per-model"))
        out.append((f"serve_speedup_{tag}_x", c["speedup"],
                    ">=2x anchor (committed full run)"))
        out.append((f"serve_fleet_{tag}_p95_ms", c["fleet_p95_ms"],
                    "burst completion tail"))
        out.append((f"serve_seq_{tag}_p95_ms", c["seq_p95_ms"], ""))
        out.append((f"serve_bit_exact_{tag}", float(c["bit_exact"]),
                    "fleet == sequential outputs"))
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.4g},{note}")
