"""Multi-tenant serving benchmark: one CIM fleet vs per-model sequential
services on the same mixed request trace, plus the cross-chip cluster
under synthetic diurnal+bursty traffic with injected tenant-mix drift.

The baseline is the pre-fleet deployment: one standalone
``CimBatchService`` per model (each generously given the *whole* chip),
processing the trace in arrival order and batching only consecutive
same-model runs — all a sequential per-model frontend can do without
reordering traffic.  The fleet routes the same trace through per-tenant
deadline-aware batchers over planner-assigned crossbar partitions, so
interleaved arrivals still fill bucketed batches and ride the
executor's sublinear batch cost.

Both sides are driven on a synthetic burst clock (all requests arrive
at t=0; the clock advances by each measured dispatch): makespan gives
throughput, per-request completion times give p50/p95 tails.  Dispatch
measurements are steady-state (first use of a batch shape warms the jit
cache untimed), and the two systems' outputs are asserted bit-exact
against each other request by request.

The fleet-scale cell drives a 2-chip ``CimCluster`` with a
million-user-shaped synthetic trace (diurnal + bursts, compressed to
benchmark size) whose tenant mix *drifts* mid-run: the cluster's
control loop must detect the drift, re-plan and migrate, and its
post-recovery throughput must reach >= 90% of a fresh oracle cluster
planned directly for the true post-drift mix (asserted here).  The
cluster clock model treats chips as parallel hardware: per round the
synthetic clock advances by the *max* per-chip busy delta, so the
fleet-vs-single-chip throughput comparison is meaningful on one CPU.
A Chrome trace of the run is emitted next to the JSON (override with
``REPRO_BENCH_SERVING_TRACE``) and schema-validated in-process.

Emits ``BENCH_serving.json`` next to this script (override with
``REPRO_BENCH_SERVING_JSON``; under ``REPRO_BENCH_SMOKE=1`` nothing is
written unless the override is set).  The committed JSON is the
regression anchor: multi-tenant throughput must stay >= 2x sequential,
cluster recovery >= 0.9x oracle, and cluster throughput >= the
single-chip baseline.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List

import numpy as np

from cim_common import SMOKE, get_arch, get_workload
from repro.cimsim.functional import make_input
from repro.serving import (CimBatchService, CimCluster, CimFleet,
                           CimRequest, ReplanPolicy, TenantSpec,
                           TraceRecorder, TrafficModel, plan_fleet,
                           plan_tenancy, synthetic_trace,
                           validate_chrome_trace)
from repro.serving.common import percentile


def _mixed_trace(tenants, n: int) -> List[CimRequest]:
    """Traffic-weighted fair interleave (Bresenham): the arrival pattern
    of many concurrent users — per-model runs stay short, which is
    exactly what starves a sequential per-model frontend of batches."""
    credits = {t.name: 0.0 for t in tenants}
    share = {t.name: t.traffic / sum(t.traffic for t in tenants)
             for t in tenants}
    graphs = {t.name: t.graph for t in tenants}
    out = []
    for i in range(n):
        for name in credits:
            credits[name] += share[name]
        pick = max(credits, key=lambda k: credits[k])
        credits[pick] -= 1.0
        out.append(CimRequest(rid=i, model=pick,
                              inputs=make_input(graphs[pick], i)))
    return out


def _run_sequential(services: Dict[str, CimBatchService],
                    trace: List[CimRequest], max_batch: int):
    """Arrival-order serving through per-model services; returns
    (makespan_s, completion latencies).  Only consecutive same-model
    runs batch (queueing *within* the burst is charged via the clock)."""
    clock, lat = 0.0, []
    i = 0
    while i < len(trace):
        j = i
        while (j < len(trace) and trace[j].model == trace[i].model
               and j - i < max_batch):
            j += 1
        batch = trace[i:j]
        dt = services[batch[0].model].dispatch(batch)
        clock += dt
        lat.extend([clock] * len(batch))
        i = j
    return clock, lat


def _run_fleet(fleet: CimFleet, trace: List[CimRequest]):
    """Burst-clock fleet serving; returns (makespan_s, latencies)."""
    for r in trace:
        fleet.submit_request(r, now=0.0)
    clock, lat = 0.0, []
    while fleet.pending:
        before = {n: fleet.pool[n].stats.serve_s for n in fleet.pool.names}
        done = fleet.step(now=clock, force=True)
        assert done, "fleet.step(force=True) must make progress"
        step_s = sum(fleet.pool[n].stats.serve_s - before[n]
                     for n in fleet.pool.names)
        clock += step_s
        lat.extend(clock - r.arrival_s for r in done)
    return clock, lat


def _measure_cell(tag: str, tenants: List[TenantSpec], arch,
                  n_requests: int, max_batch: int = 8) -> dict:
    plan = plan_tenancy(tenants, arch)
    fleet = CimFleet(tenants, arch, plan=plan, max_wait_s=0.0,
                     buckets=tuple(b for b in (1, 2, 4, 8)
                                   if b <= max_batch))
    services = {t.name: CimBatchService(t.graph, arch, max_batch=max_batch)
                for t in tenants}

    fleet_trace = _mixed_trace(tenants, n_requests)
    seq_trace = _mixed_trace(tenants, n_requests)

    fleet_s, fleet_lat = _run_fleet(fleet, fleet_trace)
    seq_s, seq_lat = _run_sequential(services, seq_trace, max_batch)

    graphs = {t.name: t.graph for t in tenants}
    bit_exact = True
    for a, b in zip(sorted(fleet_trace, key=lambda r: r.rid),
                    sorted(seq_trace, key=lambda r: r.rid)):
        for t in graphs[a.model].outputs:
            if not np.array_equal(a.outputs[t], b.outputs[t]):
                bit_exact = False
    agg = fleet.stats().aggregate
    return {
        "cell": tag,
        "tenants": [{"name": t.name, "traffic": t.traffic,
                     "resident": plan.tenants[t.name].resident,
                     "replicas": plan.tenants[t.name].replicas,
                     "cores": plan.tenants[t.name].cores}
                    for t in tenants],
        "arch": arch.name,
        "n_requests": n_requests,
        "fleet_makespan_s": round(fleet_s, 4),
        "seq_makespan_s": round(seq_s, 4),
        "speedup": round(seq_s / fleet_s, 2) if fleet_s > 0 else None,
        "fleet_rps": round(n_requests / fleet_s, 1) if fleet_s > 0 else None,
        "seq_rps": round(n_requests / seq_s, 1) if seq_s > 0 else None,
        "fleet_p50_ms": round(percentile(fleet_lat, 50) * 1e3, 3),
        "fleet_p95_ms": round(percentile(fleet_lat, 95) * 1e3, 3),
        "seq_p50_ms": round(percentile(seq_lat, 50) * 1e3, 3),
        "seq_p95_ms": round(percentile(seq_lat, 95) * 1e3, 3),
        "fleet_batches": agg.batches,
        "xbs_used": plan.xbs_used,
        "xbs_chip": arch.chip.n_cores * arch.core.n_xbs,
        "bit_exact": bit_exact,
    }


# ---------------------------------------------------------------------------
# Fleet-scale cell: cross-chip cluster under diurnal+bursty drifting traffic.
# ---------------------------------------------------------------------------

def _drive_round(cluster, trace, clock: float, round_s: float):
    """Submit one round's trace on the service clock, drain, and return
    (completed requests, parallel-chips busy delta).  Chips are parallel
    hardware, so the round costs max-over-chips busy seconds."""
    before = cluster.chip_busy_s()
    for r in trace:
        cluster.submit_request(r, now=clock + r.arrival_s)
    done = cluster.drain(now=clock + round_s)
    after = cluster.chip_busy_s()
    busy = max(after[c] - before.get(c, 0.0) for c in after)
    return done, busy


def _measure_fleet_cell(tag: str, n_chips: int = 2) -> dict:
    isaac = get_arch("isaac-baseline")
    chips = {f"chip{i}": isaac.subarch(8, f"isaac-8c-{i}")
             for i in range(n_chips)}
    cnn, mlp = get_workload("tiny_cnn"), get_workload("tiny_mlp")
    graphs = {"tiny_cnn": cnn, "tiny_mlp": mlp}
    # planned for an mlp-heavy mix; traffic drifts to the heavy cnn —
    # exactly the shift that demands more spanning replicas of the
    # expensive tenant, so a stale plan visibly underperforms
    tenants = [TenantSpec("tiny_cnn", cnn, traffic=1.0, priority=1),
               TenantSpec("tiny_mlp", mlp, traffic=3.0, priority=0)]
    assumed = {"tiny_cnn": 1.0, "tiny_mlp": 3.0}   # what the plan expects
    drifted = {"tiny_cnn": 3.0, "tiny_mlp": 1.0}   # what traffic becomes
    # a million-user day compressed into 60s benchmark rounds: the trace
    # keeps the diurnal+burst *shape* at whatever n the benchmark affords
    model = TrafficModel(users=1e6, req_per_user_day=50.0,
                         diurnal_amp=0.6, bursts_per_day=8.0)
    round_s, n_round = 60.0, (32 if SMOKE else 64)
    pre, post, reps = (1, 3, 5) if SMOKE else (2, 4, 7)

    def round_trace(idx: int, shares) -> List[CimRequest]:
        return synthetic_trace(graphs, n_round, round_s, shares=shares,
                               model=model, seed=idx,
                               rid_base=idx * n_round)

    recorder = TraceRecorder()
    cluster = CimCluster(
        tenants, chips, max_wait_s=0.0, trace=recorder,
        policy=ReplanPolicy(ewma_alpha=0.7, drift_threshold=0.4,
                            min_requests=8))
    # phase 1 — adaptation: drive the mix drift through the control
    # loop until the cluster has re-planned onto the true mix
    clock = 0.0
    for idx in range(pre + post):
        shares = assumed if idx < pre else drifted
        done, _ = _drive_round(cluster, round_trace(idx, shares),
                               clock, round_s)
        assert len(done) == n_round, "cluster dropped requests"
        clock += round_s
        cluster.control(now=clock)
    assert cluster.migrations >= 1, "drift never triggered a re-plan"

    # phase 2 — paired measurement: the *same* post-drift round through
    # the recovered cluster, a fresh oracle cluster planned directly
    # for the true mix, and a single-chip fleet, back to back; medians
    # of the paired busy-time ratios cancel machine noise that dwarfs
    # any single round's wall-clock timing at this workload size
    o_tenants = [TenantSpec(n, graphs[n], traffic=drifted[n])
                 for n in sorted(graphs)]
    oracle = CimCluster(o_tenants, chips,
                        plan=plan_fleet(o_tenants, chips), max_wait_s=0.0)
    single = CimFleet(o_tenants, chips["chip0"], max_wait_s=0.0)
    warm = round_trace(pre + post, drifted)          # untimed warm pass
    _drive_round(oracle, warm, 0.0, round_s)
    single.serve(round_trace(pre + post, drifted), now=0.0)
    ratios_oracle, ratios_single = [], []
    c_busy_total, o_busy_total, s_busy_total = 0.0, 0.0, 0.0
    bit_exact = True
    o_clock = 0.0
    for rep in range(reps):
        idx = pre + post + 1 + rep
        # min-of-k per side, rotating the run order each pass:
        # scheduler/GC outliers on sub-ms dispatches would dominate any
        # single timing, and a fixed order would hand whichever system
        # runs first the cache-cold slot every time
        busy_c = busy_o = busy_s = float("inf")
        for k in range(3):
            results = {}

            def run_c():
                nonlocal clock
                done, b = _drive_round(cluster, round_trace(idx, drifted),
                                       clock, round_s)
                clock += round_s
                results["c"] = (done, b)

            def run_o():
                nonlocal o_clock
                done, b = _drive_round(oracle, round_trace(idx, drifted),
                                       o_clock, round_s)
                o_clock += round_s
                results["o"] = (done, b)

            def run_s():
                before = single.serve_s()
                done = single.serve(round_trace(idx, drifted), now=0.0)
                results["s"] = (done, single.serve_s() - before)

            runners = [run_c, run_o, run_s]
            for j in range(3):
                runners[(j + k) % 3]()
            (done_c, bc), (done_o, bo), (done_s, bs) = \
                results["c"], results["o"], results["s"]
            busy_c, busy_o, busy_s = (min(busy_c, bc), min(busy_o, bo),
                                      min(busy_s, bs))
            if k == 0:
                out_c = {r.rid: r.outputs for r in done_c}
                for ref in list(done_o) + list(done_s):  # same rid+inputs
                    for t in graphs[ref.model].outputs:
                        if not np.array_equal(ref.outputs[t],
                                              out_c[ref.rid][t]):
                            bit_exact = False
        ratios_oracle.append(busy_o / busy_c)
        ratios_single.append(busy_s / busy_c)
        c_busy_total += busy_c
        o_busy_total += busy_o
        s_busy_total += busy_s
    recovered = float(np.median(ratios_oracle))
    vs_single = float(np.median(ratios_single))
    replanned_rps = reps * n_round / c_busy_total
    oracle_rps = reps * n_round / o_busy_total
    single_rps = reps * n_round / s_busy_total
    assert recovered >= 0.9, \
        f"re-planning recovered only {recovered:.2f}x of the oracle plan"
    assert vs_single >= 1.0, \
        f"{n_chips}-chip fleet only {vs_single:.2f}x of single chip"

    validate_chrome_trace(recorder.to_dict())
    trace_path = os.environ.get("REPRO_BENCH_SERVING_TRACE")
    if trace_path or not SMOKE:
        trace_path = Path(trace_path) if trace_path else \
            Path(__file__).resolve().parent / "BENCH_serving_trace.json"
        recorder.save(trace_path)

    return {
        "cell": tag,
        "chips": sorted(chips),
        "n_requests": n_round * (pre + post + 1 + reps),
        "rounds": {"pre_drift": pre, "post_drift": post,
                   "measured_reps": reps, "round_s": round_s,
                   "per_round": n_round},
        "traffic": {"model_users": model.users,
                    "assumed_mix": assumed, "drifted_mix": drifted},
        "migrations": cluster.migrations,
        "fleet_rps": round(replanned_rps, 1),
        "oracle_rps": round(oracle_rps, 1),
        "recovered_ratio": round(recovered, 3),
        "single_chip_rps": round(single_rps, 1),
        "fleet_vs_single_x": round(vs_single, 2),
        "trace_events": len(recorder),
        "bit_exact": bit_exact,
    }


def cells() -> list:
    chip12 = get_arch("isaac-baseline").subarch(12, "isaac-12c")
    out = [_measure_cell(
        "tiny_cnn+tiny_mlp+toy/isaac-12c",
        [TenantSpec("tiny_cnn", get_workload("tiny_cnn"), traffic=2.0),
         TenantSpec("tiny_mlp", get_workload("tiny_mlp"), traffic=1.0),
         TenantSpec("conv_toy", get_workload("conv_relu_toy"),
                    traffic=1.0)],
        chip12, n_requests=24 if SMOKE else 64)]
    if not SMOKE:
        # conv workloads, where executor batch cost is strongly sublinear
        # (committed BENCH_simulator.json: resnet18@16 batch8 = 1.87x
        # batch1).  Compute-bound f32-exact matmul stacks (ViT on CPU)
        # scale ~linearly with batch, so the fleet's win there is
        # co-residency and routing, not batching — the bit-exactness of
        # that case is covered by examples/serve_cim_fleet.py.
        out.append(_measure_cell(
            "resnet18@16+vgg7@16+tiny_cnn/isaac",
            [TenantSpec("resnet18", get_workload("resnet18", in_hw=16),
                        traffic=2.0),
             TenantSpec("vgg7", get_workload("vgg7", in_hw=16),
                        traffic=1.0),
             TenantSpec("tiny_cnn", get_workload("tiny_cnn"),
                        traffic=1.0)],
            get_arch("isaac-baseline"), n_requests=48))
    out.append(_measure_fleet_cell("cluster_drift_2chip/isaac-8c x2"))
    return out


def rows():
    data = {"schema": 1, "smoke": SMOKE, "cells": cells()}
    path = os.environ.get("REPRO_BENCH_SERVING_JSON")
    if path or not SMOKE:
        path = Path(path) if path else \
            Path(__file__).resolve().parent / "BENCH_serving.json"
        path.write_text(json.dumps(data, indent=2) + "\n", encoding="utf-8")
    out = []
    for c in data["cells"]:
        tag = c["cell"].split("/")[0].replace("+", "_").replace("@", "")
        if "recovered_ratio" in c:          # fleet-scale cluster cell
            out.append((f"serve_{tag}_rps", c["fleet_rps"],
                        "cluster post-recovery"))
            out.append((f"serve_{tag}_oracle_rps", c["oracle_rps"],
                        "fresh plan on true mix"))
            out.append((f"serve_{tag}_recovered_x", c["recovered_ratio"],
                        ">=0.9 asserted"))
            out.append((f"serve_{tag}_vs_single_x", c["fleet_vs_single_x"],
                        ">=1 asserted"))
            out.append((f"serve_{tag}_migrations", c["migrations"],
                        "drift re-plans applied"))
            continue
        out.append((f"serve_fleet_{tag}_rps", c["fleet_rps"],
                    "multi-tenant fleet"))
        out.append((f"serve_seq_{tag}_rps", c["seq_rps"],
                    "sequential per-model"))
        out.append((f"serve_speedup_{tag}_x", c["speedup"],
                    ">=2x anchor (committed full run)"))
        out.append((f"serve_fleet_{tag}_p95_ms", c["fleet_p95_ms"],
                    "burst completion tail"))
        out.append((f"serve_seq_{tag}_p95_ms", c["seq_p95_ms"], ""))
        out.append((f"serve_bit_exact_{tag}", float(c["bit_exact"]),
                    "fleet == sequential outputs"))
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.4g},{note}")
