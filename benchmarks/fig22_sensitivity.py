"""Figure 22 — CIM architecture sensitivity on ViT (crossbar 128x256
variant of the Table-3 baseline).

(a) core count 256 -> 1024     [paper: CG speedup 15x -> 30x]
(b) crossbars per core 2 -> 8
(c) crossbar size 64x512 ... 512x64
(d) parallel rows 8 -> 128     [paper: VVM ~20% over MVM at 8 rows]
"""
from __future__ import annotations

from cim_common import get_arch, run_policy, smoke_subset
from repro.core.abstraction import ChipTier, CoreTier, CrossbarTier


def _variant(core_number=(32, 32), xb_number=(2, 4), xb_size=(128, 256),
             parallel_row=8):
    return get_arch("isaac-baseline").replace(
        chip=ChipTier(core_number=core_number, alu_ops_per_cycle=1024,
                      l0_bw_bits=8192),
        core=CoreTier(xb_number=xb_number, alu_ops_per_cycle=1024,
                      l1_bw_bits=8192),
        xb=CrossbarTier(xb_size=xb_size, dac_bits=1, adc_bits=8,
                        cell_precision=2, parallel_row=parallel_row),
    )


def _levels(arch):
    noopt = run_policy("vit", arch, "no_opt")
    base = noopt.latency_cycles
    return {lvl: base / run_policy("vit", arch, "ours",
                                   level=lvl).latency_cycles
            for lvl in ("CM", "XBM", "WLM")}


def rows():
    out = []
    for n in smoke_subset((256, 512, 1024)):
        s = _levels(_variant(core_number=(n // 16, 16)))
        for lvl, x in s.items():
            out.append((f"fig22a_cores{n}_{lvl}_x", x, ""))
    for xbs in smoke_subset((2, 4, 8)):
        s = _levels(_variant(xb_number=(xbs, 1)))
        for lvl, x in s.items():
            out.append((f"fig22b_xbs{xbs}_{lvl}_x", x, ""))
    for size in smoke_subset(((64, 512), (128, 256), (256, 128), (512, 64))):
        s = _levels(_variant(xb_size=size))
        for lvl, x in s.items():
            out.append((f"fig22c_xb{size[0]}x{size[1]}_{lvl}_x", x, ""))
    for pr in smoke_subset((8, 16, 32, 128)):
        s = _levels(_variant(parallel_row=pr))
        out.append((f"fig22d_pr{pr}_vvm_over_mvm_x",
                    s["WLM"] / s["XBM"], "paper ~1.2x at pr=8"))
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.3f},{note}")
