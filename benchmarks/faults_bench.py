"""Fault-injection benchmark: chip-kill failover recovery and the
resnet18 accuracy-vs-fault-rate curve.

Two cells anchor the fault stack (docs/FAULTS.md):

* **failover** — a 2-chip cluster serves mixed traffic; mid-run a
  ``FaultSchedule`` kills one chip.  Zero accepted requests may be
  lost, and the recovered cluster's throughput must reach >= 0.8x an
  *oracle* cluster planned directly for the surviving hardware (the
  fair bound: half the fleet can't match the pre-failure rate, but it
  must match what the survivors could ever do).  Pre-failure and
  post-failure rates are both reported; the paired oracle rounds use
  min-of-k timing with rotated run order so scheduler noise on sub-ms
  dispatches cancels.

* **accuracy curve** — executor-backed top-1 agreement vs the
  fault-free reference on resnet18 across stuck-bitline rates, with
  the fault-aware remapped point alongside the unmitigated one: on the
  exact-ADC isaac abstraction remapping recovers agreement exactly
  (asserted), while the unmitigated curve visibly degrades.

Emits ``BENCH_faults.json`` next to this script (override with
``REPRO_BENCH_FAULTS_JSON``; under ``REPRO_BENCH_SMOKE=1`` nothing is
written unless the override is set).  The committed JSON is the
regression anchor: ``rows()`` re-asserts its failover row (lost == 0,
recovered >= 0.8x oracle) on every benchmark run, so a regression in
the committed numbers fails CI even before re-measurement.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import List

import numpy as np

from cim_common import SMOKE, get_arch, get_workload
from repro.cimsim.faults import FaultModel, accuracy_under_faults
from repro.cimsim.functional import make_input
from repro.serving import (ChipFault, CimCluster, CimRequest,
                           FaultSchedule, TenantSpec, TraceRecorder)

HERE = Path(__file__).resolve().parent


def _round_trace(graphs, n: int, round_s: float, idx: int) -> List[CimRequest]:
    """Deterministic interleaved arrivals spread over one round."""
    names = sorted(graphs)
    out = []
    for i in range(n):
        name = names[i % len(names)]
        rid = idx * n + i
        out.append(CimRequest(rid=rid, model=name,
                              inputs=make_input(graphs[name], rid),
                              arrival_s=i * round_s / n))
    return out


def _drive_round(cluster, trace, clock: float, round_s: float):
    """Submit + drain one round; returns (done, max-over-chips busy)."""
    before = cluster.chip_busy_s()
    for r in trace:
        cluster.submit_request(r, now=clock + r.arrival_s)
    done = cluster.drain(now=clock + round_s)
    after = cluster.chip_busy_s()
    busy = max(after[c] - before.get(c, 0.0) for c in after)
    return done, busy


def failover_cell() -> dict:
    isaac = get_arch("isaac-baseline")
    chips = {"chip0": isaac.subarch(8, "isaac-8c-0"),
             "chip1": isaac.subarch(8, "isaac-8c-1")}
    graphs = {"tiny_cnn": get_workload("tiny_cnn"),
              "tiny_mlp": get_workload("tiny_mlp")}
    tenants = [TenantSpec("tiny_cnn", graphs["tiny_cnn"], traffic=1.0,
                          priority=1),
               TenantSpec("tiny_mlp", graphs["tiny_mlp"], traffic=2.0,
                          priority=0)]
    round_s, n_round = 30.0, (16 if SMOKE else 48)
    pre, reps = (1, 3) if SMOKE else (2, 5)

    kill_at = pre * round_s + round_s / 2          # mid-round, mid-run
    recorder = TraceRecorder()
    cluster = CimCluster(
        tenants, chips, max_wait_s=0.0, trace=recorder,
        faults=FaultSchedule([ChipFault(at_s=kill_at, chip="chip0",
                                        kind="kill")]))
    # oracle: a fresh cluster planned directly for the survivors — the
    # throughput bound the recovered cluster is held to
    oracle = CimCluster(tenants, {"chip1": chips["chip1"]},
                        max_wait_s=0.0)

    clock, submitted, completed = 0.0, 0, 0
    pre_busy = 0.0
    for idx in range(pre):                          # healthy 2-chip phase
        done, busy = _drive_round(cluster, _round_trace(graphs, n_round,
                                                        round_s, idx),
                                  clock, round_s)
        submitted += n_round
        completed += len(done)
        pre_busy += busy
        clock += round_s
    prefail_rps = pre * n_round / pre_busy

    # the kill round: the fault fires mid-round; every accepted request
    # must still complete on the survivor
    done, _ = _drive_round(cluster, _round_trace(graphs, n_round, round_s,
                                                 pre), clock, round_s)
    submitted += n_round
    completed += len(done)
    clock += round_s
    assert cluster.chip_kills == 1 and cluster.failed == {"chip0"}
    lost = submitted - completed

    # paired recovery measurement vs the survivor oracle (min-of-k,
    # rotated order: scheduler outliers on sub-ms dispatches dominate
    # any single timing)
    _drive_round(oracle, _round_trace(graphs, n_round, round_s, pre + 1),
                 0.0, round_s)                      # untimed warm pass
    o_clock, ratios = round_s, []
    c_busy_total = o_busy_total = 0.0
    for rep in range(reps):
        idx = pre + 2 + rep
        busy_c = busy_o = float("inf")
        for k in range(3):
            runs = {}

            def run_c():
                nonlocal clock
                _, b = _drive_round(cluster, _round_trace(graphs, n_round,
                                                          round_s, idx),
                                    clock, round_s)
                clock += round_s
                runs["c"] = b

            def run_o():
                nonlocal o_clock
                _, b = _drive_round(oracle, _round_trace(graphs, n_round,
                                                         round_s, idx),
                                    o_clock, round_s)
                o_clock += round_s
                runs["o"] = b

            runners = [run_c, run_o]
            for j in range(2):
                runners[(j + k) % 2]()
            busy_c, busy_o = min(busy_c, runs["c"]), min(busy_o, runs["o"])
        ratios.append(busy_o / busy_c)
        c_busy_total += busy_c
        o_busy_total += busy_o
    recovered = float(np.median(ratios))
    postfail_rps = reps * n_round / c_busy_total
    oracle_rps = reps * n_round / o_busy_total

    assert lost == 0, f"chip kill lost {lost} accepted requests"
    assert recovered >= 0.8, \
        f"failover recovered only {recovered:.2f}x of the survivor oracle"
    kills = [e for e in recorder.events if e.get("name") == "chip_kill"]
    assert len(kills) == 1

    return {
        "cell": "failover_2chip_kill/isaac-8c x2",
        "rounds": {"pre_kill": pre, "measured_reps": reps,
                   "round_s": round_s, "per_round": n_round},
        "kill_at_s": kill_at,
        "submitted": submitted + reps * n_round * 1,
        "lost": lost,
        "prefail_rps": round(prefail_rps, 1),
        "postfail_rps": round(postfail_rps, 1),
        "oracle_rps": round(oracle_rps, 1),
        "recovered_ratio": round(recovered, 3),
        "evacuated": int(kills[0]["args"]["evacuated"]),
        "trace_events": len(recorder),
    }


def accuracy_cell() -> dict:
    """Top-1 agreement vs the fault-free reference on resnet18 as the
    stuck-bitline rate grows, unmitigated and remapped."""
    arch = get_arch("isaac-baseline")
    g = get_workload("resnet18", in_hw=32, n_classes=16)
    rates = (0.01,) if SMOKE else (0.005, 0.01, 0.02)
    n_inputs = 2 if SMOKE else 4
    curve = []
    for rate in rates:
        model = FaultModel(seed=7, stuck_col_rate=rate,
                           dead_row_rate=rate / 2)
        unmit = accuracy_under_faults(g, arch, model, n_inputs=n_inputs)
        remap = accuracy_under_faults(g, arch, model, n_inputs=n_inputs,
                                      remap=True)
        # exact-ADC isaac: remapping must recover agreement exactly
        assert remap == 1.0, f"remap failed to recover at rate {rate}"
        curve.append({"stuck_col_rate": rate,
                      "unmitigated_top1": round(float(unmit), 4),
                      "remapped_top1": round(float(remap), 4)})
    assert any(p["unmitigated_top1"] < 1.0 for p in curve), \
        "fault rates too low to measure degradation"
    return {"cell": "accuracy_vs_fault_rate/resnet18@32/isaac",
            "workload": "resnet18 in_hw=32 n_classes=16",
            "n_inputs": n_inputs, "curve": curve}


def _check_committed() -> List[tuple]:
    """Re-assert the committed anchor's failover row: the regression
    gate holds even when this run is a trimmed smoke measurement."""
    path = HERE / "BENCH_faults.json"
    data = json.loads(path.read_text(encoding="utf-8"))
    cell = next(c for c in data["cells"] if "recovered_ratio" in c)
    assert cell["lost"] == 0, f"committed anchor lost requests: {cell}"
    assert cell["recovered_ratio"] >= 0.8, \
        f"committed anchor below the 0.8x recovery bar: {cell}"
    acc = next(c for c in data["cells"] if "curve" in c)
    assert all(p["remapped_top1"] == 1.0 for p in acc["curve"]), \
        f"committed accuracy curve lost exact recovery: {acc}"
    return [("faults_committed_recovered_x", cell["recovered_ratio"],
             "committed anchor, >=0.8 asserted"),
            ("faults_committed_lost", float(cell["lost"]),
             "committed anchor, ==0 asserted")]


def rows():
    data = {"schema": 1, "smoke": SMOKE,
            "cells": [failover_cell(), accuracy_cell()]}
    path = os.environ.get("REPRO_BENCH_FAULTS_JSON")
    if path or not SMOKE:
        path = Path(path) if path else HERE / "BENCH_faults.json"
        path.write_text(json.dumps(data, indent=2) + "\n", encoding="utf-8")
    out = []
    fo = data["cells"][0]
    out.append(("faults_failover_prefail_rps", fo["prefail_rps"],
                "2 chips healthy"))
    out.append(("faults_failover_postfail_rps", fo["postfail_rps"],
                "survivor after kill"))
    out.append(("faults_failover_recovered_x", fo["recovered_ratio"],
                ">=0.8 vs survivor oracle, asserted"))
    out.append(("faults_failover_lost", float(fo["lost"]), "==0 asserted"))
    acc = data["cells"][1]
    for p in acc["curve"]:
        r = p["stuck_col_rate"]
        out.append((f"faults_top1_rate{r}_unmitigated",
                    p["unmitigated_top1"], "vs fault-free reference"))
        out.append((f"faults_top1_rate{r}_remapped",
                    p["remapped_top1"], "==1.0 asserted (exact ADC)"))
    out.extend(_check_committed())
    return out


if __name__ == "__main__":
    print("name,value,note")
    for name, val, note in rows():
        print(f"{name},{val:.4g},{note}")
