"""Functional-simulation benchmark: op-by-op interpreter vs the
trace-lowered batched executor (cimsim.executor), single-inference and
batched — plus the streamed multi-segment cell (weight-update streaming
vs the interpreter walk it replaces) and per-route ``cim_mvm_tiles``
kernel timings from the backend registry.

Emits ``BENCH_simulator.json`` next to this script (override the path
with ``REPRO_BENCH_SIM_JSON``; under ``REPRO_BENCH_SMOKE=1`` nothing is
written unless the override is set) so future PRs can regress-check the
perf trajectory: the executor must stay >=10x faster than the
interpreter on ResNet single-inference, batch=8 must cost <4x batch=1,
and the streamed-segment cell must stay >=5x over the interpreter.

Note the full (non-smoke) run interprets ResNet once op by op — that is
the point being measured and takes a few minutes.
"""
from __future__ import annotations

import json
import os
import statistics
import time
from pathlib import Path

import numpy as np

from cim_common import SMOKE, get_arch, get_workload
from repro.cimsim.functional import (FunctionalSimulator, calibrate_shifts,
                                     make_input, make_weights)
from repro.cimsim.executor import lower
from repro.core import compiler
from repro.kernels.cim_mvm import cim_mvm_params


def _steady_ms(fn, runs: int) -> float:
    ts = []
    for _ in range(runs):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e3)
    return statistics.median(ts)


def _measure_cell(tag: str, workload, arch, *, interp_runs: int = 1,
                  exec_runs: int = 20, batch_sizes=(1, 2, 8)) -> dict:
    graph = get_workload(workload) if isinstance(workload, str) else workload
    params = cim_mvm_params(arch)
    weights = make_weights(graph, 0)
    x0 = make_input(graph, 0)
    shifts = calibrate_shifts(graph, weights, x0, params)

    # interpreter: expanded flow, one jnp dispatch per crossbar read
    res_i = compiler.compile_graph(graph, arch, expand=True)
    sim = FunctionalSimulator(res_i.plan, res_i.program, weights, shifts,
                              params=params)
    t0 = time.perf_counter()
    for _ in range(interp_runs):
        sim_out = sim.run(x0)
    interp_ms = (time.perf_counter() - t0) * 1e3 / interp_runs

    # executor: lower once, batched dispatches thereafter
    res_e = compiler.compile_graph(graph, arch)
    t0 = time.perf_counter()
    exe = lower(res_e.plan, res_e.program, params=params)
    packed = exe.pack(weights)
    lower_ms = (time.perf_counter() - t0) * 1e3
    out = exe.run(x0, packed=packed, shifts=shifts)   # traces batch=1
    for t in graph.outputs:                            # stays bit-exact
        np.testing.assert_array_equal(out[t], sim_out[t])
    exec_ms = _steady_ms(lambda: exe.run(x0, packed=packed, shifts=shifts),
                         exec_runs)

    batch_ms = {}
    for b in batch_sizes:
        xs = {name: np.stack([make_input(graph, s)[name] for s in range(b)])
              for name in graph.inputs}
        exe.run_batch(xs, packed=packed, shifts=shifts)   # trace this shape
        batch_ms[str(b)] = _steady_ms(
            lambda: exe.run_batch(xs, packed=packed, shifts=shifts),
            exec_runs)

    return {
        "cell": tag,
        "workload": graph.name,
        "arch": arch.name,
        "mode": arch.mode.value,
        "interp_ms": round(interp_ms, 3),
        "exec_ms": round(exec_ms, 3),
        "speedup": round(interp_ms / exec_ms, 1),
        "lower_ms": round(lower_ms, 3),
        "batch_ms": {k: round(v, 3) for k, v in batch_ms.items()},
        "batch8_over_batch1": round(batch_ms["8"] / batch_ms["1"], 3)
        if "8" in batch_ms else None,
        "units": exe.stats.units,
        "dispatches": exe.stats.dispatches,
        "segments": exe.stats.segments,
        "streamed": exe.stats.streamed,
        "swaps": exe.stats.swaps,
        "kernel_mode": exe.stats.kernel_mode,
    }


def _segmented_arch():
    """A chip deliberately too small for tiny workloads: compiles to a
    multi-segment schedule, so the executor's weight-update streaming
    path (traced crossbar-pool swaps) is what gets measured."""
    from repro.core.abstraction import (CellType, ChipTier, CIMArch,
                                        ComputingMode, CoreTier,
                                        CrossbarTier)
    return CIMArch(
        name="wlm-2c-seg", mode=ComputingMode.WLM,
        chip=ChipTier(core_number=(2, 1), alu_ops_per_cycle=64,
                      l0_bw_bits=1024),
        core=CoreTier(xb_number=(1, 1), l1_bw_bits=1024),
        xb=CrossbarTier(xb_size=(32, 32), dac_bits=1, adc_bits=8,
                        cell_type=CellType.SRAM, cell_precision=2,
                        parallel_row=8),
    )


def cells() -> list:
    out = [_measure_cell("tiny_cnn/toy", "tiny_cnn", get_arch("toy"),
                         interp_runs=1 if SMOKE else 3)]
    # streamed multi-segment cell: interpreter walk vs weight-update
    # streaming through the traced executor (the fallback it replaces)
    out.append(_measure_cell(
        "tiny_mlp@seg/wlm-2c" if SMOKE else "tiny_cnn@seg/wlm-2c",
        "tiny_mlp" if SMOKE else "tiny_cnn", _segmented_arch(),
        interp_runs=1 if SMOKE else 3))
    assert out[-1]["streamed"] and out[-1]["segments"] > 1
    if not SMOKE:
        out.append(_measure_cell(
            "resnet18@16/isaac", get_workload("resnet18", in_hw=16),
            get_arch("isaac-baseline")))
    return out


def kernel_backend() -> dict:
    """Per-route ``cim_mvm_tiles`` timings from the backend registry —
    the accelerator rows land here when an accel host runs this."""
    import jax.numpy as jnp
    from repro.kernels import backend
    from repro.kernels.cim_mvm import CimMvmParams, cim_mvm_tiles
    p = CimMvmParams(8, 8, 1, 2, 8, 8)
    rng = np.random.default_rng(0)
    t, m, r, c = (8, 16, 32, 32) if SMOKE else (64, 16, 128, 32)
    xt = jnp.asarray(rng.integers(0, 256, (t, m, r)), jnp.int32)
    wt = jnp.asarray(rng.integers(0, 256, (t, r, c)), jnp.int32)
    platform = backend.detect_platform()
    route_us = {}
    for mode in backend.REGISTRY["cim_mvm_tiles"].modes_on(platform):
        cim_mvm_tiles(xt, wt, p, mode=mode).block_until_ready()   # warm
        us = _steady_ms(
            lambda: cim_mvm_tiles(xt, wt, p, mode=mode).block_until_ready(),
            3) * 1e3
        route_us[mode] = round(us, 1)
    return {"platform": platform,
            "auto_mode": backend.resolve("cim_mvm_tiles").mode,
            "shape": [t, m, r, c], "route_us": route_us}


def rows():
    data = {"schema": 2, "smoke": SMOKE, "cells": cells(),
            "kernel_backend": kernel_backend()}
    path = os.environ.get("REPRO_BENCH_SIM_JSON")
    if path or not SMOKE:
        path = Path(path) if path else \
            Path(__file__).resolve().parent / "BENCH_simulator.json"
        path.write_text(json.dumps(data, indent=2) + "\n", encoding="utf-8")
    out = []
    for c in data["cells"]:
        tag = c["cell"].replace("/", "_").replace("@", "")
        out.append((f"sim_interp_{tag}_ms", c["interp_ms"], "op-by-op"))
        out.append((f"sim_exec_{tag}_ms", c["exec_ms"], "trace-lowered"))
        out.append((f"sim_speedup_{tag}_x", c["speedup"], ""))
        out.append((f"sim_lower_{tag}_ms", c["lower_ms"], "one-time"))
        for b, ms in c["batch_ms"].items():
            out.append((f"sim_exec_{tag}_b{b}_ms", ms, "batched dispatch"))
        if c["batch8_over_batch1"] is not None:
            out.append((f"sim_batch8_cost_{tag}_x", c["batch8_over_batch1"],
                        "<4x = sublinear"))
        if c["streamed"]:
            out.append((f"sim_swaps_{tag}", c["swaps"],
                        "traced weight-pool updates"))
    kb = data["kernel_backend"]
    for mode, us in kb["route_us"].items():
        out.append((f"sim_kernel_tiles_{mode}_us", us,
                    f"{kb['platform']} route"))
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.4g},{note}")
