"""Observability overhead benchmark: the telemetry tax on the executor
hot path, measured honestly (docs/OBSERVABILITY.md).

Two cells anchor the telemetry stack:

* **overhead** — the same warmed trace-lowered executable dispatches
  the same batch with telemetry fully enabled (metrics registry +
  process-wide trace recorder) and fully disabled, paired min-of-k
  with rotated run order so scheduler noise on sub-ms dispatches
  cancels.  The enabled/disabled ratio must stay within the <= 5 %
  acceptance bar, and outputs must be **bit-identical** both ways
  (telemetry never touches numerics — asserted).

* **explain coverage** — ``obs.explain.explain_compile`` on resnet18
  must produce a provenance row for 100 % of the plan's graph nodes
  (the acceptance bar for the provenance report), and carries the
  compile wall seconds it measured.

Emits ``BENCH_obs.json`` next to this script (override with
``REPRO_BENCH_OBS_JSON``; under ``REPRO_BENCH_SMOKE=1`` nothing is
written unless the override is set).  The committed JSON is the
regression anchor: ``rows()`` re-asserts its overhead and coverage
rows on every benchmark run, so a telemetry-tax regression fails CI
even before re-measurement.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import List

import numpy as np

from cim_common import SMOKE, get_arch, get_workload
from repro.cimsim import executor
from repro.cimsim.functional import make_input, make_weights
from repro.core import compiler
from repro.obs import metrics, trace
from repro.obs.explain import explain_compile

HERE = Path(__file__).resolve().parent

#: acceptance bar: enabled telemetry may cost at most this much on the
#: executor hot path (fraction of the disabled dispatch time)
OVERHEAD_BAR = 0.05


def _batched(graph, batch: int):
    singles = [make_input(graph, i) for i in range(batch)]
    return {t: np.stack([s[t] for s in singles]) for t in singles[0]}


def overhead_cell() -> dict:
    """Paired enabled-vs-disabled dispatch timing on one warmed
    executable; min-of-k with rotated order per side."""
    arch = get_arch("isaac-baseline")
    g = get_workload("tiny_cnn")
    # batch stays 32 even under smoke: a sub-500us batch-8 dispatch puts
    # the ~5-10us telemetry cost inside scheduler noise of the 5% bar;
    # smoke only trims the paired measurement rounds.  Rounds are cheap
    # (~1ms per dispatch) and the min is only as good as its sample
    # count — too few pairs lets a load burst land on one side only
    batch = 32
    rounds = 40 if SMOKE else 200

    res = compiler.compile_graph(g, arch)
    executor.clear_lower_cache()
    exe = executor.lower(res.plan, res.program)
    w = make_weights(g, 0)
    x = _batched(g, batch)
    packed = exe.pack(w)
    base = exe.run_batch(x, packed=packed)        # warm the jit, off
    reg = metrics.enable()
    tr = trace.install()
    try:
        on_out = exe.run_batch(x, packed=packed)  # warm telemetry path
    finally:
        metrics.disable()
        trace.uninstall()
    bit_exact = all(np.array_equal(base[t], on_out[t]) for t in base)

    def dispatch_s() -> float:
        t0 = time.perf_counter()
        exe.run_batch(x, packed=packed)
        return time.perf_counter() - t0

    t_on = t_off = float("inf")
    for r in range(rounds):
        # rotate which side gets the cache-cold slot of each pass
        for side in ("on", "off") if r % 2 else ("off", "on"):
            if side == "on":
                metrics.enable(reg)
                trace.install(tr)
                try:
                    t_on = min(t_on, dispatch_s())
                finally:
                    metrics.disable()
                    trace.uninstall()
            else:
                t_off = min(t_off, dispatch_s())

    overhead = t_on / t_off - 1.0
    assert bit_exact, "telemetry changed executor outputs"
    assert overhead <= OVERHEAD_BAR, (
        f"telemetry overhead {overhead:.2%} above the "
        f"{OVERHEAD_BAR:.0%} bar (on {t_on*1e6:.0f}us vs "
        f"off {t_off*1e6:.0f}us)")
    snap = reg.flat()
    return {"cell": "executor_overhead/tiny_cnn/isaac",
            "batch": batch, "rounds": rounds,
            "dispatch_off_us": round(t_off * 1e6, 1),
            "dispatch_on_us": round(t_on * 1e6, 1),
            "overhead_pct": round(overhead * 100, 2),
            "overhead_bar_pct": OVERHEAD_BAR * 100,
            "bit_exact": bool(bit_exact),
            "dispatches_counted": sum(
                v for k, v in snap.items()
                if k.startswith("executor_dispatches_total")),
            "trace_events": len(tr)}


def explain_cell() -> dict:
    """Provenance coverage on resnet18 — every node gets a row."""
    report = explain_compile(get_workload("resnet18"),
                             get_arch("isaac-baseline"))
    assert report.coverage == 1.0, (
        f"explain covered {report.coverage:.0%} of resnet18 nodes")
    return {"cell": "explain_coverage/resnet18/isaac",
            "coverage": report.coverage,
            "nodes": report.meta["nodes"],
            "cim_nodes": report.meta["cim_nodes"],
            "crossbars_used": report.meta["crossbars_used"],
            "compile_wall_s": report.meta["compile_wall_s"]}


def _check_committed() -> List[tuple]:
    """Re-assert the committed anchor: the regression gate holds even
    when this run is a trimmed smoke measurement."""
    path = HERE / "BENCH_obs.json"
    data = json.loads(path.read_text(encoding="utf-8"))
    ov = next(c for c in data["cells"] if "overhead_pct" in c)
    assert ov["overhead_pct"] <= ov["overhead_bar_pct"], \
        f"committed anchor above the overhead bar: {ov}"
    assert ov["bit_exact"], f"committed anchor not bit-exact: {ov}"
    ex = next(c for c in data["cells"] if "coverage" in c)
    assert ex["coverage"] == 1.0, \
        f"committed anchor lost full explain coverage: {ex}"
    return [("obs_committed_overhead_pct", ov["overhead_pct"],
             "committed anchor, <=5 asserted"),
            ("obs_committed_coverage", ex["coverage"],
             "committed anchor, ==1.0 asserted")]


def rows():
    data = {"schema": 1, "smoke": SMOKE,
            "cells": [overhead_cell(), explain_cell()]}
    path = os.environ.get("REPRO_BENCH_OBS_JSON")
    if path or not SMOKE:
        path = Path(path) if path else HERE / "BENCH_obs.json"
        path.write_text(json.dumps(data, indent=2) + "\n", encoding="utf-8")
    out = []
    ov, ex = data["cells"]
    out.append(("obs_dispatch_off_us", ov["dispatch_off_us"],
                "telemetry disabled (min-of-k)"))
    out.append(("obs_dispatch_on_us", ov["dispatch_on_us"],
                "registry + trace enabled (min-of-k)"))
    out.append(("obs_overhead_pct", ov["overhead_pct"],
                "<=5 asserted; bit-exact both ways"))
    out.append(("obs_bitexact", float(ov["bit_exact"]), "==1 asserted"))
    out.append(("obs_trace_events", float(ov["trace_events"]),
                "events recorded during the timed on-passes"))
    out.append(("obs_explain_coverage", ex["coverage"],
                "resnet18 nodes with provenance rows, ==1.0 asserted"))
    out.append(("obs_explain_compile_ms", ex["compile_wall_s"] * 1e3,
                "resnet18 compile wall, measured by the report"))
    out.extend(_check_committed())
    return out


if __name__ == "__main__":
    print("name,value,note")
    for name, val, note in rows():
        print(f"{name},{val:.4g},{note}")
