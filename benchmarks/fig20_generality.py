"""Figure 20 — generality across published CIM accelerators + the
Poly-Schedule comparison.

(a) Jia et al. (CM SRAM chip): CIM-MLC CG-P&D / pipeline-only speedup
    over the native schedule                          [paper: 3.7x / 1.2x]
(b) PUMA (XBM ReRAM chip): peak-power reduction from the staggered MVM
    pipeline                                          [paper: -75%]
(c) Jain et al. (WLM SRAM macro): three-level speedup [paper: 2.3x]
(d) ISAAC-like Table-3 baseline vs Poly-Schedule      [paper: 3.2x,
    cycle reduction -84% (poly) vs -95% (ours)]
"""
from __future__ import annotations

from cim_common import SMOKE, get_arch, run_policy, smoke_subset

# the smoke budget swaps the big VGG for its 7-layer cousin
WL_BIG = "vgg7" if SMOKE else "vgg16"


def rows():
    out = []
    # (a) Jia et al.
    arch = get_arch("jia-issc21")
    nat = run_policy(WL_BIG, arch, "native")
    ours = run_policy(WL_BIG, arch, "ours")
    pipe = run_policy(WL_BIG, arch, "cg_pipe")
    out.append(("fig20a_jia_speedup_pd", nat.latency_cycles / ours.latency_cycles,
                "paper 3.7x"))
    out.append(("fig20a_jia_speedup_pipeline_only",
                nat.latency_cycles / pipe.latency_cycles, "paper 1.2x"))

    # (b) PUMA peak power
    arch = get_arch("puma")
    nat = run_policy(WL_BIG, arch, "native")
    ours = run_policy(WL_BIG, arch, "ours")
    out.append(("fig20b_puma_peak_power_reduction_pct",
                100 * (1 - ours.peak_active_xbs / nat.peak_active_xbs),
                "paper 75%"))
    out.append(("fig20b_puma_speedup",
                nat.latency_cycles / ours.latency_cycles, ""))

    # (c) Jain et al.
    arch = get_arch("jain-jssc21")
    nat = run_policy("vgg7", arch, "native")
    ours = run_policy("vgg7", arch, "ours")
    cg = run_policy("vgg7", arch, "ours", level="CM")
    mvm = run_policy("vgg7", arch, "ours", level="XBM")
    out.append(("fig20c_jain_speedup_full",
                nat.latency_cycles / ours.latency_cycles, "paper 2.3x"))
    out.append(("fig20c_jain_speedup_cg_only",
                nat.latency_cycles / cg.latency_cycles, "paper 1.2x"))
    out.append(("fig20c_jain_speedup_cg_mvm",
                nat.latency_cycles / mvm.latency_cycles, "paper ~1.2x"))

    # (d) Poly-Schedule on the ISAAC-like baseline
    arch = get_arch("isaac-baseline")
    for wl in smoke_subset(("resnet18", "vgg16", "resnet50", "vit"), keep=1):
        noopt = run_policy(wl, arch, "no_opt")
        poly = run_policy(wl, arch, "poly")
        ours = run_policy(wl, arch, "ours")
        out.append((f"fig20d_{wl}_speedup_vs_poly",
                    poly.latency_cycles / ours.latency_cycles,
                    "paper avg 3.2x"))
        out.append((f"fig20d_{wl}_cycle_reduction_poly_pct",
                    100 * (1 - poly.latency_cycles / noopt.latency_cycles),
                    "paper 84%"))
        out.append((f"fig20d_{wl}_cycle_reduction_ours_pct",
                    100 * (1 - ours.latency_cycles / noopt.latency_cycles),
                    "paper 95%"))
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.3f},{note}")
