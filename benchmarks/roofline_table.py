"""§Roofline — render the dry-run records (experiments/dryrun.json) as
the per-(arch x shape x mesh) roofline table."""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

DEFAULT = Path(__file__).resolve().parent.parent / "experiments/dryrun.json"


def load(path=DEFAULT):
    recs = json.loads(Path(path).read_text())
    return sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"]))


def table(path=DEFAULT, mesh="16x16") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck "
        "| useful_flops | roofline_frac | temp_GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(path):
        if r["mesh"] != mesh:
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL: "
                         f"{r.get('error','?')[:60]} | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| {r['bottleneck']} | {r['useful_flops_frac']:.3f} "
            f"| {r['roofline_frac']:.4f} | {r['temp_bytes']/2**30:.2f} |")
    return "\n".join(lines)


def rows(path=DEFAULT):
    out = []
    for r in load(path):
        if r.get("status") != "ok":
            out.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                        float("nan"), "FAIL"))
            continue
        out.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                    r["roofline_frac"],
                    f"bottleneck={r['bottleneck']}"))
    return out


if __name__ == "__main__":
    print(table())
    print()
    print(table(mesh="2x16x16"))
