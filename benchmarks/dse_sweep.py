"""DSE engine section — fig21/fig22-style queries as sweep + frontier.

Reports, for a compact cross-tier space on the Table-3 baseline:
  * feasible point count and Pareto-frontier size;
  * best latency found by the sweep vs the single default compile
    (the sweep should never lose to the default configuration);
  * cold vs warm (disk-cache) sweep wall time and the speedup;
  * exhaustive enumeration vs multi-fidelity successive halving — the
    full-compile reduction and whether both return the same best point;
  * a multi-workload campaign pass through the shared job queue;
  * batched proxy rung throughput: the scalar per-point analytic loop
    vs one ``dse.proxy_vec`` structure-of-arrays pass over a large
    cross-tier space, asserted bit-equal point by point.

The proxy section emits ``BENCH_dse.json`` next to this script
(override the path with ``REPRO_BENCH_DSE_JSON``; under
``REPRO_BENCH_SMOKE=1`` nothing is written unless the override is set)
so future PRs can regress-check the rung's perf trajectory: the batched
pass must stay >= 50x faster than the scalar loop on a >= 1000-point
ResNet-18 space while ranking points identically.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path

from cim_common import SMOKE, get_arch, get_workload
from repro.core import compiler
from repro.dse import (CompileCache, DesignSpace, NodeTensor,
                       pareto_frontier, proxy_metrics_batch, run_campaign,
                       successive_halving, sweep)

SMOKE_NET = "tiny_cnn"


def proxy_rows():
    """Batched vs scalar proxy rung on a large cross-tier space."""
    if SMOKE:
        graph, arch = get_workload(SMOKE_NET), get_arch("toy")
        space = DesignSpace(arch, arch_axes={
            "xb.xb_size": [(32, 128), (64, 128)],
            "xb.cell_precision": [1, 2]})
    else:
        graph = get_workload("resnet18", in_hw=32)
        arch = get_arch("isaac-baseline")
        space = DesignSpace(arch, arch_axes={
            "xb.xb_size": [(64, 64), (96, 96), (128, 128), (192, 192),
                           (256, 256), (512, 512)],
            "xb.cell_precision": [1, 2, 4],
            "xb.dac_bits": [1, 2, 4],
            "core.xb_number": [(2, 2), (2, 4), (4, 4)],
            "chip.core_number": [(8, 8), (16, 16), (32, 32)]})
    points = space.points()

    # Measure the scalar rung (the per-job loop the pre-batching runner
    # executed) and the batched rung in *interleaved* rounds: both sides
    # are single-threaded CPU work, so background machine load slows
    # them proportionally and the per-round ratio stays stable where
    # back-to-back measurement would drift.  One warm-up batched pass
    # (first-touch numpy dispatch), then median per side and median of
    # the per-round speedups.
    nt = NodeTensor.from_graph(graph)
    proxy_metrics_batch(graph, points, arch, node_tensor=nt)
    rounds = 1 if SMOKE else 3
    scalar_runs, batch_runs, ratios = [], [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        scalar = []
        for pt in points:
            try:
                scalar.append((compiler.proxy_metrics(
                    graph, pt.arch_for(arch), **pt.compile_kwargs()), None))
            except Exception as e:
                scalar.append((None, f"{type(e).__name__}: {e}"))
        scalar_runs.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        batch = proxy_metrics_batch(graph, points, arch, node_tensor=nt)
        batch_runs.append(time.perf_counter() - t0)
        ratios.append(scalar_runs[-1] / batch_runs[-1])
    scalar_s = sorted(scalar_runs)[len(scalar_runs) // 2]
    batch_s = sorted(batch_runs)[len(batch_runs) // 2]
    speedup = sorted(ratios)[len(ratios) // 2]

    mismatches = sum(
        1 for i, (m, err) in enumerate(scalar)
        if batch.metrics(i) != m or (err or None) != batch.errors[i])
    assert mismatches == 0, \
        f"batched proxy diverged from scalar on {mismatches} points"

    def best(metrics_of):
        feas = [(metrics_of(i)["latency_cycles"], i)
                for i in range(len(points)) if metrics_of(i) is not None]
        return min(feas)[1] if feas else None

    same_best = best(lambda i: scalar[i][0]) == best(batch.metrics)
    assert same_best, "batched rung would promote a different best point"

    payload = {
        "schema": 1,
        "smoke": SMOKE,
        "workload": graph.name,
        "arch": arch.name,
        "points": len(points),
        "feasible": int(batch.feasible.sum()),
        "scalar_s": round(scalar_s, 4),
        "batched_s": round(batch_s, 4),
        "speedup": round(speedup, 1),
        "points_per_sec": round(len(points) / batch_s, 0),
        "bit_exact": mismatches == 0,
        "best_matches_scalar": bool(same_best),
    }
    path = os.environ.get("REPRO_BENCH_DSE_JSON")
    if path or not SMOKE:
        path = Path(path) if path else \
            Path(__file__).resolve().parent / "BENCH_dse.json"
        path.write_text(json.dumps(payload, indent=2) + "\n",
                        encoding="utf-8")

    return [
        ("dse_proxy_points", float(len(points)),
         f"{payload['feasible']} feasible"),
        ("dse_proxy_scalar_s", scalar_s, "per-point python loop"),
        ("dse_proxy_batched_s", batch_s, "one structure-of-arrays pass"),
        ("dse_proxy_speedup_x", speedup,
         "acceptance: >= 50x non-smoke (median of interleaved rounds)"),
        ("dse_proxy_points_per_s", len(points) / max(batch_s, 1e-9), ""),
        ("dse_proxy_bit_exact", 1.0, "asserted point by point"),
        ("dse_proxy_best_matches_scalar", float(same_best),
         "same promotion decision as the scalar rung"),
    ]


def rows():
    out = []
    if SMOKE:
        graph, arch = get_workload(SMOKE_NET), get_arch("toy")
        space = DesignSpace(arch)
    else:
        graph = get_workload("resnet18", in_hw=32)
        arch = get_arch("isaac-baseline")
        space = DesignSpace(
            arch, arch_axes={"xb.xb_size": [(128, 128), (256, 256)]})

    with tempfile.TemporaryDirectory() as d:
        cache = CompileCache(d)
        t0 = time.perf_counter()
        results = sweep(graph, space, cache=cache)
        cold_s = time.perf_counter() - t0
        cache.drop_memory()
        t0 = time.perf_counter()
        warm = sweep(graph, space, cache=cache)
        warm_s = time.perf_counter() - t0

    ok = [r for r in results if r.ok]
    front = pareto_frontier(ok)
    best = min(r.metrics["latency_cycles"] for r in ok)
    default = next(
        r.metrics["latency_cycles"] for r in ok
        if r.point.level == arch.mode.value
        and r.point.binding == "B->XBC"
        and r.point.use_pipeline and r.point.use_duplication
        and (not r.point.arch_overrides
             or r.point.arch_overrides[0][1] == arch.xb.xb_size))
    assert all(r.cached for r in warm if r.ok), \
        "warm sweep recompiled points that should have been cached"
    assert all(a.metrics == b.metrics for a, b in zip(results, warm)), \
        "warm sweep diverged from cold sweep"

    out.append(("dse_points_feasible", float(len(ok)),
                f"of {len(results)} swept"))
    out.append(("dse_pareto_front_size", float(len(front)), ""))
    out.append(("dse_best_over_default_latency_x",
                default / best, "sweep never loses to default config"))
    out.append(("dse_cold_sweep_s", cold_s, ""))
    out.append(("dse_warm_sweep_s", warm_s, "disk cache, no recompiles"))
    out.append(("dse_warm_speedup_x", cold_s / max(warm_s, 1e-9),
                "acceptance: >= 10x"))

    # --- exhaustive vs successive halving --------------------------------
    best_pt = min(ok, key=lambda r: (r.metrics["latency_cycles"], r.index))
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        sr = successive_halving(graph, space, cache=CompileCache(d))
        halve_s = time.perf_counter() - t0
    match = (sr.best is not None and sr.best.point == best_pt.point)
    out.append(("dse_halving_full_evals", float(sr.full_evals),
                f"of {len(results)} points"))
    out.append(("dse_halving_reduction_x",
                len(results) / max(sr.full_evals, 1),
                "full compiles saved; acceptance: >= 3x"))
    out.append(("dse_halving_best_matches_exhaustive", float(match),
                "1 = same best-latency point"))
    out.append(("dse_halving_cold_s", halve_s, ""))

    # --- multi-workload campaign through the shared queue ----------------
    names = ("tiny_cnn", "tiny_mlp") if SMOKE else ("resnet18", "vgg7")
    kw = {} if SMOKE else {"in_hw": 32}
    graphs = {n: get_workload(n, **kw) for n in names}
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        camp = run_campaign(graphs, space, cache=CompileCache(d))
        camp_s = time.perf_counter() - t0
    out.append(("dse_campaign_workloads", float(len(camp.workloads)), ""))
    out.append(("dse_campaign_full_evals", float(camp.full_evals),
                f"exhaustive would pay {camp.exhaustive_evals}"))
    out.append(("dse_campaign_s", camp_s, "single shared job queue"))
    out.extend(proxy_rows())
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.4g},{note}")
