"""DSE engine section — fig21/fig22-style queries as sweep + frontier.

Reports, for a compact cross-tier space on the Table-3 baseline:
  * feasible point count and Pareto-frontier size;
  * best latency found by the sweep vs the single default compile
    (the sweep should never lose to the default configuration);
  * cold vs warm (disk-cache) sweep wall time and the speedup;
  * exhaustive enumeration vs multi-fidelity successive halving — the
    full-compile reduction and whether both return the same best point;
  * a multi-workload campaign pass through the shared job queue;
  * batched proxy rung throughput: the scalar per-point analytic loop
    vs one ``dse.proxy_vec`` structure-of-arrays pass over a large
    cross-tier space, asserted bit-equal point by point;
  * adaptive vs exhaustive successive halving on the same large space:
    the seeded ask/tell searcher must match halving's best cost while
    paying >= 5x fewer full-fidelity compiles (non-smoke);
  * the shared compile farm: two adaptive campaigns run concurrently
    against one content-addressed store, asserted to report nonzero
    cross-campaign (``foreign_hits``) reuse.

The proxy and adaptive sections emit ``BENCH_dse.json`` next to this
script (override the path with ``REPRO_BENCH_DSE_JSON``; under
``REPRO_BENCH_SMOKE=1`` nothing is written unless the override is set)
so future PRs can regress-check the perf trajectory: the batched pass
must stay >= 50x faster than the scalar loop on a >= 1000-point
ResNet-18 space while ranking points identically, and the adaptive row
must keep ``best_le_halving`` true at >= 5x full-compile reduction.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path

from cim_common import SMOKE, get_arch, get_workload
from repro.core import compiler
from repro.dse import (CompileCache, DesignSpace, NodeTensor,
                       adaptive_search, pareto_frontier,
                       proxy_metrics_batch, run_campaign,
                       successive_halving, sweep)

SMOKE_NET = "tiny_cnn"

#: searcher knobs for the large-space cell — also recorded in the JSON
#: row so the committed numbers are reproducible verbatim
ADAPTIVE_KNOBS = dict(seed=0, batch=512, max_rounds=16, patience=3,
                      gamma=0.2, explore=0.1, prefix_keep=128,
                      full_keep=64)
SMOKE_KNOBS = dict(seed=0, batch=32, max_rounds=8, patience=2,
                   gamma=0.25, explore=0.1, prefix_keep=12, full_keep=4)


def _large_space():
    """The big cross-tier benchmark space (11664-point ResNet-18 space
    non-smoke; a small toy space under ``REPRO_BENCH_SMOKE``)."""
    if SMOKE:
        graph, arch = get_workload(SMOKE_NET), get_arch("toy")
        space = DesignSpace(arch, arch_axes={
            "xb.xb_size": [(32, 128), (64, 128)],
            "xb.cell_precision": [1, 2]})
    else:
        graph = get_workload("resnet18", in_hw=32)
        arch = get_arch("isaac-baseline")
        space = DesignSpace(arch, arch_axes={
            "xb.xb_size": [(64, 64), (96, 96), (128, 128), (192, 192),
                           (256, 256), (512, 512)],
            "xb.cell_precision": [1, 2, 4],
            "xb.dac_bits": [1, 2, 4],
            "core.xb_number": [(2, 2), (2, 4), (4, 4)],
            "chip.core_number": [(8, 8), (16, 16), (32, 32)]})
    return graph, arch, space


def _bench_json_path():
    path = os.environ.get("REPRO_BENCH_DSE_JSON")
    if path or not SMOKE:
        return Path(path) if path else \
            Path(__file__).resolve().parent / "BENCH_dse.json"
    return None


def proxy_rows():
    """Batched vs scalar proxy rung on a large cross-tier space."""
    graph, arch, space = _large_space()
    points = space.points()

    # Measure the scalar rung (the per-job loop the pre-batching runner
    # executed) and the batched rung in *interleaved* rounds: both sides
    # are single-threaded CPU work, so background machine load slows
    # them proportionally and the per-round ratio stays stable where
    # back-to-back measurement would drift.  One warm-up batched pass
    # (first-touch numpy dispatch), then median per side and median of
    # the per-round speedups.
    nt = NodeTensor.from_graph(graph)
    proxy_metrics_batch(graph, points, arch, node_tensor=nt)
    rounds = 1 if SMOKE else 3
    scalar_runs, batch_runs, ratios = [], [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        scalar = []
        for pt in points:
            try:
                scalar.append((compiler.proxy_metrics(
                    graph, pt.arch_for(arch), **pt.compile_kwargs()), None))
            except Exception as e:
                scalar.append((None, f"{type(e).__name__}: {e}"))
        scalar_runs.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        batch = proxy_metrics_batch(graph, points, arch, node_tensor=nt)
        batch_runs.append(time.perf_counter() - t0)
        ratios.append(scalar_runs[-1] / batch_runs[-1])
    scalar_s = sorted(scalar_runs)[len(scalar_runs) // 2]
    batch_s = sorted(batch_runs)[len(batch_runs) // 2]
    speedup = sorted(ratios)[len(ratios) // 2]

    mismatches = sum(
        1 for i, (m, err) in enumerate(scalar)
        if batch.metrics(i) != m or (err or None) != batch.errors[i])
    assert mismatches == 0, \
        f"batched proxy diverged from scalar on {mismatches} points"

    def best(metrics_of):
        feas = [(metrics_of(i)["latency_cycles"], i)
                for i in range(len(points)) if metrics_of(i) is not None]
        return min(feas)[1] if feas else None

    same_best = best(lambda i: scalar[i][0]) == best(batch.metrics)
    assert same_best, "batched rung would promote a different best point"

    payload = {
        "schema": 2,
        "smoke": SMOKE,
        "workload": graph.name,
        "arch": arch.name,
        "points": len(points),
        "feasible": int(batch.feasible.sum()),
        "scalar_s": round(scalar_s, 4),
        "batched_s": round(batch_s, 4),
        "speedup": round(speedup, 1),
        "points_per_sec": round(len(points) / batch_s, 0),
        "bit_exact": mismatches == 0,
        "best_matches_scalar": bool(same_best),
    }
    path = _bench_json_path()
    if path is not None:
        if path.exists():    # keep the adaptive row a prior section wrote
            prior = json.loads(path.read_text(encoding="utf-8"))
            if "adaptive" in prior:
                payload["adaptive"] = prior["adaptive"]
        path.write_text(json.dumps(payload, indent=2) + "\n",
                        encoding="utf-8")

    return [
        ("dse_proxy_points", float(len(points)),
         f"{payload['feasible']} feasible"),
        ("dse_proxy_scalar_s", scalar_s, "per-point python loop"),
        ("dse_proxy_batched_s", batch_s, "one structure-of-arrays pass"),
        ("dse_proxy_speedup_x", speedup,
         "acceptance: >= 50x non-smoke (median of interleaved rounds)"),
        ("dse_proxy_points_per_s", len(points) / max(batch_s, 1e-9), ""),
        ("dse_proxy_bit_exact", 1.0, "asserted point by point"),
        ("dse_proxy_best_matches_scalar", float(same_best),
         "same promotion decision as the scalar rung"),
    ]


def adaptive_rows():
    """Adaptive ask/tell search vs exhaustive halving on the big space,
    plus two campaigns drawing from one shared compile store.

    Non-smoke acceptance (committed to ``BENCH_dse.json``): the adaptive
    searcher's best point costs no more than exhaustive successive
    halving's while issuing >= 5x fewer full-fidelity compiles, and the
    two store-sharing campaigns report nonzero cross-campaign hits.
    """
    import threading

    graph, arch, space = _large_space()
    points = space.points()
    knobs = SMOKE_KNOBS if SMOKE else ADAPTIVE_KNOBS

    # exhaustive successive halving: the fixed-grid reference
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        sr = successive_halving(graph, space,
                                cache=CompileCache(d, memory=False))
        halving_s = time.perf_counter() - t0

    # the learned searcher, cold store
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        ar = adaptive_search(graph, space,
                             cache=CompileCache(d, memory=False), **knobs)
        adaptive_s = time.perf_counter() - t0

    assert ar.best is not None and sr.best is not None
    obj = "latency_cycles"
    best_le = ar.best.metrics[obj] <= sr.best.metrics[obj]
    reduction = sr.full_evals / max(ar.full_evals, 1)
    if not SMOKE:
        assert best_le, (
            f"adaptive best {ar.best.metrics[obj]} worse than halving "
            f"{sr.best.metrics[obj]}")
        assert reduction >= 5.0, \
            f"only {reduction:.1f}x fewer full compiles"

    # two campaigns, one artifact pool: campaign B starts once A has
    # published its first entry, so their execution windows overlap and
    # B's lookups land on entries A paid for (and vice versa once B
    # overtakes) — the cross-campaign reuse the shared store exists for
    with tempfile.TemporaryDirectory() as d:
        store = Path(d) / "store"
        wl = {graph.name: graph}
        camps = {}

        def campaign(tag, wait_for_entry):
            cache = CompileCache(store, owner=tag, memory=False)
            if wait_for_entry:
                deadline = time.time() + 600
                while not any(cache._base.glob("*/*.pkl")):
                    if time.time() > deadline:
                        break
                    time.sleep(0.01)
            camps[tag] = run_campaign(wl, space, mode="adaptive",
                                      cache=cache, adaptive=knobs)
            cache.publish_stats()

        tb = threading.Thread(target=campaign, args=("campB", True))
        tb.start()
        campaign("campA", False)
        tb.join()
        cross_hits = sum(c.cache_stats["foreign_hits"]
                         for c in camps.values())
    assert cross_hits > 0, "store sharing produced no cross-campaign hits"
    for c in camps.values():    # both campaigns still find a winner
        assert all(w.best is not None for w in c.workloads.values())

    row = {
        "workload": graph.name,
        "arch": arch.name,
        "points": len(points),
        "knobs": {k: v for k, v in knobs.items()},
        "proxy_evals": ar.proxy_evals,
        "ask_rounds": ar.ask_rounds,
        "prefix_evals": ar.prefix_evals,
        "full_evals": ar.full_evals,
        "best_cost": ar.best.metrics[obj],
        "best_point": ar.best.point.label(),
        "halving_full_evals": sr.full_evals,
        "halving_best_cost": sr.best.metrics[obj],
        "best_le_halving": bool(best_le),
        "full_eval_reduction_x": round(reduction, 1),
        "adaptive_s": round(adaptive_s, 2),
        "halving_s": round(halving_s, 2),
        "cross_campaign_hits": int(cross_hits),
    }
    path = _bench_json_path()
    if path is not None:
        payload = (json.loads(path.read_text(encoding="utf-8"))
                   if path.exists() else {"schema": 2, "smoke": SMOKE})
        payload["adaptive"] = row
        path.write_text(json.dumps(payload, indent=2) + "\n",
                        encoding="utf-8")

    return [
        ("dse_adaptive_proxy_evals", float(ar.proxy_evals),
         f"of {len(points)} points, {ar.ask_rounds} ask rounds"),
        ("dse_adaptive_full_evals", float(ar.full_evals),
         f"halving paid {sr.full_evals}"),
        ("dse_adaptive_full_eval_reduction_x", reduction,
         "acceptance: >= 5x non-smoke"),
        ("dse_adaptive_best_le_halving", float(best_le),
         "1 = adaptive best cost <= halving best cost"),
        ("dse_adaptive_s", adaptive_s, f"halving: {halving_s:.1f}s"),
        ("dse_shared_store_cross_hits", float(cross_hits),
         "entries one campaign compiled, the other consumed"),
    ]


def rows():
    out = []
    if SMOKE:
        graph, arch = get_workload(SMOKE_NET), get_arch("toy")
        space = DesignSpace(arch)
    else:
        graph = get_workload("resnet18", in_hw=32)
        arch = get_arch("isaac-baseline")
        space = DesignSpace(
            arch, arch_axes={"xb.xb_size": [(128, 128), (256, 256)]})

    with tempfile.TemporaryDirectory() as d:
        cache = CompileCache(d)
        t0 = time.perf_counter()
        results = sweep(graph, space, cache=cache)
        cold_s = time.perf_counter() - t0
        cache.drop_memory()
        t0 = time.perf_counter()
        warm = sweep(graph, space, cache=cache)
        warm_s = time.perf_counter() - t0

    ok = [r for r in results if r.ok]
    front = pareto_frontier(ok)
    best = min(r.metrics["latency_cycles"] for r in ok)
    default = next(
        r.metrics["latency_cycles"] for r in ok
        if r.point.level == arch.mode.value
        and r.point.binding == "B->XBC"
        and r.point.use_pipeline and r.point.use_duplication
        and (not r.point.arch_overrides
             or r.point.arch_overrides[0][1] == arch.xb.xb_size))
    assert all(r.cached for r in warm if r.ok), \
        "warm sweep recompiled points that should have been cached"
    assert all(a.metrics == b.metrics for a, b in zip(results, warm)), \
        "warm sweep diverged from cold sweep"

    out.append(("dse_points_feasible", float(len(ok)),
                f"of {len(results)} swept"))
    out.append(("dse_pareto_front_size", float(len(front)), ""))
    out.append(("dse_best_over_default_latency_x",
                default / best, "sweep never loses to default config"))
    out.append(("dse_cold_sweep_s", cold_s, ""))
    out.append(("dse_warm_sweep_s", warm_s, "disk cache, no recompiles"))
    out.append(("dse_warm_speedup_x", cold_s / max(warm_s, 1e-9),
                "acceptance: >= 10x"))

    # --- exhaustive vs successive halving --------------------------------
    best_pt = min(ok, key=lambda r: (r.metrics["latency_cycles"], r.index))
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        sr = successive_halving(graph, space, cache=CompileCache(d))
        halve_s = time.perf_counter() - t0
    match = (sr.best is not None and sr.best.point == best_pt.point)
    out.append(("dse_halving_full_evals", float(sr.full_evals),
                f"of {len(results)} points"))
    out.append(("dse_halving_reduction_x",
                len(results) / max(sr.full_evals, 1),
                "full compiles saved; acceptance: >= 3x"))
    out.append(("dse_halving_best_matches_exhaustive", float(match),
                "1 = same best-latency point"))
    out.append(("dse_halving_cold_s", halve_s, ""))

    # --- multi-workload campaign through the shared queue ----------------
    names = ("tiny_cnn", "tiny_mlp") if SMOKE else ("resnet18", "vgg7")
    kw = {} if SMOKE else {"in_hw": 32}
    graphs = {n: get_workload(n, **kw) for n in names}
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        camp = run_campaign(graphs, space, cache=CompileCache(d))
        camp_s = time.perf_counter() - t0
    out.append(("dse_campaign_workloads", float(len(camp.workloads)), ""))
    out.append(("dse_campaign_full_evals", float(camp.full_evals),
                f"exhaustive would pay {camp.exhaustive_evals}"))
    out.append(("dse_campaign_s", camp_s, "single shared job queue"))
    out.extend(proxy_rows())
    out.extend(adaptive_rows())
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.4g},{note}")
