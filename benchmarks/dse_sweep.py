"""DSE engine section — fig21/fig22-style queries as sweep + frontier.

Reports, for a compact cross-tier space on the Table-3 baseline:
  * feasible point count and Pareto-frontier size;
  * best latency found by the sweep vs the single default compile
    (the sweep should never lose to the default configuration);
  * cold vs warm (disk-cache) sweep wall time and the speedup;
  * exhaustive enumeration vs multi-fidelity successive halving — the
    full-compile reduction and whether both return the same best point;
  * a multi-workload campaign pass through the shared job queue.
"""
from __future__ import annotations

import tempfile
import time

from cim_common import SMOKE, get_arch, get_workload
from repro.dse import (CompileCache, DesignSpace, pareto_frontier,
                       run_campaign, successive_halving, sweep)

SMOKE_NET = "tiny_cnn"


def rows():
    out = []
    if SMOKE:
        graph, arch = get_workload(SMOKE_NET), get_arch("toy")
        space = DesignSpace(arch)
    else:
        graph = get_workload("resnet18", in_hw=32)
        arch = get_arch("isaac-baseline")
        space = DesignSpace(
            arch, arch_axes={"xb.xb_size": [(128, 128), (256, 256)]})

    with tempfile.TemporaryDirectory() as d:
        cache = CompileCache(d)
        t0 = time.perf_counter()
        results = sweep(graph, space, cache=cache)
        cold_s = time.perf_counter() - t0
        cache.drop_memory()
        t0 = time.perf_counter()
        warm = sweep(graph, space, cache=cache)
        warm_s = time.perf_counter() - t0

    ok = [r for r in results if r.ok]
    front = pareto_frontier(ok)
    best = min(r.metrics["latency_cycles"] for r in ok)
    default = next(
        r.metrics["latency_cycles"] for r in ok
        if r.point.level == arch.mode.value
        and r.point.binding == "B->XBC"
        and r.point.use_pipeline and r.point.use_duplication
        and (not r.point.arch_overrides
             or r.point.arch_overrides[0][1] == arch.xb.xb_size))
    assert all(r.cached for r in warm if r.ok), \
        "warm sweep recompiled points that should have been cached"
    assert all(a.metrics == b.metrics for a, b in zip(results, warm)), \
        "warm sweep diverged from cold sweep"

    out.append(("dse_points_feasible", float(len(ok)),
                f"of {len(results)} swept"))
    out.append(("dse_pareto_front_size", float(len(front)), ""))
    out.append(("dse_best_over_default_latency_x",
                default / best, "sweep never loses to default config"))
    out.append(("dse_cold_sweep_s", cold_s, ""))
    out.append(("dse_warm_sweep_s", warm_s, "disk cache, no recompiles"))
    out.append(("dse_warm_speedup_x", cold_s / max(warm_s, 1e-9),
                "acceptance: >= 10x"))

    # --- exhaustive vs successive halving --------------------------------
    best_pt = min(ok, key=lambda r: (r.metrics["latency_cycles"], r.index))
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        sr = successive_halving(graph, space, cache=CompileCache(d))
        halve_s = time.perf_counter() - t0
    match = (sr.best is not None and sr.best.point == best_pt.point)
    out.append(("dse_halving_full_evals", float(sr.full_evals),
                f"of {len(results)} points"))
    out.append(("dse_halving_reduction_x",
                len(results) / max(sr.full_evals, 1),
                "full compiles saved; acceptance: >= 3x"))
    out.append(("dse_halving_best_matches_exhaustive", float(match),
                "1 = same best-latency point"))
    out.append(("dse_halving_cold_s", halve_s, ""))

    # --- multi-workload campaign through the shared queue ----------------
    names = ("tiny_cnn", "tiny_mlp") if SMOKE else ("resnet18", "vgg7")
    kw = {} if SMOKE else {"in_hw": 32}
    graphs = {n: get_workload(n, **kw) for n in names}
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        camp = run_campaign(graphs, space, cache=CompileCache(d))
        camp_s = time.perf_counter() - t0
    out.append(("dse_campaign_workloads", float(len(camp.workloads)), ""))
    out.append(("dse_campaign_full_evals", float(camp.full_evals),
                f"exhaustive would pay {camp.exhaustive_evals}"))
    out.append(("dse_campaign_s", camp_s, "single shared job queue"))
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.4g},{note}")
