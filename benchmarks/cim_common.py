"""Shared helpers for the CIM benchmark scripts."""
from __future__ import annotations

import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.cimsim import perf                                   # noqa: E402
from repro.core import baselines, compiler                      # noqa: E402
from repro.core.abstraction import get_arch                     # noqa: E402,F401
from repro.workloads import get_workload                        # noqa: E402,F401

#: REPRO_BENCH_SMOKE=1 trims every section to its cheapest workloads so
#: CI can exercise the whole benchmark harness under a small budget.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "").lower() not in ("", "0", "false")


def smoke_subset(workloads, keep: int = 1):
    """First ``keep`` workloads under the smoke budget, all otherwise."""
    return tuple(workloads)[:keep] if SMOKE else tuple(workloads)


def run_policy(workload, arch, policy: str, level=None):
    """policy in {ours, no_opt, native, poly, cg_pipe, cg_dup}."""
    g = get_workload(workload) if isinstance(workload, str) else workload
    if policy == "ours":
        plan = compiler.compile_graph(g, arch, level=level).plan
    elif policy == "no_opt":
        plan = baselines.no_opt(g, arch)
    elif policy == "native":
        plan = baselines.native(g, arch)
    elif policy == "poly":
        plan = baselines.poly_schedule(g, arch)
    elif policy == "cg_pipe":      # pipeline only, no duplication
        plan = compiler.compile_graph(g, arch, level="CM",
                                      use_duplication=False).plan
    elif policy == "cg_dup":       # duplication only, no pipeline
        plan = compiler.compile_graph(g, arch, level="CM",
                                      use_pipeline=False).plan
    else:
        raise ValueError(policy)
    return perf.estimate(plan)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6
