"""Benchmark aggregator — one section per paper table/figure plus the
harness-required roofline table.  Prints ``name,value,note`` CSV."""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))


def main() -> None:
    import dse_sweep
    import faults_bench
    import fig20_generality
    import fig21_ablation
    import fig22_sensitivity
    import kernel_bench
    import obs_bench
    import roofline_table
    import serving_bench
    import simulator_bench

    sections = [
        ("fig20 (generality: Jia/PUMA/Jain/Poly-Schedule)",
         fig20_generality.rows),
        ("fig21 (ResNet multi-level ablation)", fig21_ablation.rows),
        ("fig22 (architecture sensitivity, ViT)", fig22_sensitivity.rows),
        ("kernels (cim_mvm)", kernel_bench.rows),
        ("simulator (interpreter vs trace-lowered executor)",
         simulator_bench.rows),
        ("dse (cross-tier sweep + compile cache)", dse_sweep.rows),
        ("serving (multi-tenant fleet vs sequential services)",
         serving_bench.rows),
        ("faults (injection accuracy + chip-kill failover)",
         faults_bench.rows),
        ("obs (telemetry overhead + explain coverage)", obs_bench.rows),
    ]
    print("name,value,note")
    for title, fn in sections:
        print(f"# --- {title} ---")
        t0 = time.time()
        for name, val, note in fn():
            print(f"{name},{val:.4g},{note}")
        print(f"# ({time.time()-t0:.1f}s)")

    print("# --- roofline (from experiments/dryrun.json) ---")
    try:
        for name, val, note in roofline_table.rows():
            print(f"{name},{val:.4g},{note}")
    except FileNotFoundError:
        print("# run `python -m repro.launch.dryrun --all` first")


if __name__ == "__main__":
    main()
