"""cim_mvm kernel micro-benchmark (interpret mode on CPU; the numbers
locate the oracle/kernel overhead, not TPU performance)."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from cim_common import smoke_subset
from repro.kernels.cim_mvm import cim_mvm, cim_mvm_tiles, CimMvmParams


def rows():
    out = []
    p = CimMvmParams(8, 8, 1, 2, 8, 8)
    rng = np.random.default_rng(0)
    for (m, r, c) in smoke_subset(((64, 128, 128), (128, 1152, 256))):
        x = jnp.asarray(rng.integers(0, 256, (m, r)), jnp.int32)
        w = jnp.asarray(rng.integers(0, 256, (r, c)), jnp.int32)
        for use_kernel, tag in ((True, "pallas_interpret"), (False, "oracle")):
            cim_mvm(x, w, p, use_kernel=use_kernel).block_until_ready()
            t0 = time.time()
            n = 3
            for _ in range(n):
                cim_mvm(x, w, p, use_kernel=use_kernel).block_until_ready()
            us = (time.time() - t0) / n * 1e6
            out.append((f"kernel_{tag}_{m}x{r}x{c}_us", us, ""))

    # executor-style tile batching: T crossbar tiles in one dispatch vs
    # one oracle dispatch per tile (the interpreter's access pattern);
    # shapes mirror real per-node tile sets, where dispatch overhead
    # dominates the small per-tile compute
    for (t_tiles, m, r, c) in smoke_subset(((16, 16, 32, 32),
                                            (64, 16, 128, 32))):
        xt = jnp.asarray(rng.integers(0, 256, (t_tiles, m, r)), jnp.int32)
        wt = jnp.asarray(rng.integers(0, 256, (t_tiles, r, c)), jnp.int32)

        def batched():
            cim_mvm_tiles(xt, wt, p).block_until_ready()

        def per_tile():
            for i in range(t_tiles):
                cim_mvm(xt[i], wt[i], p, use_kernel=False).block_until_ready()

        for fn in (batched, per_tile):
            fn()                      # warm the jit caches
        n = 3
        for fn, tag in ((batched, "tiles_batched"), (per_tile, "tiles_loop")):
            t0 = time.time()
            for _ in range(n):
                fn()
            us = (time.time() - t0) / n * 1e6
            out.append((f"kernel_{tag}_{t_tiles}x{m}x{r}x{c}_us", us, ""))
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.1f},{note}")
