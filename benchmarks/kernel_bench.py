"""cim_mvm kernel micro-benchmark (interpret mode on CPU; the numbers
locate the oracle/kernel overhead, not TPU performance)."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from cim_common import smoke_subset
from repro.kernels.cim_mvm import cim_mvm, CimMvmParams


def rows():
    out = []
    p = CimMvmParams(8, 8, 1, 2, 8, 8)
    rng = np.random.default_rng(0)
    for (m, r, c) in smoke_subset(((64, 128, 128), (128, 1152, 256))):
        x = jnp.asarray(rng.integers(0, 256, (m, r)), jnp.int32)
        w = jnp.asarray(rng.integers(0, 256, (r, c)), jnp.int32)
        for use_kernel, tag in ((True, "pallas_interpret"), (False, "oracle")):
            cim_mvm(x, w, p, use_kernel=use_kernel).block_until_ready()
            t0 = time.time()
            n = 3
            for _ in range(n):
                cim_mvm(x, w, p, use_kernel=use_kernel).block_until_ready()
            us = (time.time() - t0) / n * 1e6
            out.append((f"kernel_{tag}_{m}x{r}x{c}_us", us, ""))
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.1f},{note}")
