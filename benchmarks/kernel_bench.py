"""cim_mvm kernel micro-benchmark across backend-registry routes.

One row per (shape, route) for every route the active platform
supports — ``xla`` (the XLA-compiled oracle, the fast CPU path),
``interpret`` (the Pallas interpreter, validation-only), and
``compiled`` (a real ``pallas_call``) on TPU/GPU hosts.  On CPU the
numbers locate oracle/interpreter overhead, not accelerator
performance.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from cim_common import smoke_subset
from repro.kernels import backend
from repro.kernels.cim_mvm import cim_mvm, cim_mvm_tiles, CimMvmParams

#: every route the registry supports here, benchmarked side by side
ROUTES = backend.REGISTRY["cim_mvm"].modes_on(backend.detect_platform())


def rows():
    out = []
    p = CimMvmParams(8, 8, 1, 2, 8, 8)
    rng = np.random.default_rng(0)
    for (m, r, c) in smoke_subset(((64, 128, 128), (128, 1152, 256))):
        x = jnp.asarray(rng.integers(0, 256, (m, r)), jnp.int32)
        w = jnp.asarray(rng.integers(0, 256, (r, c)), jnp.int32)
        for mode in ROUTES:
            cim_mvm(x, w, p, mode=mode).block_until_ready()   # warm jit
            t0 = time.time()
            n = 3
            for _ in range(n):
                cim_mvm(x, w, p, mode=mode).block_until_ready()
            us = (time.time() - t0) / n * 1e6
            out.append((f"kernel_{mode}_{m}x{r}x{c}_us", us, ""))

    # executor-style tile batching: T crossbar tiles in one dispatch vs
    # one oracle dispatch per tile (the interpreter's access pattern);
    # shapes mirror real per-node tile sets, where dispatch overhead
    # dominates the small per-tile compute
    tiles_mode = backend.resolve("cim_mvm_tiles").mode     # auto route
    for (t_tiles, m, r, c) in smoke_subset(((16, 16, 32, 32),
                                            (64, 16, 128, 32))):
        xt = jnp.asarray(rng.integers(0, 256, (t_tiles, m, r)), jnp.int32)
        wt = jnp.asarray(rng.integers(0, 256, (t_tiles, r, c)), jnp.int32)

        def batched():
            cim_mvm_tiles(xt, wt, p, mode=tiles_mode).block_until_ready()

        def per_tile():
            for i in range(t_tiles):
                cim_mvm(xt[i], wt[i], p, mode="xla").block_until_ready()

        for fn in (batched, per_tile):
            fn()                      # warm the jit caches
        n = 3
        for fn, tag in ((batched, f"tiles_batched_{tiles_mode}"),
                        (per_tile, "tiles_loop")):
            t0 = time.time()
            for _ in range(n):
                fn()
            us = (time.time() - t0) / n * 1e6
            out.append((f"kernel_{tag}_{t_tiles}x{m}x{r}x{c}_us", us, ""))
    return out


if __name__ == "__main__":
    for name, val, note in rows():
        print(f"{name},{val:.1f},{note}")
