"""§Perf hillclimbing driver: measure each optimization lever on the
three chosen cells and log hypothesis -> change -> before -> after.

Cells (chosen per the §Perf rubric):
  * gemma2-2b x train_4k      — most collective-bound baseline
  * qwen1.5-4b x decode_32k   — worst roofline fraction (decode family)
  * mixtral-8x7b x train_4k   — most representative of the paper's
    technique (operator duplication / expert mapping <-> CG duplication)

Run:  PYTHONPATH=src python benchmarks/perf_iterations.py [--cell N]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis import roofline                       # noqa: E402
from repro.configs import get_config                      # noqa: E402
from repro.configs.base import SHAPES                     # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.launch.steps import build_cell                 # noqa: E402
from repro.models.perfopts import PerfOpts                # noqa: E402

CELLS = [("gemma2-2b", "train_4k"), ("qwen1.5-4b", "decode_32k"),
         ("mixtral-8x7b", "train_4k")]

VARIANTS = {
    "baseline": PerfOpts(),
    "reshard": PerfOpts(attn_reshard="auto"),
    "triangular": PerfOpts(triangular_attention=True),
    "reshard+triangular": PerfOpts(attn_reshard="auto",
                                   triangular_attention=True),
    "reshard+tri+dots": PerfOpts(attn_reshard="auto",
                                 triangular_attention=True,
                                 remat_policy="dots"),
    "decode_opt": PerfOpts(decode_opt=True),
    "reshard+tri+dots+moecap": PerfOpts(attn_reshard="auto",
                                        triangular_attention=True,
                                        remat_policy="dots",
                                        moe_capacity_shard=True),
}

OUT = Path(__file__).resolve().parent.parent / "experiments/perf_iterations.json"


def measure(arch, shape_name, variant, opts):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    t0 = time.time()
    with mesh:
        cell = build_cell(cfg, shape, mesh, perf=opts)
        compiled = cell.lower().compile()
        ma = compiled.memory_analysis()
        coll = roofline.parse_collectives(compiled.as_text(), 256)
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "walked_flops": coll["walked_flops"],
           "walked_hbm_bytes": coll["walked_hbm_bytes"],
           "collective_bytes": coll["total_bytes"],
           "collective_count": coll["count"],
           "temp_bytes": int(ma.temp_size_in_bytes),
           "compile_s": round(time.time() - t0, 1)}
    rec.update(roofline.terms(rec, cfg, shape, 256))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", type=int, default=None)
    ap.add_argument("--variant", default=None)
    args = ap.parse_args()
    cells = CELLS if args.cell is None else [CELLS[args.cell]]
    records = json.loads(OUT.read_text()) if OUT.exists() else []
    done = {(r["arch"], r["shape"], r["variant"]) for r in records}
    for arch, shape in cells:
        for variant, opts in VARIANTS.items():
            if args.variant and variant != args.variant:
                continue
            if (arch, shape, variant) in done:
                continue
            print(f"[perf] {arch} x {shape} :: {variant}", flush=True)
            try:
                rec = measure(arch, shape, variant, opts)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "variant": variant,
                       "error": f"{type(e).__name__}: {e}"}
            records.append(rec)
            OUT.parent.mkdir(parents=True, exist_ok=True)
            OUT.write_text(json.dumps(records, indent=1))
            ok = "error" not in rec
            if ok:
                print(f"  compute={rec['compute_s']:.2f}s "
                      f"memory={rec['memory_s']:.2f}s "
                      f"coll={rec['collective_s']:.2f}s "
                      f"frac={rec['roofline_frac']:.5f} "
                      f"temp={rec['temp_bytes']/2**30:.1f}GiB")
            else:
                print("  ERROR:", rec["error"])


if __name__ == "__main__":
    main()
