"""Fault-tolerant training loop.

Scale features (exercised on CPU with reduced configs; designed for the
production mesh):
  * auto-resume: picks up the latest intact checkpoint, including the
    data-iterator state (exact stream position);
  * atomic checkpoints every N steps with retention;
  * elastic restart: restore re-shards onto whatever mesh the relaunch
    has (checkpoint stores logical arrays);
  * NaN/inf guard: skips poisoned updates, counts them, aborts past a
    threshold (rollback point = last checkpoint);
  * loss-spike detection (EMA-relative) with optional rollback;
  * straggler watchdog: logs steps slower than ``straggler_factor`` x
    the running median (on a real pod this feeds the reschedule/restart
    controller; here it logs and counts).
"""
from __future__ import annotations

import dataclasses
import json
import math
import statistics
import time
from pathlib import Path
from typing import Any, Dict, Iterator

import jax

from ..checkpoint import CheckpointManager, restore_resharded
from ..configs.base import ModelConfig, ShapeSpec
from ..models import lm
from ..optim import adamw
from ..launch.steps import build_cell


@dataclasses.dataclass
class TrainerConfig:
    workdir: str
    num_steps: int = 100
    save_every: int = 50
    keep_checkpoints: int = 3
    lr: float = 3e-4
    log_every: int = 10
    nan_limit: int = 10
    spike_factor: float = 4.0
    rollback_on_spike: bool = False
    straggler_factor: float = 3.0
    microbatches: int = 1


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 mesh, tcfg: TrainerConfig, data_iter: Iterator,
                 data_state=None):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.tcfg = tcfg
        self.data = data_iter
        self.data_state = data_state
        self.ckpt = CheckpointManager(Path(tcfg.workdir) / "ckpt",
                                      tcfg.save_every, tcfg.keep_checkpoints)
        self.metrics_path = Path(tcfg.workdir) / "metrics.jsonl"
        self.metrics_path.parent.mkdir(parents=True, exist_ok=True)
        self.cell = build_cell(cfg, shape, mesh, lr=tcfg.lr,
                               microbatches=tcfg.microbatches)
        self.step_fn = self.cell.jitted()
        self.nan_steps = 0
        self.straggler_steps = 0
        self._times: list = []

    # -- state ------------------------------------------------------------
    def init_state(self, seed: int = 0):
        with self.mesh:
            params = lm.init_params(self.cfg, jax.random.PRNGKey(seed))
            params = jax.device_put(params, self.cell.in_shardings[0])
            opt = adamw.adamw_init(params)
            opt = jax.device_put(opt, self.cell.in_shardings[1])
        return params, opt, 0

    def restore_or_init(self, seed: int = 0):
        latest = self.ckpt.latest()
        if latest is None:
            return self.init_state(seed)
        params_like = lm.param_specs(self.cfg)
        opt_like = adamw.adamw_state_specs(params_like)
        (params, opt), extra = restore_resharded(
            latest, (params_like, opt_like),
            (self.cell.in_shardings[0], self.cell.in_shardings[1]))
        step = extra["step"]
        if self.data_state is not None and "data" in extra:
            self.data_state.seed = extra["data"]["seed"]
            self.data_state.step = extra["data"]["step"]
        print(f"[trainer] resumed from {latest} at step {step}")
        return params, opt, step

    # -- loop --------------------------------------------------------------
    def train(self, seed: int = 0) -> Dict[str, Any]:
        params, opt, step = self.restore_or_init(seed)
        ema_loss = None
        last_good = step
        t_wall = time.time()
        while step < self.tcfg.num_steps:
            batch = next(self.data)
            t0 = time.time()
            with self.mesh:
                params_new, opt_new, metrics = self.step_fn(
                    params, opt, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            self._watchdog(step, dt)

            if not math.isfinite(loss):
                # poisoned step: drop the update, keep old state
                self.nan_steps += 1
                self._log(step, {"loss": loss, "event": "nan_skip"})
                if self.nan_steps > self.tcfg.nan_limit:
                    raise RuntimeError(
                        f"{self.nan_steps} non-finite steps; aborting to "
                        f"last checkpoint at step {last_good}")
                step += 1
                continue

            if (ema_loss is not None and self.tcfg.rollback_on_spike
                    and loss > self.tcfg.spike_factor * ema_loss):
                self._log(step, {"loss": loss, "event": "spike_rollback"})
                params, opt, step = self.restore_or_init(seed)
                continue

            params, opt = params_new, opt_new
            ema_loss = loss if ema_loss is None else \
                0.95 * ema_loss + 0.05 * loss
            step += 1
            if step % self.tcfg.log_every == 0 or step == self.tcfg.num_steps:
                self._log(step, {"loss": loss, "ema": ema_loss,
                                 "grad_norm": float(metrics["grad_norm"]),
                                 "step_s": round(dt, 3)})
            extra = {"step": step}
            if self.data_state is not None:
                extra["data"] = self.data_state.to_dict()
            if self.ckpt.maybe_save(step, (params, opt), extra):
                last_good = step
        total = time.time() - t_wall
        final = {"final_loss": ema_loss, "steps": step,
                 "wall_s": round(total, 1), "nan_steps": self.nan_steps,
                 "straggler_steps": self.straggler_steps}
        self._log(step, {"event": "done", **final})
        return final

    def _watchdog(self, step: int, dt: float) -> None:
        self._times.append(dt)
        if len(self._times) > 200:
            self._times = self._times[-100:]
        if len(self._times) >= 10:
            med = statistics.median(self._times)
            if dt > self.tcfg.straggler_factor * med:
                self.straggler_steps += 1
                self._log(step, {"event": "straggler", "step_s": dt,
                                 "median_s": med})

    def _log(self, step: int, rec: Dict) -> None:
        rec = {"step": step, **rec}
        with self.metrics_path.open("a") as f:
            f.write(json.dumps(rec) + "\n")
