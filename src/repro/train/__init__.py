from .trainer import Trainer, TrainerConfig  # noqa
