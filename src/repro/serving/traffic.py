"""Synthetic million-user traffic: diurnal + bursty arrival processes.

Real serving traffic is not a constant-rate trickle: request rates
follow a daily sine (the diurnal cycle of a geographic user base) with
short multiplicative bursts riding on top (launches, retries, thundering
herds).  This module generates deterministic request traces with that
shape so fleet benchmarks and re-planning tests exercise the traffic
the planner will actually face.

``TrafficModel`` describes the population-scale process (users x
per-user rate, diurnal amplitude, burst statistics); ``synthetic_trace``
samples a bounded number of requests from it — the *shape* of a
million-user day compressed into however many requests the benchmark
can afford — by inverse-CDF sampling of the non-homogeneous intensity.

Tenant mix drift is first-class: ``shares`` may be a callable
``t_s -> {tenant: share}``, so a trace can start on the planner's
assumed mix and drift to a different one mid-stream — exactly the
input the cluster's re-planner must detect and chase.

Units and clocks: all times are service-clock **seconds** (``arrival_s``
stamps land on the same caller-chosen clock the fleet runs on);
``TrafficModel.rps`` is requests per second for the *modeled*
population, independent of how many requests are actually sampled.
Determinism: everything is driven by ``numpy.random.default_rng(seed)``
— same seed, same trace.  Thread-safety: pure functions over local rng
state; safe to call from anywhere.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Union

import numpy as np

from .common import CimRequest

#: tenant mix: fixed shares, or a function of service-clock seconds
SharesLike = Union[Dict[str, float], Callable[[float], Dict[str, float]]]


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """Population-scale arrival process: diurnal sine + random bursts.

    The modeled mean rate is ``users * req_per_user_day / day_s``
    requests/second, modulated by a diurnal factor in
    ``[1 - diurnal_amp, 1 + diurnal_amp]`` and multiplied by
    ``burst_mult`` inside burst windows (on average
    ``bursts_per_day`` windows of ``burst_s`` seconds each day).
    """

    users: float = 1_000_000.0          # population size
    req_per_user_day: float = 50.0      # requests per user per day
    day_s: float = 86_400.0             # diurnal period, seconds
    diurnal_amp: float = 0.6            # peak/trough modulation (0..1)
    peak_hour: float = 20.0             # local hour of the diurnal peak
    bursts_per_day: float = 8.0         # expected burst windows per day
    burst_s: float = 600.0              # burst window length, seconds
    burst_mult: float = 3.0             # rate multiplier inside a burst

    def __post_init__(self):
        if not 0.0 <= self.diurnal_amp < 1.0:
            raise ValueError("diurnal_amp must be in [0, 1)")
        if self.burst_mult < 1.0:
            raise ValueError("burst_mult must be >= 1")

    @property
    def mean_rps(self) -> float:
        """Modeled mean request rate (requests/second, whole population)."""
        return self.users * self.req_per_user_day / self.day_s

    def diurnal(self, t_s: float) -> float:
        """Diurnal modulation factor at service-clock second ``t_s``."""
        phase = 2.0 * math.pi * (t_s / self.day_s - self.peak_hour / 24.0)
        return 1.0 + self.diurnal_amp * math.cos(phase)

    def rps(self, t_s: float, burst: bool = False) -> float:
        """Modeled offered load at ``t_s`` (requests/second)."""
        rate = self.mean_rps * self.diurnal(t_s)
        return rate * self.burst_mult if burst else rate


def burst_windows(model: TrafficModel, duration_s: float,
                  rng: np.random.Generator) -> List[tuple]:
    """Sample burst windows over ``[0, duration_s)`` as ``(start_s,
    end_s)`` tuples (Poisson count, uniform starts; deterministic in
    ``rng``)."""
    expect = model.bursts_per_day * duration_s / model.day_s
    n = int(rng.poisson(expect))
    starts = np.sort(rng.uniform(0.0, duration_s, size=n))
    return [(float(s), float(min(s + model.burst_s, duration_s)))
            for s in starts]


def intensity_grid(model: TrafficModel, duration_s: float,
                   rng: np.random.Generator,
                   resolution: int = 2048) -> tuple:
    """(times_s, rps) — the modeled rate profile sampled on a uniform
    grid, bursts included.  The benchmark uses this both to sample
    arrivals and to report the population-scale offered load."""
    t = np.linspace(0.0, duration_s, resolution, endpoint=False)
    rate = np.array([model.rps(ti) for ti in t])
    for lo, hi in burst_windows(model, duration_s, rng):
        rate[(t >= lo) & (t < hi)] *= model.burst_mult
    return t, rate


def _shares_at(shares: SharesLike, t_s: float) -> Dict[str, float]:
    s = shares(t_s) if callable(shares) else shares
    total = sum(s.values())
    if total <= 0:
        raise ValueError(f"tenant shares must sum > 0, got {s}")
    return {k: v / total for k, v in s.items()}


def synthetic_trace(graphs: Dict[str, object], n_requests: int,
                    duration_s: float, *, shares: SharesLike,
                    model: Optional[TrafficModel] = None, seed: int = 0,
                    deadline_s: Optional[float] = None,
                    rid_base: int = 0) -> List[CimRequest]:
    """Sample ``n_requests`` arrivals shaped like a diurnal+bursty day.

    ``graphs`` maps tenant name -> workload graph (inputs are generated
    deterministically per request id via ``cimsim.make_input``);
    ``shares`` fixes the tenant mix (or lets it drift when callable).
    Arrival times are inverse-CDF samples of the model's intensity over
    ``[0, duration_s)`` — the *shape* of the modeled load at whatever
    sample size the caller affords.  ``deadline_s`` (seconds of slack)
    stamps per-request absolute deadlines on the same clock.

    Returns requests sorted by ``arrival_s`` with ``rid`` assigned in
    arrival order starting at ``rid_base``.
    """
    from ..cimsim.functional import make_input
    if n_requests <= 0:
        return []
    model = model or TrafficModel()
    rng = np.random.default_rng(seed)
    t, rate = intensity_grid(model, duration_s, rng)
    cdf = np.cumsum(rate)
    cdf = cdf / cdf[-1]
    # stratified quantiles keep the empirical histogram close to the
    # intensity even for small n; jitter keeps arrivals distinct
    q = (np.arange(n_requests) + rng.uniform(0.2, 0.8, n_requests)) \
        / n_requests
    arrivals = np.interp(q, cdf, t)
    out: List[CimRequest] = []
    names = sorted(graphs)
    for i, arr in enumerate(arrivals):
        share = _shares_at(shares, float(arr))
        probs = np.array([share.get(n, 0.0) for n in names])
        pick = names[int(rng.choice(len(names), p=probs / probs.sum()))]
        rid = rid_base + i
        out.append(CimRequest(
            rid=rid, model=pick, inputs=make_input(graphs[pick], rid),
            arrival_s=float(arr),
            deadline_s=(float(arr) + deadline_s
                        if deadline_s is not None else None)))
    return out
