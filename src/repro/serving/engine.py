"""Tenant engine pool: warm-loaded executables behind a tenancy plan.

The pool turns a ``TenancyPlan`` into running engines — one
``CimBatchService`` per tenant, compiled against that tenant's sub-arch
view (its crossbar partition) and trace-lowered to a jitted executable:

  * **compile warm-load** — every engine compile goes through the shared
    ``dse.CompileCache`` when one is passed, so a fleet restart (or a
    DSE campaign that already compiled the winning point) pays a disk
    read instead of a recompile;
  * **executor reuse** — ``cimsim.executor.lower`` keys its process-wide
    cache by compile content x kernel params, so two tenants serving the
    same (graph, sub-arch, knobs) share one traced executable;
  * **DSE handoff** — ``points_from_campaign`` maps a finished
    ``CampaignResult`` to per-tenant compiler knobs, closing the
    campaign -> fleet loop (the campaign's best point becomes the
    tenant's serving configuration).

Engines pre-trace their bucket shapes on demand (first dispatch per
bucket runs once untimed inside ``CimBatchService.dispatch``), so
steady-state fleet latencies never include jit tracing.

Units and clocks: engine serve times are **wall-clock seconds** (what
``CimBatchService.serve_padded`` measures around the executable);
compile-side costs (weight-write, schedule latency) are **compiler
cycles** and appear only in plan/compile metadata, never in serve
times.  Thread-safety: a pool is built once and then read-only;
individual engines carry mutable ``ServiceStats`` and are not
thread-safe — one fleet (thread) per pool.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

from .cim_service import CimBatchService
from .placement import TenancyPlan


def points_from_campaign(campaign_result) -> Dict[str, Dict]:
    """Per-workload compiler knobs from a DSE ``CampaignResult``.

    Returns ``{workload name: compile_kwargs}`` for every workload whose
    campaign found a feasible best point — feed it to ``EnginePool`` (or
    ``TenantSpec.compile_kwargs``) so each tenant serves its winning
    configuration.  Arch *overrides* of the best point are ignored here:
    tenancy partitions one concrete chip, so only the scheduling knobs
    transfer.
    """
    out: Dict[str, Dict] = {}
    for name, outcome in campaign_result.workloads.items():
        best = getattr(outcome, "best", None)
        if best is not None:
            out[name] = best.point.compile_kwargs()
    return out


class EnginePool:
    """One warm engine per tenant of a ``TenancyPlan``.

    Engines are keyed by tenant name; each serves on its tenant's
    crossbar partition (``plan.subarch(name)``).  Built eagerly in the
    constructor (compiles may hit ``cache``); afterwards the mapping is
    read-only.  Per-engine ``stats`` are mutable and single-threaded.
    """

    def __init__(self, plan: TenancyPlan, *, cache=None, seed: int = 0,
                 max_batch: int = 8, use_executor: bool = True,
                 points: Optional[Dict[str, Dict]] = None):
        self.plan = plan
        self.engines: Dict[str, CimBatchService] = {}
        points = points or {}
        for name, tenant in plan.tenants.items():
            kwargs = dict(tenant.spec.compile_kwargs)
            kwargs.update(points.get(name, {}))
            self.engines[name] = CimBatchService(
                tenant.graph, plan.subarch(name), seed=seed,
                max_batch=max_batch, use_executor=use_executor,
                cache=cache, compile_kwargs=kwargs)

    def __getitem__(self, name: str) -> CimBatchService:
        return self.engines[name]

    def __contains__(self, name: str) -> bool:
        return name in self.engines

    def items(self) -> Iterator[Tuple[str, CimBatchService]]:
        return iter(self.engines.items())

    @property
    def names(self):
        return list(self.engines)
