"""Batched CIM inference service over a trace-lowered executor.

The serving-side consumer of cimsim.executor: compile a workload for a
CIM chip once, lower the meta-operator flow once, then serve request
traffic by stacking queued inputs on the executor's batch axis — one
device dispatch per batch instead of one interpreter walk per request.
``use_executor=False`` keeps the op-by-op interpreter as a
reference/fallback path (same outputs, orders of magnitude slower),
which is also how the service is tested.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from ..core import compiler
from ..core.abstraction import CIMArch
from ..core.graph import Graph
from ..kernels.cim_mvm import CimMvmParams, cim_mvm_params


@dataclasses.dataclass
class CimRequest:
    rid: int
    inputs: Dict[str, np.ndarray]            # unbatched graph inputs
    # filled by the service:
    outputs: Optional[Dict[str, np.ndarray]] = None
    latency_s: float = 0.0


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0
    serve_s: float = 0.0

    @property
    def requests_per_s(self) -> float:
        return self.requests / self.serve_s if self.serve_s > 0 else 0.0


class CimBatchService:
    """Fixed-workload inference service with batched execution.

    Weights default to the deterministic test weights and shifts to one
    reference calibration pass (the §4.1 verification setup); production
    embedders can pass their own ``weights``/``shifts``.
    """

    def __init__(self, graph: Graph, arch: CIMArch, *, level=None,
                 seed: int = 0, max_batch: int = 8,
                 params: Optional[CimMvmParams] = None,
                 weights: Optional[Dict[str, np.ndarray]] = None,
                 shifts: Optional[Dict[str, int]] = None,
                 use_executor: bool = True):
        from ..cimsim.functional import (calibrate_shifts, make_input,
                                         make_weights)
        self.graph = graph
        self.arch = arch
        self.max_batch = max_batch
        self.use_executor = use_executor
        self.params = params or cim_mvm_params(arch)
        self.weights = weights if weights is not None \
            else make_weights(graph, seed)
        self.shifts = shifts if shifts is not None else calibrate_shifts(
            graph, self.weights, make_input(graph, seed), self.params)
        self.stats = ServiceStats()
        self._warmed: set = set()        # batch sizes already jit-traced
        if use_executor:
            from ..cimsim.executor import LoweringError, lower
            res = compiler.compile_graph(graph, arch, level=level)
            try:
                self._exe = lower(res.plan, res.program, params=self.params)
                self._packed = self._exe.pack(self.weights)
            except LoweringError:
                # flow has no bit-exact fast lowering: serve op by op
                self.use_executor = use_executor = False
        if not use_executor:
            from ..cimsim.functional import FunctionalSimulator
            res = compiler.compile_graph(graph, arch, level=level,
                                         expand=True)
            self._sim = FunctionalSimulator(res.plan, res.program,
                                            self.weights, self.shifts,
                                            params=self.params)

    def serve(self, requests: List[CimRequest]) -> List[CimRequest]:
        """Serve ``requests`` in arrival order, ``max_batch`` at a time.

        Each batch is one executor dispatch (ragged final batches just
        trace a second batch shape, cached thereafter).  The first
        dispatch of a new batch shape runs once untimed to warm the jit
        cache, so ``latency_s`` / ``ServiceStats`` report steady-state
        serving cost rather than trace time.
        """
        done: List[CimRequest] = []
        for i in range(0, len(requests), self.max_batch):
            batch = requests[i:i + self.max_batch]
            if self.use_executor and len(batch) not in self._warmed:
                self._serve_batch(batch)
                self._warmed.add(len(batch))
            t0 = time.time()
            self._serve_batch(batch)
            dt = time.time() - t0
            for r in batch:
                r.latency_s = dt
            self.stats.batches += 1
            self.stats.requests += len(batch)
            self.stats.serve_s += dt
            done.extend(batch)
        return done

    def _serve_batch(self, batch: List[CimRequest]) -> None:
        if not self.use_executor:
            for r in batch:
                out = self._sim.run({k: np.asarray(v)
                                     for k, v in r.inputs.items()})
                r.outputs = {t: np.asarray(out[t]) for t in self.graph.outputs}
            return
        stacked = {name: np.stack([np.asarray(r.inputs[name])
                                   for r in batch])
                   for name in self.graph.inputs}
        outs = self._exe.run_batch(stacked, packed=self._packed,
                                   shifts=self.shifts)
        for i, r in enumerate(batch):
            r.outputs = {t: outs[t][i] for t in self.graph.outputs}
