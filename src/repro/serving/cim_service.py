"""Batched CIM inference service over a trace-lowered executor.

The serving-side consumer of cimsim.executor: compile a workload for a
CIM chip once, lower the meta-operator flow once, then serve request
traffic by stacking queued inputs on the executor's batch axis — one
device dispatch per batch instead of one interpreter walk per request.
``use_executor=False`` keeps the op-by-op interpreter as a
reference/fallback path (same outputs, orders of magnitude slower),
which is also how the service is tested.

Request/stats shapes live in ``serving.common`` (shared with the LM
batch server and the multi-tenant fleet); ``serve_padded`` is the
fleet batcher's entry point — it pads a partial batch up to a bucket
size so the bucket's already-traced executable is reused instead of
tracing a new batch shape per ragged queue drain.

Units and clocks: ``dispatch``/``serve_padded`` return **wall-clock
seconds** (``time.time()`` around the device call); the compiled plan's
latency/energy estimates are **compiler cycles/pJ** and never mix into
serve times.  Thread-safety: the jitted executable is safe to share,
but ``stats`` and the warm-shape set are plain mutable state — one
service instance per serving thread.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from ..core import compiler
from ..core.abstraction import CIMArch
from ..core.graph import Graph
from ..kernels.cim_mvm import CimMvmParams, cim_mvm_params
from .common import CimRequest, ServiceStats  # noqa: F401  (re-export)


class CimBatchService:
    """Fixed-workload inference service with batched execution.

    Weights default to the deterministic test weights and shifts to one
    reference calibration pass (the §4.1 verification setup); production
    embedders can pass their own ``weights``/``shifts``.

    ``cache`` (a ``dse.CompileCache``) warm-loads the compiled plan from
    disk instead of recompiling — the fleet engine pool hands every
    tenant the campaign cache here.  ``compile_kwargs`` carries compiler
    knob overrides (binding / use_pipeline / use_duplication, e.g. a DSE
    best point's ``compile_kwargs()``); ``level`` stays a convenience
    alias for the common single-knob case.
    """

    def __init__(self, graph: Graph, arch: CIMArch, *, level=None,
                 seed: int = 0, max_batch: int = 8,
                 params: Optional[CimMvmParams] = None,
                 weights: Optional[Dict[str, np.ndarray]] = None,
                 shifts: Optional[Dict[str, int]] = None,
                 use_executor: bool = True,
                 cache=None,
                 compile_kwargs: Optional[Dict] = None):
        from ..cimsim.functional import (calibrate_shifts, make_input,
                                         make_weights)
        self.graph = graph
        self.arch = arch
        self.max_batch = max_batch
        self.use_executor = use_executor
        self.params = params or cim_mvm_params(arch)
        self.weights = weights if weights is not None \
            else make_weights(graph, seed)
        self.shifts = shifts if shifts is not None else calibrate_shifts(
            graph, self.weights, make_input(graph, seed), self.params)
        self.stats = ServiceStats()
        self._warmed: set = set()        # batch sizes already jit-traced
        kwargs = dict(compile_kwargs or {})
        kwargs.setdefault("level", level)
        if use_executor:
            from ..cimsim.executor import LoweringError, lower
            res = compiler.compile_graph(graph, arch, cache=cache, **kwargs)
            try:
                self._exe = lower(res.plan, res.program, params=self.params)
                self._packed = self._exe.pack(self.weights)
            except LoweringError:
                # flow has no bit-exact fast lowering: serve op by op
                self.use_executor = use_executor = False
        if not use_executor:
            from ..cimsim.functional import FunctionalSimulator
            res = compiler.compile_graph(graph, arch, cache=cache,
                                         expand=True, **kwargs)
            self._sim = FunctionalSimulator(res.plan, res.program,
                                            self.weights, self.shifts,
                                            params=self.params)

    @property
    def executor_stats(self):
        """The lowered executable's ``ExecutorStats`` (segments, streamed
        weight updates, resolved kernel route), or ``None`` when the
        service degraded to the op-by-op interpreter."""
        return self._exe.stats if self.use_executor else None

    def serve(self, requests: List[CimRequest]) -> List[CimRequest]:
        """Serve ``requests`` in arrival order, ``max_batch`` at a time.

        Each batch is one executor dispatch (ragged final batches just
        trace a second batch shape, cached thereafter).  The first
        dispatch of a new batch shape runs once untimed to warm the jit
        cache, so ``latency_s`` / ``ServiceStats`` report steady-state
        serving cost rather than trace time.
        """
        done: List[CimRequest] = []
        for i in range(0, len(requests), self.max_batch):
            batch = requests[i:i + self.max_batch]
            dt = self.dispatch(batch)
            for r in batch:
                r.latency_s = dt
            self.stats.record([dt] * len(batch), dt)
            done.extend(batch)
        return done

    def serve_padded(self, batch: List[CimRequest],
                     bucket: Optional[int] = None) -> float:
        """One bucket-shaped dispatch for ``len(batch) <= bucket``
        requests; returns the wall time.  The fleet batcher's entry
        point: padding to a bucket reuses that bucket's cached
        executable instead of tracing every ragged batch size.  Fills
        ``outputs`` but leaves latency/stats accounting to the caller
        (the fleet adds queue wait before recording)."""
        return self.dispatch(batch, pad_to=bucket)

    def dispatch(self, batch: List[CimRequest],
                 pad_to: Optional[int] = None) -> float:
        """Serve one batch (warm-once per shape), return the wall time."""
        if not batch:
            return 0.0
        shape = pad_to if (pad_to and self.use_executor) else len(batch)
        if self.use_executor and shape not in self._warmed:
            self._serve_batch(batch, pad_to=pad_to)
            self._warmed.add(shape)
        t0 = time.time()
        self._serve_batch(batch, pad_to=pad_to)
        return time.time() - t0

    def _serve_batch(self, batch: List[CimRequest],
                     pad_to: Optional[int] = None) -> None:
        if not self.use_executor:
            for r in batch:
                out = self._sim.run({k: np.asarray(v)
                                     for k, v in r.inputs.items()})
                r.outputs = {t: np.asarray(out[t]) for t in self.graph.outputs}
            return
        n = len(batch)
        pad = max(0, (pad_to or n) - n)
        stacked = {}
        for name in self.graph.inputs:
            rows = [np.asarray(r.inputs[name]) for r in batch]
            rows += [rows[-1]] * pad      # pad-to-bucket: repeat last row
            stacked[name] = np.stack(rows)
        outs = self._exe.run_batch(stacked, packed=self._packed,
                                   shifts=self.shifts)
        for i, r in enumerate(batch):
            r.outputs = {t: outs[t][i] for t in self.graph.outputs}
