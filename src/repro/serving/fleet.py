"""Multi-tenant CIM serving fleet: router + batchers + engine pool.

``CimFleet`` is the frontend that turns the compiler stack into a
serving system: N workloads co-resident on one chip, each owning the
crossbar partition the tenancy planner assigned it, fronted by a
deadline-aware dynamic batcher and served by a warm trace-lowered
executable.

    fleet = CimFleet([TenantSpec("resnet", g1, traffic=3.0),
                      TenantSpec("vit", g2, traffic=1.0)], arch)
    fleet.submit("resnet", inputs)            # -> CimRequest
    done = fleet.drain()                      # flush queues, fill outputs
    print(fleet.stats().summary())

Request lifecycle: ``submit`` stamps the arrival time and routes by
model id; ``step`` dispatches every tenant queue whose release policy
fires (full bucket / age / deadline pressure); ``drain`` flushes
everything.  Per-request ``latency_s`` is queue wait plus batch
execution; per-tenant ``ServiceStats`` (p50/p95 tails, deadline misses)
aggregate into ``FleetStats``.

The fleet is clock-agnostic like the batcher: pass explicit ``now``
values for simulated traffic, or let it use wall time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..core.abstraction import CIMArch
from .batcher import DEFAULT_BUCKETS, DynamicBatcher
from .common import CimRequest, ServiceStats
from .engine import EnginePool
from .placement import TenancyPlan, TenantSpec, plan_tenancy


@dataclasses.dataclass
class FleetStats:
    """Per-tenant stats plus the fleet-wide aggregate."""

    tenants: Dict[str, ServiceStats]

    @property
    def aggregate(self) -> ServiceStats:
        total = ServiceStats()
        for s in self.tenants.values():
            total = total.merge(s)
        return total

    def summary(self) -> str:
        agg = self.aggregate
        lines = [f"fleet: {agg.requests} requests in {agg.batches} batches; "
                 f"p50 {agg.p50_latency_s * 1e3:.2f}ms / "
                 f"p95 {agg.p95_latency_s * 1e3:.2f}ms; "
                 f"{agg.deadline_misses} deadline misses"]
        for name, s in self.tenants.items():
            lines.append(f"  {name}: {s.requests} reqs / {s.batches} batches,"
                         f" p50 {s.p50_latency_s * 1e3:.2f}ms,"
                         f" p95 {s.p95_latency_s * 1e3:.2f}ms")
        return "\n".join(lines)


class CimFleet:
    """Serve N workloads on one CIM chip behind one frontend."""

    def __init__(self, tenants: Sequence[TenantSpec], arch: CIMArch, *,
                 plan: Optional[TenancyPlan] = None,
                 cache=None, seed: int = 0,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_wait_s: float = 0.002,
                 use_executor: bool = True,
                 points: Optional[Dict[str, Dict]] = None):
        if plan is None:
            plan = plan_tenancy(tenants, arch)
        else:
            # an explicit plan must describe exactly these tenants on this
            # chip — a stale plan would silently serve the wrong fleet.
            # The engines run from the plan's embedded specs, so the
            # caller's specs must match them in substance (graph, knobs,
            # traffic), not just by name.
            by_name = {t.name: t for t in tenants}
            if set(plan.tenants) != set(by_name):
                raise ValueError(
                    f"plan tenants {sorted(plan.tenants)} != specs "
                    f"{sorted(by_name)}")
            if plan.arch.to_dict() != arch.to_dict():
                raise ValueError(
                    f"plan was built for arch {plan.arch.name!r}, "
                    f"fleet got {arch.name!r}")
            for name, spec in by_name.items():
                ps = plan.tenants[name].spec
                if ps is spec:
                    continue
                if (ps.traffic != spec.traffic
                        or ps.compile_kwargs != spec.compile_kwargs
                        or ps.graph.to_dict() != spec.graph.to_dict()):
                    raise ValueError(
                        f"plan tenant {name!r} was planned from a "
                        "different spec (graph/knobs/traffic) than the "
                        "one passed to the fleet")
        self.plan = plan
        self.plan.validate()
        self.pool = EnginePool(self.plan, cache=cache, seed=seed,
                               max_batch=max(buckets),
                               use_executor=use_executor, points=points)
        # deadline pressure uses observed dispatch times; before a
        # tenant's first dispatch the estimate is unknown (None), which
        # the batcher treats as "release deadlined work immediately" —
        # simulated cycles don't convert to wall time, so not waiting is
        # the only estimate-free way to avoid cold-start deadline misses
        self._batchers: Dict[str, DynamicBatcher] = {}
        self._observed_s: Dict[str, float] = {}
        for name in self.pool.names:
            self._batchers[name] = DynamicBatcher(
                buckets=tuple(buckets), max_wait_s=max_wait_s,
                est_batch_s=lambda n, t=name: self._observed_s.get(t))
        self._rid = 0

    # -- admission -------------------------------------------------------
    def submit(self, model: str, inputs: Dict[str, np.ndarray], *,
               deadline_s: Optional[float] = None,
               now: Optional[float] = None) -> CimRequest:
        """Admit one request for ``model``; returns the queued request."""
        if model not in self.pool:
            raise KeyError(f"unknown model {model!r}; "
                           f"tenants: {self.pool.names}")
        now = time.monotonic() if now is None else now
        req = CimRequest(rid=self._rid, inputs=inputs, model=model,
                         arrival_s=now, deadline_s=deadline_s)
        self._rid += 1
        self._batchers[model].submit(req)
        return req

    def submit_request(self, req: CimRequest,
                       now: Optional[float] = None) -> CimRequest:
        """Admit a pre-built request (its ``model`` field routes it)."""
        if req.model not in self.pool:
            raise KeyError(f"unknown model {req.model!r}; "
                           f"tenants: {self.pool.names}")
        req.arrival_s = time.monotonic() if now is None else now
        self._batchers[req.model].submit(req)
        return req

    @property
    def pending(self) -> int:
        return sum(len(b) for b in self._batchers.values())

    # -- dispatch --------------------------------------------------------
    def step(self, now: Optional[float] = None,
             force: bool = False) -> List[CimRequest]:
        """Dispatch every tenant queue whose release policy fires.

        Returns the requests completed this step (outputs + latency
        filled).  ``force=True`` releases partial batches regardless of
        the policy (one bucketed batch per tenant per call).
        """
        now = time.monotonic() if now is None else now
        done: List[CimRequest] = []
        for name, batcher in self._batchers.items():
            batch = batcher.next_batch(now, force=force)
            if batch is None:
                continue
            done.extend(self._dispatch(name, batch, now))
        return done

    def drain(self, now: Optional[float] = None) -> List[CimRequest]:
        """Flush every queue to empty (bucketed batches throughout)."""
        now = time.monotonic() if now is None else now
        done: List[CimRequest] = []
        for name, batcher in self._batchers.items():
            for batch in batcher.drain(now):
                done.extend(self._dispatch(name, batch, now))
        return done

    def serve(self, requests: Iterable[CimRequest],
              now: Optional[float] = None) -> List[CimRequest]:
        """Synchronous convenience: admit every request, then drain.

        Requests are routed by their ``model`` field; arrival times are
        stamped at admission (pass ``now`` for a synthetic clock).
        """
        for r in requests:
            self.submit_request(r, now=now)
        return self.drain(now=now)

    def _dispatch(self, name: str, batch, now: float) -> List[CimRequest]:
        engine = self.pool[name]
        dt = engine.serve_padded(batch.requests, batch.bucket)
        # steady-state estimate feeding the deadline-pressure policy
        prev = self._observed_s.get(name)
        self._observed_s[name] = dt if prev is None else 0.5 * (prev + dt)
        latencies, misses = [], 0
        for r in batch.requests:
            r.latency_s = (now - r.arrival_s) + dt
            latencies.append(r.latency_s)
            misses += r.missed_deadline(now + dt)
        engine.stats.record(latencies, dt, misses)
        return batch.requests

    # -- introspection ---------------------------------------------------
    def stats(self) -> FleetStats:
        return FleetStats(tenants={name: self.pool[name].stats
                                   for name in self.pool.names})

    def summary(self) -> str:
        return self.plan.summary() + "\n" + self.stats().summary()
