"""CIM serving fleet: single-chip router plus the cross-chip cluster.

Two tiers live here:

``CimFleet`` — N workloads co-resident on *one* chip, each owning the
crossbar partition the tenancy planner assigned it, fronted by a
deadline-aware dynamic batcher and served by a warm trace-lowered
executable:

    fleet = CimFleet([TenantSpec("resnet", g1, traffic=3.0),
                      TenantSpec("vit", g2, traffic=1.0)], arch)
    fleet.submit("resnet", inputs)            # -> CimRequest
    done = fleet.drain()                      # flush queues, fill outputs
    print(fleet.stats().summary())

``CimCluster`` — the fleet tier over *N chips* (per-chip arch may
differ): a 2-D ``FleetPlan`` (tenant -> chip -> crossbar pool) routes
each tenant's traffic across its chip replicas; observed per-tenant
traffic is tracked with an EWMA and, when it drifts from the plan's
assumed shares, the cluster re-plans online and migrates tenants over
the weight-rewrite path; admission control sheds lowest-priority
tenants to time-multiplexed residency before rejecting (typed
``AdmissionError``) under overload.

Request lifecycle: ``submit`` stamps the arrival time and routes by
model id; ``step`` dispatches every tenant queue whose release policy
fires (full bucket / age / deadline pressure); ``drain`` flushes
everything.  Per-request ``latency_s`` is queue wait plus batch
execution; per-tenant ``ServiceStats`` aggregate into ``FleetStats``.

Units and clocks: all public ``*_s`` values are **seconds** on one
caller-chosen service clock — wall time by default (``time.monotonic``),
synthetic when every call passes explicit ``now`` values (tests and
benchmarks do).  Engine dispatch durations are measured wall-clock
seconds placed on that same timeline; crossbar weight-rewrite costs are
**compiler cycles** and only ever appear in trace/plan metadata, never
on the clock.  Thread-safety: neither class is thread-safe — one fleet
or cluster is driven from one thread; batchers and stats are plain
mutable state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Mapping, Optional, Sequence

import numpy as np

from ..core.abstraction import CIMArch
from .batcher import DEFAULT_BUCKETS, DynamicBatcher
from .common import CimRequest, ServiceStats
from .engine import EnginePool
from .placement import (FleetPlan, TenancyPlan, TenantSpec, plan_fleet,
                        plan_tenancy)
from .trace import TraceRecorder


class AdmissionError(RuntimeError):
    """Typed rejection: the cluster is saturated for this tenant and the
    degradation ladder is exhausted (every lower-priority tenant is
    already time-multiplexed).  Carries ``model``, ``pending`` and
    ``limit`` so callers can back off or shed load upstream."""

    def __init__(self, model: str, pending: int, limit: int):
        self.model, self.pending, self.limit = model, pending, limit
        super().__init__(
            f"tenant {model!r} rejected: {pending} pending >= "
            f"limit {limit} and no lower-priority tenant left to shed")


class TransientKernelError(RuntimeError):
    """A kernel dispatch failed for a transient, retryable reason (a
    flaky device link, a spurious launch failure injected by a fault
    schedule).  ``CimFleet`` retries the dispatch up to ``max_retries``
    times before letting it propagate — anything *else* an engine
    raises is treated as permanent and surfaces immediately."""


@dataclasses.dataclass(frozen=True)
class ChipFault:
    """One scheduled chip-level fault (service-clock seconds).

    ``kind="kill"`` removes the chip: its pending requests are
    evacuated onto survivors through the pending-preserving re-plan
    path.  ``kind="degrade"`` keeps the chip serving but multiplies
    its dispatch durations by ``degrade_factor`` (a thermally-throttled
    or half-dead chip), compounding across repeated degrades.
    """

    at_s: float
    chip: str
    kind: str = "kill"                  # "kill" | "degrade"
    degrade_factor: float = 2.0

    def __post_init__(self):
        if self.kind not in ("kill", "degrade"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "degrade" and self.degrade_factor <= 0:
            raise ValueError("degrade_factor must be positive")


class FaultSchedule:
    """Deterministic time-ordered chip-fault injector for a cluster.

    Faults fire when the cluster's clock passes ``at_s`` — checked on
    every ``submit``/``step``/``drain``/``control`` — each exactly
    once.  Purely driven by the caller's clock, so replays are exact.
    """

    def __init__(self, faults: Iterable[ChipFault]):
        self.faults: List[ChipFault] = sorted(faults,
                                              key=lambda f: (f.at_s, f.chip))
        self._next = 0

    def due(self, now: float) -> List[ChipFault]:
        """Pop every not-yet-fired fault with ``at_s <= now``."""
        out: List[ChipFault] = []
        while self._next < len(self.faults) \
                and self.faults[self._next].at_s <= now:
            out.append(self.faults[self._next])
            self._next += 1
        return out

    @property
    def remaining(self) -> int:
        return len(self.faults) - self._next


@dataclasses.dataclass
class FleetStats:
    """Per-tenant stats plus the fleet-wide aggregate (see
    ``ServiceStats`` for the cumulative-vs-windowed field split)."""

    tenants: Dict[str, ServiceStats]

    @property
    def aggregate(self) -> ServiceStats:
        """All tenants merged into one ``ServiceStats``."""
        total = ServiceStats()
        for s in self.tenants.values():
            total = total.merge(s)
        return total

    def summary(self) -> str:
        """Human-readable one-screen digest (latencies in ms)."""
        agg = self.aggregate
        lines = [f"fleet: {agg.requests} requests in {agg.batches} batches; "
                 f"p50 {agg.p50_latency_s * 1e3:.2f}ms / "
                 f"p95 {agg.p95_latency_s * 1e3:.2f}ms; "
                 f"{agg.deadline_misses} deadline misses"]
        for name, s in self.tenants.items():
            lines.append(f"  {name}: {s.requests} reqs / {s.batches} batches,"
                         f" p50 {s.p50_latency_s * 1e3:.2f}ms,"
                         f" p95 {s.p95_latency_s * 1e3:.2f}ms")
        return "\n".join(lines)


class CimFleet:
    """Serve N workloads on one CIM chip behind one frontend.

    Clock: every public method takes an optional ``now`` (service-clock
    seconds); omitted, it falls back to ``time.monotonic()``.  Pass a
    ``TraceRecorder`` (plus ``chip`` label) to emit batcher queue-wait
    and engine dispatch spans onto its timeline.  Not thread-safe.
    """

    def __init__(self, tenants: Sequence[TenantSpec], arch: CIMArch, *,
                 plan: Optional[TenancyPlan] = None,
                 cache=None, seed: int = 0,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_wait_s: float = 0.002,
                 use_executor: bool = True,
                 points: Optional[Dict[str, Dict]] = None,
                 trace: Optional[TraceRecorder] = None,
                 chip: Optional[str] = None,
                 max_retries: int = 2):
        if plan is None:
            plan = plan_tenancy(tenants, arch)
        else:
            # an explicit plan must describe exactly these tenants on this
            # chip — a stale plan would silently serve the wrong fleet.
            # The engines run from the plan's embedded specs, so the
            # caller's specs must match them in substance (graph, knobs,
            # traffic), not just by name.
            by_name = {t.name: t for t in tenants}
            if set(plan.tenants) != set(by_name):
                raise ValueError(
                    f"plan tenants {sorted(plan.tenants)} != specs "
                    f"{sorted(by_name)}")
            if plan.arch.to_dict() != arch.to_dict():
                raise ValueError(
                    f"plan was built for arch {plan.arch.name!r}, "
                    f"fleet got {arch.name!r}")
            for name, spec in by_name.items():
                ps = plan.tenants[name].spec
                if ps is spec:
                    continue
                if (ps.traffic != spec.traffic
                        or ps.compile_kwargs != spec.compile_kwargs
                        or ps.graph.to_dict() != spec.graph.to_dict()):
                    raise ValueError(
                        f"plan tenant {name!r} was planned from a "
                        "different spec (graph/knobs/traffic) than the "
                        "one passed to the fleet")
        self.plan = plan
        self.plan.validate()
        self.trace = trace
        self.chip = chip or arch.name
        self.pool = EnginePool(self.plan, cache=cache, seed=seed,
                               max_batch=max(buckets),
                               use_executor=use_executor, points=points)
        # deadline pressure uses observed dispatch times; before a
        # tenant's first dispatch the estimate is unknown (None), which
        # the batcher treats as "release deadlined work immediately" —
        # simulated cycles don't convert to wall time, so not waiting is
        # the only estimate-free way to avoid cold-start deadline misses
        self._batchers: Dict[str, DynamicBatcher] = {}
        self._observed_s: Dict[str, float] = {}
        for name in self.pool.names:
            self._batchers[name] = DynamicBatcher(
                buckets=tuple(buckets), max_wait_s=max_wait_s,
                est_batch_s=lambda n, t=name: self._observed_s.get(t))
        self._rid = 0
        #: bounded deterministic retry budget for TransientKernelError
        self.max_retries = max_retries
        self.retries = 0                 # cumulative retried dispatches
        #: dispatch-duration multiplier (>1 when the chip is degraded by
        #: a fault schedule; the cluster sets it)
        self.slowdown = 1.0

    # -- admission -------------------------------------------------------
    def submit(self, model: str, inputs: Dict[str, np.ndarray], *,
               deadline_s: Optional[float] = None,
               now: Optional[float] = None) -> CimRequest:
        """Admit one request for ``model``; returns the queued request.

        ``now``/``deadline_s`` are service-clock seconds; arrival is
        stamped here."""
        if model not in self.pool:
            raise KeyError(f"unknown model {model!r}; "
                           f"tenants: {self.pool.names}")
        now = time.monotonic() if now is None else now
        req = CimRequest(rid=self._rid, inputs=inputs, model=model,
                         arrival_s=now, deadline_s=deadline_s)
        self._rid += 1
        self._batchers[model].submit(req)
        return req

    def submit_request(self, req: CimRequest,
                       now: Optional[float] = None) -> CimRequest:
        """Admit a pre-built request (its ``model`` field routes it);
        re-stamps ``arrival_s`` to ``now`` (service clock)."""
        if req.model not in self.pool:
            raise KeyError(f"unknown model {req.model!r}; "
                           f"tenants: {self.pool.names}")
        req.arrival_s = time.monotonic() if now is None else now
        self._batchers[req.model].submit(req)
        return req

    def requeue(self, req: CimRequest) -> None:
        """Admit a carried-over request *preserving* its ``arrival_s``
        (cluster migration uses this so queue-wait accounting survives a
        re-plan)."""
        if req.model not in self.pool:
            raise KeyError(f"unknown model {req.model!r}; "
                           f"tenants: {self.pool.names}")
        self._batchers[req.model].submit(req)

    @property
    def pending(self) -> int:
        """Queued (not yet dispatched) requests across all tenants."""
        return sum(len(b) for b in self._batchers.values())

    def queue_depth(self, model: str) -> int:
        """Queued requests for one tenant (admission control input)."""
        return len(self._batchers[model])

    def evict_pending(self, now: Optional[float] = None) -> List[CimRequest]:
        """Remove and return every queued request (cluster migration /
        chip failover: the new plan's fleets re-admit them; nothing is
        dropped).  With ``now`` given, evicted requests already past
        their deadline are counted into the tenant's ``ServiceStats``
        here (exactly once, via ``miss_recorded``) — they may complete
        on another chip much later or never, and dropping the miss at
        eviction silently undercounted the deadline-miss counters."""
        out: List[CimRequest] = []
        for name, b in self._batchers.items():
            evicted, b.queue = b.queue, []
            if now is not None:
                n = 0
                for r in evicted:
                    if r.missed_deadline(now) and not r.miss_recorded:
                        r.miss_recorded = True
                        n += 1
                if n:
                    self.pool[name].stats.record_misses(n)
            out.extend(evicted)
        return out

    # -- dispatch --------------------------------------------------------
    def step(self, now: Optional[float] = None,
             force: bool = False) -> List[CimRequest]:
        """Dispatch every tenant queue whose release policy fires.

        Returns the requests completed this step (outputs + latency
        filled).  ``force=True`` releases partial batches regardless of
        the policy (one bucketed batch per tenant per call).
        """
        now = time.monotonic() if now is None else now
        done: List[CimRequest] = []
        for name, batcher in self._batchers.items():
            batch = batcher.next_batch(now, force=force)
            if batch is None:
                continue
            done.extend(self._dispatch(name, batch, now))
        return done

    def drain(self, now: Optional[float] = None) -> List[CimRequest]:
        """Flush every queue to empty (bucketed batches throughout)."""
        now = time.monotonic() if now is None else now
        done: List[CimRequest] = []
        for name, batcher in self._batchers.items():
            for batch in batcher.drain(now):
                done.extend(self._dispatch(name, batch, now))
        return done

    def serve(self, requests: Iterable[CimRequest],
              now: Optional[float] = None) -> List[CimRequest]:
        """Synchronous convenience: admit every request, then drain.

        Requests are routed by their ``model`` field; arrival times are
        stamped at admission (pass ``now`` for a synthetic clock).
        """
        for r in requests:
            self.submit_request(r, now=now)
        return self.drain(now=now)

    def _dispatch(self, name: str, batch, now: float) -> List[CimRequest]:
        engine = self.pool[name]
        # bounded deterministic retry: only the typed transient channel
        # is retried (no sleeps — the service clock is caller-driven);
        # exhaustion re-raises so permanent failures stay loud
        for attempt in range(self.max_retries + 1):
            try:
                dt = engine.serve_padded(batch.requests, batch.bucket)
                break
            except TransientKernelError:
                if attempt >= self.max_retries:
                    raise
                self.retries += 1
                if self.trace is not None:
                    self.trace.instant(self.chip, f"retry:{name}", "fault",
                                       now, attempt=attempt + 1,
                                       bucket=batch.bucket)
        dt *= self.slowdown
        # steady-state estimate feeding the deadline-pressure policy
        prev = self._observed_s.get(name)
        self._observed_s[name] = dt if prev is None else 0.5 * (prev + dt)
        latencies, missed = [], []
        for r in batch.requests:
            r.latency_s = (now - r.arrival_s) + dt
            latencies.append(r.latency_s)
            m = r.missed_deadline(now + dt) and not r.miss_recorded
            if m:
                r.miss_recorded = True
            missed.append(m)
        misses = sum(missed)
        engine.stats.record(latencies, dt, misses, missed=missed)
        if self.trace is not None:
            oldest = min(r.arrival_s for r in batch.requests)
            self.trace.complete(
                self.chip, name, f"queue n={len(batch.requests)}",
                "batcher", oldest, now - oldest,
                reason=batch.reason, bucket=batch.bucket)
            self.trace.complete(
                self.chip, name, f"dispatch b={batch.bucket}", "engine",
                now, dt, n=len(batch.requests), misses=misses)
        return batch.requests

    # -- introspection ---------------------------------------------------
    def stats(self) -> FleetStats:
        """Per-tenant ``ServiceStats`` for this chip."""
        return FleetStats(tenants={name: self.pool[name].stats
                                   for name in self.pool.names})

    def serve_s(self) -> float:
        """Cumulative engine busy seconds on this chip (wall-clock)."""
        return sum(self.pool[name].stats.serve_s
                   for name in self.pool.names)

    def summary(self) -> str:
        """Plan + stats digest for this chip."""
        return self.plan.summary() + "\n" + self.stats().summary()


# ---------------------------------------------------------------------------
# Cross-chip cluster: routing, traffic drift, live re-planning.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplanPolicy:
    """When the cluster re-plans (all times service-clock seconds).

    Observed per-tenant rates are EWMA-smoothed per ``control`` window
    (``ewma_alpha`` weights the newest window).  A re-plan triggers when
    the worst per-tenant relative divergence between observed and
    planned traffic *shares* exceeds ``drift_threshold`` and at least
    ``min_requests`` arrivals were seen since the last re-plan (noise
    guard).
    """

    ewma_alpha: float = 0.5
    drift_threshold: float = 0.5
    min_requests: int = 32
    #: floor share for divergence normalization (avoids exploding
    #: ratios for near-zero planned shares)
    share_floor: float = 0.02
    #: absolute share gap below which a tenant contributes no drift —
    #: without it, tiny-share tenants keep large *relative* divergence
    #: after a re-plan and the cluster thrashes (migrates every window)
    min_share_delta: float = 0.1


class _TrafficEwma:
    """Per-tenant arrival-rate EWMA over ``control`` windows.  Rates are
    requests/second on the service clock; not thread-safe."""

    def __init__(self, alpha: float):
        self.alpha = alpha
        self.rates: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}
        self.window_total = 0
        self._last: Optional[float] = None

    def arrival(self, model: str, now: float) -> None:
        if self._last is None:
            self._last = now
        self.counts[model] = self.counts.get(model, 0) + 1
        self.window_total += 1

    def roll(self, now: float) -> float:
        """Fold the window ending at ``now`` into the EWMA; returns the
        window length in seconds (0 when no arrivals were ever seen)."""
        if self._last is None:
            return 0.0
        window = max(now - self._last, 1e-9)
        names = set(self.rates) | set(self.counts)
        for n in names:
            obs = self.counts.get(n, 0) / window
            prev = self.rates.get(n)
            self.rates[n] = obs if prev is None \
                else self.alpha * obs + (1 - self.alpha) * prev
        self.counts = {}
        self._last = now
        return window

    def shares(self) -> Dict[str, float]:
        total = sum(self.rates.values())
        if total <= 0:
            return {}
        return {k: v / total for k, v in self.rates.items()}


class CimCluster:
    """N-chip CIM serving cluster: 2-D placement, drift-driven live
    re-planning, admission control and Chrome-trace observability.

    One ``CimFleet`` per planned chip serves that chip's tenant subset;
    the cluster routes each tenant's traffic across its chip replicas
    in the ``FleetPlan``'s proportions (deterministic weighted
    round-robin).  ``control`` is the operator heartbeat: it rolls the
    traffic EWMA, samples per-chip utilization/queue counters into the
    trace, and re-plans + migrates when observed shares drift from the
    plan's assumptions.  Migration reuses the weight-rewrite path: the
    affected chips' engines are rebuilt against the new partitions
    (compiles warm-load from ``cache``), queued requests carry over,
    and the rewrite cost (crossbars x ``t_write_xb`` cycles) is
    recorded in the trace.

    Clock: explicit ``now`` (service-clock seconds) everywhere, wall
    time by default — same contract as ``CimFleet``.  Not thread-safe:
    drive one cluster from one thread.
    """

    def __init__(self, tenants: Sequence[TenantSpec],
                 chips: Mapping[str, CIMArch], *,
                 plan: Optional[FleetPlan] = None,
                 cache=None, seed: int = 0,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_wait_s: float = 0.002,
                 use_executor: bool = True,
                 points: Optional[Dict[str, Dict]] = None,
                 trace: Optional[TraceRecorder] = None,
                 max_queue: int = 256,
                 policy: Optional[ReplanPolicy] = None,
                 faults: Optional[FaultSchedule] = None,
                 max_retries: int = 2):
        self.specs = {t.name: t for t in tenants}
        if len(self.specs) != len(list(tenants)):
            raise ValueError("tenant names must be unique")
        self.archs = dict(chips)
        if plan is None:
            plan = plan_fleet(tenants, self.archs)
        if set(plan.routes) != set(self.specs):
            raise ValueError(
                f"plan tenants {sorted(plan.routes)} != specs "
                f"{sorted(self.specs)}")
        self.cache = cache
        self.seed = seed
        self.buckets = tuple(buckets)
        self.max_wait_s = max_wait_s
        self.use_executor = use_executor
        self.points = points
        self.trace = trace
        self.max_queue = max_queue
        self.policy = policy or ReplanPolicy()
        self.traffic = _TrafficEwma(self.policy.ewma_alpha)
        self.fault_schedule = faults
        self.max_retries = max_retries
        # operator counters (cumulative)
        self.migrations = 0              # applied re-plans
        self.demotions = 0               # tenants shed to time-multiplexed
        self.rejected = 0                # AdmissionError count
        self.demoted: set = set()        # currently-shed tenant names
        self.failed: set = set()         # chips killed by the schedule
        self.chip_kills = 0              # cumulative kill faults applied
        self.chip_degrades = 0           # cumulative degrade faults applied
        self._chip_slowdown: Dict[str, float] = {}
        self._arrivals_since_replan = 0
        self._rid = 0
        self._retired: Dict[str, ServiceStats] = {}
        self._chip_busy_base: Dict[str, float] = {}
        self._credits: Dict[str, Dict[str, float]] = {}
        self.fleets: Dict[str, CimFleet] = {}
        self.plan = None
        self._install_plan(plan)

    # -- plan installation / migration -----------------------------------
    def _build_chip(self, chip: str, tplan: TenancyPlan) -> CimFleet:
        specs = [p.spec for p in tplan.tenants.values()]
        fleet = CimFleet(specs, self.archs[chip], plan=tplan,
                         cache=self.cache, seed=self.seed,
                         buckets=self.buckets, max_wait_s=self.max_wait_s,
                         use_executor=self.use_executor, points=self.points,
                         trace=self.trace, chip=chip,
                         max_retries=self.max_retries)
        # an active degrade fault outlives re-plans of its chip
        fleet.slowdown = self._chip_slowdown.get(chip, 1.0)
        return fleet

    def _install_plan(self, plan: FleetPlan,
                      now: Optional[float] = None) -> None:
        plan.validate()
        old = self.plan
        pending: List[CimRequest] = []
        rebuilt = []
        for chip, tplan in plan.chips.items():
            prior = self.fleets.get(chip)
            if prior is not None and old is not None \
                    and chip in old.chips \
                    and _same_chip_plan(old.chips[chip], tplan):
                continue                       # placement unchanged: keep
            if prior is not None:
                pending.extend(prior.evict_pending(now=now))
                self._retire(prior)
                self._chip_busy_base[chip] = \
                    self._chip_busy_base.get(chip, 0.0) + prior.serve_s()
            rebuilt.append(chip)
            self.fleets[chip] = self._build_chip(chip, tplan)
        for chip in list(self.fleets):
            if chip not in plan.chips:         # chip emptied by the plan
                prior = self.fleets.pop(chip)
                pending.extend(prior.evict_pending(now=now))
                self._retire(prior)
                self._chip_busy_base[chip] = \
                    self._chip_busy_base.get(chip, 0.0) + prior.serve_s()
        self.plan = plan
        self._credits = {t: {c: 0.0 for c in plan.routes[t]}
                         for t in plan.routes}
        if self.trace is not None and now is not None:
            for chip in rebuilt:
                cost = _rewrite_cost(old, plan, chip)
                self.trace.instant(
                    chip, "migrate", "rewrite", now,
                    rewritten_xbs=cost["xbs"],
                    rewrite_cycles=cost["cycles"])
        for req in pending:                    # carried over, never dropped
            self._route(req)

    def _retire(self, fleet: CimFleet) -> None:
        for name, s in fleet.stats().tenants.items():
            prev = self._retired.get(name, ServiceStats())
            self._retired[name] = prev.merge(s)

    # -- admission + routing ---------------------------------------------
    @property
    def names(self) -> List[str]:
        """All tenant names (sorted)."""
        return sorted(self.specs)

    @property
    def pending(self) -> int:
        """Queued requests across every chip."""
        return sum(f.pending for f in self.fleets.values())

    def queue_depth(self, model: str) -> int:
        """Queued requests for one tenant across its chips."""
        return sum(f.queue_depth(model) for f in self.fleets.values()
                   if model in f.pool)

    def _admit(self, model: str, now: float) -> None:
        """Admission control: at ``max_queue`` pending, first climb the
        degradation ladder; rejection raises ``AdmissionError``."""
        if self.queue_depth(model) >= self.max_queue:
            if not self._degrade(model, now):
                self.rejected += 1
                if self.trace is not None:
                    chip = next(iter(self.plan.routes[model]))
                    self.trace.instant(chip, f"reject:{model}",
                                       "admission", now,
                                       pending=self.queue_depth(model),
                                       limit=self.max_queue)
                raise AdmissionError(model, self.queue_depth(model),
                                     self.max_queue)

    def submit(self, model: str, inputs: Dict[str, np.ndarray], *,
               deadline_s: Optional[float] = None,
               now: Optional[float] = None) -> CimRequest:
        """Admit one request: admission control, then weighted routing.

        Raises ``AdmissionError`` when the tenant's cluster-wide queue
        is at ``max_queue`` and the degradation ladder is exhausted;
        otherwise the first overload demotes the lowest-priority
        still-resident tenant to time-multiplexed residency (re-plan +
        migration) and the request is accepted.
        """
        req = CimRequest(rid=self._rid, inputs=inputs, model=model,
                         deadline_s=deadline_s)
        self._rid += 1
        return self.submit_request(req, now=now)

    def submit_request(self, req: CimRequest,
                       now: Optional[float] = None) -> CimRequest:
        """Admit a pre-built request (same admission path as
        ``submit``; ``arrival_s`` is re-stamped to ``now``).  The
        *same* object is queued, so the caller sees ``outputs`` and
        ``latency_s`` once it completes."""
        if req.model not in self.specs:
            raise KeyError(f"unknown model {req.model!r}; tenants: "
                           f"{self.names}")
        now = time.monotonic() if now is None else now
        self._apply_faults(now)
        self._admit(req.model, now)
        req.arrival_s = now
        self.traffic.arrival(req.model, now)
        self._arrivals_since_replan += 1
        self._route(req)
        return req

    def _route(self, req: CimRequest) -> None:
        """Deterministic weighted round-robin over the tenant's chips
        (Bresenham credits follow the plan's route proportions)."""
        row = self.plan.routes[req.model]
        credits = self._credits[req.model]
        for chip, w in row.items():
            credits[chip] = credits.get(chip, 0.0) + w
        chip = max(sorted(credits), key=lambda c: credits[c])
        credits[chip] -= 1.0
        self.fleets[chip].requeue(req)

    # -- degradation ladder ----------------------------------------------
    def _degrade(self, model: str, now: float) -> bool:
        """Shed the lowest-priority still-resident tenant (strictly
        below ``model``'s priority) to time-multiplexed residency.
        Returns True when a demotion was applied."""
        mine = self.specs[model].priority
        candidates = sorted(
            (s for s in self.specs.values()
             if s.name != model and s.name not in self.demoted
             and s.priority < mine
             and self.plan.total_replicas(s.name) > 0),
            key=lambda s: (s.priority, s.name))
        if not candidates:
            return False
        victim = candidates[0]
        self.demoted.add(victim.name)
        self.demotions += 1
        if self.trace is not None:
            chip = next(iter(self.plan.routes[victim.name]))
            self.trace.instant(chip, f"demote:{victim.name}",
                               "admission", now, for_tenant=model)
        self._replan(now, reason="degrade")
        return True

    # -- fault injection / failover --------------------------------------
    def _apply_faults(self, now: float) -> None:
        """Fire every due fault of the schedule (kills first would not
        matter: ``due`` preserves time order, ties break by chip)."""
        if self.fault_schedule is None:
            return
        for f in self.fault_schedule.due(now):
            if f.kind == "kill":
                if f.chip in self.archs:
                    self._fail_chip(f.chip, now)
            else:
                self._degrade_chip(f, now)

    def _degrade_chip(self, fault: ChipFault, now: float) -> None:
        factor = self._chip_slowdown.get(fault.chip, 1.0) \
            * fault.degrade_factor
        self._chip_slowdown[fault.chip] = factor
        fleet = self.fleets.get(fault.chip)
        if fleet is not None:
            fleet.slowdown = factor
        self.chip_degrades += 1
        if self.trace is not None:
            self.trace.instant(fault.chip, "chip_degrade", "fault", now,
                               factor=round(factor, 4))

    def _fail_chip(self, chip: str, now: float) -> None:
        """Chip loss: retire its stats, evacuate its queued requests,
        re-plan the survivors (climbing the degradation ladder when the
        remaining capacity cannot hold every resident tenant), and
        re-route the evacuees.  Zero accepted requests are dropped."""
        fleet = self.fleets.pop(chip, None)
        self.archs.pop(chip, None)
        self.failed.add(chip)
        self.chip_kills += 1
        pending: List[CimRequest] = []
        if fleet is not None:
            pending = fleet.evict_pending(now=now)
            self._retire(fleet)
            self._chip_busy_base[chip] = \
                self._chip_busy_base.get(chip, 0.0) + fleet.serve_s()
        if self.trace is not None:
            self.trace.instant(chip, "chip_kill", "fault", now,
                               evacuated=len(pending),
                               survivors=len(self.archs))
        if not self.archs:
            raise AdmissionError("*", len(pending), 0)
        self._failover_replan(now)
        for req in pending:                    # evacuated, never dropped
            self._route(req)

    def _failover_replan(self, now: float) -> None:
        """Re-plan onto the surviving chips.  When the lost capacity
        makes the plan infeasible, extend the degradation ladder —
        demote the lowest-priority not-yet-demoted tenant to
        time-multiplexed residency and retry — before giving up (the
        planner's error propagates once everyone is demoted)."""
        while True:
            try:
                self._replan(now, reason="failover")
                return
            except ValueError:
                candidates = sorted(
                    (s for s in self.specs.values()
                     if s.name not in self.demoted),
                    key=lambda s: (s.priority, s.name))
                if not candidates:
                    raise
                victim = candidates[0]
                self.demoted.add(victim.name)
                self.demotions += 1
                if self.trace is not None:
                    chip = sorted(self.archs)[0]
                    self.trace.instant(chip, f"demote:{victim.name}",
                                       "admission", now,
                                       for_tenant="failover")

    # -- dispatch --------------------------------------------------------
    def step(self, now: Optional[float] = None,
             force: bool = False) -> List[CimRequest]:
        """One dispatch pass over every chip (see ``CimFleet.step``)."""
        now = time.monotonic() if now is None else now
        self._apply_faults(now)
        done: List[CimRequest] = []
        for chip in sorted(self.fleets):
            done.extend(self.fleets[chip].step(now, force=force))
        return done

    def drain(self, now: Optional[float] = None) -> List[CimRequest]:
        """Flush every chip's queues to empty."""
        now = time.monotonic() if now is None else now
        self._apply_faults(now)
        done: List[CimRequest] = []
        for chip in sorted(self.fleets):
            done.extend(self.fleets[chip].drain(now))
        return done

    def serve(self, requests: Iterable[CimRequest],
              now: Optional[float] = None) -> List[CimRequest]:
        """Admit every request (admission control applies!), then
        drain.  Raises ``AdmissionError`` like ``submit``."""
        for r in requests:
            self.submit_request(r, now=now)
        return self.drain(now=now)

    # -- control loop -----------------------------------------------------
    def control(self, now: Optional[float] = None) -> dict:
        """The operator heartbeat: roll traffic EWMA, sample
        utilization/queue counters into the trace, re-plan on drift.

        Returns ``{"drift": float, "replanned": bool, "shares":
        {...}}`` for operator introspection.  Call it periodically
        (every batching window or few) on the same clock as ``submit``.
        """
        now = time.monotonic() if now is None else now
        self._apply_faults(now)
        window = self.traffic.roll(now)
        if self.trace is not None and window > 0:
            for chip in sorted(self.fleets):
                fleet = self.fleets[chip]
                busy = fleet.serve_s()
                prev = getattr(fleet, "_last_busy_s", 0.0)
                fleet._last_busy_s = busy
                self.trace.counter(
                    chip, "chip", now,
                    {"utilization": min(1.0, (busy - prev) / window),
                     "queue_depth": fleet.pending})
        observed = self.traffic.shares()
        drift = self._drift(observed)
        replanned = False
        if (drift > self.policy.drift_threshold
                and self._arrivals_since_replan
                >= self.policy.min_requests):
            if self.trace is not None:
                chip = sorted(self.fleets)[0]
                self.trace.instant(chip, "replan", "rewrite", now,
                                   drift=round(drift, 4))
            self._replan(now, reason="drift")
            replanned = True
        return {"drift": drift, "replanned": replanned,
                "shares": observed}

    def _drift(self, observed: Dict[str, float]) -> float:
        """Worst per-tenant relative divergence of observed vs planned
        traffic shares (0 when no traffic has been observed).  Tenants
        whose *absolute* share gap is under ``policy.min_share_delta``
        contribute nothing — small-share noise must not look like a
        large relative drift."""
        if not observed:
            return 0.0
        assumed = self.plan.assumed_shares
        floor = self.policy.share_floor
        worst = 0.0
        for name in self.specs:
            a = max(assumed.get(name, 0.0), floor)
            o = observed.get(name, 0.0)
            if abs(o - a) < self.policy.min_share_delta:
                continue
            worst = max(worst, abs(o - a) / a)
        return worst

    def _replan(self, now: float, reason: str) -> None:
        """Re-plan from current EWMA rates and migrate.  Tenants with
        no observed traffic get a floor share (``policy.share_floor``
        of the observed total) — observed rates are requests/second,
        so mixing in the spec's unit-less assumed traffic would skew
        the split."""
        rates = self.traffic.rates
        total = sum(rates.values())
        floor = max(total, 1.0) * self.policy.share_floor
        specs = [dataclasses.replace(spec,
                                     traffic=max(rates.get(name, 0.0),
                                                 floor))
                 for name, spec in sorted(self.specs.items())]
        new_plan = plan_fleet(specs, self.archs,
                              force_multiplexed=self.demoted)
        self._install_plan(new_plan, now=now)
        self.migrations += 1
        self._arrivals_since_replan = 0

    # -- introspection ----------------------------------------------------
    def stats(self) -> FleetStats:
        """Per-tenant stats merged across chips *and* across any
        engines retired by migration (counters are cumulative over the
        cluster's whole life)."""
        merged: Dict[str, ServiceStats] = {
            n: s for n, s in self._retired.items()}
        for fleet in self.fleets.values():
            for name, s in fleet.stats().tenants.items():
                prev = merged.get(name, ServiceStats())
                merged[name] = prev.merge(s)
        return FleetStats(tenants=merged)

    def chip_busy_s(self) -> Dict[str, float]:
        """Cumulative engine busy seconds per chip (wall-clock),
        surviving migrations — the benchmark's parallel-chips clock
        uses max-over-chips deltas of this."""
        out = dict(self._chip_busy_base)
        for chip, fleet in self.fleets.items():
            out[chip] = out.get(chip, 0.0) + fleet.serve_s()
        return out

    def summary(self) -> str:
        """Plan + stats + control-counter digest."""
        extra = (f"cluster: {self.migrations} migrations, "
                 f"{self.demotions} demotions, {self.rejected} rejected, "
                 f"demoted={sorted(self.demoted)}, "
                 f"{self.chip_kills} kills / {self.chip_degrades} degrades, "
                 f"failed={sorted(self.failed)}")
        return "\n".join([self.plan.summary(), self.stats().summary(),
                          extra])


def _same_chip_plan(a: TenancyPlan, b: TenancyPlan) -> bool:
    """True when two intra-chip plans place the same tenants with the
    same partitions (cores/replicas/residency) — i.e. no weight
    movement is needed."""
    if set(a.tenants) != set(b.tenants):
        return False
    return all(
        (a.tenants[n].cores, a.tenants[n].replicas, a.tenants[n].resident)
        == (b.tenants[n].cores, b.tenants[n].replicas,
            b.tenants[n].resident)
        for n in a.tenants)


def _rewrite_cost(old: Optional[FleetPlan], new: FleetPlan,
                  chip: str) -> Dict[str, float]:
    """Crossbars (and cycles) that must be (re)programmed to realize
    ``new`` on ``chip`` — every resident copy whose placement differs
    from ``old`` (all of them on a fresh install).  Cycles use the
    arch's ``t_write_xb`` (compiler cycles, not wall-clock)."""
    tplan = new.chips[chip]
    arch = tplan.arch
    xbs = 0
    for name, p in tplan.tenants.items():
        if not p.resident:
            continue
        prior = None
        if old is not None and chip in old.chips:
            prior = old.chips[chip].tenants.get(name)
        if prior is not None and prior.resident \
                and (prior.replicas, prior.footprint_cores) \
                == (p.replicas, p.footprint_cores):
            continue                       # weights already in place
        xbs += p.replicas * p.footprint_cores * arch.core.n_xbs
    return {"xbs": xbs, "cycles": xbs * arch.t_write_xb()}
