from .server import BatchServer, Request  # noqa
