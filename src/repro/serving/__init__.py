from .server import BatchServer, Request  # noqa
from .cim_service import CimBatchService, CimRequest, ServiceStats  # noqa
