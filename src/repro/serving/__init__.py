"""Serving subsystem: shared request primitives, the LM batch server,
the single-workload CIM batch service, and the multi-tenant CIM fleet
(tenancy planner -> engine pool -> dynamic batcher -> router)."""
from .common import (BaseRequest, CimRequest, LmRequest,        # noqa: F401
                     ServiceStats)
from .server import BatchServer, Request                        # noqa: F401
from .cim_service import CimBatchService                        # noqa: F401
from .placement import (TenancyPlan, TenantPlacement,           # noqa: F401
                        TenantSpec, plan_tenancy)
from .engine import EnginePool, points_from_campaign            # noqa: F401
from .batcher import (DEFAULT_BUCKETS, Batch, DynamicBatcher,   # noqa: F401
                      bucket_for)
from .fleet import CimFleet, FleetStats                         # noqa: F401
