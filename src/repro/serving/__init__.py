"""Serving subsystem: shared request primitives, the LM batch server,
the single-workload CIM batch service, the single-chip multi-tenant
fleet and the cross-chip cluster (2-D tenancy planner -> engine pools
-> dynamic batchers -> routers), plus Chrome-trace observability and
synthetic diurnal+bursty traffic generation."""
from .common import (BaseRequest, CimRequest, LmRequest,        # noqa: F401
                     ServiceStats)
from .server import BatchServer, Request                        # noqa: F401
from .cim_service import CimBatchService                        # noqa: F401
from .placement import (FleetPlan, TenancyPlan,                 # noqa: F401
                        TenantPlacement, TenantSpec, plan_fleet,
                        plan_tenancy)
from .engine import EnginePool, points_from_campaign            # noqa: F401
from .batcher import (DEFAULT_BUCKETS, Batch, DynamicBatcher,   # noqa: F401
                      bucket_for)
from .trace import (TraceRecorder, load_trace,                  # noqa: F401
                    validate_chrome_trace)
from .traffic import TrafficModel, synthetic_trace              # noqa: F401
from .fleet import (AdmissionError, ChipFault, CimCluster,      # noqa: F401
                    CimFleet, FaultSchedule, FleetStats,
                    ReplanPolicy, TransientKernelError)
