"""Crossbar tenancy planner: partition one CIM chip across N models.

CIM serving is a *mapping* problem before it is a scheduling problem:
weights are stationary in crossbars, so which model owns which share of
the crossbar pool decides everything downstream — replica counts for hot
models, weight-rewrite time-multiplexing for cold ones, and whether a
request ever meets its deadline.  The planner answers that question with
the same machinery the compiler uses inside one model:

  1. **Footprint + service time** per tenant come from the real cost
     model: ``cg_opt.CostModel.placement`` / ``mapping.bind`` give the
     cores one resident copy occupies, and
     ``cg_opt.estimate_segment_cycles`` the pipelined cycles one copy
     needs per request.
  2. **Residency** is greedy by traffic: tenants are admitted resident
     (weights programmed once) in descending traffic order while their
     footprint fits, always reserving at least one core for every tenant
     still waiting.  Tenants that do not fit are *time-multiplexed*:
     their partition is smaller than one copy, so their compile becomes
     multi-segment and reprograms crossbars per inference — exactly the
     compiler's existing segmentation path, now used as a tenancy tier.
  3. **Replicas** for resident tenants reuse ``balance_duplication``
     verbatim: each tenant is presented to the CG duplication search as
     one pseudo-operator whose ``n_mvm`` is its traffic weight and whose
     ``t_window`` is its per-request service cycles, with one copy
     costing its footprint in cores.  The min-bottleneck search then
     equalizes per-replica offered load — hot models get duplicated
     copies, and the leftover-spending pass hands spare cores to
     whichever tenant is slowest, the same way it does for operators.

  The result is a ``TenancyPlan`` whose per-tenant ``CIMArch`` views
  (``CIMArch.subarch``) provably sum to at most the chip's crossbar
  pool (``TenancyPlan.validate``, asserted in tests).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

from ..core.abstraction import CIMArch
from ..core.cg_opt import CostModel, balance_duplication, \
    estimate_segment_cycles
from ..core.graph import Graph
from ..core.mapping import BitBinding


@dataclasses.dataclass
class TenantSpec:
    """One co-resident model: its graph and relative traffic share."""

    name: str
    graph: Graph
    traffic: float = 1.0             # relative request rate (any scale)
    #: compiler knob overrides for this tenant (level / binding /
    #: use_pipeline / use_duplication), e.g. a DSE campaign best point's
    #: ``DesignPoint.compile_kwargs()``
    compile_kwargs: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.traffic <= 0:
            raise ValueError(f"tenant {self.name!r}: traffic must be > 0")


@dataclasses.dataclass
class TenantPlacement:
    """The planner's verdict for one tenant."""

    spec: TenantSpec
    cores: int                       # cores in this tenant's partition
    xbs: int                         # crossbars in the partition
    replicas: int                    # resident weight copies (>= 1)
    resident: bool                   # False -> time-multiplexed (rewrites)
    footprint_cores: int             # cores one resident copy needs
    est_cycles_per_req: float        # one copy, pipelined, no duplication

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def graph(self) -> Graph:
        return self.spec.graph


@dataclasses.dataclass
class TenancyPlan:
    """A budget-respecting partition of one chip across tenants."""

    arch: CIMArch
    tenants: Dict[str, TenantPlacement]

    @property
    def cores_used(self) -> int:
        return sum(t.cores for t in self.tenants.values())

    @property
    def xbs_used(self) -> int:
        return sum(t.xbs for t in self.tenants.values())

    def subarch(self, name: str) -> CIMArch:
        """The tenant's compiler-facing ``CIMArch`` view (its partition)."""
        t = self.tenants[name]
        return self.arch.subarch(t.cores, f"{self.arch.name}/{name}")

    def validate(self) -> None:
        """Assert the plan respects the physical chip, tenant by tenant."""
        chip_xbs = self.arch.chip.n_cores * self.arch.core.n_xbs
        if self.cores_used > self.arch.chip.n_cores:
            raise AssertionError(
                f"plan uses {self.cores_used} cores > chip "
                f"{self.arch.chip.n_cores}")
        if self.xbs_used > chip_xbs:
            raise AssertionError(
                f"plan uses {self.xbs_used} crossbars > chip {chip_xbs}")
        for t in self.tenants.values():
            if t.cores < 1:
                raise AssertionError(f"tenant {t.name} got no cores")
            if t.resident and t.cores < t.replicas * t.footprint_cores:
                raise AssertionError(
                    f"tenant {t.name}: {t.replicas} replicas x "
                    f"{t.footprint_cores} cores > partition {t.cores}")

    def summary(self) -> str:
        chip_xbs = self.arch.chip.n_cores * self.arch.core.n_xbs
        lines = [f"tenancy on {self.arch.name}: {self.cores_used}/"
                 f"{self.arch.chip.n_cores} cores, {self.xbs_used}/"
                 f"{chip_xbs} crossbars"]
        for t in sorted(self.tenants.values(),
                        key=lambda p: -p.spec.traffic):
            kind = (f"resident x{t.replicas}" if t.resident
                    else "time-multiplexed")
            lines.append(
                f"  {t.name}: traffic {t.spec.traffic:g} -> {t.cores} cores "
                f"({t.xbs} xbs), {kind} "
                f"[footprint {t.footprint_cores}c, "
                f"~{t.est_cycles_per_req:.0f}cy/req]")
        return "\n".join(lines)


def _tenant_profile(spec: TenantSpec, arch: CIMArch) -> tuple:
    """(footprint cores, pipelined cycles/request at one copy, placements).

    The real cost model, not a heuristic: ``CostModel.placement`` runs
    ``mapping.bind`` per CIM node, so the footprint is exactly the cores
    one resident weight copy occupies under this tenant's binding.
    """
    binding = spec.compile_kwargs.get("binding", BitBinding.B_TO_XBC)
    if isinstance(binding, str):
        binding = BitBinding(binding)
    cm = CostModel(arch, binding)
    pls = [cm.placement(node, spec.graph) for node in spec.graph.cim_nodes]
    footprint = sum(p.cores for p in pls)
    use_pipeline = bool(spec.compile_kwargs.get("use_pipeline", True))
    cycles = estimate_segment_cycles(pls, use_pipeline)
    return max(1, footprint), max(1.0, cycles), pls


def _traffic_weights(tenants: Sequence[TenantSpec],
                     scale: int = 10_000) -> List[int]:
    """Integer traffic weights for the duplication search's ``n_mvm``.

    ``balance_duplication`` caps a pseudo-op's replicas at its ``n_mvm``,
    so the hottest tenant gets ``scale`` quanta — far above any physical
    core count — and the rest are proportional (>= 1)."""
    top = max(t.traffic for t in tenants)
    return [max(1, round(t.traffic / top * scale)) for t in tenants]


def plan_tenancy(tenants: Sequence[TenantSpec], arch: CIMArch, *,
                 min_cores: int = 1) -> TenancyPlan:
    """Partition ``arch``'s crossbar pool across ``tenants``.

    Deterministic: ties in traffic resolve by input order.  Raises if
    the chip cannot give every tenant ``min_cores`` cores; any other
    overload degrades to time-multiplexing, never to rejection.
    """
    tenants = list(tenants)
    if not tenants:
        raise ValueError("plan_tenancy needs at least one tenant")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"tenant names must be unique, got {names}")
    budget = arch.chip.n_cores
    if budget < min_cores * len(tenants):
        raise ValueError(
            f"chip has {budget} cores < {min_cores} x {len(tenants)} tenants")

    profiles = {t.name: _tenant_profile(t, arch) for t in tenants}

    # -- residency: traffic-desc greedy with a reservation for the rest --
    order = sorted(range(len(tenants)),
                   key=lambda i: (-tenants[i].traffic, i))
    resident: List[TenantSpec] = []
    multiplexed: List[TenantSpec] = []
    remaining = budget
    for rank, i in enumerate(order):
        spec = tenants[i]
        footprint = profiles[spec.name][0]
        reserve = min_cores * (len(order) - rank - 1)   # tenants after this
        if footprint <= remaining - reserve:
            resident.append(spec)
            remaining -= footprint
        else:
            multiplexed.append(spec)
            remaining -= min_cores
    resident_names = {t.name for t in resident}

    # -- partition sizes ------------------------------------------------
    cores: Dict[str, int] = {}
    pos = {t.name: i for i, t in enumerate(tenants)}
    if multiplexed:
        # the multiplexed group gets cores proportional to its share of
        # the offered load (traffic x service cycles), floored at
        # min_cores each and capped so residents keep their footprints
        load = {t.name: t.traffic * profiles[t.name][1] for t in tenants}
        total_load = sum(load.values())
        mult_load = sum(load[t.name] for t in multiplexed)
        resident_floor = sum(profiles[t.name][0] for t in resident)
        pool = round(budget * mult_load / total_load)
        pool = max(min_cores * len(multiplexed),
                   min(pool, budget - resident_floor))
        shares = sorted(multiplexed, key=lambda t: (-load[t.name],
                                                    pos[t.name]))
        left = pool
        for k, spec in enumerate(shares):
            rest = len(shares) - k - 1
            c = max(min_cores,
                    math.floor(pool * load[spec.name] / mult_load))
            c = min(c, left - min_cores * rest)
            cores[spec.name] = c
            left -= c
        cores[shares[0].name] += left          # remainder to the hottest
        resident_budget = budget - pool
    else:
        resident_budget = budget

    # -- replicas for residents: the CG duplication search verbatim -----
    replicas = {t.name: 1 for t in resident}
    for spec in resident:
        cores[spec.name] = profiles[spec.name][0]
    searchable = [t for t in resident if profiles[t.name][2]]
    if searchable:
        weights = _traffic_weights(searchable)
        fixed = sum(profiles[t.name][0] for t in resident
                    if not profiles[t.name][2])
        pseudo = []
        for spec, w in zip(searchable, weights):
            footprint, cycles, pls = profiles[spec.name]
            # one pseudo-operator per tenant: n_mvm = traffic quanta,
            # t_window = service cycles (via t_load; phases=row_groups=1),
            # one copy costs the tenant's footprint in cores
            p = dataclasses.replace(pls[0], n_mvm=w, cores=footprint,
                                    phases=1, row_groups=1, row_spread=1,
                                    t_load=float(cycles), alu_epilogue=0.0,
                                    dup=1)
            pseudo.append(p)
        balance_duplication(pseudo, resident_budget - fixed, unit="cores")
        for spec, p in zip(searchable, pseudo):
            replicas[spec.name] = p.dup
            cores[spec.name] = p.dup * profiles[spec.name][0]

    placements = {}
    for spec in tenants:
        footprint, cycles, _ = profiles[spec.name]
        placements[spec.name] = TenantPlacement(
            spec=spec, cores=cores[spec.name],
            xbs=cores[spec.name] * arch.core.n_xbs,
            replicas=replicas.get(spec.name, 1),
            resident=spec.name in resident_names,
            footprint_cores=footprint, est_cycles_per_req=cycles)
    plan = TenancyPlan(arch=arch, tenants=placements)
    plan.validate()
    return plan
