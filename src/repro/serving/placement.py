"""Crossbar tenancy planner: partition one CIM chip across N models.

CIM serving is a *mapping* problem before it is a scheduling problem:
weights are stationary in crossbars, so which model owns which share of
the crossbar pool decides everything downstream — replica counts for hot
models, weight-rewrite time-multiplexing for cold ones, and whether a
request ever meets its deadline.  The planner answers that question with
the same machinery the compiler uses inside one model:

  1. **Footprint + service time** per tenant come from the real cost
     model: ``cg_opt.CostModel.placement`` / ``mapping.bind`` give the
     cores one resident copy occupies, and
     ``cg_opt.estimate_segment_cycles`` the pipelined cycles one copy
     needs per request.
  2. **Residency** is greedy by traffic: tenants are admitted resident
     (weights programmed once) in descending traffic order while their
     footprint fits, always reserving at least one core for every tenant
     still waiting.  Tenants that do not fit are *time-multiplexed*:
     their partition is smaller than one copy, so their compile becomes
     multi-segment and reprograms crossbars per inference — exactly the
     compiler's existing segmentation path, now used as a tenancy tier.
  3. **Replicas** for resident tenants reuse ``balance_duplication``
     verbatim: each tenant is presented to the CG duplication search as
     one pseudo-operator whose ``n_mvm`` is its traffic weight and whose
     ``t_window`` is its per-request service cycles, with one copy
     costing its footprint in cores.  The min-bottleneck search then
     equalizes per-replica offered load — hot models get duplicated
     copies, and the leftover-spending pass hands spare cores to
     whichever tenant is slowest, the same way it does for operators.

  The result is a ``TenancyPlan`` whose per-tenant ``CIMArch`` views
  (``CIMArch.subarch``) provably sum to at most the chip's crossbar
  pool (``TenancyPlan.validate``, asserted in tests).

Above the single chip sits the fleet dimension: ``plan_fleet`` assigns
tenant -> chip -> crossbar pool over an N-chip fleet (per-chip arch may
differ) by water-filling offered load across chip capacities — hot
tenants split across chips (replicas span chips), cold tenants land
whole on the least-loaded chip — then runs ``plan_tenancy`` per chip,
so every intra-chip guarantee above holds per chip of the fleet.

Units: footprints are **cores/crossbars**, service times are
**compiler cycles** (not wall-clock), traffic is a caller-scaled
relative rate.  Planning is deterministic and purely functional — no
clock, no shared state — and therefore thread-safe.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Collection, Dict, List, Mapping, Sequence

from ..core.abstraction import CIMArch
from ..core.cg_opt import CostModel, balance_duplication, \
    estimate_segment_cycles
from ..core.graph import Graph
from ..core.mapping import BitBinding


@dataclasses.dataclass
class TenantSpec:
    """One co-resident model: its graph and relative traffic share."""

    name: str
    graph: Graph
    traffic: float = 1.0             # relative request rate (any scale)
    #: compiler knob overrides for this tenant (level / binding /
    #: use_pipeline / use_duplication), e.g. a DSE campaign best point's
    #: ``DesignPoint.compile_kwargs()``
    compile_kwargs: Dict = dataclasses.field(default_factory=dict)
    #: degradation rank under overload: lower-priority tenants are shed
    #: to time-multiplexed residency first (see ``CimCluster``)
    priority: int = 0

    def __post_init__(self):
        if self.traffic <= 0:
            raise ValueError(f"tenant {self.name!r}: traffic must be > 0")


@dataclasses.dataclass
class TenantPlacement:
    """The planner's verdict for one tenant."""

    spec: TenantSpec
    cores: int                       # cores in this tenant's partition
    xbs: int                         # crossbars in the partition
    replicas: int                    # resident weight copies (>= 1)
    resident: bool                   # False -> time-multiplexed (rewrites)
    footprint_cores: int             # cores one resident copy needs
    est_cycles_per_req: float        # one copy, pipelined, no duplication

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def graph(self) -> Graph:
        return self.spec.graph


@dataclasses.dataclass
class TenancyPlan:
    """A budget-respecting partition of one chip across tenants."""

    arch: CIMArch
    tenants: Dict[str, TenantPlacement]

    @property
    def cores_used(self) -> int:
        return sum(t.cores for t in self.tenants.values())

    @property
    def xbs_used(self) -> int:
        return sum(t.xbs for t in self.tenants.values())

    def subarch(self, name: str) -> CIMArch:
        """The tenant's compiler-facing ``CIMArch`` view (its partition)."""
        t = self.tenants[name]
        return self.arch.subarch(t.cores, f"{self.arch.name}/{name}")

    def validate(self) -> None:
        """Assert the plan respects the physical chip, tenant by tenant."""
        chip_xbs = self.arch.chip.n_cores * self.arch.core.n_xbs
        if self.cores_used > self.arch.chip.n_cores:
            raise AssertionError(
                f"plan uses {self.cores_used} cores > chip "
                f"{self.arch.chip.n_cores}")
        if self.xbs_used > chip_xbs:
            raise AssertionError(
                f"plan uses {self.xbs_used} crossbars > chip {chip_xbs}")
        for t in self.tenants.values():
            if t.cores < 1:
                raise AssertionError(f"tenant {t.name} got no cores")
            if t.resident and t.cores < t.replicas * t.footprint_cores:
                raise AssertionError(
                    f"tenant {t.name}: {t.replicas} replicas x "
                    f"{t.footprint_cores} cores > partition {t.cores}")

    def summary(self) -> str:
        chip_xbs = self.arch.chip.n_cores * self.arch.core.n_xbs
        lines = [f"tenancy on {self.arch.name}: {self.cores_used}/"
                 f"{self.arch.chip.n_cores} cores, {self.xbs_used}/"
                 f"{chip_xbs} crossbars"]
        for t in sorted(self.tenants.values(),
                        key=lambda p: -p.spec.traffic):
            kind = (f"resident x{t.replicas}" if t.resident
                    else "time-multiplexed")
            lines.append(
                f"  {t.name}: traffic {t.spec.traffic:g} -> {t.cores} cores "
                f"({t.xbs} xbs), {kind} "
                f"[footprint {t.footprint_cores}c, "
                f"~{t.est_cycles_per_req:.0f}cy/req]")
        return "\n".join(lines)


def _tenant_profile(spec: TenantSpec, arch: CIMArch) -> tuple:
    """(footprint cores, pipelined cycles/request at one copy, placements).

    The real cost model, not a heuristic: ``CostModel.placement`` runs
    ``mapping.bind`` per CIM node, so the footprint is exactly the cores
    one resident weight copy occupies under this tenant's binding.
    """
    binding = spec.compile_kwargs.get("binding", BitBinding.B_TO_XBC)
    if isinstance(binding, str):
        binding = BitBinding(binding)
    cm = CostModel(arch, binding)
    pls = [cm.placement(node, spec.graph) for node in spec.graph.cim_nodes]
    footprint = sum(p.cores for p in pls)
    use_pipeline = bool(spec.compile_kwargs.get("use_pipeline", True))
    cycles = estimate_segment_cycles(pls, use_pipeline)
    return max(1, footprint), max(1.0, cycles), pls


def _traffic_weights(tenants: Sequence[TenantSpec],
                     scale: int = 10_000) -> List[int]:
    """Integer traffic weights for the duplication search's ``n_mvm``.

    ``balance_duplication`` caps a pseudo-op's replicas at its ``n_mvm``,
    so the hottest tenant gets ``scale`` quanta — far above any physical
    core count — and the rest are proportional (>= 1)."""
    top = max(t.traffic for t in tenants)
    return [max(1, round(t.traffic / top * scale)) for t in tenants]


def plan_tenancy(tenants: Sequence[TenantSpec], arch: CIMArch, *,
                 min_cores: int = 1,
                 force_multiplexed: Collection[str] = ()) -> TenancyPlan:
    """Partition ``arch``'s crossbar pool across ``tenants``.

    Deterministic: ties in traffic resolve by input order.  Raises if
    the chip cannot give every tenant ``min_cores`` cores; any other
    overload degrades to time-multiplexing, never to rejection.

    ``force_multiplexed`` names tenants demoted to time-multiplexed
    residency regardless of fit — the cluster's graceful-degradation
    ladder uses this to shed low-priority tenants' resident cores to
    overloaded neighbours before rejecting traffic.
    """
    tenants = list(tenants)
    if not tenants:
        raise ValueError("plan_tenancy needs at least one tenant")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"tenant names must be unique, got {names}")
    budget = arch.chip.n_cores
    if budget < min_cores * len(tenants):
        raise ValueError(
            f"chip has {budget} cores < {min_cores} x {len(tenants)} tenants")

    profiles = {t.name: _tenant_profile(t, arch) for t in tenants}
    force_multiplexed = set(force_multiplexed)

    # -- residency: traffic-desc greedy with a reservation for the rest --
    order = sorted(range(len(tenants)),
                   key=lambda i: (-tenants[i].traffic, i))
    resident: List[TenantSpec] = []
    multiplexed: List[TenantSpec] = []
    remaining = budget
    for rank, i in enumerate(order):
        spec = tenants[i]
        footprint = profiles[spec.name][0]
        reserve = min_cores * (len(order) - rank - 1)   # tenants after this
        if (spec.name not in force_multiplexed
                and footprint <= remaining - reserve):
            resident.append(spec)
            remaining -= footprint
        else:
            multiplexed.append(spec)
            remaining -= min_cores
    resident_names = {t.name for t in resident}

    # -- partition sizes ------------------------------------------------
    cores: Dict[str, int] = {}
    pos = {t.name: i for i, t in enumerate(tenants)}
    if multiplexed:
        # the multiplexed group gets cores proportional to its share of
        # the offered load (traffic x service cycles), floored at
        # min_cores each and capped so residents keep their footprints
        load = {t.name: t.traffic * profiles[t.name][1] for t in tenants}
        total_load = sum(load.values())
        mult_load = sum(load[t.name] for t in multiplexed)
        resident_floor = sum(profiles[t.name][0] for t in resident)
        pool = round(budget * mult_load / total_load)
        pool = max(min_cores * len(multiplexed),
                   min(pool, budget - resident_floor))
        shares = sorted(multiplexed, key=lambda t: (-load[t.name],
                                                    pos[t.name]))
        left = pool
        for k, spec in enumerate(shares):
            rest = len(shares) - k - 1
            c = max(min_cores,
                    math.floor(pool * load[spec.name] / mult_load))
            c = min(c, left - min_cores * rest)
            cores[spec.name] = c
            left -= c
        cores[shares[0].name] += left          # remainder to the hottest
        resident_budget = budget - pool
    else:
        resident_budget = budget

    # -- replicas for residents: the CG duplication search verbatim -----
    replicas = {t.name: 1 for t in resident}
    for spec in resident:
        cores[spec.name] = profiles[spec.name][0]
    searchable = [t for t in resident if profiles[t.name][2]]
    if searchable:
        weights = _traffic_weights(searchable)
        fixed = sum(profiles[t.name][0] for t in resident
                    if not profiles[t.name][2])
        pseudo = []
        for spec, w in zip(searchable, weights):
            footprint, cycles, pls = profiles[spec.name]
            # one pseudo-operator per tenant: n_mvm = traffic quanta,
            # t_window = service cycles (via t_load; phases=row_groups=1),
            # one copy costs the tenant's footprint in cores
            p = dataclasses.replace(pls[0], n_mvm=w, cores=footprint,
                                    phases=1, row_groups=1, row_spread=1,
                                    t_load=float(cycles), alu_epilogue=0.0,
                                    dup=1)
            pseudo.append(p)
        balance_duplication(pseudo, resident_budget - fixed, unit="cores")
        for spec, p in zip(searchable, pseudo):
            replicas[spec.name] = p.dup
            cores[spec.name] = p.dup * profiles[spec.name][0]

    placements = {}
    for spec in tenants:
        footprint, cycles, _ = profiles[spec.name]
        placements[spec.name] = TenantPlacement(
            spec=spec, cores=cores[spec.name],
            xbs=cores[spec.name] * arch.core.n_xbs,
            replicas=replicas.get(spec.name, 1),
            resident=spec.name in resident_names,
            footprint_cores=footprint, est_cycles_per_req=cycles)
    plan = TenancyPlan(arch=arch, tenants=placements)
    plan.validate()
    return plan


# ---------------------------------------------------------------------------
# Fleet dimension: tenant -> chip -> crossbar pool.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetPlan:
    """A 2-D tenancy plan: which chips a tenant lives on, and its
    crossbar partition within each.

    ``chips`` maps chip name -> intra-chip ``TenancyPlan`` (only chips
    that received tenants appear); ``routes`` maps tenant -> {chip:
    traffic fraction} and each row sums to 1 — the router splits a
    tenant's request stream across its chip replicas in these
    proportions.  ``archs`` keeps every chip of the fleet (including
    currently-empty ones) so re-planning can use the whole pool.
    Purely descriptive state — no clock, thread-safe to share read-only.
    """

    archs: Dict[str, CIMArch]
    chips: Dict[str, TenancyPlan]
    routes: Dict[str, Dict[str, float]]

    @property
    def tenant_names(self) -> List[str]:
        """All tenants, in deterministic (sorted) order."""
        return sorted(self.routes)

    @property
    def assumed_shares(self) -> Dict[str, float]:
        """The global traffic shares this plan was built for (summing
        each tenant's per-chip planned traffic; normalized to 1)."""
        tot = {}
        for plan in self.chips.values():
            for t in plan.tenants.values():
                tot[t.name] = tot.get(t.name, 0.0) + t.spec.traffic
        s = sum(tot.values())
        return {k: v / s for k, v in tot.items()}

    def total_replicas(self, tenant: str) -> int:
        """Resident weight copies of ``tenant`` across the whole fleet
        (0 when it is time-multiplexed everywhere)."""
        n = 0
        for chip in self.routes.get(tenant, {}):
            p = self.chips[chip].tenants[tenant]
            n += p.replicas if p.resident else 0
        return n

    def validate(self) -> None:
        """Assert per-chip budgets and route consistency (raises
        ``AssertionError``)."""
        for name, plan in self.chips.items():
            if plan.arch.to_dict() != self.archs[name].to_dict():
                raise AssertionError(f"chip {name}: plan arch mismatch")
            plan.validate()
        for tenant, row in self.routes.items():
            if not row:
                raise AssertionError(f"tenant {tenant} routed nowhere")
            if abs(sum(row.values()) - 1.0) > 1e-6:
                raise AssertionError(
                    f"tenant {tenant} route weights sum to "
                    f"{sum(row.values())}, want 1")
            for chip, w in row.items():
                if w <= 0:
                    raise AssertionError(
                        f"tenant {tenant} has non-positive weight on "
                        f"{chip}")
                if tenant not in self.chips[chip].tenants:
                    raise AssertionError(
                        f"tenant {tenant} routed to {chip} but not "
                        "planned there")
        for chip, plan in self.chips.items():
            for t in plan.tenants:
                if chip not in self.routes.get(t, {}):
                    raise AssertionError(
                        f"tenant {t} planned on {chip} but not routed")

    def summary(self) -> str:
        lines = [f"fleet: {len(self.routes)} tenants on "
                 f"{len(self.chips)}/{len(self.archs)} chips"]
        for chip in sorted(self.chips):
            lines.append(self.chips[chip].summary())
        for tenant in self.tenant_names:
            row = ", ".join(f"{c}:{w:.0%}"
                            for c, w in sorted(self.routes[tenant].items()))
            lines.append(f"  route {tenant}: {row}")
        return "\n".join(lines)

    @classmethod
    def from_split(cls, split: Mapping[str, Sequence[TenantSpec]],
                   archs: Mapping[str, CIMArch], *,
                   min_cores: int = 1) -> "FleetPlan":
        """A pinned plan: each chip serves exactly the tenants ``split``
        assigns it (no cross-chip replicas).  This is the reference
        construction for the N-chip == N-independent-fleets
        bit-exactness property."""
        chips, routes = {}, {}
        for chip, specs in split.items():
            if not specs:
                continue
            chips[chip] = plan_tenancy(specs, archs[chip],
                                       min_cores=min_cores)
            for s in specs:
                if s.name in routes:
                    raise ValueError(
                        f"tenant {s.name} split onto multiple chips; "
                        "use plan_fleet for spanning replicas")
                routes[s.name] = {chip: 1.0}
        plan = cls(archs=dict(archs), chips=chips, routes=routes)
        plan.validate()
        return plan


#: route-weight grid: fractions snap to multiples of 1/16 so that
#: near-identical demand estimates (e.g. EWMA-observed vs true traffic)
#: produce *identical* routes — jittery weights like 0.51/0.49 would
#: otherwise quantize into different batch buckets than 0.50/0.50 and
#: make equivalent plans perform measurably differently
_ROUTE_GRID = 16


def _snap_route(row: Dict[str, float]) -> Dict[str, float]:
    """Snap a normalized route row onto the ``1/_ROUTE_GRID`` grid
    (largest-remainder apportionment; every chip keeps >= 1 slot so no
    planned placement is silently dropped)."""
    if len(row) <= 1:
        return {c: 1.0 for c in row}
    chips = sorted(row)
    raw = {c: row[c] * _ROUTE_GRID for c in chips}
    slots = {c: max(1, int(raw[c])) for c in chips}
    while sum(slots.values()) > _ROUTE_GRID:   # floors + min-1 overshoot
        c = min((c for c in chips if slots[c] > 1),
                key=lambda k: raw[k] - slots[k])
        slots[c] -= 1
    by_remainder = sorted(chips, key=lambda c: (slots[c] - raw[c], c))
    for c in by_remainder:
        if sum(slots.values()) >= _ROUTE_GRID:
            break
        slots[c] += 1
    return {c: slots[c] / _ROUTE_GRID for c in chips}


def plan_fleet(tenants: Sequence[TenantSpec],
               archs: Mapping[str, CIMArch], *, min_cores: int = 1,
               force_multiplexed: Collection[str] = ()) -> FleetPlan:
    """Assign tenant -> chip -> crossbar pool over an N-chip fleet.

    Offered load (traffic x per-request service cycles, profiled with
    the real cost model on each chip's own arch) is water-filled across
    chip core capacities: tenants in descending-load order each grab
    the emptiest eligible chip, spilling onto further chips when their
    demand exceeds what one chip has left — so hot tenants get
    replicas *spanning* chips while cold ones land whole.  Each chip's
    subset is then partitioned by ``plan_tenancy`` (per-chip traffic
    scaled by the split), so all intra-chip invariants hold per chip.

    Deterministic: ties resolve by input order (tenants) and sorted
    name (chips).  Raises ``ValueError`` when the fleet cannot give
    every tenant ``min_cores`` somewhere.
    """
    tenants = list(tenants)
    if not tenants:
        raise ValueError("plan_fleet needs at least one tenant")
    if not archs:
        raise ValueError("plan_fleet needs at least one chip")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"tenant names must be unique, got {names}")
    archs = dict(archs)
    chip_names = sorted(archs)
    capacity = {c: archs[c].chip.n_cores for c in chip_names}
    if sum(capacity.values()) < min_cores * len(tenants):
        raise ValueError(
            f"fleet has {sum(capacity.values())} cores < "
            f"{min_cores} x {len(tenants)} tenants")

    # offered load per tenant: traffic x mean service cycles across the
    # (possibly heterogeneous) chips it could land on
    cycles = {t.name: [_tenant_profile(t, archs[c])[1]
                       for c in chip_names] for t in tenants}
    load = {t.name: t.traffic * sum(cycles[t.name]) / len(chip_names)
            for t in tenants}
    total_load = sum(load.values())
    total_cores = sum(capacity.values())

    # -- water-fill demand (in cores) across chip capacities ------------
    remaining = dict(capacity)
    assigned: Dict[str, List[str]] = {c: [] for c in chip_names}
    weights: Dict[str, Dict[str, float]] = {}
    order = sorted(range(len(tenants)), key=lambda i: (-load[names[i]], i))

    def eligible(c: str, tenant: str) -> bool:
        # room for one more tenant under the per-chip min_cores floor
        extra = 0 if tenant in assigned[c] else 1
        return min_cores * (len(assigned[c]) + extra) <= capacity[c]

    for i in order:
        spec = tenants[i]
        demand = max(float(min_cores),
                     load[spec.name] / total_load * total_cores)
        weights[spec.name] = {}
        while demand > 1e-9:
            open_chips = [c for c in chip_names
                          if eligible(c, spec.name) and remaining[c] > 0]
            if not open_chips:
                break
            c = max(open_chips, key=lambda k: remaining[k])
            take = min(demand, remaining[c])
            # avoid sliver replicas: a spill-over piece worth less than
            # one core folds into the previous chip's share instead
            if weights[spec.name] and take < 1.0:
                break
            weights[spec.name][c] = weights[spec.name].get(c, 0.0) + take
            assigned[c] = assigned[c] if spec.name in assigned[c] \
                else assigned[c] + [spec.name]
            remaining[c] -= take
            demand -= take
        if not weights[spec.name]:
            # fleet fully claimed: park on the least-crowded eligible
            # chip (plan_tenancy will time-multiplex it there)
            fallback = [c for c in chip_names if eligible(c, spec.name)]
            if not fallback:
                raise ValueError(
                    f"no chip can host tenant {spec.name!r} (fleet "
                    f"capacity {total_cores} cores, {len(tenants)} "
                    "tenants)")
            c = max(fallback, key=lambda k: remaining[k])
            weights[spec.name][c] = float(min_cores)
            assigned[c] = assigned[c] + [spec.name]
            remaining[c] -= min_cores

    # -- per-chip tenancy plans over the split traffic -------------------
    chips: Dict[str, TenancyPlan] = {}
    routes: Dict[str, Dict[str, float]] = {}
    for t in tenants:
        tot = sum(weights[t.name].values())
        routes[t.name] = _snap_route(
            {c: w / tot for c, w in weights[t.name].items()})
    for c in chip_names:
        subset = [t for t in tenants if c in routes[t.name]]
        if not subset:
            continue
        specs = [dataclasses.replace(t, traffic=t.traffic
                                     * routes[t.name][c])
                 for t in subset]
        chips[c] = plan_tenancy(
            specs, archs[c], min_cores=min_cores,
            force_multiplexed=[n for n in force_multiplexed
                               if any(s.name == n for s in specs)])
    plan = FleetPlan(archs=archs, chips=chips, routes=routes)
    plan.validate()
    return plan
