"""Shared serving primitives: request shapes and latency accounting.

One set of dataclasses serves both frontends — the LM batch server
(``serving.server``) and the CIM fleet (``serving.cim_service`` /
``serving.fleet``) — so request identity, deadlines and latency
bookkeeping cannot drift between them:

  * ``BaseRequest`` — identity + timing fields every service shares;
  * ``CimRequest`` — one CIM inference (unbatched graph inputs/outputs);
  * ``LmRequest``  — one LM generation (prompt -> token list);
  * ``ServiceStats`` — per-service accounting with an explicit
    cumulative/windowed split: all-time counters next to windowed
    p50/p95 tail latency over recent requests.

Timing model: ``arrival_s`` / ``deadline_s`` live on one caller-chosen
clock (wall time by default; tests may inject a synthetic ``now``).
``latency_s`` is filled by the serving layer — queue wait plus batch
execution for fleet-routed requests, execution only for direct
``serve()`` calls.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class BaseRequest:
    """Base request: identity plus the timing fields every service shares.

    The timing fields are keyword-only so subclass payloads keep their
    historical positional slot right after ``rid`` (``CimRequest(3,
    inputs)`` / ``LmRequest(1, prompt)`` still bind the payload, never a
    clock field).
    """

    rid: int
    # submission time (service clock)
    arrival_s: float = dataclasses.field(default=0.0, kw_only=True)
    # absolute deadline, same clock
    deadline_s: Optional[float] = dataclasses.field(default=None,
                                                    kw_only=True)
    # filled by the service
    latency_s: float = dataclasses.field(default=0.0, kw_only=True)
    # set once the miss has been counted into some ServiceStats — a
    # request that is evicted past-deadline during migration and later
    # completes (or is evicted twice) must be counted exactly once
    miss_recorded: bool = dataclasses.field(default=False, kw_only=True)

    def missed_deadline(self, completion_s: float) -> bool:
        return self.deadline_s is not None and completion_s > self.deadline_s


@dataclasses.dataclass
class CimRequest(BaseRequest):
    """One CIM inference request (unbatched graph inputs)."""

    inputs: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    model: Optional[str] = None          # tenant id (fleet routing key)
    # filled by the service:
    outputs: Optional[Dict[str, np.ndarray]] = None


@dataclasses.dataclass
class LmRequest(BaseRequest):
    """One LM generation request (prompt in, greedy tokens out)."""

    prompt: Optional[np.ndarray] = None  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos: Optional[int] = None
    # filled by the server:
    output: Optional[List[int]] = None


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (0 for an empty list) — small-sample
    friendly: p95 of 10 requests is the 10th value, not an interpolation
    between observations that never happened."""
    if not values:
        return 0.0
    ordered = sorted(values)
    rank = max(1, int(np.ceil(q / 100.0 * len(ordered))))
    return ordered[rank - 1]


#: per-service cap on retained latencies: tails are computed over the
#: most recent window so long-running fleets stay O(1) in memory and the
#: percentiles track current behavior, not all-time history
LATENCY_WINDOW = 4096


@dataclasses.dataclass
class ServiceStats:
    """Throughput counters + tail-latency accounting for one service.

    The bundle holds two kinds of state, and the split is part of the
    contract:

      * **cumulative** (all-time, monotone): ``requests``, ``batches``,
        ``serve_s`` and ``deadline_misses`` count everything the service
        ever did — dashboards diff them across scrapes;
      * **windowed** (recent, bounded): ``window_latencies_s`` and
        ``window_missed`` retain only the most recent ``LATENCY_WINDOW``
        requests, so ``p50_latency_s`` / ``p95_latency_s`` /
        ``window_deadline_misses`` describe *current* traffic and a
        long-running fleet stays O(1) in memory.

    Units and clocks: latencies and ``serve_s`` are **seconds on the
    service clock** the caller drives (wall time by default, synthetic
    in tests/benchmarks) — never compiler cycles.  Thread-safety: plain
    mutable state owned by one service on one thread; ``merge`` returns
    a new bundle and mutates neither operand.
    """

    requests: int = 0                    # cumulative served requests
    batches: int = 0                     # cumulative dispatched batches
    serve_s: float = 0.0                 # cumulative busy seconds
    deadline_misses: int = 0             # cumulative missed deadlines
    #: sliding window of recent per-request latencies (seconds)
    window_latencies_s: List[float] = dataclasses.field(default_factory=list)
    #: window of recent per-request miss flags.  Served requests append
    #: in lockstep with ``window_latencies_s``; misses discovered
    #: outside a batch (``record_misses`` — e.g. eviction during
    #: migration) append here only, so the two windows may differ in
    #: length while ``window_deadline_misses`` stays complete.
    window_missed: List[bool] = dataclasses.field(default_factory=list)

    def record(self, latencies_s: List[float], batch_s: float,
               misses: int = 0,
               missed: Optional[List[bool]] = None) -> None:
        """Account one served batch: per-request latencies (seconds) +
        batch busy seconds.  ``missed`` optionally flags which of the
        batch's requests missed their deadline (defaults to the first
        ``misses`` positions, which preserves the windowed count)."""
        self.requests += len(latencies_s)
        self.batches += 1
        self.serve_s += batch_s
        self.deadline_misses += misses
        if missed is None:
            missed = [i < misses for i in range(len(latencies_s))]
        self.window_latencies_s.extend(latencies_s)
        self.window_missed.extend(missed)
        del self.window_latencies_s[:-LATENCY_WINDOW]
        del self.window_missed[:-LATENCY_WINDOW]

    def record_misses(self, n: int) -> None:
        """Account ``n`` deadline misses discovered outside a served
        batch — requests evicted past-deadline during migration or
        chip failover never reach ``record``, and silently dropping
        their misses undercounts both the cumulative and the windowed
        counters.  No latency is recorded (none was measured)."""
        if n <= 0:
            return
        self.deadline_misses += n
        self.window_missed.extend([True] * n)
        del self.window_missed[:-LATENCY_WINDOW]

    @property
    def requests_per_s(self) -> float:
        """Cumulative throughput: all-time requests over busy seconds."""
        return self.requests / self.serve_s if self.serve_s > 0 else 0.0

    @property
    def p50_latency_s(self) -> float:
        """Median latency over the recent window (seconds)."""
        return percentile(self.window_latencies_s, 50.0)

    @property
    def p95_latency_s(self) -> float:
        """Tail latency over the recent window (seconds)."""
        return percentile(self.window_latencies_s, 95.0)

    @property
    def window_deadline_misses(self) -> int:
        """Missed deadlines among the window's requests (recent, not
        all-time — compare with cumulative ``deadline_misses``)."""
        return sum(self.window_missed)

    def merge(self, other: "ServiceStats") -> "ServiceStats":
        """Combine two bundles (fleet aggregate view): cumulative
        counters add; the merged window keeps the most recent
        ``LATENCY_WINDOW`` entries of the concatenation."""
        return ServiceStats(
            requests=self.requests + other.requests,
            batches=self.batches + other.batches,
            serve_s=self.serve_s + other.serve_s,
            deadline_misses=self.deadline_misses + other.deadline_misses,
            window_latencies_s=(self.window_latencies_s
                                + other.window_latencies_s)[-LATENCY_WINDOW:],
            window_missed=(self.window_missed
                           + other.window_missed)[-LATENCY_WINDOW:])
