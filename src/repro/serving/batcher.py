"""Deadline-aware dynamic batching with bucketed batch sizes.

One ``DynamicBatcher`` fronts one tenant's engine.  Requests accumulate
in an earliest-deadline-first queue; a batch is released when any of
three conditions holds:

  * **full bucket** — the queue can fill the largest bucket, so waiting
    longer cannot improve packing;
  * **age** — the oldest request has waited ``max_wait_s``, the classic
    dynamic-batching knob bounding added latency under light traffic;
  * **deadline pressure** — the earliest absolute deadline minus the
    estimated service time of the would-be batch says dispatching any
    later would miss it.

Batch sizes are *bucketed* (default powers of two up to the engine's
``max_batch``): a drained batch of 3 is padded up to the 4-bucket by the
engine (``CimBatchService.serve_padded``), so only ``len(buckets)``
batch shapes are ever jit-traced per tenant — ragged queue lengths reuse
cached executables instead of paying a fresh trace each.

The batcher is clock-agnostic: every decision takes an explicit ``now``
so fleets can run on wall time while tests drive a synthetic clock.
All times (``now``, ``max_wait_s``, ``est_batch_s``, deadlines) are
**seconds on that one caller-chosen clock** — never compiler cycles.
Thread-safety: plain mutable queue state, not locked; one batcher is
owned by one fleet thread.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Union

from .common import CimRequest

#: default bucket ladder (powers of two; the engine's max_batch caps it)
DEFAULT_BUCKETS = (1, 2, 4, 8)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (the largest bucket for oversized n)."""
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


@dataclasses.dataclass
class Batch:
    """One released batch: the requests plus the executable bucket."""

    requests: List[CimRequest]
    bucket: int
    reason: str                      # "full" | "age" | "deadline" | "flush"

    def __len__(self) -> int:
        return len(self.requests)


class DynamicBatcher:
    """EDF queue + bucketed release policy for one tenant."""

    def __init__(self, *, buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_wait_s: float = 0.002,
                 est_batch_s: Union[float, None,
                                    Callable[[int], Optional[float]]] = 0.0):
        """``est_batch_s`` estimates the service time of a batch of the
        given bucket size (constant or callable).  ``None`` (or a
        callable returning ``None``) means *unknown* — deadlined work is
        then released immediately rather than gambling on a wait."""
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be sorted unique, got {buckets}")
        self.buckets = tuple(int(b) for b in buckets)
        self.max_wait_s = max_wait_s
        self._est = (est_batch_s if callable(est_batch_s)
                     else (lambda n, c=est_batch_s: c))
        self.queue: List[CimRequest] = []

    def __len__(self) -> int:
        return len(self.queue)

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def submit(self, req: CimRequest) -> None:
        self.queue.append(req)

    def _edf_order(self) -> List[CimRequest]:
        """Earliest deadline first; deadline-free requests by arrival."""
        return sorted(self.queue,
                      key=lambda r: (r.deadline_s if r.deadline_s is not None
                                     else float("inf"), r.arrival_s, r.rid))

    def release_reason(self, now: float) -> Optional[str]:
        """Why a batch should be released at ``now`` (None: keep waiting)."""
        if not self.queue:
            return None
        if len(self.queue) >= self.max_bucket:
            return "full"
        if now - min(r.arrival_s for r in self.queue) >= self.max_wait_s:
            return "age"
        deadlines = [r.deadline_s for r in self.queue
                     if r.deadline_s is not None]
        if deadlines:
            est = self._est(bucket_for(len(self.queue), self.buckets))
            # unknown service time: waiting on a deadline is a gamble we
            # cannot price, so dispatch deadlined work right away
            if est is None or min(deadlines) - now <= est:
                return "deadline"
        return None

    def next_batch(self, now: float, force: bool = False) -> Optional[Batch]:
        """Pop one batch if the release policy (or ``force``) says go.

        Pops up to ``max_bucket`` requests in EDF order and assigns the
        smallest covering bucket; remaining requests stay queued for the
        next call (an over-full queue drains ``max_bucket`` at a time).
        """
        reason = self.release_reason(now)
        if reason is None:
            if not force or not self.queue:
                return None
            reason = "flush"
        ordered = self._edf_order()
        take = ordered[:self.max_bucket]
        taken_ids = {id(r) for r in take}
        self.queue = [r for r in self.queue if id(r) not in taken_ids]
        return Batch(requests=take, bucket=bucket_for(len(take),
                                                      self.buckets),
                     reason=reason)

    def drain(self, now: float) -> List[Batch]:
        """Flush the whole queue as bucketed batches (end of trace /
        shutdown).  An empty queue yields no batches."""
        out = []
        while self.queue:
            out.append(self.next_batch(now, force=True))
        return out
