"""Chrome-trace observability for the serving tier.

``TraceRecorder`` collects Chrome Trace Event Format events (the JSON
consumed by ``chrome://tracing`` and https://ui.perfetto.dev) from the
fleet's hot paths — batcher queue waits, engine dispatch spans,
weight-rewrite/migration events and per-chip utilization counters — so
"where did the time go" is a drag-and-drop question, not a printf one.

Mapping onto the trace model:

  * **process (pid)** = one CIM chip (``chrome://tracing`` groups rows
    by process; ``register_chip`` emits the ``process_name`` metadata);
  * **thread (tid)**  = one tenant on that chip (``register_tenant``
    emits ``thread_name``), plus tid 0 reserved for chip-level control
    events (plan application, migration);
  * **complete events (``ph: "X"``)** = spans: queue waits and engine
    dispatches;
  * **instant events (``ph: "i"``)** = points: admissions rejections,
    re-plan triggers;
  * **counter events (``ph: "C"``)** = per-chip utilization and queue
    depth sampled by the cluster control loop.

Units and clocks: the recorder's timeline is the *service clock* — the
same caller-chosen ``now`` values (seconds) the fleet and batcher run
on (wall time in production, synthetic in tests/benchmarks).  Event
``ts``/``dur`` are emitted in **microseconds** as the trace format
requires.  Durations measured in wall-clock seconds (engine dispatch
time) are placed on that same timeline at the caller's ``now`` — under
a wall clock the two coincide; under a synthetic clock the spans show
the serving model's own accounting.  Cycle-denominated costs (weight
rewrites) are attached as ``args``, never as span durations.

Thread-safety: a recorder is plain mutable state owned by one fleet /
cluster on one thread; share one recorder across chips of one cluster,
not across clusters running concurrently.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Union

#: event phases the serving layer emits (subset of the trace format)
_PHASES = ("X", "i", "C", "M")

#: fields every emitted event carries (the format's required core)
_REQUIRED = ("name", "ph", "ts", "pid", "tid")


def _us(t_s: float) -> float:
    """Service-clock seconds -> trace microseconds (float is allowed)."""
    return round(t_s * 1e6, 3)


class TraceRecorder:
    """Accumulates Chrome-trace events for one fleet/cluster.

    All ``*_s`` arguments are service-clock seconds (see module
    docstring); ``args`` values must be JSON-serializable.  Not
    thread-safe — one recorder per serving frontend.
    """

    def __init__(self):
        self.events: List[dict] = []
        self._pids: Dict[str, int] = {}          # chip name -> pid
        self._tids: Dict[tuple, int] = {}        # (pid, tenant) -> tid

    # -- registry --------------------------------------------------------
    def register_chip(self, chip: str) -> int:
        """Assign (or return) the pid for ``chip``; emits process_name
        metadata on first registration."""
        if chip not in self._pids:
            pid = len(self._pids) + 1
            self._pids[chip] = pid
            self.events.append({"name": "process_name", "ph": "M",
                                "ts": 0, "pid": pid, "tid": 0,
                                "args": {"name": f"chip:{chip}"}})
        return self._pids[chip]

    def register_tenant(self, chip: str, tenant: str) -> int:
        """Assign (or return) the tid for ``tenant`` on ``chip``; emits
        thread_name metadata on first registration (tid 0 is reserved
        for chip-level control events)."""
        pid = self.register_chip(chip)
        key = (pid, tenant)
        if key not in self._tids:
            tid = 1 + sum(1 for (p, _) in self._tids if p == pid)
            self._tids[key] = tid
            self.events.append({"name": "thread_name", "ph": "M",
                                "ts": 0, "pid": pid, "tid": tid,
                                "args": {"name": f"tenant:{tenant}"}})
        return self._tids[key]

    # -- emitters --------------------------------------------------------
    def complete(self, chip: str, tenant: str, name: str, cat: str,
                 ts_s: float, dur_s: float, **args) -> None:
        """One span (``ph: "X"``): starts at ``ts_s``, lasts ``dur_s``
        (service-clock seconds; negative durations are clamped to 0)."""
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": _us(ts_s), "dur": _us(max(0.0, dur_s)),
            "pid": self.register_chip(chip),
            "tid": self.register_tenant(chip, tenant),
            "args": args})

    def instant(self, chip: str, name: str, cat: str, ts_s: float,
                tenant: Optional[str] = None, **args) -> None:
        """One point event (``ph: "i"``, thread scope); chip-level when
        ``tenant`` is None (tid 0)."""
        tid = (self.register_tenant(chip, tenant) if tenant is not None
               else (self.register_chip(chip), 0)[1])
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": _us(ts_s), "pid": self.register_chip(chip),
            "tid": tid, "args": args})

    def counter(self, chip: str, name: str, ts_s: float,
                values: Dict[str, float]) -> None:
        """One counter sample (``ph: "C"``): ``values`` maps series name
        to value (e.g. ``{"utilization": 0.73}``)."""
        self.events.append({
            "name": name, "cat": "counter", "ph": "C",
            "ts": _us(ts_s), "pid": self.register_chip(chip),
            "tid": 0, "args": dict(values)})

    # -- output ----------------------------------------------------------
    def to_dict(self) -> dict:
        """The JSON-object trace (``traceEvents`` array form) — the shape
        both ``chrome://tracing`` and Perfetto load directly."""
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: Union[str, Path]) -> Path:
        """Write the trace as JSON; returns the path.  Load the file in
        https://ui.perfetto.dev ("Open trace file") or chrome://tracing."""
        path = Path(path)
        path.write_text(json.dumps(self.to_dict()) + "\n", encoding="utf-8")
        return path

    def __len__(self) -> int:
        return len(self.events)


def validate_chrome_trace(trace: dict) -> None:
    """Validate ``trace`` against the Chrome Trace Event Format subset
    this layer emits; raises ``ValueError`` with the first violation.

    Checks the JSON-object form (``traceEvents`` array), per-event
    required fields, known phases, numeric non-negative timestamps,
    ``dur`` on complete events, and ``args`` being JSON objects — the
    properties Perfetto's importer actually relies on.
    """
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a JSON object with 'traceEvents'")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be an array")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        for field in _REQUIRED:
            if field not in ev:
                raise ValueError(f"event {i}: missing field {field!r}")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"event {i}: unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i}: bad ts {ev['ts']!r}")
        for field in ("pid", "tid"):
            if not isinstance(ev[field], int):
                raise ValueError(f"event {i}: {field} must be an int")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i}: complete event needs dur >= 0")
        if ev["ph"] == "C" and not ev.get("args"):
            raise ValueError(f"event {i}: counter event needs args values")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i}: args must be an object")
    # one timeline: metadata aside, events must carry registered pids
    pids = {ev["pid"] for ev in events if ev["ph"] == "M"}
    for i, ev in enumerate(events):
        if ev["ph"] != "M" and pids and ev["pid"] not in pids:
            raise ValueError(f"event {i}: pid {ev['pid']} never registered")


def load_trace(path: Union[str, Path]) -> dict:
    """Read a trace JSON file and validate it; returns the trace dict."""
    trace = json.loads(Path(path).read_text(encoding="utf-8"))
    validate_chrome_trace(trace)
    return trace
