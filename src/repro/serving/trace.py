"""Compatibility shim — the Chrome-trace recorder moved to
:mod:`repro.obs.trace` when observability became stack-wide (compiler,
executor and DSE spans share the serving fleet's timeline).  Importing
``TraceRecorder`` / ``validate_chrome_trace`` / ``load_trace`` from
here keeps working; new code should import from ``repro.obs.trace``.
"""
from ..obs.trace import (TraceRecorder, load_trace,       # noqa: F401
                         validate_chrome_trace)

__all__ = ["TraceRecorder", "validate_chrome_trace", "load_trace"]
