"""Batched serving loop: prefill + decode with slot-based continuous
batching.

A fixed pool of batch slots serves a request queue: finished sequences
free their slot, the next request's prompt is prefilled into it (padded
prefill per slot batch), and every decode step advances all live slots
together — the standard TPU serving shape (decode_32k lowers exactly
this ``serve_step``).
"""
from __future__ import annotations

import time
from collections import deque
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import lm
from .common import LmRequest as Request  # shared serving primitives


class BatchServer:
    """Slot-based LM batch server over jitted prefill/decode.

    Units and clocks: request ``latency_s`` is **wall-clock seconds**
    measured around each served batch with ``time.time()`` — this
    frontend does not take a caller-supplied ``now`` (unlike the CIM
    fleet).  Thread-safety: not thread-safe; one server instance per
    thread (the jitted callables are shared safely, the queue walk is
    not).
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(p, cfg, b, cache_len=max_len))
        self._decode = jax.jit(
            lambda p, c, b, pos: lm.decode_step(p, cfg, c, b, pos))

    def serve(self, requests: List[Request], greedy: bool = True
              ) -> List[Request]:
        """Serve all ``requests`` to completion in slot-sized batches;
        fills each request's ``output`` tokens and wall-clock
        ``latency_s``, returning the requests in completion order."""
        queue = deque(requests)
        done: List[Request] = []
        while queue:
            batch = [queue.popleft() for _ in range(min(self.slots,
                                                        len(queue)))]
            t0 = time.time()
            self._serve_batch(batch)
            for r in batch:
                r.latency_s = time.time() - t0
            done.extend(batch)
        return done

    def _serve_batch(self, batch: List[Request]) -> None:
        b = len(batch)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(toks)})
        outputs = [[] for _ in batch]
        live = np.ones(b, bool)
        cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        for i in range(b):
            outputs[i].append(int(cur[i]))
        max_new = max(r.max_new_tokens for r in batch)
        pos = plen
        for _ in range(max_new - 1):
            if not live.any() or pos >= self.max_len:
                break
            step_batch = {"tokens": jnp.asarray(cur[:, None])}
            logits, cache = self._decode(self.params, cache, step_batch,
                                         jnp.int32(pos))
            cur = np.asarray(jnp.argmax(logits[:, 0], axis=-1)).astype(np.int32)
            pos += 1
            for i, r in enumerate(batch):
                if not live[i] or len(outputs[i]) >= r.max_new_tokens:
                    live[i] = live[i] and len(outputs[i]) < r.max_new_tokens
                    continue
                outputs[i].append(int(cur[i]))
                if r.eos is not None and cur[i] == r.eos:
                    live[i] = False
        for r, out in zip(batch, outputs):
            r.output = out
