"""Run-level performance options (§Perf hillclimbing levers).

These are *scheduling* choices, not architecture hyperparameters, so
they live outside ModelConfig; the defaults reproduce the paper-faithful
baseline, and the dry-run's ``--opt`` mode enables the optimized set.
Threaded via a context manager so the model code stays signature-stable.

Levers (each a recorded hypothesis->measure iteration in EXPERIMENTS.md):
  * ``triangular_attention`` — blockwise attention iterates only visible
    (q-block, kv-block) pairs (causal lower-triangle / sliding-window
    band) instead of the full nq x nk grid: ~2x less attention compute
    and HBM traffic for causal, ~S/window for banded prefill.
  * ``attn_reshard`` — explicit sharding constraints around attention:
    "head" shards heads on "model" when they divide evenly, otherwise
    replicates attention over "model" (trading a little redundant
    compute for eliminating the per-score-block all-reduces that the
    baseline's head_dim-sharded activations induce).
  * ``kv_quant_int8`` — int8 KV cache with per-(position, head) scales:
    halves the decode-attention cache traffic (memory-bound cells).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class PerfOpts:
    triangular_attention: bool = False
    attn_reshard: str = "none"          # none | auto
    kv_quant_int8: bool = False
    remat_policy: str = "full"          # full | dots (save matmul outputs)
    decode_opt: bool = False            # append-style decode, no-cast scores
    moe_capacity_shard: bool = False    # shard expert token buffers on data
    mesh: Optional[object] = None       # concrete mesh for constraints
    batch_axes: Tuple[str, ...] = ("data",)


_CURRENT = PerfOpts()


def current() -> PerfOpts:
    return _CURRENT


@contextlib.contextmanager
def use_perf_opts(opts: PerfOpts):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = opts
    try:
        yield
    finally:
        _CURRENT = prev


# moe_capacity_shard stays OFF: measured a 2.7x collective REGRESSION on
# mixtral train (the xe resharding all-to-alls outweigh the saved
# all-reduces) — kept as a lever, documented as refuted in EXPERIMENTS.md
OPTIMIZED = PerfOpts(triangular_attention=True, attn_reshard="auto",
                     remat_policy="dots", decode_opt=True)
