"""Generic language model covering the assigned architecture pool.

One config-driven implementation provides:
  * attention mixers: GQA/MHA (full, sliding-window, alternating),
    softcaps, QKV bias, RoPE / M-RoPE; MLA (DeepSeek-V2) with compressed
    KV cache and absorbed decode; Mamba2 SSD; Hymba parallel attn+SSM.
  * MLPs: gated (SwiGLU/GeGLU), dense, MoE (top-k, shared experts), none.
  * encoder-decoder (Seamless-M4T): bidirectional encoder + causal
    decoder with cross-attention.

Layers are scan-stacked over the repeating ``cfg.unit`` recipe; params
are plain nested dicts with a parallel *logical-axes* tree consumed by
launch/sharding.py.  Entry points: ``forward`` / ``lm_loss`` (train),
``prefill`` and ``decode_step`` (serving).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LayerSpec, ModelConfig
from . import ssm as ssm_mod
from .layers import (AttnSpec, apply_mrope, apply_rope, attention,
                     cache_update, decode_attention, dense_mlp, gated_mlp,
                     init_from_specs, moe_mlp, rms_norm, softcap)

Params = Dict[str, Any]
P_AXES = "__axes__"  # sentinel unused; axes tree is separate


def _sds(shape, dtype=None):
    return jax.ShapeDtypeStruct(tuple(shape), dtype or jnp.bfloat16)


# ---------------------------------------------------------------------------
# Parameter specs + logical axes
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ModelConfig):
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sp = {"wq": _sds((d, h * hd)), "wk": _sds((d, k * hd)),
          "wv": _sds((d, k * hd)), "wo": _sds((h * hd, d))}
    ax = {"wq": ("embed", "heads"), "wk": ("embed", "kv"),
          "wv": ("embed", "kv"), "wo": ("heads", "embed")}
    if cfg.qkv_bias:
        sp.update({"bq": _sds((h * hd,)), "bk": _sds((k * hd,)),
                   "bv": _sds((k * hd,))})
        ax.update({"bq": ("heads",), "bk": ("kv",), "bv": ("kv",)})
    return sp, ax


def _mla_specs(cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    sp = {"wq": _sds((d, h * qd)),
          "w_dkv": _sds((d, cfg.kv_lora + cfg.qk_rope_dim)),
          "kv_norm": _sds((cfg.kv_lora,), jnp.float32),
          "w_uk": _sds((cfg.kv_lora, h * cfg.qk_nope_dim)),
          "w_uv": _sds((cfg.kv_lora, h * cfg.v_head_dim)),
          "wo": _sds((h * cfg.v_head_dim, d))}
    ax = {"wq": ("embed", "heads"), "w_dkv": ("embed", None),
          "kv_norm": (None,), "w_uk": (None, "heads"),
          "w_uv": (None, "heads"), "wo": ("heads", "embed")}
    return sp, ax


def _ssm_specs(cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.n_ssm_heads
    n = cfg.ssm_state
    sp = {"w_z": _sds((d, di)), "w_x": _sds((d, di)),
          "w_B": _sds((d, n)), "w_C": _sds((d, n)), "w_dt": _sds((d, h)),
          "A_log": _sds((h,), jnp.float32), "D_skip": _sds((h,), jnp.float32),
          "dt_bias": _sds((h,), jnp.float32),
          "ssm_norm": _sds((di,), jnp.float32),
          "out_proj": _sds((di, d))}
    ax = {"w_z": ("embed", "inner"), "w_x": ("embed", "inner"),
          "w_B": ("embed", None), "w_C": ("embed", None),
          "w_dt": ("embed", None), "A_log": (None,), "D_skip": (None,),
          "dt_bias": (None,), "ssm_norm": (None,),
          "out_proj": ("inner", "embed")}
    return sp, ax


def _mlp_specs(cfg: ModelConfig, kind: str):
    d, f = cfg.d_model, cfg.d_ff
    if kind == "none":
        return {}, {}
    if kind == "gated":
        return ({"wi": _sds((d, f)), "wg": _sds((d, f)),
                 "wo_mlp": _sds((f, d))},
                {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"),
                 "wo_mlp": ("mlp", "embed")})
    if kind == "dense":
        return ({"wi": _sds((d, f)), "wo_mlp": _sds((f, d))},
                {"wi": ("embed", "mlp"), "wo_mlp": ("mlp", "embed")})
    if kind == "moe":
        e, fm = cfg.n_experts, cfg.moe_d_ff
        sp = {"router": _sds((d, e), jnp.float32),
              "wi": _sds((e, d, fm)), "wg": _sds((e, d, fm)),
              "wo_mlp": _sds((e, fm, d))}
        ax = {"router": ("embed", None),
              "wi": ("expert", "embed", "mlp_e"),
              "wg": ("expert", "embed", "mlp_e"),
              "wo_mlp": ("expert", "mlp_e", "embed")}
        if cfg.n_shared_experts:
            fs = fm * cfg.n_shared_experts
            sp.update({"swi": _sds((d, fs)), "swg": _sds((d, fs)),
                       "swo": _sds((fs, d))})
            ax.update({"swi": ("embed", "mlp"), "swg": ("embed", "mlp"),
                       "swo": ("mlp", "embed")})
        return sp, ax
    raise ValueError(kind)


def _layer_specs(cfg: ModelConfig, spec: LayerSpec, cross_attn: bool = False):
    sp: Params = {"norm": _sds((cfg.d_model,), jnp.float32)}
    ax: Params = {"norm": (None,)}
    if spec.mixer == "attn":
        s, a = _attn_specs(cfg)
        sp.update(s), ax.update(a)
    elif spec.mixer == "mla":
        s, a = _mla_specs(cfg)
        sp.update(s), ax.update(a)
    elif spec.mixer == "ssm":
        s, a = _ssm_specs(cfg)
        sp.update(s), ax.update(a)
    elif spec.mixer == "hybrid":
        s, a = _attn_specs(cfg)
        sp["attn"] = s
        ax["attn"] = a
        s, a = _ssm_specs(cfg)
        del s["w_z"], a["w_z"]          # hymba branch: no gate path
        sp["ssm"] = s
        ax["ssm"] = a
        sp.update({"fuse_a": _sds((cfg.d_model,), jnp.float32),
                   "fuse_s": _sds((cfg.d_model,), jnp.float32)})
        ax.update({"fuse_a": (None,), "fuse_s": (None,)})
    else:
        raise ValueError(spec.mixer)
    if cross_attn:
        s, a = _attn_specs(cfg)
        sp["cross"] = s
        ax["cross"] = a
        sp["cross_norm"] = _sds((cfg.d_model,), jnp.float32)
        ax["cross_norm"] = (None,)
    if spec.mlp != "none":
        sp["mlp_norm"] = _sds((cfg.d_model,), jnp.float32)
        ax["mlp_norm"] = (None,)
        s, a = _mlp_specs(cfg, spec.mlp)
        sp.update(s), ax.update(a)
    return sp, ax


def _stack(tree: Params, n: int) -> Params:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n,) + tuple(x.shape), x.dtype), tree)


def _stack_axes(tree: Params) -> Params:
    return jax.tree.map(lambda a: ("layers",) + tuple(a), tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def param_specs(cfg: ModelConfig) -> Params:
    return _specs_and_axes(cfg)[0]


def logical_axes(cfg: ModelConfig) -> Params:
    return _specs_and_axes(cfg)[1]


def _specs_and_axes(cfg: ModelConfig) -> Tuple[Params, Params]:
    # the embedding's feature dim stays unsharded: a (vocab x feature)
    # double-sharded table makes the token gather fall into SPMD's
    # "involuntary full rematerialization" path (observed on the dry-run)
    sp: Params = {"embed": _sds((cfg.vocab, cfg.d_model)),
                  "final_norm": _sds((cfg.d_model,), jnp.float32)}
    ax: Params = {"embed": ("vocab", None), "final_norm": (None,)}

    pre_sp, pre_ax = [], []
    for spec in cfg.pre:
        s, a = _layer_specs(cfg, spec)
        pre_sp.append(s), pre_ax.append(a)
    if pre_sp:
        sp["pre"] = tuple(pre_sp)
        ax["pre"] = tuple(pre_ax)

    unit_sp, unit_ax = {}, {}
    r = cfg.n_unit_repeats
    for i, spec in enumerate(cfg.unit):
        s, a = _layer_specs(cfg, spec, cross_attn=cfg.enc_dec)
        unit_sp[f"u{i}"] = _stack(s, r)
        unit_ax[f"u{i}"] = _stack_axes(a)
    sp["unit"] = unit_sp
    ax["unit"] = unit_ax

    if cfg.enc_dec:
        es, ea = _layer_specs(cfg, LayerSpec(mixer="attn", mlp="dense"))
        sp["enc_unit"] = _stack(es, cfg.n_enc_layers)
        ax["enc_unit"] = _stack_axes(ea)
        sp["enc_norm"] = _sds((cfg.d_model,), jnp.float32)
        ax["enc_norm"] = (None,)
    return sp, ax


def init_params(cfg: ModelConfig, rng: jax.Array) -> Params:
    params = init_from_specs(param_specs(cfg), rng)
    # SSM decay init: A in [-1, -e] keeps exp(dt*A) in (0,1)
    def fix(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "A_log":
            return jnp.zeros_like(x)          # A = -1
        if name == "dt_bias":
            return jnp.full_like(x, -2.0)     # small positive dt
        return x
    return jax.tree_util.tree_map_with_path(fix, params)


# ---------------------------------------------------------------------------
# Mixers (forward, full sequence)
# ---------------------------------------------------------------------------

def _attn_spec(cfg: ModelConfig, spec: LayerSpec, causal: bool = True):
    return AttnSpec(causal=causal, window=spec.window,
                    logit_softcap=cfg.attn_softcap)


def _qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    b, s, _ = x.shape
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    kk = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, kk, v = q + p["bq"], kk + p["bk"], v + p["bv"]
    return (q.reshape(b, s, h, hd), kk.reshape(b, s, k, hd),
            v.reshape(b, s, k, hd))


def _rope_qk(cfg: ModelConfig, q, k, positions, positions3):
    if cfg.mrope and positions3 is not None:
        q = apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _attn_reshard(t: jnp.ndarray) -> jnp.ndarray:
    """PerfOpts lever: explicit sharding for attention activations.

    The baseline lets SPMD propagate the projections' model-sharded
    feature dim into the (B,S,H,D) views, which shards head_dim and
    turns every score-block einsum into an all-reduce.  "auto" instead
    shards the *head* axis when it divides the model axis, else
    replicates attention over "model" (a little redundant compute for
    zero per-block collectives)."""
    from .perfopts import current
    opts = current()
    if opts.attn_reshard == "none" or opts.mesh is None:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = opts.mesh
    batch = opts.batch_axes if len(opts.batch_axes) > 1 else opts.batch_axes[0]
    h = t.shape[2]
    head_ax = "model" if h % mesh.shape["model"] == 0 else None
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(batch, None, head_ax, None)))


def attn_mixer(p: Params, cfg: ModelConfig, spec: LayerSpec, x, positions,
               positions3=None, causal=True):
    q, k, v = _qkv(p, cfg, x)
    q, k, v = _attn_reshard(q), _attn_reshard(k), _attn_reshard(v)
    q, k = _rope_qk(cfg, q, k, positions, positions3)
    out = attention(q, k, v, _attn_spec(cfg, spec, causal))
    b, s, _, _ = q.shape
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, -1), p["wo"])
    return y, {"k": k, "v": v}


def mla_mixer(p: Params, cfg: ModelConfig, spec: LayerSpec, x, positions):
    b, s, d = x.shape
    h = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dh->bsh", x, p["w_dkv"])
    ckv, k_rope = dkv[..., :cfg.kv_lora], dkv[..., cfg.kv_lora:]
    ckv = rms_norm(ckv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)                   # (B,S,1,rd)
    k_nope = jnp.einsum("bsl,lh->bsh", ckv, p["w_uk"]).reshape(b, s, h, nd)
    v = jnp.einsum("bsl,lh->bsh", ckv, p["w_uv"]).reshape(b, s, h, vd)

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope,
                          jnp.broadcast_to(k_rope, (b, s, h, rd))], axis=-1)
    out = attention(qf, kf, v, _attn_spec(cfg, spec))
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, h * vd), p["wo"])
    return y, {"ckv": ckv, "kr": k_rope[:, :, 0, :]}


def _ssm_inputs(p: Params, cfg: ModelConfig, x):
    xs = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    B = jnp.einsum("bsd,dn->bsn", x, p["w_B"])
    C = jnp.einsum("bsd,dn->bsn", x, p["w_C"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    return xs, B, C, dt, A


def ssm_mixer(p: Params, cfg: ModelConfig, x, gated: bool = True):
    b, s, _ = x.shape
    h, hp = cfg.n_ssm_heads, cfg.ssm_headdim
    xs, B, C, dt, A = _ssm_inputs(p, cfg, x)
    y = ssm_mod.ssd_scan(xs.reshape(b, s, h, hp), dt, A, B, C,
                         p["D_skip"], cfg.ssm_chunk).reshape(b, s, -1)
    if gated and "w_z" in p:
        z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
        y = y * jax.nn.silu(z)
    y = rms_norm(y, p["ssm_norm"])
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


def hybrid_mixer(p: Params, cfg: ModelConfig, spec: LayerSpec, x, positions):
    ya, kv = attn_mixer(p["attn"], cfg, spec, x, positions)
    ys = ssm_mixer(p["ssm"], cfg, x, gated=False)
    y = 0.5 * (rms_norm(ya, p["fuse_a"]) + rms_norm(ys, p["fuse_s"]))
    return y, kv


def mlp_block(p: Params, cfg: ModelConfig, spec: LayerSpec, x):
    if spec.mlp == "none":
        return jnp.zeros_like(x), False
    h = rms_norm(x, p["mlp_norm"])
    if spec.mlp == "gated":
        return gated_mlp(h, p["wi"], p["wg"], p["wo_mlp"], cfg.act), True
    if spec.mlp == "dense":
        return dense_mlp(h, p["wi"], p["wo_mlp"], cfg.act), True
    shared = (p["swi"], p["swg"], p["swo"]) if "swi" in p else None
    return moe_mlp(h, p["router"], p["wi"], p["wg"], p["wo_mlp"],
                   cfg.top_k, cfg.act, shared), True


# ---------------------------------------------------------------------------
# Full-sequence layer + stack
# ---------------------------------------------------------------------------

def layer_forward(p: Params, cfg: ModelConfig, spec: LayerSpec, x,
                  positions, positions3=None, enc_out=None,
                  collect_cache: bool = False, cache_len: int = 0):
    """One transformer layer; returns (x, cache_entry or None)."""
    h = rms_norm(x, p["norm"])
    cache = None
    if spec.mixer == "attn":
        y, kv = attn_mixer(p, cfg, spec, h, positions, positions3)
    elif spec.mixer == "mla":
        y, kv = mla_mixer(p, cfg, spec, h, positions)
    elif spec.mixer == "ssm":
        y, kv = ssm_mixer(p, cfg, h), None
    elif spec.mixer == "hybrid":
        y, kv = hybrid_mixer(p, cfg, spec, h, positions)
    else:
        raise ValueError(spec.mixer)
    x = x + y

    if enc_out is not None:                      # decoder cross-attention
        hc = rms_norm(x, p["cross_norm"])
        q, _, _ = _qkv(p["cross"], cfg, hc)
        ck = jnp.einsum("bsd,dh->bsh", enc_out, p["cross"]["wk"])
        cv = jnp.einsum("bsd,dh->bsh", enc_out, p["cross"]["wv"])
        b, se, _ = enc_out.shape
        ck = ck.reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
        cv = cv.reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qkv_bias:
            pass
        out = attention(q, ck, cv, AttnSpec(causal=False))
        x = x + jnp.einsum("bsh,hd->bsd",
                           out.reshape(*out.shape[:2], -1),
                           p["cross"]["wo"])

    y, has_mlp = mlp_block(p, cfg, spec, x)
    if has_mlp:
        x = x + y

    if collect_cache:
        cache = _make_cache_entry(cfg, spec, kv, cache_len, x.shape[0],
                                  positions)
    return x, cache


def _cache_seq_len(cfg: ModelConfig, spec: LayerSpec, seq_len: int) -> int:
    if spec.window is not None:
        return min(seq_len, spec.window)
    return seq_len


def _make_cache_entry(cfg, spec, kv, cache_len, batch, positions):
    """Build a decode cache entry from prefill-computed K/V (keep the
    last ``cache_len`` positions; window layers keep the window)."""
    if kv is None:        # ssm — state comes from a dedicated prefill pass
        return None
    out = {}
    for key, val in kv.items():
        s = val.shape[1]
        keep = min(cache_len, s)
        ent = val[:, s - keep:]
        if keep < cache_len:
            pad = jnp.zeros((val.shape[0], cache_len - keep) + val.shape[2:],
                            val.dtype)
            ent = jnp.concatenate([ent, pad], axis=1)
        out[key] = ent
    return out


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            remat: bool = False) -> jnp.ndarray:
    """Token (+stub-modality) inputs -> final hidden states (B,S,D)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.vision_stub and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(cfg.dtype)
        x = jax.lax.dynamic_update_slice(x, ve, (0, 0, 0))
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(s)[None, :]
    positions3 = batch.get("positions3")

    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(params, cfg, batch["enc_embeds"], remat=remat)

    for p, spec in zip(params.get("pre", ()), cfg.pre):
        x, _ = layer_forward(p, cfg, spec, x, positions, positions3, None)

    def unit_body(x, unit_p):
        for i, spec in enumerate(cfg.unit):
            x, _ = layer_forward(unit_p[f"u{i}"], cfg, spec, x, positions,
                                 positions3, enc_out)
        return x, None

    body = _maybe_remat(unit_body) if remat else unit_body
    x, _ = jax.lax.scan(body, x, params["unit"])
    return rms_norm(x, params["final_norm"])


def _maybe_remat(fn):
    """Unit-scan remat with the PerfOpts-selected policy: "full"
    recomputes everything (minimum memory), "dots" saves matmul outputs
    (less backward recompute -> lower compute/memory roofline terms, at
    a measured temp-memory cost)."""
    from .perfopts import current
    if current().remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def encode(params: Params, cfg: ModelConfig, enc_embeds: jnp.ndarray,
           remat: bool = False) -> jnp.ndarray:
    x = enc_embeds.astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])[None, :]
    spec = LayerSpec(mixer="attn", mlp="dense")

    def body(x, p):
        h = rms_norm(x, p["norm"])
        y, _ = attn_mixer(p, cfg, spec, h, positions, causal=False)
        x = x + y
        y, _ = mlp_block(p, cfg, spec, x)
        return x + y, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_unit"])
    return rms_norm(x, params["enc_norm"])


# ---------------------------------------------------------------------------
# Loss (chunked over sequence to bound the logits temp)
# ---------------------------------------------------------------------------

def logits_fn(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    return softcap(logits, cfg.logit_softcap)


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            chunk: int = 512, remat: bool = True) -> jnp.ndarray:
    x = forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    b, s, d = x.shape
    c = min(chunk, s)
    nc = s // c
    xc = x.reshape(b, nc, c, d)
    lc = labels.reshape(b, nc, c)

    # checkpointed: the (B, chunk, vocab) logits are recomputed in the
    # backward instead of being saved for every chunk
    @partial(jax.checkpoint, prevent_cse=False)
    def step(carry, i):
        tot, cnt = carry
        logits = logits_fn(params, cfg, xc[:, i])
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[:, i][..., None], axis=-1)[..., 0]
        return (tot + jnp.sum(lse - ll), cnt + lse.size), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0.0), 0), jnp.arange(nc))
    return tot / cnt


# ---------------------------------------------------------------------------
# Serving: cache specs, prefill, decode
# ---------------------------------------------------------------------------

def _layer_cache_specs(cfg: ModelConfig, spec: LayerSpec, batch: int,
                       seq_len: int):
    cl = _cache_seq_len(cfg, spec, seq_len)
    k, hd = cfg.n_kv_heads, cfg.head_dim
    if spec.mixer == "attn":
        sp = {"k": _sds((batch, cl, k, hd)), "v": _sds((batch, cl, k, hd))}
        ax = {"k": ("batch", "kvseq", None, None),
              "v": ("batch", "kvseq", None, None)}
    elif spec.mixer == "mla":
        sp = {"ckv": _sds((batch, cl, cfg.kv_lora)),
              "kr": _sds((batch, cl, cfg.qk_rope_dim))}
        ax = {"ckv": ("batch", "kvseq", None),
              "kr": ("batch", "kvseq", None)}
    elif spec.mixer == "ssm":
        sp = {"h": _sds((batch, cfg.n_ssm_heads, cfg.ssm_headdim,
                         cfg.ssm_state), jnp.float32)}
        ax = {"h": ("batch", "ssm_heads", None, None)}
    elif spec.mixer == "hybrid":
        sp = {"k": _sds((batch, cl, k, hd)), "v": _sds((batch, cl, k, hd)),
              "h": _sds((batch, cfg.n_ssm_heads, cfg.ssm_headdim,
                         cfg.ssm_state), jnp.float32)}
        ax = {"k": ("batch", "kvseq", None, None),
              "v": ("batch", "kvseq", None, None),
              "h": ("batch", "ssm_heads", None, None)}
    else:
        raise ValueError(spec.mixer)
    return sp, ax


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int,
                enc_len: int = 0) -> Tuple[Params, Params]:
    sp: Params = {}
    ax: Params = {}
    pre_sp, pre_ax = [], []
    for spec in cfg.pre:
        s, a = _layer_cache_specs(cfg, spec, batch, seq_len)
        pre_sp.append(s), pre_ax.append(a)
    if pre_sp:
        sp["pre"], ax["pre"] = tuple(pre_sp), tuple(pre_ax)
    unit_sp, unit_ax = {}, {}
    r = cfg.n_unit_repeats
    for i, spec in enumerate(cfg.unit):
        s, a = _layer_cache_specs(cfg, spec, batch, seq_len)
        unit_sp[f"u{i}"] = _stack(s, r)
        unit_ax[f"u{i}"] = _stack_axes(a)
    sp["unit"], ax["unit"] = unit_sp, unit_ax
    if cfg.enc_dec:
        k, hd = cfg.n_kv_heads, cfg.head_dim
        sp["cross"] = {"k": _sds((r, batch, enc_len, k, hd)),
                       "v": _sds((r, batch, enc_len, k, hd))}
        ax["cross"] = {"k": ("layers", "batch", None, None, None),
                       "v": ("layers", "batch", None, None, None)}
    return sp, ax


def _decode_mixer(p, cfg, spec, h, cache, pos, positions3=None):
    """One-token mixer against the cache; returns (y, new_cache).

    Under PerfOpts.decode_opt the mixer does NOT rewrite the cache: it
    attends over past entries plus the current token's K/V (append
    style) and returns only the small per-token update — decode_step
    writes it into the stacked cache with one in-place update per leaf.
    """
    from .perfopts import current as _perf_current
    append = _perf_current().decode_opt
    b = h.shape[0]
    if spec.mixer in ("attn", "hybrid"):
        ap = p["attn"] if spec.mixer == "hybrid" else p
        q, k, v = _qkv(ap, cfg, h)
        posv = jnp.full((b, 1), pos)
        if cfg.mrope and positions3 is not None:
            q = apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, posv, cfg.rope_theta)
            k = apply_rope(k, posv, cfg.rope_theta)
        cl = cache["k"].shape[1]
        slot = pos if spec.window is None else pos % cl
        aspec = AttnSpec(causal=True, window=None,
                         logit_softcap=cfg.attn_softcap)
        if append:
            length = pos if spec.window is None else jnp.minimum(pos, cl)
            inv = slot if spec.window is not None else None
            out = decode_attention(q, cache["k"], cache["v"], length, aspec,
                                   extra_kv=(k, v), invalid_slot=inv)
            ya = jnp.einsum("bsh,hd->bsd", out.reshape(b, 1, -1), ap["wo"])
            new_cache = dict(cache, k=k, v=v)   # per-token updates only
            if spec.mixer == "attn":
                return ya, new_cache
            new_k = new_v = None
        else:
            new_k = cache_update(cache["k"], k, slot)
            new_v = cache_update(cache["v"], v, slot)
            if spec.window is not None:
                # rolling window cache: slots < min(pos+1, cl) are valid
                length = jnp.minimum(pos + 1, cl)
            else:
                length = pos + 1
            out = decode_attention(q, new_k, new_v, length, aspec)
            ya = jnp.einsum("bsh,hd->bsd", out.reshape(b, 1, -1), ap["wo"])
            new_cache = dict(cache, k=new_k, v=new_v)
            if spec.mixer == "attn":
                return ya, new_cache
        # hybrid: add the SSM branch
        sp_ = p["ssm"]
        xs = jnp.einsum("bsd,di->bsi", h, sp_["w_x"])[:, 0]
        B = jnp.einsum("bsd,dn->bsn", h, sp_["w_B"])[:, 0]
        C = jnp.einsum("bsd,dn->bsn", h, sp_["w_C"])[:, 0]
        dt = jax.nn.softplus(
            jnp.einsum("bsd,dh->bsh", h, sp_["w_dt"])[:, 0].astype(jnp.float32)
            + sp_["dt_bias"])
        A = -jnp.exp(sp_["A_log"])
        hs, hp_ = cfg.n_ssm_heads, cfg.ssm_headdim
        hn, ys = ssm_mod.ssd_decode_step(cache["h"], xs.reshape(b, hs, hp_),
                                         dt, A, B, C, sp_["D_skip"])
        ys = rms_norm(ys.reshape(b, 1, -1), sp_["ssm_norm"])
        ys = jnp.einsum("bsi,id->bsd", ys, sp_["out_proj"])
        y = 0.5 * (rms_norm(ya, p["fuse_a"]) + rms_norm(ys, p["fuse_s"]))
        return y, dict(new_cache, h=hn)

    if spec.mixer == "ssm":
        xs = jnp.einsum("bsd,di->bsi", h, p["w_x"])[:, 0]
        B = jnp.einsum("bsd,dn->bsn", h, p["w_B"])[:, 0]
        C = jnp.einsum("bsd,dn->bsn", h, p["w_C"])[:, 0]
        dt = jax.nn.softplus(
            jnp.einsum("bsd,dh->bsh", h, p["w_dt"])[:, 0].astype(jnp.float32)
            + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        hs, hp_ = cfg.n_ssm_heads, cfg.ssm_headdim
        hn, y = ssm_mod.ssd_decode_step(cache["h"], xs.reshape(b, hs, hp_),
                                        dt, A, B, C, p["D_skip"])
        z = jnp.einsum("bsd,di->bsi", h, p["w_z"])[:, 0] if "w_z" in p else None
        y = y.reshape(b, 1, -1)
        if z is not None:
            y = y * jax.nn.silu(z)[:, None]
        y = rms_norm(y, p["ssm_norm"])
        return jnp.einsum("bsi,id->bsd", y, p["out_proj"]), dict(cache, h=hn)

    if spec.mixer == "mla":
        # absorbed MLA decode: score against the compressed cache directly
        nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        hH = cfg.n_heads
        q = jnp.einsum("bsd,dh->bsh", h, p["wq"]).reshape(b, 1, hH, nd + rd)
        q_nope, q_rope = q[..., :nd], q[..., nd:]
        posv = jnp.full((b, 1), pos)
        q_rope = apply_rope(q_rope, posv, cfg.rope_theta)
        dkv = jnp.einsum("bsd,dh->bsh", h, p["w_dkv"])
        ckv_new = rms_norm(dkv[..., :cfg.kv_lora], p["kv_norm"])
        kr_new = apply_rope(dkv[:, :, None, cfg.kv_lora:], posv,
                            cfg.rope_theta)[:, :, 0]
        if append:
            ckv, kr = cache["ckv"], cache["kr"]
            n_valid = pos
        else:
            ckv = cache_update(cache["ckv"], ckv_new, pos)
            kr = cache_update(cache["kr"], kr_new, pos)
            n_valid = pos + 1
        # absorb W_uk into q: q' = q_nope @ W_uk^T  -> (B,H,lora)
        w_uk = p["w_uk"].reshape(cfg.kv_lora, hH, nd)
        q_abs = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], w_uk)
        scores = (jnp.einsum("bhl,bsl->bhs", q_abs.astype(jnp.float32),
                             ckv.astype(jnp.float32))
                  + jnp.einsum("bhr,bsr->bhs",
                               q_rope[:, 0].astype(jnp.float32),
                               kr.astype(jnp.float32)))
        valid = jnp.arange(ckv.shape[1])[None] < n_valid
        scores = scores / math.sqrt(nd + rd)
        scores = jnp.where(valid[:, None], scores, -1e30)
        if append:
            # two-part online softmax (no concat on the sharded seq axis)
            s_new = (jnp.einsum("bhl,bsl->bhs", q_abs.astype(jnp.float32),
                                ckv_new.astype(jnp.float32))
                     + jnp.einsum("bhr,bsr->bhs",
                                  q_rope[:, 0].astype(jnp.float32),
                                  kr_new.astype(jnp.float32)))[..., 0]
            s_new = s_new / math.sqrt(nd + rd)
            m = jnp.maximum(scores.max(axis=-1), s_new)
            p_cache = jnp.exp(scores - m[..., None])
            p_new = jnp.exp(s_new - m)
            denom = p_cache.sum(axis=-1) + p_new
            ctx = jnp.einsum("bhs,bsl->bhl", p_cache,
                             ckv.astype(jnp.float32))
            ctx = (ctx + p_new[..., None]
                   * ckv_new[:, 0, None, :].astype(jnp.float32))
            ctx = ctx / denom[..., None]
        else:
            pr = jax.nn.softmax(scores, axis=-1)
            ctx = jnp.einsum("bhs,bsl->bhl", pr,
                             ckv.astype(jnp.float32))      # (B,H,lora)
        w_uv = p["w_uv"].reshape(cfg.kv_lora, hH, vd)
        out = jnp.einsum("bhl,lhd->bhd", ctx,
                         w_uv.astype(jnp.float32)).astype(h.dtype)
        y = jnp.einsum("bh,hd->bd", out.reshape(b, hH * vd),
                       p["wo"])[:, None]
        if append:
            return y, dict(cache, ckv=ckv_new, kr=kr_new)
        return y, dict(cache, ckv=ckv, kr=kr)

    raise ValueError(spec.mixer)


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                batch: Dict[str, jnp.ndarray], pos: jnp.ndarray):
    """One token for every sequence in the batch.

    batch: {"tokens": (B,1)} (+ positions3 for M-RoPE).
    Returns (logits (B,1,V) fp32, new cache).
    """
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.dtype)
    positions3 = batch.get("positions3")

    new_pre = []
    for p, spec, c in zip(params.get("pre", ()), cfg.pre,
                          cache.get("pre", ())):
        h = rms_norm(x, p["norm"])
        y, nc = _decode_mixer(p, cfg, spec, h, c, pos, positions3)
        x = x + y
        y, has = mlp_block(p, cfg, spec, x)
        if has:
            x = x + y
        new_pre.append(nc)

    cross = cache.get("cross")
    from .perfopts import current as _perf_current
    cache_as_carry = _perf_current().decode_opt

    def layer_apply(x, unit_p, unit_c, cross_kv):
        new_c = {}
        for i, spec in enumerate(cfg.unit):
            p, c = unit_p[f"u{i}"], unit_c[f"u{i}"]
            h = rms_norm(x, p["norm"])
            y, nc = _decode_mixer(p, cfg, spec, h, c, pos, positions3)
            x = x + y
            new_c[f"u{i}"] = nc
            if cfg.enc_dec and cross_kv is not None:
                hc = rms_norm(x, p["cross_norm"])
                q, _, _ = _qkv(p["cross"], cfg, hc)
                out = decode_attention(q, cross_kv["k"], cross_kv["v"],
                                       cross_kv["k"].shape[1],
                                       AttnSpec(causal=False))
                x = x + jnp.einsum("bsh,hd->bsd",
                                   out.reshape(b, 1, -1), p["cross"]["wo"])
            y, has = mlp_block(p, cfg, spec, x)
            if has:
                x = x + y
        return x, new_c

    if cache_as_carry:
        # append-style decode: mixers read the (unmodified) cache plus
        # the current token's K/V, and return only the small per-token
        # updates as scan ys; the stacked cache is then written with ONE
        # top-level in-place slice update per leaf.  The baseline scan
        # instead rebuilds the full multi-GB stacked cache every layer
        # (measured: the dominant HBM term of the decode baseline).
        def body(x, xs):
            unit_p, unit_c, cross_kv = xs
            x, new_c = layer_apply(x, unit_p, unit_c, cross_kv)
            return x, new_c

        xs = (params["unit"], cache["unit"], cross)
        x, updates = jax.lax.scan(body, x, xs)
        new_unit = {}
        for i, spec in enumerate(cfg.unit):
            key = f"u{i}"
            upd, cur = updates[key], cache["unit"][key]
            out_c = {}
            for name, stack_arr in cur.items():
                u = upd[name]
                if name == "h":                  # SSM state: full replace
                    out_c[name] = u.astype(stack_arr.dtype)
                    continue
                cl = stack_arr.shape[2]
                slot = pos if (spec.window is None or name in
                               ("ckv", "kr")) else pos % cl
                idx = (0, 0, slot) + (0,) * (stack_arr.ndim - 3)
                out_c[name] = jax.lax.dynamic_update_slice(
                    stack_arr, u.astype(stack_arr.dtype), idx)
            new_unit[key] = out_c
    else:
        def body(x, xs):
            unit_p, unit_c, cross_kv = xs
            x, new_c = layer_apply(x, unit_p, unit_c, cross_kv)
            return x, new_c

        xs = (params["unit"], cache["unit"], cross)
        x, new_unit = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["final_norm"])
    logits = logits_fn(params, cfg, x)
    new_cache = dict(cache, unit=new_unit)
    if new_pre:
        new_cache["pre"] = tuple(new_pre)
    return logits, new_cache


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            cache_len: Optional[int] = None):
    """Run the full prompt, return (last-position logits, decode cache).

    SSM/hybrid states are produced by running the recurrent form over the
    prompt inside the same lowered computation (chunked scan reuse)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache_len = cache_len or s
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.vision_stub and "vision_embeds" in batch:
        x = jax.lax.dynamic_update_slice(
            x, batch["vision_embeds"].astype(cfg.dtype), (0, 0, 0))
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(s)[None, :]
    positions3 = batch.get("positions3")

    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(params, cfg, batch["enc_embeds"])

    new_pre = []
    for p, spec in zip(params.get("pre", ()), cfg.pre):
        xin = x
        x, ce = layer_forward(p, cfg, spec, x, positions, positions3,
                              enc_out, collect_cache=True,
                              cache_len=_cache_seq_len(cfg, spec, cache_len))
        new_pre.append(_prefill_ssm_state(p, cfg, spec, ce, xin))

    def body(x, unit_p):
        caches = {}
        for i, spec in enumerate(cfg.unit):
            xin = x
            x, ce = layer_forward(unit_p[f"u{i}"], cfg, spec, x, positions,
                                  positions3, enc_out, collect_cache=True,
                                  cache_len=_cache_seq_len(cfg, spec,
                                                           cache_len))
            ce = _prefill_ssm_state(unit_p[f"u{i}"], cfg, spec, ce, xin)
            caches[f"u{i}"] = ce
            if cfg.enc_dec:
                ck = jnp.einsum("bsd,dh->bsh", enc_out,
                                unit_p[f"u{i}"]["cross"]["wk"])
                cv = jnp.einsum("bsd,dh->bsh", enc_out,
                                unit_p[f"u{i}"]["cross"]["wv"])
                se = enc_out.shape[1]
                caches["_cross"] = {
                    "k": ck.reshape(b, se, cfg.n_kv_heads, cfg.head_dim),
                    "v": cv.reshape(b, se, cfg.n_kv_heads, cfg.head_dim)}
        return x, caches

    x, unit_caches = jax.lax.scan(body, x, params["unit"])
    x = rms_norm(x, params["final_norm"])
    logits = logits_fn(params, cfg, x[:, -1:])

    cache: Params = {"unit": {k: v for k, v in unit_caches.items()
                              if not k.startswith("_")}}
    if cfg.enc_dec:
        cache["cross"] = unit_caches["_cross"]
    if new_pre:
        cache["pre"] = tuple(new_pre)
    return logits, cache


def _prefill_ssm_state(p, cfg, spec, ce, xin):
    """Attach the post-prompt SSM state to a prefill cache entry."""
    if spec.mixer not in ("ssm", "hybrid"):
        return ce
    pp = p["ssm"] if spec.mixer == "hybrid" else p
    h = rms_norm(xin, p["norm"])
    b, s, _ = h.shape
    hs, hp_ = cfg.n_ssm_heads, cfg.ssm_headdim
    xs, B, C, dt, A = _ssm_inputs(pp, cfg, h)
    state = _ssd_final_state(xs.reshape(b, s, hs, hp_), dt, A, B,
                             cfg.ssm_chunk)
    ce = dict(ce or {}, h=state)
    return ce


def _ssd_final_state(x, dt, A, B, chunk):
    """Final SSM state after a prompt (for prefill->decode handoff)."""
    bt, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        s += pad
    nc = s // q
    xc = x.astype(jnp.float32).reshape(bt, nc, q, h, p)
    dtc = dt.astype(jnp.float32).reshape(bt, nc, q, h)
    Bc = B.astype(jnp.float32).reshape(bt, nc, q, n)
    dtA = dtc * A.astype(jnp.float32)
    cum = jnp.cumsum(dtA, axis=2)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, dtc * decay_to_end, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def step(hstate, inp):
        st, dec = inp
        return hstate * dec[..., None, None] + st, None

    h0 = jnp.zeros((bt, h, p, n), jnp.float32)
    hT, _ = jax.lax.scan(step, h0,
                         (jnp.moveaxis(states, 1, 0),
                          jnp.moveaxis(chunk_decay, 1, 0)))
    return hT
