"""Shared neural layers: norms, RoPE / M-RoPE, flash-style attention,
KV caches, MLPs, MoE dispatch — pure functions over param dicts.

Design constraints (see DESIGN.md §5):
  * layers are `lax.scan`-stacked -> small HLO at 512 devices;
  * attention is blockwise with an online softmax -> bounded temp memory
    at 32k prefill (no S x S score materialization);
  * everything lowers on the CPU backend (dry-run) and is shardable by
    pjit — no Pallas in the model path (kernels/ is the CIM compute).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

DEFAULT_DTYPE = jnp.bfloat16
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(jnp.float32)
    return (y * s).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return (jnp.tanh(x.astype(jnp.float32) / cap) * cap).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                sections: Tuple[int, int, int],
                theta: float = 10000.0) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): the head dim is split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  positions3: (3, ..., S)."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                       # (D/2,)
    # choose per-frequency position stream by section
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections),
                         total_repeat_length=d // 2)   # (D/2,)
    # gather: ang[..., s, f] = positions3[sec_ids[f], ..., s] * freqs[f]
    p = jnp.moveaxis(positions3, 0, -1)                # (..., S, 3)
    p_sel = jnp.take(p, sec_ids, axis=-1)              # (..., S, D/2)
    ang = p_sel.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure jnp + lax.scan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    causal: bool = True
    window: Optional[int] = None          # sliding-window size (None = full)
    logit_softcap: Optional[float] = None
    q_block: int = 512
    kv_block: int = 512


def _block_mask(qi: jnp.ndarray, kj: jnp.ndarray, spec: AttnSpec,
                q_block: int, kv_block: int, kv_len: int) -> jnp.ndarray:
    """(q_block, kv_block) bool mask for query block qi, kv block kj."""
    q_pos = qi * q_block + jnp.arange(q_block)[:, None]
    k_pos = kj * kv_block + jnp.arange(kv_block)[None, :]
    m = k_pos < kv_len          # masks the padded tail of K/V
    if spec.causal:
        m &= k_pos <= q_pos
    if spec.window is not None:
        m &= k_pos > q_pos - spec.window
    return m


def _visible_pairs(nq: int, nk: int, qb: int, kb: int, spec: AttnSpec):
    """(q-block, kv-block) pairs with at least one unmasked element."""
    pairs = []
    for qi in range(nq):
        for kj in range(nk):
            if spec.causal and kj * kb > qi * qb + qb - 1:
                continue
            if spec.window is not None and \
                    kj * kb + kb - 1 <= qi * qb - spec.window:
                continue
            pairs.append((qi, kj))
    return pairs


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              spec: AttnSpec = AttnSpec()) -> jnp.ndarray:
    """Blockwise multi-query/grouped attention with online softmax.

    q: (B, S, Hq, D); k, v: (B, S, Hkv, D); Hq % Hkv == 0.
    Memory is O(q_block x kv_block) per step instead of O(S^2).

    With PerfOpts.triangular_attention, only *visible* (q, kv) block
    pairs are iterated (causal lower triangle / sliding-window band):
    ~2x less compute+traffic for causal, ~S/window for banded prefill.
    """
    b, sq, hq, d = q.shape
    s = k.shape[1]
    dv = v.shape[-1]                 # may differ from d (MLA)
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qb = min(spec.q_block, sq)
    kb = min(spec.kv_block, s)
    # pad to whole blocks; padded keys are masked, padded queries sliced off
    pq, pk = (-sq) % qb, (-s) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (sq + pq) // qb, (s + pk) // kb

    qr = q.reshape(b, nq, qb, hkv, g, d)
    kr = k.reshape(b, nk, kb, hkv, d)
    vr = v.reshape(b, nk, kb, hkv, dv)

    from .perfopts import current as _perf_current
    if _perf_current().triangular_attention and (spec.causal or
                                                 spec.window is not None):
        out = _pair_attention(qr, kr, vr, spec, qb, kb, s, scale)
        out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq + pq, hq, dv)
        return out[:, :sq]

    def q_step(_, qi):
        qblk = qr[:, qi].astype(jnp.float32) * scale   # (B,qb,hkv,g,D)

        @partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, kj):
            m_prev, l_prev, acc = carry
            kblk = kr[:, kj].astype(jnp.float32)
            vblk = vr[:, kj].astype(jnp.float32)
            sblk = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk)
            if spec.logit_softcap is not None:
                sblk = jnp.tanh(sblk / spec.logit_softcap) * spec.logit_softcap
            mask = _block_mask(qi, kj, spec, qb, kb, s)
            sblk = jnp.where(mask[None, None, None], sblk, NEG_INF)
            m_new = jnp.maximum(m_prev, sblk.max(axis=-1))
            p = jnp.exp(sblk - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qb), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qb, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]    # (B,hkv,g,qb,Dv)
        return None, out.astype(q.dtype)

    # checkpoint both scan levels: backward recomputes score blocks
    # (flash-attention-style) instead of saving O(S^2) residuals
    q_step = partial(jax.checkpoint, prevent_cse=False)(q_step)
    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq,B,hkv,g,qb,Dv)
    out = jnp.moveaxis(outs, 0, 1)                        # (B,nq,hkv,g,qb,Dv)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, sq + pq, hq, dv)
    return out[:, :sq]


def _pair_attention(qr, kr, vr, spec: AttnSpec, qb: int, kb: int,
                    kv_len: int, scale: float):
    """Visible-pair blockwise attention.

    qr: (B, nq, qb, hkv, g, D); kr/vr: (B, nk, kb, hkv, D[v]).
    Returns (nq, B, hkv, g, qb, Dv) — same layout as the dense path's
    stacked q-block outputs.  Accumulators for every q block ride the
    scan carry; each step updates only its q block (dynamic slice/update
    along the leading nq axis).
    """
    b, nq, _, hkv, g, d = qr.shape
    nk = kr.shape[1]
    dv = vr.shape[-1]
    pairs = _visible_pairs(nq, nk, qb, kb, spec)
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    kj_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

    m0 = jnp.full((nq, b, hkv, g, qb), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, hkv, g, qb), jnp.float32)
    a0 = jnp.zeros((nq, b, hkv, g, qb, dv), jnp.float32)

    @partial(jax.checkpoint, prevent_cse=False)
    def step(carry, i):
        m, l, acc = carry
        qi, kj = qi_arr[i], kj_arr[i]
        qblk = jax.lax.dynamic_index_in_dim(qr, qi, axis=1, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kr, kj, axis=1, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vr, kj, axis=1, keepdims=False)
        sblk = jnp.einsum("bqhgd,bkhd->bhgqk",
                          qblk.astype(jnp.float32) * scale,
                          kblk.astype(jnp.float32))
        if spec.logit_softcap is not None:
            sblk = jnp.tanh(sblk / spec.logit_softcap) * spec.logit_softcap
        mask = _block_mask(qi, kj, spec, qb, kb, kv_len)
        sblk = jnp.where(mask[None, None, None], sblk, NEG_INF)
        m_prev = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_prev = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_prev = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_prev, sblk.max(axis=-1))
        p = jnp.exp(sblk - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
        a_new = a_prev * corr[..., None] + pv
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  jnp.arange(len(pairs)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(qr.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, length: jnp.ndarray,
                     spec: AttnSpec = AttnSpec(),
                     extra_kv=None, invalid_slot=None) -> jnp.ndarray:
    """Single-step attention over a (possibly sharded) KV cache.

    q: (B, 1, Hq, D); caches: (B, S, Hkv, D); length: () current length.
    The softmax over the sharded S axis partitions cleanly under pjit
    (XLA inserts the max/sum all-reduces).

    ``extra_kv=(k_new, v_new)`` — append-style decode: the cache holds
    only PAST tokens (entries with index < length are valid) and the
    current token's K/V ride separately; the caller writes them to the
    cache afterwards (one top-level in-place update instead of a
    rewritten cache per layer).
    """
    from .perfopts import current as _perf_current
    b, _, hq, d = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    s = k_cache.shape[1]
    scale = 1.0 / math.sqrt(d)
    if _perf_current().decode_opt:
        # keep the cache in its storage dtype; accumulate in f32 via
        # preferred_element_type (no materialized f32 cache copy)
        qr = (q.reshape(b, hkv, g, d).astype(jnp.float32)
              * scale).astype(k_cache.dtype)
        scores = jnp.einsum("bhgd,bshd->bhgs", qr, k_cache,
                            preferred_element_type=jnp.float32)
    else:
        qr = q.reshape(b, hkv, g, d).astype(jnp.float32) * scale
        scores = jnp.einsum("bhgd,bshd->bhgs", qr,
                            k_cache.astype(jnp.float32))
    if spec.logit_softcap is not None:
        scores = jnp.tanh(scores / spec.logit_softcap) * spec.logit_softcap
    pos = jnp.arange(s)
    valid = pos[None] < length
    if spec.window is not None:
        valid &= pos[None] > length - 1 - spec.window
    if invalid_slot is not None:
        # append-style rolling window: the slot about to be overwritten
        # holds the expired token and must not be attended
        valid &= pos[None] != invalid_slot
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)

    if extra_kv is None:
        p = jax.nn.softmax(scores, axis=-1)
        if _perf_current().decode_opt:
            out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype),
                             v_cache, preferred_element_type=jnp.float32)
        else:
            out = jnp.einsum("bhgs,bshd->bhgd", p,
                             v_cache.astype(jnp.float32))
        return out.reshape(b, 1, hq, d).astype(q.dtype)

    # append-style: combine the sharded-cache softmax with the current
    # token via a two-part online softmax — NO concat along the sharded
    # seq axis (a concat loses the sharding and forces the partitioner
    # to all-gather the f32 cache; measured on the decode baseline).
    k_new, v_new = extra_kv                      # (B, 1, Hkv, D)
    s_new = jnp.einsum("bhgd,bshd->bhgs", qr.astype(jnp.float32),
                       k_new.astype(jnp.float32))[..., 0]      # (B,Hkv,g)
    if spec.logit_softcap is not None:
        s_new = jnp.tanh(s_new / spec.logit_softcap) * spec.logit_softcap
    m = jnp.maximum(scores.max(axis=-1), s_new)
    p_cache = jnp.exp(scores - m[..., None])
    p_new = jnp.exp(s_new - m)
    denom = p_cache.sum(axis=-1) + p_new
    if _perf_current().decode_opt:
        ctx = jnp.einsum("bhgs,bshd->bhgd", p_cache.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
    else:
        ctx = jnp.einsum("bhgs,bshd->bhgd", p_cache,
                         v_cache.astype(jnp.float32))
    ctx = ctx + p_new[..., None] * v_new[:, 0, :, None, :].astype(jnp.float32)
    out = ctx / denom[..., None]
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def cache_update(cache: jnp.ndarray, new: jnp.ndarray,
                 pos: jnp.ndarray) -> jnp.ndarray:
    """Write one token's K or V at position ``pos`` (dynamic)."""
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype),
                                               pos, axis=1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def gated_mlp(x: jnp.ndarray, wi: jnp.ndarray, wg: jnp.ndarray,
              wo: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, wi)
    gate = jnp.einsum("...d,df->...f", x, wg)
    gate = _act(gate, act)
    return jnp.einsum("...f,fd->...d", h * gate, wo)


def dense_mlp(x: jnp.ndarray, wi: jnp.ndarray, wo: jnp.ndarray,
              act: str = "gelu") -> jnp.ndarray:
    h = _act(jnp.einsum("...d,df->...f", x, wi), act)
    return jnp.einsum("...f,fd->...d", h, wo)


def _act(x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "silu":
        return jax.nn.silu(x)
    if act == "relu":
        return jax.nn.relu(x)
    if act == "relu2":      # squared ReLU (nemotron/minitron)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(act)


# ---------------------------------------------------------------------------
# MoE (dense one-hot dispatch: SPMD-friendly, experts shard on "model")
# ---------------------------------------------------------------------------

def moe_mlp(x: jnp.ndarray, router_w: jnp.ndarray, wi: jnp.ndarray,
            wg: jnp.ndarray, wo: jnp.ndarray, top_k: int,
            act: str = "silu",
            shared: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None,
            capacity_factor: float = 1.25,
            token_chunk: int = 2048) -> jnp.ndarray:
    """Capacity-based top-k MoE (Switch/mesh-TF-style dispatch).

    x: (B,S,D); wi/wg: (E,D,F); wo: (E,F,D); router_w: (D,E).

    Tokens are processed in chunks (lax.scan) so the dispatch/expert
    intermediates stay O(chunk) instead of O(B*S); per chunk every
    expert receives at most C = ceil(top_k * chunk * cf / E) tokens
    (overflow drops — standard).  Under expert-sharding the dispatch
    einsums partition into the expected all-to-all pattern.
    """
    b, s, d = x.shape
    e = router_w.shape[-1]
    tokens = x.reshape(b * s, d)
    t_all = tokens.shape[0]
    tc = min(token_chunk, t_all)
    pad = (-t_all) % tc
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    nchunk = tokens.shape[0] // tc
    cap = max(1, int(math.ceil(top_k * tc * capacity_factor / e)))

    @partial(jax.checkpoint, prevent_cse=False)
    def chunk_step(_, xt):                       # xt: (tc, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        gates = jax.nn.softmax(logits, axis=-1)
        weights, ids = jax.lax.top_k(gates, top_k)     # (tc, k)
        weights = weights / jnp.maximum(
            weights.sum(-1, keepdims=True), 1e-9)
        # position of each (token, slot) within its expert's capacity
        oh = jax.nn.one_hot(ids, e, dtype=jnp.int32)   # (tc, k, e)
        flat = oh.reshape(tc * top_k, e)
        pos = (jnp.cumsum(flat, axis=0) - flat)        # entries before us
        pos = jnp.einsum("xe,xe->x", pos.astype(jnp.float32),
                         flat.astype(jnp.float32)).astype(jnp.int32)
        pos = pos.reshape(tc, top_k)
        keep = pos < cap
        disp = jnp.zeros((tc, e, cap), x.dtype)
        comb = jnp.zeros((tc, e, cap), jnp.float32)
        for j in range(top_k):
            oh_e = jax.nn.one_hot(ids[:, j], e, dtype=x.dtype)
            oh_c = jax.nn.one_hot(pos[:, j], cap, dtype=x.dtype)
            oh_c = oh_c * keep[:, j][:, None].astype(x.dtype)
            dk = jnp.einsum("te,tc->tec", oh_e, oh_c)
            disp = disp + dk
            comb = comb + dk.astype(jnp.float32) * weights[:, j][:, None, None]
        xe = jnp.einsum("tec,td->ecd", disp, xt)       # (e, cap, d)
        from .perfopts import current as _perf_current
        opts = _perf_current()
        if opts.moe_capacity_shard and opts.mesh is not None:
            # shard the per-expert token buffers over "data": the expert
            # matmuls then contract a LOCAL d (weights all-gather once
            # per layer) instead of all-reducing (e,cap,f) partials per
            # chunk — measured dominant collective on mixtral train
            from jax.sharding import NamedSharding, PartitionSpec as P
            xe = jax.lax.with_sharding_constraint(
                xe, NamedSharding(opts.mesh, P(None, "data", None)))
        h = jnp.einsum("ecd,edf->ecf", xe, wi)
        g = _act(jnp.einsum("ecd,edf->ecf", xe, wg), act)
        ye = jnp.einsum("ecf,efd->ecd", h * g, wo)     # (e, cap, d)
        yt = jnp.einsum("tec,ecd->td", comb.astype(x.dtype), ye)
        return None, yt

    _, y = jax.lax.scan(chunk_step, None, tokens.reshape(nchunk, tc, d))
    y = y.reshape(-1, d)[:t_all].reshape(b, s, d)
    if shared is not None:
        swi, swg, swo = shared
        y = y + gated_mlp(x, swi, swg, swo, act)
    return y


# ---------------------------------------------------------------------------
# Parameter initialization over spec trees
# ---------------------------------------------------------------------------

def init_from_specs(specs: Params, rng: jax.Array,
                    scale: float = 0.02) -> Params:
    """Materialize a ShapeDtypeStruct tree with scaled-normal params."""
    leaves, treedef = jax.tree.flatten(specs)
    keys = jax.random.split(rng, len(leaves))
    vals = []
    for key, leaf in zip(keys, leaves):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            v = (jax.random.normal(key, leaf.shape, jnp.float32)
                 * scale).astype(leaf.dtype)
        else:
            v = jnp.zeros(leaf.shape, leaf.dtype)
        vals.append(v)
    return jax.tree.unflatten(treedef, vals)
