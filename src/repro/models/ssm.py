"""Mamba2 SSD (state-space duality) blocks — chunked training scan and
O(1)-state decode step.  Pure jnp + lax.scan (shardable; heads shard on
the "model" mesh axis).

Recurrence (per head h, head dim P, state N, shared B/C of one group):

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * (B_t outer x_t)
    y_t = C_t . h_t + D * x_t

Training uses the chunked SSD form: intra-chunk quadratic attention-like
term + inter-chunk state recurrence (lax.scan over chunks), which keeps
temp memory O(chunk^2) and the HLO small.

Simplifications vs the reference implementation (recorded in DESIGN.md
§3/§4): the short causal conv1d on x/B/C is omitted, and n_groups = 1.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def segsum(dtA: jnp.ndarray) -> jnp.ndarray:
    """dtA: (..., Q) -> (..., Q, Q) lower-triangular pairwise decay sums:
    out[t, s] = sum_{s < u <= t} dtA[u]  (for s <= t)."""
    q = dtA.shape[-1]
    cs = jnp.cumsum(dtA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., t, s)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
             chunk: int = 256) -> jnp.ndarray:
    """Chunked SSD forward.

    x:  (Bt, S, H, P)    inputs per head
    dt: (Bt, S, H)       positive step sizes (post-softplus)
    A:  (H,)             negative decay rates
    B:  (Bt, S, N)       input projection to state (n_groups=1)
    C:  (Bt, S, N)       state readout
    D:  (H,)             skip
    returns (Bt, S, H, P)
    """
    bt, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # dt=0 on padding -> decay 1, zero state contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        out = ssd_scan(x, dt, A, B, C, D, chunk)
        return out[:, :s]
    nc = s // q

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    xc = xf.reshape(bt, nc, q, h, p)
    dtc = dtf.reshape(bt, nc, q, h)
    Bc = Bf.reshape(bt, nc, q, n)
    Cc = Cf.reshape(bt, nc, q, n)
    dtA = dtc * A.astype(jnp.float32)                    # (bt,nc,q,h)

    # ---- intra-chunk (quadratic within the chunk) ----
    Lmat = jnp.exp(segsum(jnp.moveaxis(dtA, -1, -2)))    # (bt,nc,h,q,q)
    CB = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)           # (bt,nc,q,q)
    W = CB[:, :, None] * Lmat                            # (bt,nc,h,q,q)
    xdt = xc * dtc[..., None]                            # (bt,nc,q,h,p)
    y_intra = jnp.einsum("bchts,bcshp->bcthp", W, xdt)

    # ---- chunk states ----
    cum = jnp.cumsum(dtA, axis=2)                        # (bt,nc,q,h)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (bt,nc,q,h)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn",
                        Bc, dtc * decay_to_end, xc)      # (bt,nc,h,p,n)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (bt,nc,h)

    # ---- inter-chunk recurrence over chunks ----
    def step(hstate, inp):
        st, dec = inp                                    # (bt,h,p,n),(bt,h)
        h_prev = hstate
        hstate = h_prev * dec[..., None, None] + st
        return hstate, h_prev

    h0 = jnp.zeros((bt, h, p, n), jnp.float32)
    _, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (bt,nc,h,p,n)

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(cum)                              # (bt,nc,q,h)
    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp", Cc, in_decay, h_prevs)

    y = (y_intra + y_inter
         + xf.reshape(bt, nc, q, h, p) * D.astype(jnp.float32)[:, None])
    return y.reshape(bt, s, h, p).astype(x.dtype)


def ssd_decode_step(hstate: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                    A: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
                    D: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step.

    hstate: (Bt, H, P, N); x: (Bt, H, P); dt: (Bt, H); B,C: (Bt, N).
    Returns (new_state, y (Bt, H, P))."""
    dtf = dt.astype(jnp.float32)
    dec = jnp.exp(dtf * A.astype(jnp.float32))           # (Bt,H)
    upd = jnp.einsum("bn,bh,bhp->bhpn", B.astype(jnp.float32), dtf,
                     x.astype(jnp.float32))
    hnew = hstate * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), hnew)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[:, None]
    return hnew, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Reference (sequential) implementation for tests
# ---------------------------------------------------------------------------

def ssd_reference(x, dt, A, B, C, D):
    """O(S) sequential recurrence — the oracle for ssd_scan."""
    bt, s, h, p = x.shape
    n = B.shape[-1]
    hstate = jnp.zeros((bt, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        hstate, y = ssd_decode_step(hstate, x[:, t], dt[:, t], A,
                                    B[:, t], C[:, t], D)
        ys.append(y)
    return jnp.stack(ys, axis=1).astype(x.dtype)
