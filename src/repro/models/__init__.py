"""The 10 assigned LM architectures, pure JAX (scan-stacked, shardable)."""
