"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206 — encoder-decoder, multimodal [arXiv:2308.11596].

The speech/text modality frontend is a STUB per the assignment:
input_specs() provides precomputed frame embeddings for the encoder; the
enc-dec transformer backbone (bidirectional encoder, causal decoder with
cross-attention) is fully implemented.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,           # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256_206,
    act="gelu",
    unit=(LayerSpec(mixer="attn", mlp="dense"),),
    enc_dec=True,
    supports_long=False,
    notes="enc-dec; frame-embedding frontend stubbed; encoder context "
          "capped at 4096 frames for decode shapes",
)
