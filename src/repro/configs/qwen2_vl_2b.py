"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution [arXiv:2409.12191].

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings; the transformer backbone (text+vision
token stream with 3-D M-RoPE positions) is fully implemented.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151_936,
    act="silu",
    qkv_bias=True,
    unit=(LayerSpec(mixer="attn", mlp="gated"),),
    mrope=True,
    mrope_sections=(16, 24, 24),
    vision_stub=True,
    n_vision_tokens=1024,
    supports_long=False,
    notes="M-RoPE backbone; patch-embed frontend stubbed",
)
