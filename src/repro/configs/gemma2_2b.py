"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — local+global alternating attention, logit softcapping
[arXiv:2408.00118]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256_000,
    act="gelu",
    # alternating local (sliding 4096) / global attention
    unit=(LayerSpec(mixer="attn", window=4096, mlp="gated"),
          LayerSpec(mixer="attn", window=None, mlp="gated")),
    attn_softcap=50.0,
    logit_softcap=30.0,
    supports_long=False,   # global layers keep an unbounded KV at 500k
    notes="local+global alternation; GeGLU; attn/logit softcaps",
)
