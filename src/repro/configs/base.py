"""Architecture & shape configuration for the assigned model pool.

Every architecture is a ``ModelConfig``; every workload shape is a
``ShapeSpec``.  ``repro.configs.get_config(name)`` returns the full-size
config; ``.reduced()`` returns the CPU-smoke-test version of the same
family (same structure, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer's mixer/MLP recipe (the scan unit repeats a tuple of
    these — e.g. gemma2's (sliding, full) alternation)."""
    mixer: str = "attn"          # attn | mla | ssm | hybrid
    window: Optional[int] = None  # sliding-window size for attn mixers
    mlp: str = "gated"           # gated | dense | moe


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"
    # layer recipe: ``pre`` layers first, then ``unit`` repeated
    pre: Tuple[LayerSpec, ...] = ()
    unit: Tuple[LayerSpec, ...] = (LayerSpec(),)
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # MLA (deepseek-v2)
    kv_lora: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    # SSM
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # enc-dec
    enc_dec: bool = False
    n_enc_layers: int = 0
    # vision stub
    vision_stub: bool = False
    n_vision_tokens: int = 1024
    dtype: jnp.dtype = jnp.bfloat16
    # does decode state stay bounded at 500k context?
    supports_long: bool = False
    notes: str = ""

    @property
    def n_unit_repeats(self) -> int:
        n = self.n_layers - len(self.pre)
        if self.enc_dec:
            n = self.n_layers  # decoder layers; encoder counted separately
        assert n % len(self.unit) == 0, (self.name, n, len(self.unit))
        return n // len(self.unit)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Approximate parameter count (reported in EXPERIMENTS.md)."""
        from ..models import lm
        import math
        specs = lm.param_specs(self)
        import jax
        return sum(math.prod(x.shape) for x in jax.tree.leaves(specs))


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shapes_for(cfg: ModelConfig):
    """The shape cells that apply to this architecture (skips recorded in
    DESIGN.md §4: long_500k only for bounded-state decoders)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long:
        out.append(SHAPES["long_500k"])
    return out
