"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE [arXiv:2402.19173]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab=49_152,
    act="gelu",
    qkv_bias=True,
    unit=(LayerSpec(mixer="attn", mlp="dense"),),
    rope_theta=100_000.0,
    supports_long=False,
    notes="full attention (assignment lists GQA+RoPE only); gelu MLP",
)
