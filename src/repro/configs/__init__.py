"""Config registry: one module per assigned architecture (--arch <id>).

All hyperparameters follow the assignment table (public-literature
configs); ``reduced(cfg)`` maps any config to a CPU-smoke-test version
of the same family.
"""
from __future__ import annotations

import dataclasses

from .base import (LayerSpec, ModelConfig, ShapeSpec, SHAPES,  # noqa: F401
                   shapes_for)

from . import (gemma2_2b, minitron_4b, starcoder2_15b, qwen1_5_4b,
               mamba2_780m, hymba_1_5b, mixtral_8x7b, deepseek_v2_lite_16b,
               qwen2_vl_2b, seamless_m4t_large_v2)

ARCHS = {
    "gemma2-2b": gemma2_2b.CONFIG,
    "minitron-4b": minitron_4b.CONFIG,
    "starcoder2-15b": starcoder2_15b.CONFIG,
    "qwen1.5-4b": qwen1_5_4b.CONFIG,
    "mamba2-780m": mamba2_780m.CONFIG,
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "mixtral-8x7b": mixtral_8x7b.CONFIG,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b.CONFIG,
    "qwen2-vl-2b": qwen2_vl_2b.CONFIG,
    "seamless-m4t-large-v2": seamless_m4t_large_v2.CONFIG,
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: same layer recipe,
    small dims, 2 unit repeats."""
    n_layers = len(cfg.pre) + 2 * len(cfg.unit)
    if cfg.enc_dec:
        n_layers = 2 * len(cfg.unit)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        kv_lora=32,
        qk_rope_dim=8,
        qk_nope_dim=16,
        v_head_dim=16,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        n_enc_layers=2 if cfg.enc_dec else 0,
        n_vision_tokens=16 if cfg.vision_stub else 1024,
        mrope_sections=(2, 3, 3) if cfg.mrope else cfg.mrope_sections,
    )
