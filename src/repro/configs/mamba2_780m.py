"""mamba2-780m [ssm]: 48L d_model=1536 (attn-free) vocab=50280
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,           # attention-free; SSM heads derived from d_inner
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50_280,
    unit=(LayerSpec(mixer="ssm", mlp="none"),),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    supports_long=True,   # O(1) decode state
    notes="pure SSD blocks, no MLP; conv1d omitted (DESIGN.md §3)",
)
