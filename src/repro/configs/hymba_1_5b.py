"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001 ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32_001,
    act="silu",
    # parallel attention + mamba heads per layer; attention is sliding
    # window (Hymba uses SWA in all but 3 layers — simplified to all-SWA,
    # recorded in DESIGN.md §4)
    unit=(LayerSpec(mixer="hybrid", window=1024, mlp="gated"),),
    ssm_state=16,
    ssm_headdim=64,
    ssm_expand=1,         # mamba branch matches model width
    supports_long=True,   # SSM state + window-bounded KV
    notes="parallel attn+SSM heads fused by mean; all-SWA simplification",
)
