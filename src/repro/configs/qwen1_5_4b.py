"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-4B]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151_936,
    act="silu",
    qkv_bias=True,
    unit=(LayerSpec(mixer="attn", mlp="gated"),),
    supports_long=False,
    notes="MHA (kv=heads), SwiGLU, QKV bias",
)
