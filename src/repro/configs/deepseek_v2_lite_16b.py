"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408(expert)
vocab=102400 — MLA kv_lora=512, 2 shared + 64 routed experts top-6,
first layer dense [arXiv:2405.04434].

The assignment header says "MoE 64e top-6" and the inline note
"2 shared+160 routed top-6"; 160 routed belongs to full V2 — V2-Lite has
64 routed experts, which we follow (consistent with the 64e header).
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,          # v_head_dim; attention dims come from MLA fields
    d_ff=10944,            # first dense layer's FFN
    vocab=102_400,
    act="silu",
    pre=(LayerSpec(mixer="mla", mlp="gated"),),   # layer 0: dense FFN
    unit=(LayerSpec(mixer="mla", mlp="moe"),),
    kv_lora=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=64,
    top_k=6,
    moe_d_ff=1408,
    n_shared_experts=2,
    supports_long=False,   # MLA cache is compressed but unbounded in S
    notes="MLA with absorbed decode; 2 shared experts",
)
