"""AdamW in pure JAX: state pytrees mirror the params (so they inherit
the params' shardings — ZeRO-3-like when params are FSDP-sharded), plus
global-norm clipping and optional int8 gradient compression with error
feedback (distributed-optimization option for cross-pod all-reduce).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(count=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_state_specs(param_specs: Any) -> AdamWState:
    def f32(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(count=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree.map(f32, param_specs),
                      nu=jax.tree.map(f32, param_specs))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), norm


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1
                 ) -> Tuple[Any, AdamWState]:
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    # separate maps (param trees may contain tuples as structure, so we
    # avoid tuple-leaf tricks); XLA CSEs the recomputed moments.
    new_mu = jax.tree.map(
        lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32),
        grads, state.mu)
    new_nu = jax.tree.map(
        lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        grads, state.nu)

    def upd(p, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_mu, new_nu)
    return new_params, AdamWState(count=count, mu=new_mu, nu=new_nu)


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback) — used for the cross-pod
# all-reduce where the interconnect, not ICI, is the bottleneck.
# ---------------------------------------------------------------------------

def compress_int8(g: jnp.ndarray, err: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q, scale, new_err); dequantized value is q * scale."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-9) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale
