from .pipeline import TokenStream, FileCorpus, make_batch_iterator  # noqa
