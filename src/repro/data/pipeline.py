"""Data pipeline: deterministic, checkpointable token streams.

``TokenStream`` generates a synthetic-but-learnable token distribution
(order-2 Markov over a seeded transition table) so end-to-end training
examples show decreasing loss without external data.  ``FileCorpus``
memory-maps a flat binary token file.  Both expose an explicit
``state`` (seed, cursor) that the checkpointer persists, so restarts
resume the exact stream position (fault tolerance requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class StreamState:
    seed: int
    step: int

    def to_dict(self) -> Dict:
        return {"seed": int(self.seed), "step": int(self.step)}

    @classmethod
    def from_dict(cls, d: Dict) -> "StreamState":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class TokenStream:
    """Order-2 Markov synthetic corpus (deterministic per (seed, step))."""

    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.state = StreamState(seed=seed, step=0)
        rng = np.random.default_rng(seed)
        # Keep the transition table small relative to the vocab: a reduced
        # test model then shows decreasing loss within tens of steps (first
        # from the marginal — only `modulus` of `vocab` tokens ever occur —
        # then from the transitions).  A near-vocab modulus makes the
        # stream practically unlearnable at test scale.
        self._modulus = max(2, min(vocab - 1, 127))
        self._mix = rng.integers(1, self._modulus, 2, dtype=np.int64)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.state.seed * 1_000_003 + self.state.step) % (2 ** 63))
        b, s = self.batch, self.seq_len
        toks = np.empty((b, s + 1), np.int64)
        toks[:, :2] = rng.integers(0, self._modulus, (b, 2))
        noise = rng.random((b, s + 1)) < 0.05
        rand = rng.integers(0, self._modulus, (b, s + 1))
        for t in range(2, s + 1):
            nxt = (toks[:, t - 1] * self._mix[0]
                   + toks[:, t - 2] * self._mix[1]) % self._modulus
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        self.state.step += 1
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class FileCorpus:
    """Flat binary token file (uint16/uint32), sampled deterministically."""

    def __init__(self, path: str, vocab: int, batch: int, seq_len: int,
                 seed: int = 0, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.state = StreamState(seed=seed, step=0)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.state.seed * 1_000_003 + self.state.step) % (2 ** 63))
        n = len(self.tokens) - self.seq_len - 1
        starts = rng.integers(0, n, self.batch)
        toks = np.stack([np.asarray(self.tokens[i:i + self.seq_len + 1])
                         for i in starts]).astype(np.int32)
        toks = np.clip(toks, 0, self.vocab - 1)
        self.state.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch_iterator(source, extra: Optional[Dict] = None
                        ) -> Iterator[Dict[str, np.ndarray]]:
    while True:
        batch = source.next_batch()
        if extra:
            batch.update(extra)
        yield batch
