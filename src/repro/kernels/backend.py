"""Backend-capability registry: which kernel runs how, on what.

The CIM-MLC premise is that the compiler must know the hardware it
targets.  This module is that knowledge for the *host* side of the
stack: every CIM kernel has up to three execution routes —

  * ``compiled``  — a genuinely compiled ``pallas_call`` (TPU/GPU; the
                    fast path on accelerators),
  * ``interpret`` — the same Pallas kernel body run by the Pallas
                    interpreter (any platform; the CPU validation path
                    that exercises the kernel's exact block/grid logic),
  * ``xla``       — the pure-jnp oracle (``ref.cim_mvm_ref``) compiled
                    by XLA (any platform; the fast CPU path and the
                    semantic ground truth).

Callers no longer thread ``interpret=``/``use_kernel=`` booleans
through every layer; they ask the registry for a :class:`KernelRoute`
(``resolve``) and the registry decides from the active JAX platform and
per-kernel capability.  Overrides exist at three levels:

  * per-call: ``cim_mvm(..., mode="interpret")``,
  * process-scoped: ``with backend.override("interpret"): ...`` (tests,
    conformance sweeps),
  * environment: ``REPRO_KERNEL_MODE=interpret|xla|compiled|auto``
    (the CI conformance legs run the same suite under each mode).

Asking for an unsupported combination (``compiled`` on CPU) raises
``KernelUnsupportedError`` — the executor maps that to ``LoweringError``
so the serving stack's documented interpreter fallback keeps working.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Dict, Optional, Tuple

#: execution routes, in "fast on an accelerator" order
MODES = ("compiled", "interpret", "xla")
AUTO = "auto"

_ENV_MODE = "REPRO_KERNEL_MODE"
_ENV_PLATFORM = "REPRO_KERNEL_PLATFORM"


class KernelUnsupportedError(RuntimeError):
    """The requested (kernel, mode, platform) combination cannot run."""


@dataclasses.dataclass(frozen=True)
class KernelCapability:
    """Per-kernel support matrix.

    ``compiled_platforms`` lists JAX platforms whose backend can lower
    the kernel's ``pallas_call`` for real; ``interpret`` and ``xla``
    routes are platform-independent (the Pallas interpreter and the jnp
    oracle run anywhere jax does).
    """

    name: str
    compiled_platforms: Tuple[str, ...] = ("tpu", "gpu")
    has_interpret: bool = True
    has_xla: bool = True
    note: str = ""

    def modes_on(self, platform: str) -> Tuple[str, ...]:
        out = []
        if platform in self.compiled_platforms:
            out.append("compiled")
        if self.has_interpret:
            out.append("interpret")
        if self.has_xla:
            out.append("xla")
        return tuple(out)


#: the registry proper — one entry per public kernel entry point
REGISTRY: Dict[str, KernelCapability] = {
    "cim_mvm": KernelCapability(
        "cim_mvm",
        note="bit-sliced crossbar MVM; Pallas kernel is MXU-batched "
             "over parallel-row groups"),
    "cim_mvm_tiles": KernelCapability(
        "cim_mvm_tiles",
        note="tile-batched MVM (executor fast path); Pallas route adds "
             "the tile axis as the leading grid dimension"),
    "cim_mvm_signed": KernelCapability(
        "cim_mvm_signed",
        note="offset-encoded signed MVM; routes through cim_mvm"),
}


@dataclasses.dataclass(frozen=True)
class KernelRoute:
    """One resolved routing decision: *this* kernel runs *this* way."""

    kernel: str
    platform: str
    mode: str            # "compiled" | "interpret" | "xla"
    reason: str = ""

    #: legacy boolean views (the pre-registry calling convention)
    @property
    def use_kernel(self) -> bool:
        return self.mode != "xla"

    @property
    def interpret(self) -> bool:
        return self.mode == "interpret"


# -- platform detection ------------------------------------------------------

def detect_platform() -> str:
    """The active JAX platform (``cpu``/``gpu``/``tpu``).

    ``REPRO_KERNEL_PLATFORM`` overrides detection (useful to exercise
    routing decisions for a platform the test host does not have —
    resolution only; actually *running* a compiled route still needs
    the hardware).
    """
    env = os.environ.get(_ENV_PLATFORM)
    if env:
        return env
    import jax
    return jax.default_backend()


# -- overrides ---------------------------------------------------------------

#: process-scoped mode overrides: kernel name -> mode ("" key = all kernels)
_OVERRIDES: Dict[str, str] = {}


def set_override(mode: Optional[str], kernel: str = "") -> None:
    """Set (or with ``None`` clear) a process-scoped mode override.

    ``kernel=""`` applies to every kernel; a named override wins over
    the blanket one.  Overrides beat the environment variable, which
    beats auto-resolution.
    """
    if mode is None:
        _OVERRIDES.pop(kernel, None)
    else:
        _check_mode(mode)
        _OVERRIDES[kernel] = mode


@contextlib.contextmanager
def override(mode: str, kernel: str = ""):
    """``with backend.override("interpret"): ...`` — scoped route forcing."""
    prev = _OVERRIDES.get(kernel)
    set_override(mode, kernel)
    try:
        yield
    finally:
        set_override(prev, kernel)


def _check_mode(mode: str) -> None:
    if mode not in MODES and mode != AUTO:
        raise ValueError(f"unknown kernel mode {mode!r}; "
                         f"expected one of {MODES + (AUTO,)}")


def _requested_mode(kernel: str, mode: Optional[str]) -> str:
    """Resolution order: per-call > per-kernel override > blanket
    override > environment > auto."""
    if mode:
        _check_mode(mode)
        return mode
    for key in (kernel, ""):
        if key in _OVERRIDES:
            return _OVERRIDES[key]
    env = os.environ.get(_ENV_MODE, "").strip().lower()
    if env:
        _check_mode(env)
        return env
    return AUTO


# -- resolution --------------------------------------------------------------

def supports(kernel: str, mode: str, platform: Optional[str] = None) -> bool:
    """True if ``kernel`` can execute via ``mode`` on ``platform``."""
    cap = REGISTRY[kernel]
    return mode in cap.modes_on(platform or detect_platform())


def resolve(kernel: str, mode: Optional[str] = None,
            platform: Optional[str] = None) -> KernelRoute:
    """Decide how ``kernel`` should execute right now.

    Auto policy: compiled where the platform supports it (TPU/GPU);
    the XLA-compiled oracle elsewhere (CPU) — the Pallas interpreter is
    never chosen automatically, it is the explicit validation route.
    Raises :class:`KernelUnsupportedError` if a forced mode cannot run.
    """
    if kernel not in REGISTRY:
        raise KeyError(f"unknown kernel {kernel!r}; "
                       f"registered: {sorted(REGISTRY)}")
    platform = platform or detect_platform()
    want = _requested_mode(kernel, mode)
    avail = REGISTRY[kernel].modes_on(platform)
    if want == AUTO:
        if "compiled" in avail:
            return KernelRoute(kernel, platform, "compiled",
                               f"auto: {platform} compiles pallas_call")
        return KernelRoute(kernel, platform, "xla",
                           f"auto: {platform} has no compiled route, "
                           "taking the XLA-compiled oracle")
    if want not in avail:
        raise KernelUnsupportedError(
            f"{kernel}: mode {want!r} is not supported on {platform!r} "
            f"(available: {avail})")
    return KernelRoute(kernel, platform, want, "explicitly requested")


def capability_matrix(platform: Optional[str] = None) -> Dict[str, Dict]:
    """Docs/bench view: per kernel, the supported modes and the route
    auto-resolution would pick on ``platform`` (default: detected)."""
    platform = platform or detect_platform()
    out: Dict[str, Dict] = {}
    for name, cap in REGISTRY.items():
        route = resolve(name, mode=AUTO, platform=platform)
        out[name] = {
            "platforms": {p: cap.modes_on(p) for p in ("cpu", "gpu", "tpu")},
            "auto_mode": route.mode,
            "note": cap.note,
        }
    return out
