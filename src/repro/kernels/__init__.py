# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# ``backend`` is the backend-capability registry: it decides, per
# kernel and per active JAX platform, whether the compiled Pallas
# route, the Pallas interpreter, or the XLA-compiled oracle runs.
from . import backend  # noqa: F401
