"""Pallas TPU kernel for the bit-sliced CIM crossbar MVM.

Hardware adaptation (see DESIGN.md §3): the analog crossbar's compute
semantics — bit-serial DAC phases x cell-precision weight slices x
per-``parallel_row``-group ADC saturation x digital shift-accumulate —
map exactly onto integer MXU matmuls over bit-planes.  The tiling is
TPU-native rather than a port of the analog array:

  * grid = (M tiles, C tiles, row-block tiles); the row-block axis is the
    innermost grid dim so partial sums accumulate into the same VMEM out
    block (classic matmul revisiting pattern);
  * bit planes are laid out as leading non-tiled axes, pre-transposed by
    ops.py so the kernel body is pure batched ``dot_general`` — no
    in-kernel reshapes/transposes (TPU layouts stay trivial);
  * row groups become the batch dim of an int8 x int8 -> int32 MXU batch
    matmul; the ADC clamp is a VPU ``minimum`` between accumulations;
  * block sizes keep the lane dim at 128 and the working set in VMEM
    (see ops.py block-size policy).

Validated bit-exactly against ref.cim_mvm_ref (interpret mode on CPU;
the same pallas_call lowers for TPU targets).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xpg_ref, wsg_ref, out_ref, *, dac_bits: int, cell_bits: int,
            adc_max: int, n_phases: int, n_slices: int):
    """One (bm x bc) output block, one row-block of gb groups.

    xpg_ref: (P, gb, bm, pr)   input bit-planes, grouped rows
    wsg_ref: (S, gb, pr, bc)   weight bit-slices, grouped rows
    out_ref: (bm, bc) int32    accumulated across the row-block grid dim
    """
    k = pl.program_id(2)
    acc = jnp.zeros(out_ref.shape, jnp.int32)
    for p in range(n_phases):
        xg = xpg_ref[p]                       # (gb, bm, pr)
        for s in range(n_slices):
            wg = wsg_ref[s]                   # (gb, pr, bc)
            # analog column sum of one activation: batched over groups
            part = jax.lax.dot_general(
                xg, wg,
                dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.int32)        # (gb, bm, bc)
            # ADC saturation happens per analog read (per group)
            part = jnp.minimum(part, adc_max)
            shift = p * dac_bits + s * cell_bits
            acc = acc + (part.sum(axis=0) << shift)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(k > 0)
    def _accum():
        out_ref[...] = out_ref[...] + acc


def cim_mvm_pallas(xpg: jnp.ndarray, wsg: jnp.ndarray, *, dac_bits: int,
                   cell_bits: int, adc_bits: int, block_m: int,
                   block_c: int, groups_per_block: int,
                   interpret: bool = False) -> jnp.ndarray:
    """Launch the kernel.

    xpg: (P, G, M, pr)  — phases x row-groups x rows-of-x x parallel_row
    wsg: (S, G, pr, C)  — slices x row-groups x parallel_row x cols
    returns (M, C) int32.
    Shapes must already be padded to the block grid (ops.py does this).
    """
    P, G, M, pr = xpg.shape
    S, G2, pr2, C = wsg.shape
    assert (G, pr) == (G2, pr2), (xpg.shape, wsg.shape)
    assert M % block_m == 0 and C % block_c == 0 and G % groups_per_block == 0

    grid = (M // block_m, C // block_c, G // groups_per_block)
    kernel = functools.partial(
        _kernel, dac_bits=dac_bits, cell_bits=cell_bits,
        adc_max=(1 << adc_bits) - 1, n_phases=P, n_slices=S)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((P, groups_per_block, block_m, pr),
                         lambda i, j, k: (0, k, i, 0)),
            pl.BlockSpec((S, groups_per_block, pr, block_c),
                         lambda i, j, k: (0, k, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_c), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, C), jnp.int32),
        interpret=interpret,
    )(xpg, wsg)


def _tiles_kernel(xpg_ref, wsg_ref, out_ref, *, dac_bits: int,
                  cell_bits: int, adc_max: int, n_phases: int,
                  n_slices: int):
    """Same body as ``_kernel`` with a leading singleton tile axis.

    xpg_ref: (1, P, gb, bm, pr); wsg_ref: (1, S, gb, pr, bc);
    out_ref: (1, bm, bc) — accumulated across the row-block grid dim.
    """
    k = pl.program_id(3)
    acc = jnp.zeros(out_ref.shape[1:], jnp.int32)
    for p in range(n_phases):
        xg = xpg_ref[0, p]                    # (gb, bm, pr)
        for s in range(n_slices):
            wg = wsg_ref[0, s]                # (gb, pr, bc)
            part = jax.lax.dot_general(
                xg, wg,
                dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.int32)        # (gb, bm, bc)
            part = jnp.minimum(part, adc_max)
            shift = p * dac_bits + s * cell_bits
            acc = acc + (part.sum(axis=0) << shift)

    @pl.when(k == 0)
    def _init():
        out_ref[0] = acc

    @pl.when(k > 0)
    def _accum():
        out_ref[0] = out_ref[0] + acc


def cim_mvm_tiles_pallas(xpg: jnp.ndarray, wsg: jnp.ndarray, *,
                         dac_bits: int, cell_bits: int, adc_bits: int,
                         block_m: int, block_c: int,
                         groups_per_block: int,
                         interpret: bool = False) -> jnp.ndarray:
    """Tile-batched launch: the tile axis is the *leading grid dim*.

    xpg: (T, P, G, M, pr); wsg: (T, S, G, pr, C); returns (T, M, C)
    int32.  One ``pallas_call`` covers all T crossbar tiles (instead of
    T independent launches), with the row-block axis still innermost so
    per-tile partial sums accumulate into the same out block.
    Shapes must already be padded to the block grid (ops.py does this).
    """
    T, P, G, M, pr = xpg.shape
    T2, S, G2, pr2, C = wsg.shape
    assert (T, G, pr) == (T2, G2, pr2), (xpg.shape, wsg.shape)
    assert M % block_m == 0 and C % block_c == 0 and G % groups_per_block == 0

    grid = (T, M // block_m, C // block_c, G // groups_per_block)
    kernel = functools.partial(
        _tiles_kernel, dac_bits=dac_bits, cell_bits=cell_bits,
        adc_max=(1 << adc_bits) - 1, n_phases=P, n_slices=S)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, P, groups_per_block, block_m, pr),
                         lambda t, i, j, k: (t, 0, k, i, 0)),
            pl.BlockSpec((1, S, groups_per_block, pr, block_c),
                         lambda t, i, j, k: (t, 0, k, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_c),
                               lambda t, i, j, k: (t, i, j)),
        out_shape=jax.ShapeDtypeStruct((T, M, C), jnp.int32),
        interpret=interpret,
    )(xpg, wsg)
