"""Public wrappers around the CIM-MVM kernel, routed by the backend
registry.

``cim_mvm``       — unsigned bit-sliced crossbar MVM.
``cim_mvm_tiles`` — tile-batched MVM (the executor fast path).
``cim_mvm_signed`` — signed ints via offset encoding (the standard CIM
                     trick: store w + 2^(wb-1), subtract the rank-1
                     correction digitally).
``cim_mvm_params`` — derive the precision/row parameters from a CIMArch.

Execution routing is a :mod:`repro.kernels.backend` decision, not a
caller-threaded boolean: every entry point resolves a
:class:`~repro.kernels.backend.KernelRoute` (``compiled`` pallas_call
on TPU/GPU, the XLA-compiled oracle on CPU, the Pallas interpreter on
request) unless the caller forces ``mode=``.  The pre-registry
``use_kernel=``/``interpret=`` keyword arguments still work but are
deprecated and emit a ``DeprecationWarning``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from .. import backend
from . import ref
from .kernel import cim_mvm_pallas, cim_mvm_tiles_pallas


@dataclasses.dataclass(frozen=True)
class CimMvmParams:
    act_bits: int = 8
    weight_bits: int = 8
    dac_bits: int = 1
    cell_bits: int = 2
    parallel_row: int = 8
    adc_bits: int = 8

    @property
    def exact(self) -> bool:
        """True if the ADC never saturates (pure integer matmul)."""
        need = ref.exact_adc_bits(self.act_bits, self.weight_bits,
                                  self.dac_bits, self.cell_bits,
                                  self.parallel_row)
        return self.adc_bits >= need


def cim_mvm_params(arch, rows_used: Optional[int] = None) -> CimMvmParams:
    """Build params from a core.abstraction.CIMArch."""
    xb = arch.xb
    pr = xb.parallel_row
    if rows_used is not None:
        pr = min(pr, rows_used)
    return CimMvmParams(act_bits=arch.act_bits, weight_bits=arch.weight_bits,
                        dac_bits=xb.dac_bits, cell_bits=xb.cell_precision,
                        parallel_row=pr, adc_bits=xb.adc_bits)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _block_policy(m: int, c: int, r_groups: int, pr: int):
    """Pick (block_m, block_c, groups_per_block) with the lane dim at 128
    and the VMEM working set bounded (~2 MiB of int8 planes)."""
    block_m = 128 if m >= 128 else max(8, 1 << (m - 1).bit_length())
    block_c = 128 if c >= 128 else max(128, c)   # pad small C up to a lane
    gb = max(1, min(r_groups, max(1, 512 // max(pr, 1))))
    while r_groups % gb:
        gb -= 1
    return block_m, block_c, gb


def _resolve_route(kernel: str, mode: Optional[str], use_kernel,
                   interpret, legacy_use_kernel: bool
                   ) -> backend.KernelRoute:
    """Per-call route resolution, honoring the deprecated boolean kwargs.

    ``legacy_use_kernel`` is the kernel's pre-registry default for
    ``use_kernel`` so the deprecated calling convention keeps its exact
    historical meaning.
    """
    if use_kernel is None and interpret is None:
        return backend.resolve(kernel, mode=mode)
    if mode is not None:
        raise ValueError("pass either mode= or the deprecated "
                         "use_kernel=/interpret= booleans, not both")
    warnings.warn(
        f"{kernel}: use_kernel=/interpret= are deprecated; pass "
        "mode='compiled'|'interpret'|'xla' or let the backend registry "
        "decide (kernels.backend.resolve)",
        DeprecationWarning, stacklevel=3)
    uk = legacy_use_kernel if use_kernel is None else use_kernel
    if not uk:
        legacy = "xla"
    elif interpret is None or interpret:
        legacy = "interpret"
    else:
        legacy = "compiled"
    return backend.resolve(kernel, mode=legacy)


# -- jitted implementations (static route mode) ------------------------------

@functools.partial(jax.jit, static_argnames=("params", "mode"))
def _cim_mvm_impl(x_u: jnp.ndarray, w_u: jnp.ndarray, params: CimMvmParams,
                  mode: str) -> jnp.ndarray:
    if mode == "xla":
        return ref.cim_mvm_ref(
            x_u, w_u, act_bits=params.act_bits,
            weight_bits=params.weight_bits, dac_bits=params.dac_bits,
            cell_bits=params.cell_bits, parallel_row=params.parallel_row,
            adc_bits=params.adc_bits)

    m, r = x_u.shape
    _, c = w_u.shape
    pr = min(params.parallel_row, r)
    n_groups = math.ceil(r / pr)

    # pad rows to a whole number of parallel-row groups
    x_u = _pad_to(x_u.astype(jnp.int32), 1, pr)
    w_u = _pad_to(w_u.astype(jnp.int32), 0, pr)

    xp = ref.bit_planes(x_u, params.act_bits, params.dac_bits)   # (P,M,R')
    ws = ref.bit_planes(w_u, params.weight_bits, params.cell_bits)  # (S,R',C)
    P, S = xp.shape[0], ws.shape[0]

    # int8 planes when they fit (MXU-native); int32 otherwise
    plane_dtype = jnp.int8 if max(params.dac_bits, params.cell_bits) <= 7 \
        else jnp.int32

    block_m, block_c, gb = _block_policy(m, c, n_groups, pr)
    # grouped layouts: (P,G,M,pr) and (S,G,pr,C), padded to the grid
    xpg = xp.reshape(P, -1, n_groups, pr).transpose(0, 2, 1, 3)
    wsg = ws.reshape(S, n_groups, pr, -1)
    xpg = _pad_to(xpg, 2, block_m).astype(plane_dtype)
    wsg = _pad_to(wsg, 3, block_c).astype(plane_dtype)
    # gb was chosen to divide n_groups (_block_policy), no group padding

    out = cim_mvm_pallas(xpg, wsg, dac_bits=params.dac_bits,
                         cell_bits=params.cell_bits,
                         adc_bits=params.adc_bits, block_m=block_m,
                         block_c=block_c, groups_per_block=gb,
                         interpret=(mode == "interpret"))
    return out[:m, :c]


@functools.partial(jax.jit, static_argnames=("params", "mode"))
def _cim_mvm_tiles_impl(x_u: jnp.ndarray, w_u: jnp.ndarray,
                        params: CimMvmParams, mode: str) -> jnp.ndarray:
    if mode == "xla":
        return ref.cim_mvm_ref_tiles(
            x_u, w_u, act_bits=params.act_bits,
            weight_bits=params.weight_bits, dac_bits=params.dac_bits,
            cell_bits=params.cell_bits, parallel_row=params.parallel_row,
            adc_bits=params.adc_bits)

    t, m, r = x_u.shape
    _, _, c = w_u.shape
    pr = min(params.parallel_row, r)
    n_groups = math.ceil(r / pr)

    x_u = _pad_to(x_u.astype(jnp.int32), 2, pr)
    w_u = _pad_to(w_u.astype(jnp.int32), 1, pr)

    xp = ref.bit_planes(x_u, params.act_bits, params.dac_bits)   # (P,T,M,R')
    ws = ref.bit_planes(w_u, params.weight_bits, params.cell_bits)
    P, S = xp.shape[0], ws.shape[0]
    plane_dtype = jnp.int8 if max(params.dac_bits, params.cell_bits) <= 7 \
        else jnp.int32

    block_m, block_c, gb = _block_policy(m, c, n_groups, pr)
    # tile-major grouped layouts: (T,P,G,M,pr) and (T,S,G,pr,C)
    xpg = xp.reshape(P, t, m, n_groups, pr).transpose(1, 0, 3, 2, 4)
    wsg = ws.reshape(S, t, n_groups, pr, c).transpose(1, 0, 2, 3, 4)
    xpg = _pad_to(xpg, 3, block_m).astype(plane_dtype)
    wsg = _pad_to(wsg, 4, block_c).astype(plane_dtype)

    out = cim_mvm_tiles_pallas(xpg, wsg, dac_bits=params.dac_bits,
                               cell_bits=params.cell_bits,
                               adc_bits=params.adc_bits, block_m=block_m,
                               block_c=block_c, groups_per_block=gb,
                               interpret=(mode == "interpret"))
    return out[:, :m, :c]


# -- public entry points -----------------------------------------------------

def cim_mvm(x_u: jnp.ndarray, w_u: jnp.ndarray, params: CimMvmParams,
            use_kernel: Optional[bool] = None,
            interpret: Optional[bool] = None, *,
            mode: Optional[str] = None) -> jnp.ndarray:
    """Unsigned crossbar MVM: (M,R) x (R,C) -> (M,C) int32.

    The execution route comes from the backend registry (``compiled``
    pallas_call on TPU/GPU, XLA-compiled oracle on CPU) unless forced
    with ``mode=``; ``use_kernel=``/``interpret=`` are deprecated.
    """
    route = _resolve_route("cim_mvm", mode, use_kernel, interpret, True)
    if x_u.ndim == 1:
        return _cim_mvm_impl(x_u[None], w_u, params, route.mode)[0]
    return _cim_mvm_impl(x_u, w_u, params, route.mode)


def cim_mvm_tiles(x_u: jnp.ndarray, w_u: jnp.ndarray, params: CimMvmParams,
                  use_kernel: Optional[bool] = None,
                  interpret: Optional[bool] = None, *,
                  mode: Optional[str] = None) -> jnp.ndarray:
    """Tile-batched unsigned crossbar MVM: (T,M,R) x (T,R,C) -> (T,M,C).

    The batched entry point used by the trace-lowered executor
    (cimsim.executor): all crossbar tiles of one operator are stacked on
    a leading tile axis and dispatched at once instead of one
    host->device round-trip per tile.  Every tile shares the bit-sliced,
    parallel-row-grouped, ADC-saturating semantics of ``cim_mvm``
    (tiles may be zero-padded along R in the unsigned domain — padding
    preserves per-group ADC values, see ``ref.cim_mvm_ref_tiles``).

    Pallas routes run one ``pallas_call`` whose leading grid dimension
    is the tile axis (``cim_mvm_tiles_pallas``); the ``xla`` route is
    one fused einsum over the tile batch.
    """
    route = _resolve_route("cim_mvm_tiles", mode, use_kernel, interpret,
                           False)
    return _cim_mvm_tiles_impl(x_u, w_u, params, route.mode)


def cim_mvm_signed(x_i: jnp.ndarray, w_i: jnp.ndarray, params: CimMvmParams,
                   use_kernel: Optional[bool] = None,
                   interpret: Optional[bool] = None, *,
                   mode: Optional[str] = None) -> jnp.ndarray:
    """Signed MVM via offset encoding.

    x in [-2^(ab-1), 2^(ab-1)), w likewise; stored as x+ox / w+ow
    unsigned; the rank-1 offset correction is applied digitally (exact
    when the ADC does not saturate — chips budget the ADC for the
    offset-encoded range, and so do our params presets).
    """
    route = _resolve_route("cim_mvm_signed", mode, use_kernel, interpret,
                           True)
    return _cim_mvm_signed_impl(x_i, w_i, params, route.mode)


@functools.partial(jax.jit, static_argnames=("params", "mode"))
def _cim_mvm_signed_impl(x_i: jnp.ndarray, w_i: jnp.ndarray,
                         params: CimMvmParams, mode: str) -> jnp.ndarray:
    squeeze = x_i.ndim == 1
    if squeeze:
        x_i = x_i[None]
    ox = 1 << (params.act_bits - 1)
    ow = 1 << (params.weight_bits - 1)
    x_u = (x_i.astype(jnp.int32) + ox)
    w_u = (w_i.astype(jnp.int32) + ow)
    y_u = _cim_mvm_impl(x_u, w_u, params, mode)
    r = x_i.shape[-1]
    sx = x_u.sum(axis=-1, keepdims=True)          # (M,1)
    sw = w_u.sum(axis=0, keepdims=True)           # (1,C)
    y = y_u - ow * sx - ox * sw + r * ox * ow
    return y[0] if squeeze else y
