"""Pure-jnp oracle for the bit-sliced CIM crossbar MVM.

Models the analog compute semantics of a CIM crossbar array exactly
(§3.2.3): the input vector is presented bit-serially (``dac_bits`` per
phase), weights are stored as ``cell_bits`` slices in adjacent columns,
at most ``parallel_row`` wordlines are activated per analog read, the
column current is digitized by an ``adc_bits`` ADC (saturating), and the
digital shift-accumulate combines phases / slices / row groups:

    y[m,c] = sum_g sum_p sum_s 2^(p*db + s*cb) *
             ADC( sum_{r in group g} x_p[m,r] * w_s[r,c] )

With an ADC wide enough for the analog dynamic range the computation is
exactly the integer matmul x @ w; a narrow ADC saturates (clips) — both
behaviors are part of the contract and are swept in tests.
"""
from __future__ import annotations

import math
import jax.numpy as jnp


def bit_planes(x: jnp.ndarray, total_bits: int, plane_bits: int) -> jnp.ndarray:
    """Decompose unsigned ints into ceil(total/plane) planes (LSB first).

    Returns (n_planes, *x.shape) int32 with each plane < 2**plane_bits.
    """
    n = math.ceil(total_bits / plane_bits)
    x = x.astype(jnp.int32)
    planes = []
    mask = (1 << plane_bits) - 1
    for i in range(n):
        planes.append((x >> (i * plane_bits)) & mask)
    return jnp.stack(planes)


def adc_saturate(v: jnp.ndarray, adc_bits: int) -> jnp.ndarray:
    return jnp.minimum(v, (1 << adc_bits) - 1)


def cim_mvm_ref(x_u: jnp.ndarray, w_u: jnp.ndarray, *, act_bits: int,
                weight_bits: int, dac_bits: int, cell_bits: int,
                parallel_row: int, adc_bits: int) -> jnp.ndarray:
    """Oracle: (M,R) uint x  @  (R,C) uint w  ->  (M,C) int32.

    All the physics happens here; the Pallas kernel must match this
    bit-exactly for every shape/precision combination.
    """
    m, r = x_u.shape
    r2, c = w_u.shape
    assert r == r2, (x_u.shape, w_u.shape)
    pr = min(parallel_row, r)
    n_groups = math.ceil(r / pr)
    pad_r = n_groups * pr - r
    if pad_r:
        x_u = jnp.pad(x_u, ((0, 0), (0, pad_r)))
        w_u = jnp.pad(w_u, ((0, pad_r), (0, 0)))

    xp = bit_planes(x_u, act_bits, dac_bits)          # (P, M, R)
    ws = bit_planes(w_u, weight_bits, cell_bits)      # (S, R, C)
    P, S = xp.shape[0], ws.shape[0]

    xg = xp.reshape(P, m, n_groups, pr)               # (P, M, G, pr)
    wg = ws.reshape(S, n_groups, pr, c)               # (S, G, pr, C)

    out = jnp.zeros((m, c), jnp.int32)
    for p in range(P):
        for s in range(S):
            # per-group analog dot + ADC, then digital accumulate
            part = jnp.einsum("mgr,grc->gmc", xg[p], wg[s],
                              preferred_element_type=jnp.int32)
            part = adc_saturate(part, adc_bits)
            out = out + (part.sum(axis=0) << (p * dac_bits + s * cell_bits))
    return out


def cim_mvm_ref_tiles(x_u: jnp.ndarray, w_u: jnp.ndarray, *, act_bits: int,
                      weight_bits: int, dac_bits: int, cell_bits: int,
                      parallel_row: int, adc_bits: int) -> jnp.ndarray:
    """Tile-batched oracle: (T,M,R) uint x  @  (T,R,C) uint w -> (T,M,C).

    Semantically ``stack([cim_mvm_ref(x_u[t], w_u[t]) for t in range(T)])``
    but evaluated as one einsum per (phase, slice) pair — tiles ride the
    batch dimension next to the parallel-row groups, so a whole node's
    crossbar tiles execute in a single device dispatch (the executor's
    saturating-ADC path).

    Row padding is safe: a tile shorter than R can be zero-padded in the
    *unsigned* domain — padded rows contribute 0 to every group's analog
    sum (so the ADC sees identical values) and extra all-zero groups
    digitize to 0.
    """
    t, m, r = x_u.shape
    t2, r2, c = w_u.shape
    assert (t, r) == (t2, r2), (x_u.shape, w_u.shape)
    pr = min(parallel_row, r)
    n_groups = math.ceil(r / pr)
    pad_r = n_groups * pr - r
    if pad_r:
        x_u = jnp.pad(x_u, ((0, 0), (0, 0), (0, pad_r)))
        w_u = jnp.pad(w_u, ((0, 0), (0, pad_r), (0, 0)))

    xp = bit_planes(x_u, act_bits, dac_bits)          # (P, T, M, R')
    ws = bit_planes(w_u, weight_bits, cell_bits)      # (S, T, R', C)
    P, S = xp.shape[0], ws.shape[0]

    xg = xp.reshape(P, t, m, n_groups, pr)            # (P, T, M, G, pr)
    wg = ws.reshape(S, t, n_groups, pr, c)            # (S, T, G, pr, C)

    out = jnp.zeros((t, m, c), jnp.int32)
    for p in range(P):
        for s in range(S):
            part = jnp.einsum("tmgr,tgrc->tgmc", xg[p], wg[s],
                              preferred_element_type=jnp.int32)
            part = adc_saturate(part, adc_bits)
            out = out + (part.sum(axis=1) << (p * dac_bits + s * cell_bits))
    return out


def exact_adc_bits(act_bits: int, weight_bits: int, dac_bits: int,
                   cell_bits: int, parallel_row: int) -> int:
    """Smallest ADC width that never saturates (exact integer matmul)."""
    vmax = parallel_row * ((1 << dac_bits) - 1) * ((1 << cell_bits) - 1)
    return max(1, math.ceil(math.log2(vmax + 1)))
