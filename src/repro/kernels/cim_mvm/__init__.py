from .ops import (cim_mvm, cim_mvm_params, cim_mvm_signed,  # noqa: F401
                  cim_mvm_tiles, CimMvmParams)
