from .ops import cim_mvm, cim_mvm_params, CimMvmParams  # noqa: F401
