# CIM simulators, per §4.1 of the paper: functional (meta-op flow ->
# numerics, op-by-op oracle interpreter + trace-lowered batched
# executor) and performance (cycles / peak power).
#
# Exports resolve lazily (PEP 562) so importing cimsim.perf from DSE
# worker processes does not pull in jax (kernels load on first use).
_EXPORTS = {
    "FunctionalSimulator": ".functional",
    "VerifyReport": ".functional",
    "compile_and_verify": ".functional",
    "simulate": ".functional",
    "ExecutorStats": ".executor",
    "LoweredExecutable": ".executor",
    "LoweringError": ".executor",
    "lower": ".executor",
    "FaultModel": ".faults",
    "FaultMap": ".faults",
    "FaultCompileResult": ".faults",
    "fault_aware_compile": ".faults",
    "accuracy_under_faults": ".faults",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(_EXPORTS[name], __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
