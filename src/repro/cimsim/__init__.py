# CIM simulators: functional (meta-op flow -> numerics) and performance
# (cycles / peak power), per §4.1 of the paper.
