"""Trace-lowered batched executor for compiled meta-operator flows.

The op-by-op interpreter (cimsim.functional.FunctionalSimulator) walks
the expanded Program in Python, dispatching one jnp oracle call per
crossbar tile with a host<->device round-trip each time.  This module
lowers a compiled ``(SchedulePlan, Program)`` **once** into a flat
jitted executable with the same bit-exact semantics:

  * ``cim.write_xb`` / ``cim.write_row`` become ahead-of-time weight
    packing: every node's crossbar tiles are sliced out of the weight
    matrix, offset-encoded, and stacked into device-resident arrays
    (``pack``);
  * all ``cim.read_xb`` / ``cim.read_row`` / ``cim.read_core`` ops of a
    node collapse into batched MVM invocations — tiles ride the leading
    tile axis of ``kernels.cim_mvm.cim_mvm_tiles`` (saturating-ADC
    configs), or the whole node folds into a single int32 matmul (the
    provably-exact ADC case);
  * ``shift_acc``, requantization and the DCOM operators are traced
    into the same jnp graph (rare float-reference ops run through
    ``jax.pure_callback`` so they stay bit-identical to the NumPy
    reference);
  * every tensor carries a leading batch axis, so N inferences execute
    in one dispatch (``run_batch``);
  * **multi-segment schedules stream weight updates through the trace**:
    when the compile reprograms crossbars between segments (the
    serving stack's time-multiplexed tenants, over-budget workloads),
    the lowering models the physical crossbar pool as per-shape device
    buffers whose contents are swapped at every segment boundary by
    traced updates — each node reads its tiles from the pool state of
    *its* segment, so the jitted program carries the same
    write-then-read dependence chain the hardware does and the
    device-resident weight working set is bounded by the pool, not by
    the sum of all segments' weights.  ``stream="auto"`` (default)
    enables this exactly when ``len(plan.segments) > 1``.

How the MVM itself executes — compiled Pallas kernel, Pallas
interpreter, or the XLA-compiled oracle — is a
``kernels.backend`` registry decision (see ``KernelRoute``); a route
the registry cannot satisfy on the active platform surfaces as
``LoweringError`` so callers keep their documented interpreter
fallback.

Lowering is cached process-wide, keyed by the *content* of the compile
(``compiler.compile_key_for_plan``) x the crossbar compute params — a
calibration loop or verification sweep pays tracing once.  Weights and
requantization shifts are runtime inputs, not baked constants: the same
executable serves any weight set (re-``pack``) and any shift table.

The interpreter remains the bit-exact oracle; tests sweep the executor
against it across chip modes, saturating-ADC configs and batch sizes.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..core.abstraction import CIMArch
from ..core.cg_opt import OpPlacement, SchedulePlan
from ..core.graph import Graph, Node, weight_matrix_shape
from ..core.mop import Program
from ..kernels import backend
from ..kernels.cim_mvm import CimMvmParams, cim_mvm_params
from ..kernels.cim_mvm.ops import _cim_mvm_tiles_impl
from .functional import (_float_dcom, chunk_offsets, spread_slice,
                         tile_ranges)

_INT32_MAX = 2 ** 31 - 1

#: largest weight-matrix R for which the exact-ADC path may use the
#: split-plane f32 GEMM: per-plane |partial| <= R * 128 * 15 must stay
#: under 2^24 (the f32 exact-integer range), so R <= 8192 is safe.
_F32_SPLIT_MAX_R = 8192

#: DCOM graph ops the lowering can trace (parity with apply_dcom).
_SUPPORTED_DCOM = {
    "Relu", "Add", "Mul", "MaxPool", "AveragePool", "GlobalAveragePool",
    "Flatten", "Reshape", "Identity", "Transpose", "Concat", "Split",
    "MatMul", "Gelu", "Silu", "Sigmoid", "Tanh", "Softmax", "LayerNorm",
    "RMSNorm",
}

#: ops whose lowering consumes a calibrated requantization shift
_SHIFTED_DCOM = {"Add", "Mul", "MatMul"}


class LoweringError(ValueError):
    """The program cannot be trace-lowered bit-exactly (unsupported op,
    int32 overflow risk, or the backend registry cannot satisfy the
    requested kernel route on this platform); callers should fall back
    to the interpreter."""


def _resolve_executor_route(route: Optional[backend.KernelRoute],
                            mode: Optional[str],
                            use_kernel: Optional[bool],
                            interpret: Optional[bool]
                            ) -> backend.KernelRoute:
    """The executor's MVM route: registry-resolved, LoweringError on an
    unsupportable request (so callers keep their interpreter fallback).

    ``use_kernel``/``interpret`` keep the pre-registry boolean calling
    convention alive (executor legacy default was the oracle path).
    """
    try:
        if use_kernel is not None or interpret is not None:
            uk = bool(use_kernel)            # legacy default: False
            legacy = "xla" if not uk else \
                ("compiled" if interpret is False else "interpret")
            return backend.resolve("cim_mvm_tiles", mode=legacy)
        if route is not None:
            return route
        return backend.resolve("cim_mvm_tiles", mode=mode)
    except backend.KernelUnsupportedError as e:
        raise LoweringError(str(e)) from None


@dataclasses.dataclass
class ExecutorStats:
    """Lowering statistics (shape of the flattened program)."""

    cim_nodes: int = 0
    dcom_nodes: int = 0
    units: int = 0          # crossbar read units folded into dispatches
    dispatches: int = 0     # batched MVM invocations in the traced graph
    matmul_nodes: int = 0   # exact-ADC nodes lowered to one int matmul
    segments: int = 1       # schedule segments of the compiled plan
    streamed: bool = False  # weight-update streaming active (multi-segment)
    swaps: int = 0          # traced segment-boundary weight-pool updates
    kernel_mode: str = ""   # resolved cim_mvm_tiles route (backend registry)

    @property
    def cim_reads(self) -> int:   # SimStats-compatible accessor
        return self.units


@dataclasses.dataclass(frozen=True)
class _Bucket:
    """Same-shaped crossbar tiles of one node, batched into one call."""

    spans: Tuple[Tuple[int, int, int, int], ...]   # (r0, r1, c0, c1) per tile
    r_len: int
    c_len: int

    @property
    def key(self) -> str:
        return f"{self.r_len}x{self.c_len}"


@dataclasses.dataclass(frozen=True)
class _StreamGroup:
    """Same-shaped tiles of one node living in one schedule segment.

    The streamed twin of ``_Bucket``: tiles are not packed per node but
    occupy slots ``[lo, hi)`` of the shared per-shape crossbar pool for
    the duration of segment ``seg`` — the node's dispatch slices them
    out of that segment's pool state.
    """

    seg: int
    spans: Tuple[Tuple[int, int, int, int], ...]
    r_len: int
    c_len: int
    lo: int                          # first pool slot (static)
    hi: int                          # one past the last pool slot

    @property
    def key(self) -> str:
        return f"{self.r_len}x{self.c_len}"


@dataclasses.dataclass
class _CimPlan:
    """Static lowering of one CIM node."""

    node: Node
    r: int
    c: int
    exact: bool                      # single-matmul path (ADC never clips)
    buckets: List[_Bucket]
    vector_in: bool                  # unbatched input was 1-D
    conv_out: Optional[Tuple[int, int, int]] = None   # (cout, oh, ow)
    im2col_idx: Optional[np.ndarray] = None           # (M, C*k*k) gather
    pad: int = 0
    stream_groups: Tuple[_StreamGroup, ...] = ()      # streamed mode only


def _im2col_indices(cin: int, h: int, w: int, k: int, stride: int,
                    pad: int) -> np.ndarray:
    """Gather indices turning a flattened padded (C,Hp,Wp) image into the
    (H_out*W_out, C*k*k) patch matrix of functional.im2col."""
    hp, wp = h + 2 * pad, w + 2 * pad
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    ci, di, dj = np.meshgrid(np.arange(cin), np.arange(k), np.arange(k),
                             indexing="ij")
    patch = (ci * hp * wp + di * wp + dj).reshape(-1)        # (C*k*k,)
    ii, jj = np.meshgrid(np.arange(oh) * stride, np.arange(ow) * stride,
                         indexing="ij")
    base = (ii * wp + jj).reshape(-1)                        # (OH*OW,)
    return (base[:, None] + patch[None, :]).astype(np.int32)


def _pool_indices(h: int, w: int, k: int, stride: int, pad: int
                  ) -> np.ndarray:
    """(OH*OW, k*k) gather indices into a flattened padded (Hp,Wp) map."""
    wp = w + 2 * pad
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    di, dj = np.meshgrid(np.arange(k), np.arange(k), indexing="ij")
    win = (di * wp + dj).reshape(-1)
    ii, jj = np.meshgrid(np.arange(oh) * stride, np.arange(ow) * stride,
                         indexing="ij")
    base = (ii * wp + jj).reshape(-1)
    return (base[:, None] + win[None, :]).astype(np.int32)


def _collect_units(program: Program, placements: Dict[Tuple[str, int],
                                                      OpPlacement],
                   graph: Graph, arch: CIMArch,
                   seg_of: Dict[Tuple[str, int], int]
                   ) -> Dict[str, List[Tuple[Tuple[int, int, int, int], int]]]:
    """Walk the (possibly Loop-compressed) program once and resolve every
    distinct crossbar read into a weight-matrix span (r0, r1, c0, c1)
    tagged with the schedule segment its chunk is placed in.

    Copies and windows are emission-side parallelism: every copy reads
    the same tiles and each window row is handled by exactly one copy,
    so the executor applies each distinct unit to *all* window rows.
    """
    seen: Dict[Tuple, None] = {}
    for op in program.walk(expand_loops=False):
        k = op.kind
        if k == "cim.read_core":
            seen.setdefault(("core", op.attrs["node"],
                             op.attrs.get("chunk", 0)))
        elif k in ("cim.read_xb", "cim.read_row"):
            a = op.attrs
            seen.setdefault((k, a["op"], a.get("chunk", 0),
                             a.get("row_tile", 0), a.get("col_tile", 0),
                             a.get("spread", 0)))
    units: Dict[str, List[Tuple[Tuple[int, int, int, int], int]]] = {}
    for key in seen:
        if key[0] == "core":
            _, name, chunk = key
            node = graph.node(name)
            p = placements[(name, chunk)]
            total_r, total_c = weight_matrix_shape(node)
            ro, co = chunk_offsets(node, p)
            span = (ro, min(ro + p.mapping.r, total_r),
                    co, min(co + p.mapping.c, total_c))
        else:
            kind, name, chunk, rt, ct, spread = key
            node = graph.node(name)
            p = placements[(name, chunk)]
            total_r, total_c = weight_matrix_shape(node)
            r0, r1, c0, c1 = tile_ranges(p, arch, rt, ct)
            ro, co = chunk_offsets(node, p)
            r_lo, r_hi = ro + r0, min(ro + r1, total_r)
            c_lo, c_hi = co + c0, min(co + c1, total_c)
            if r_hi <= r_lo or c_hi <= c_lo:
                continue
            if kind == "cim.read_row" and p.row_spread > 1:
                ss = spread_slice(r_hi - r_lo, arch.xb.parallel_row,
                                  p.row_spread, spread)
                if ss is None:
                    continue
                r_lo, r_hi = r_lo + ss[0], r_lo + ss[1]
            span = (r_lo, r_hi, c_lo, c_hi)
        if span[1] > span[0] and span[3] > span[2]:
            units.setdefault(name, []).append(
                (span, seg_of.get((name, key[2]), 0)))
    return units


class LoweredExecutable:
    """One compiled program, trace-lowered to a jitted batched function.

    Construction is pure analysis (no tracing); jax traces lazily on the
    first ``run``/``run_batch`` per batch shape.  Weights enter through
    ``pack`` (ahead-of-time tile packing) and shifts are per-call scalar
    inputs, so neither forces a re-trace.
    """

    def __init__(self, plan: SchedulePlan, program: Program,
                 params: Optional[CimMvmParams] = None, *,
                 mode: Optional[str] = None,
                 stream="auto",
                 route: Optional[backend.KernelRoute] = None,
                 use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 faults=None):
        import jax
        self.plan = plan
        #: optional cimsim.faults.FaultMap — tile weight transforms fold
        #: into ``pack`` and the per-tile post-MVM ADC offsets become
        #: trace constants, so the jitted program stays one program
        self.faults = faults
        self.graph: Graph = plan.graph
        self.arch: CIMArch = plan.arch
        self.params = params or cim_mvm_params(plan.arch)
        self.route = _resolve_executor_route(route, mode, use_kernel,
                                             interpret)
        self._n_segments = max(1, len(plan.segments))
        if stream == "auto":
            stream = self._n_segments > 1
        self._stream = bool(stream)
        self.stats = ExecutorStats(segments=self._n_segments,
                                   streamed=self._stream,
                                   kernel_mode=self.route.mode)
        #: compile-key prefix linking this executable back to the span
        #: the compiler drew (set by ``lower`` when tracing is on); the
        #: first dispatch closes the compile→dispatch flow arrow
        self._flow_key: Optional[str] = None
        self._flow_done = False
        #: host seconds spent packing each segment's pool payload on the
        #: last streamed ``pack`` (the per-segment weight-programming
        #: wall time — the only per-segment host cost that exists, since
        #: the jitted trace stays one program)
        self._seg_pack_s: List[float] = []
        #: bound metric instruments for the dispatch hot path, cached
        #: per registry identity so a dispatch pays attribute access +
        #: a float add instead of four label-key constructions
        self._prof: Optional[tuple] = None
        self._disp_span = f"dispatch:{self.graph.name}"
        self._ox = 1 << (self.params.act_bits - 1)
        self._ow = 1 << (self.params.weight_bits - 1)

        unsupported = sorted({n.op_type for n in self.graph.nodes
                              if not n.is_cim
                              and n.op_type not in _SUPPORTED_DCOM})
        if unsupported:
            raise LoweringError(f"no bit-exact lowering for {unsupported}")

        seg_of = {(p.node.name, p.chunk): si
                  for si, seg in enumerate(plan.segments)
                  for p in seg.placements}
        placements = {(p.node.name, p.chunk): p for p in plan.placements}
        units = _collect_units(program, placements, self.graph, self.arch,
                               seg_of)
        #: streamed-mode crossbar-pool layout: per (segment, shape key)
        #: the tiles resident there, in slot order (drives ``pack``)
        self._seg_layout: Dict[Tuple[int, str],
                               List[Tuple[str, Tuple[int, int, int, int]]]] \
            = {}
        self._seg_cursor: Dict[Tuple[int, str], int] = {}
        self._plans: Dict[str, _CimPlan] = {}
        for node in self.graph.cim_nodes:
            self._plans[node.name] = self._lower_cim_node(node,
                                                          units.get(node.name))
        #: per-shape pool depth = the largest simultaneous (per-segment)
        #: tile count — the device working set a real crossbar pool holds
        self._pool_shapes: Dict[str, Tuple[int, int, int]] = {}
        for (seg, key), n in self._seg_cursor.items():
            rl, cl = (int(v) for v in key.split("x"))
            depth = max(n, self._pool_shapes.get(key, (0,))[0])
            self._pool_shapes[key] = (depth, rl, cl)
        self.stats.swaps = len(self._seg_layout)
        self._build_fault_offsets()
        self._pool_idx: Dict[str, np.ndarray] = {}
        for node in self.graph.nodes:
            if node.op_type in ("MaxPool", "AveragePool"):
                _, h, w = self.graph.shapes[node.inputs[0]]
                k = node.attrs.get("kernel", 2)
                self._pool_idx[node.name] = _pool_indices(
                    h, w, k, node.attrs.get("stride", k),
                    node.attrs.get("pad", 0))
            if not node.is_cim:
                self.stats.dcom_nodes += 1
        self._shift_names = sorted(
            [n.name for n in self.graph.nodes
             if n.is_cim or n.op_type in _SHIFTED_DCOM])
        self._jit = jax.jit(self._forward)

    # -- lowering ---------------------------------------------------------
    def _lower_cim_node(self, node: Node,
                        tagged: Optional[Sequence[Tuple[
                            Tuple[int, int, int, int], int]]]
                        ) -> _CimPlan:
        total_r, total_c = weight_matrix_shape(node)
        if not tagged:
            raise LoweringError(f"{node.name}: no crossbar reads emitted")
        spans = [span for span, _ in tagged]
        covered = sum((r1 - r0) * (c1 - c0) for r0, r1, c0, c1 in spans)
        if covered != total_r * total_c:
            raise LoweringError(
                f"{node.name}: crossbar reads cover {covered} weight cells, "
                f"expected {total_r * total_c}")
        # int32 headroom: the signed accumulator is bounded by R*2^(ab+wb-2)
        # and each unit's unsigned partial by r_u*(2^ab-1)*(2^wb-1)
        ab, wb = self.params.act_bits, self.params.weight_bits
        max_r_u = max(r1 - r0 for r0, r1, _, _ in spans)
        if (total_r << (ab + wb - 2)) > _INT32_MAX or \
                max_r_u * ((1 << ab) - 1) * ((1 << wb) - 1) > _INT32_MAX:
            raise LoweringError(f"{node.name}: accumulation exceeds int32")

        by_shape: Dict[Tuple[int, int], List[Tuple[int, int, int, int]]] = {}
        for span in sorted(spans):
            r0, r1, c0, c1 = span
            by_shape.setdefault((r1 - r0, c1 - c0), []).append(span)
        buckets = [_Bucket(spans=tuple(group), r_len=rl, c_len=cl)
                   for (rl, cl), group in sorted(by_shape.items())]

        stream_groups: Tuple[_StreamGroup, ...] = ()
        if self._stream:
            # streamed mode: tiles live in the shared per-shape crossbar
            # pool only for their segment — group per (segment, shape)
            # and claim contiguous slots from that segment's cursor
            by_ss: Dict[Tuple[int, int, int],
                        List[Tuple[int, int, int, int]]] = {}
            for span, seg in sorted(tagged, key=lambda t: (t[1], t[0])):
                r0, r1, c0, c1 = span
                by_ss.setdefault((seg, r1 - r0, c1 - c0), []).append(span)
            groups = []
            for (seg, rl, cl), group in sorted(by_ss.items()):
                key = f"{rl}x{cl}"
                lo = self._seg_cursor.get((seg, key), 0)
                hi = lo + len(group)
                self._seg_cursor[(seg, key)] = hi
                self._seg_layout.setdefault((seg, key), []).extend(
                    (node.name, s) for s in group)
                groups.append(_StreamGroup(seg=seg, spans=tuple(group),
                                           r_len=rl, c_len=cl, lo=lo,
                                           hi=hi))
            stream_groups = tuple(groups)

        # streamed mode always rides the tile path: the pool models
        # physical crossbar residency, which the whole-matrix matmul
        # shortcut would bypass
        exact = self.params.exact and not self._stream
        self.stats.cim_nodes += 1
        self.stats.units += len(spans)
        self.stats.dispatches += len(stream_groups) if self._stream \
            else (1 if exact else len(buckets))
        self.stats.matmul_nodes += int(exact)

        cp = _CimPlan(node=node, r=total_r, c=total_c, exact=exact,
                      buckets=buckets,
                      vector_in=len(self.graph.shapes[node.inputs[0]]) == 1,
                      stream_groups=stream_groups)
        if node.op_type == "Conv":
            cin, h, w = self.graph.shapes[node.inputs[0]]
            k = node.attrs["weight_shape"][2]
            cp.pad = node.attrs.get("pad", 0)
            cp.im2col_idx = _im2col_indices(cin, h, w, k,
                                            node.attrs.get("stride", 1),
                                            cp.pad)
            cout = node.attrs["weight_shape"][0]
            oh, ow = self.graph.shapes[node.outputs[0]][1:]
            cp.conv_out = (cout, oh, ow)
        return cp

    # -- fault folding ----------------------------------------------------
    def _build_fault_offsets(self) -> None:
        """Precompute the fault map's post-MVM ADC-offset terms as trace
        constants, one per dispatch shape:

          * exact path — a per-node (C,) aggregate (each tile span's
            offset lands once per window row, and the spans partition
            the matrix, so columns simply sum over their row tiles);
          * bucket / stream paths — a (T, 1, c_len) stack matching the
            tile axis of the batched MVM.

        The interpreter adds ``tile_offset(name, span)`` to every span's
        partial sum; these are the same vectors pre-folded per shape.
        """
        self._off_exact: Dict[str, Optional[np.ndarray]] = {}
        self._off_bucket: Dict[Tuple[str, str], Optional[np.ndarray]] = {}
        self._off_stream: Dict[Tuple[str, int], Optional[np.ndarray]] = {}
        if self.faults is None:
            return

        def stack(spans):
            offs = [self.faults.tile_offset(name, s) for s in spans]
            if all(o is None for o in offs):
                return None
            c_len = spans[0][3] - spans[0][2]
            return np.stack(
                [np.zeros(c_len, np.int64) if o is None else o
                 for o in offs]).astype(np.int32)[:, None, :]

        for name, cp in self._plans.items():
            if cp.exact:
                off = np.zeros(cp.c, np.int64)
                any_off = False
                for b in cp.buckets:
                    for s in b.spans:
                        t = self.faults.tile_offset(name, s)
                        if t is not None:
                            off[s[2]:s[3]] += t
                            any_off = True
                self._off_exact[name] = \
                    off.astype(np.int32) if any_off else None
            elif self._stream:
                for gi, g in enumerate(cp.stream_groups):
                    self._off_stream[(name, gi)] = stack(g.spans)
            else:
                for b in cp.buckets:
                    self._off_bucket[(name, b.key)] = stack(b.spans)

    def _fault_tiles(self, name: str, spans, w: np.ndarray) -> np.ndarray:
        """Stack tile ``spans`` of signed matrix ``w``, applying the
        fault map's per-tile weight transform when one is active."""
        if self.faults is None:
            return np.stack([w[r0:r1, c0:c1] for r0, r1, c0, c1 in spans])
        return np.stack(
            [self.faults.apply_tile(name, s, w[s[0]:s[1], s[2]:s[3]])
             for s in spans])

    # -- weight packing ---------------------------------------------------
    def pack(self, weights: Dict[str, np.ndarray]) -> Dict[str, Any]:
        """Ahead-of-time weight programming: the ``cim.write_*`` ops.

        Exact-ADC nodes keep their signed (R, C) matrix; saturating
        configs get offset-encoded tile stacks plus the rank-1 column
        sums of the digital offset correction.

        Streamed (multi-segment) mode instead packs one offset-encoded
        tile stack **per (segment, tile shape)** in crossbar-pool slot
        order — the payloads the traced segment-boundary swaps write
        into the pool buffers.
        """
        reg = obs_metrics.active()
        tr = obs_trace.get_trace()
        if reg is None and tr is None:
            return self._pack_impl(weights)
        t0 = time.perf_counter()
        packed = self._pack_impl(weights)
        dt = time.perf_counter() - t0
        nbytes = _packed_nbytes(packed)
        name = self.graph.name
        if reg is not None:
            reg.counter("executor_packs_total", workload=name).inc()
            reg.counter("executor_pack_bytes_total",
                        workload=name).inc(nbytes)
            reg.histogram("executor_pack_s").observe(dt)
            for si, s in enumerate(self._seg_pack_s):
                reg.histogram("executor_segment_pack_s",
                              segment=si).observe(s)
        if tr is not None:
            tr.complete(obs_trace.EXECUTOR_TRACK, name, f"pack:{name}",
                        "executor", obs_trace.now_s() - dt, dt,
                        bytes=int(nbytes), segments=self._n_segments,
                        streamed=self._stream)
        return packed

    def _pack_impl(self, weights: Dict[str, np.ndarray]) -> Dict[str, Any]:
        import jax.numpy as jnp
        if self._stream:
            mats: Dict[str, np.ndarray] = {}
            for name, cp in self._plans.items():
                w = np.asarray(weights[name], np.int32)
                if w.shape != (cp.r, cp.c):
                    raise ValueError(f"{name}: weights {w.shape} != "
                                     f"{(cp.r, cp.c)}")
                mats[name] = w
            segs: List[Dict[str, Any]] = []
            self._seg_pack_s = []
            for si in range(self._n_segments):
                t_seg = time.perf_counter()
                entry = {}
                for (seg, key), layout in self._seg_layout.items():
                    if seg != si:
                        continue
                    if self.faults is None:
                        tiles = np.stack(
                            [mats[name][r0:r1, c0:c1]
                             for name, (r0, r1, c0, c1) in layout])
                    else:
                        tiles = np.stack(
                            [self.faults.apply_tile(
                                name, span,
                                mats[name][span[0]:span[1],
                                           span[2]:span[3]])
                             for name, span in layout])
                    entry[key] = jnp.asarray(tiles + self._ow)   # unsigned
                segs.append(entry)
                self._seg_pack_s.append(time.perf_counter() - t_seg)
            return {"segs": segs}
        packed: Dict[str, Any] = {}
        for name, cp in self._plans.items():
            w = np.asarray(weights[name], np.int32)
            if w.shape != (cp.r, cp.c):
                raise ValueError(f"{name}: weights {w.shape} != "
                                 f"{(cp.r, cp.c)}")
            if cp.exact:
                if self.faults is not None:
                    # tile spans partition the matrix (coverage is
                    # checked at lowering), so per-span surgery yields
                    # the full effective matrix; values stay in the
                    # signed weight range, keeping the split-plane GEMM
                    # exact
                    w = w.copy()
                    for b in cp.buckets:
                        for s in b.spans:
                            w[s[0]:s[1], s[2]:s[3]] = \
                                self.faults.apply_tile(
                                    name, s, w[s[0]:s[1], s[2]:s[3]])
                if cp.r <= _F32_SPLIT_MAX_R and self.params.act_bits <= 8 \
                        and self.params.weight_bits <= 8:
                    # split-plane GEMM: w = 16*w_hi + w_lo with w_hi in
                    # [-8,7], w_lo in [0,15]; each f32 partial product sum
                    # stays under 2^24 so the fast float GEMM is exact
                    packed[name] = {"hi": jnp.asarray((w >> 4), jnp.float32),
                                    "lo": jnp.asarray((w & 15), jnp.float32)}
                else:
                    packed[name] = {"w": jnp.asarray(w)}
                continue
            entry: Dict[str, Any] = {}
            for b in cp.buckets:
                tiles = self._fault_tiles(name, b.spans, w)
                w_u = tiles + self._ow                       # unsigned
                entry[b.key] = {
                    "w": jnp.asarray(w_u),
                    "sw": jnp.asarray(w_u.sum(axis=1, keepdims=True,
                                              dtype=np.int32)),
                }
            packed[name] = entry
        return packed

    # -- execution --------------------------------------------------------
    def run(self, inputs: Dict[str, np.ndarray],
            weights: Optional[Dict[str, np.ndarray]] = None,
            shifts: Optional[Dict[str, int]] = None, *,
            packed: Optional[Dict[str, Any]] = None
            ) -> Dict[str, np.ndarray]:
        """One inference on unbatched inputs (batch axis added/stripped)."""
        batched = {k: np.asarray(v)[None] for k, v in inputs.items()}
        out = self.run_batch(batched, weights, shifts, packed=packed)
        return {k: v[0] for k, v in out.items()}

    def run_batch(self, inputs: Dict[str, np.ndarray],
                  weights: Optional[Dict[str, np.ndarray]] = None,
                  shifts: Optional[Dict[str, int]] = None, *,
                  packed: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, np.ndarray]:
        """N inferences in one dispatch: every input carries a leading
        batch axis.  Pass ``packed=self.pack(weights)`` to amortize
        weight packing across calls.

        Profiling happens here, at the dispatch boundary — the jitted
        trace stays one program, so per-segment device times do not
        exist to measure; the whole dispatch (which the trailing
        ``np.asarray`` synchronizes) is the honest timing unit.
        Disabled telemetry costs two ``is None`` checks.
        """
        reg = obs_metrics.active()
        tr = obs_trace.get_trace()
        if reg is None and tr is None:
            return self._run_batch_impl(inputs, weights, shifts,
                                        packed=packed)
        t0 = time.perf_counter()
        out = self._run_batch_impl(inputs, weights, shifts, packed=packed)
        dt = time.perf_counter() - t0
        n = int(next(iter(out.values())).shape[0]) if out else 0
        name = self.graph.name
        if reg is not None:
            prof = self._prof
            if prof is None or prof[0] is not reg:
                prof = self._prof = (
                    reg,
                    reg.counter("executor_dispatches_total",
                                route=self.route.mode),
                    reg.counter("executor_requests_total", workload=name),
                    reg.counter("executor_swaps_total", workload=name),
                    reg.histogram("executor_dispatch_s",
                                  route=self.route.mode))
            prof[1].inc()
            prof[2].inc(n)
            if self.stats.swaps:
                prof[3].inc(self.stats.swaps)
            prof[4].observe(dt)
        if tr is not None:
            now = obs_trace.now_s()
            tr.complete(obs_trace.EXECUTOR_TRACK, name, self._disp_span,
                        "executor", now - dt, dt, batch=n,
                        route=self.route.mode, segments=self._n_segments,
                        swaps=self.stats.swaps)
            if self._flow_key is not None and not self._flow_done:
                # close the compile→dispatch arrow inside this span
                self._flow_done = True
                tr.flow_end(obs_trace.EXECUTOR_TRACK, name, "artifact",
                            "flow", now - dt / 2,
                            flow_id=int(self._flow_key[:12], 16),
                            key=self._flow_key[:12])
        return out

    def _run_batch_impl(self, inputs, weights=None, shifts=None, *,
                        packed=None) -> Dict[str, np.ndarray]:
        import jax.numpy as jnp
        if packed is None:
            if weights is None:
                raise ValueError("need weights=... or packed=...")
            packed = self.pack(weights)
        shifts = shifts or {}
        sh = {name: jnp.int32(shifts.get(name, 0))
              for name in self._shift_names}
        xs = {name: jnp.asarray(np.asarray(v), jnp.int32)
              for name, v in inputs.items()}
        out = self._jit(packed, sh, xs)
        return {name: np.asarray(v) for name, v in out.items()}

    # -- the traced program ----------------------------------------------
    def _swap_chain(self, segs):
        """Trace the segment-boundary weight swaps: one pool state per
        segment, each produced from the previous by in-place ``.at``
        updates — the jitted program carries the hardware's
        write-then-read dependence chain and holds at most the pool
        (not the sum of all segments' tiles) on device."""
        import jax.numpy as jnp
        cur = {key: jnp.zeros(shape, jnp.int32)
               for key, shape in self._pool_shapes.items()}
        states = []
        for entry in segs:
            cur = dict(cur)
            for key, w in entry.items():
                cur[key] = cur[key].at[:w.shape[0]].set(w)
            states.append(cur)
        return states

    def _forward(self, packed, shifts, inputs):
        pools = self._swap_chain(packed["segs"]) if self._stream else None
        tensors: Dict[str, Any] = dict(inputs)
        for node in self.graph.nodes:
            xs = [tensors[t] for t in node.inputs]
            if node.is_cim:
                pw = None if self._stream else packed[node.name]
                tensors[node.outputs[0]] = self._cim(node, xs[0], pw,
                                                     shifts[node.name],
                                                     pools)
            elif node.op_type == "Split":
                for name, part in zip(node.outputs,
                                      self._split(node, xs[0])):
                    tensors[name] = part
            else:
                tensors[node.outputs[0]] = self._dcom(node, xs, shifts)
        return {t: tensors[t] for t in self.graph.outputs}

    def _rows(self, node: Node, x):
        """(N, windows, R) MVM input rows (im2col for Conv)."""
        import jax.numpy as jnp
        cp = self._plans[node.name]
        if node.op_type == "Conv":
            n = x.shape[0]
            p = cp.pad
            if p:
                x = jnp.pad(x, ((0, 0), (0, 0), (p, p), (p, p)))
            return x.reshape(n, -1)[:, cp.im2col_idx]
        return x[:, None, :] if cp.vector_in else x

    def _cim(self, node: Node, x, pw, sh, pools=None):
        import jax.numpy as jnp
        cp = self._plans[node.name]
        rows = self._rows(node, x)                     # (N, M, R)
        n, m, _ = rows.shape
        if cp.exact:
            if "hi" in pw:
                xf = rows.astype(jnp.float32)
                acc = ((xf @ pw["hi"]).astype(jnp.int32) << 4) \
                    + (xf @ pw["lo"]).astype(jnp.int32)
            else:
                acc = jnp.matmul(rows, pw["w"],
                                 preferred_element_type=jnp.int32)
            off = self._off_exact.get(node.name)
            if off is not None:
                acc = acc + off
        elif self._stream:
            flat = (rows + self._ox).reshape(n * m, cp.r)
            acc = jnp.zeros((n * m, cp.c), jnp.int32)
            for gi, g in enumerate(cp.stream_groups):
                rows_idx = np.stack([np.arange(r0, r1, dtype=np.int32)
                                     for r0, r1, _, _ in g.spans])
                xt = jnp.moveaxis(flat[:, rows_idx], 1, 0)  # (T, NM, r_len)
                # tiles come out of *this segment's* pool state, so the
                # dispatch depends on the traced swap chain; the offset
                # correction's column sums are recomputed in-trace
                w_u = pools[g.seg][g.key][g.lo:g.hi]
                sw = w_u.sum(axis=1, keepdims=True)
                y_u = _cim_mvm_tiles_impl(xt, w_u, self.params,
                                          self.route.mode)
                sx = xt.sum(-1, keepdims=True)
                y = (y_u - self._ow * sx - self._ox * sw
                     + g.r_len * self._ox * self._ow)
                off = self._off_stream.get((node.name, gi))
                if off is not None:
                    y = y + off
                col_idx = np.concatenate(
                    [np.arange(c0, c1, dtype=np.int32)
                     for _, _, c0, c1 in g.spans])
                acc = acc.at[:, col_idx].add(
                    jnp.moveaxis(y, 0, 1).reshape(n * m, -1))
            acc = acc.reshape(n, m, cp.c)
        else:
            flat = (rows + self._ox).reshape(n * m, cp.r)
            acc = jnp.zeros((n * m, cp.c), jnp.int32)
            for b in cp.buckets:
                rows_idx = np.stack([np.arange(r0, r1, dtype=np.int32)
                                     for r0, r1, _, _ in b.spans])
                xt = jnp.moveaxis(flat[:, rows_idx], 1, 0)  # (T, NM, r_len)
                y_u = _cim_mvm_tiles_impl(xt, pw[b.key]["w"], self.params,
                                          self.route.mode)
                sx = xt.sum(-1, keepdims=True)
                y = (y_u - self._ow * sx - self._ox * pw[b.key]["sw"]
                     + b.r_len * self._ox * self._ow)
                off = self._off_bucket.get((node.name, b.key))
                if off is not None:
                    y = y + off
                col_idx = np.concatenate(
                    [np.arange(c0, c1, dtype=np.int32)
                     for _, _, c0, c1 in b.spans])
                acc = acc.at[:, col_idx].add(
                    jnp.moveaxis(y, 0, 1).reshape(n * m, -1))
            acc = acc.reshape(n, m, cp.c)
        y = jnp.clip(acc >> sh, -128, 127).astype(jnp.int32)
        if cp.conv_out is not None:
            cout, oh, ow = cp.conv_out
            return y.transpose(0, 2, 1).reshape(n, cout, oh, ow)
        if cp.vector_in:
            return y[:, 0]
        return y

    def _split(self, node: Node, x):
        import jax.numpy as jnp
        axis = node.attrs.get("axis", -1) % (x.ndim - 1) + 1
        parts = node.attrs["parts"]
        return jnp.split(x, np.cumsum(parts[:-1]), axis=axis)

    def _pool(self, node: Node, x, reduce_max: bool):
        import jax.numpy as jnp
        k = node.attrs.get("kernel", 2)
        pad = node.attrs.get("pad", 0)
        n, c = x.shape[0], x.shape[1]
        if pad:
            fill = -(2 ** 31) if reduce_max else 0
            x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)),
                        constant_values=fill)
        win = x.reshape(n, c, -1)[:, :, self._pool_idx[node.name]]
        if reduce_max:
            red = win.max(axis=-1)
        else:
            red = jnp.floor_divide(win.sum(axis=-1), k * k)
        oh, ow = self.graph.shapes[node.outputs[0]][1:]
        return red.reshape(n, c, oh, ow)

    def _dcom(self, node: Node, xs: List, shifts):
        import jax
        import jax.numpy as jnp
        t = node.op_type
        if t == "Relu":
            return jnp.maximum(xs[0], 0)
        if t in ("Add", "Mul"):
            y = xs[0] + xs[1] if t == "Add" else xs[0] * xs[1]
            return jnp.clip(y >> shifts[node.name], -128, 127) \
                .astype(jnp.int32)
        if t == "MaxPool":
            return self._pool(node, xs[0], reduce_max=True)
        if t == "AveragePool":
            return self._pool(node, xs[0], reduce_max=False)
        if t == "GlobalAveragePool":
            hw = xs[0].shape[2] * xs[0].shape[3]
            return jnp.floor_divide(
                xs[0].sum(axis=(2, 3), keepdims=True), hw).astype(jnp.int32)
        if t == "Flatten":
            return xs[0].reshape(xs[0].shape[0], -1)
        if t == "Reshape":
            return xs[0].reshape((xs[0].shape[0],)
                                 + tuple(node.attrs["shape"]))
        if t == "Identity":
            return xs[0]
        if t == "Transpose":
            perm = (0,) + tuple(q + 1 for q in node.attrs["perm"])
            return jnp.transpose(xs[0], perm)
        if t == "Concat":
            axis = node.attrs.get("axis", -1)
            return jnp.concatenate(xs, axis if axis < 0 else axis + 1)
        if t == "MatMul":
            b = xs[1]
            if node.attrs.get("transpose_b"):
                b = jnp.swapaxes(b, -1, -2)
            y = jnp.matmul(xs[0], b, preferred_element_type=jnp.int32)
            return jnp.clip(y >> shifts[node.name], -128, 127) \
                .astype(jnp.int32)
        # float-reference ops: the NumPy float64 path is the contract, so
        # call it (batch-transparent: elementwise / last-axis only)
        x = xs[0]

        def cb(xv):
            y = _float_dcom(t, [np.asarray(xv)], node)
            return np.clip(np.round(y * 32.0), -128, 127).astype(np.int32)

        return jax.pure_callback(
            cb, jax.ShapeDtypeStruct(x.shape, jnp.int32), x)


# ---------------------------------------------------------------------------
# Process-wide lowering cache
# ---------------------------------------------------------------------------

_LOWER_CACHE: "OrderedDict[Tuple, LoweredExecutable]" = OrderedDict()
_LOWER_CACHE_MAX = 32


def clear_lower_cache() -> None:
    _LOWER_CACHE.clear()


def _packed_nbytes(obj: Any) -> int:
    """Device-bound bytes in a ``pack`` payload (recursive over the
    dict/list nesting; array leaves expose ``nbytes``)."""
    if hasattr(obj, "nbytes"):
        return int(obj.nbytes)
    if isinstance(obj, dict):
        return sum(_packed_nbytes(v) for v in obj.values())
    if isinstance(obj, (list, tuple)):
        return sum(_packed_nbytes(v) for v in obj)
    return 0


def lower(plan: SchedulePlan, program: Program,
          params: Optional[CimMvmParams] = None, *,
          mode: Optional[str] = None, stream="auto",
          use_kernel: Optional[bool] = None,
          interpret: Optional[bool] = None,
          faults=None,
          cache: bool = True) -> LoweredExecutable:
    """Lower a compiled ``(plan, program)`` to a batched executable.

    The MVM execution route is a backend-registry decision (force with
    ``mode=``; the deprecated ``use_kernel=``/``interpret=`` booleans
    keep their historical meaning); ``stream="auto"`` enables
    weight-update streaming exactly for multi-segment schedules.
    ``faults`` (a ``cimsim.faults.FaultMap``) folds device faults into
    weight packing plus trace-constant post-MVM offsets.

    Cached process-wide by ``compile_key_for_plan(plan) x params x
    resolved route x streaming x fault-map identity``, so repeated
    lowerings of the same compile config — calibration loops,
    verification sweeps, serving restarts — reuse the traced executable
    and its jit cache.
    """
    from ..core import compiler
    params = params or cim_mvm_params(plan.arch)
    route = _resolve_executor_route(None, mode, use_kernel, interpret)
    streamed = (max(1, len(plan.segments)) > 1) if stream == "auto" \
        else bool(stream)
    key = None
    if cache:
        key = (compiler.compile_key_for_plan(plan), params, route.mode,
               streamed, None if faults is None else faults.token)
        hit = _LOWER_CACHE.get(key)
        if hit is not None:
            _LOWER_CACHE.move_to_end(key)
            obs_metrics.count("executor_lower_cache_hits_total")
            return hit
    t0 = time.perf_counter()
    exe = LoweredExecutable(plan, program, params, route=route,
                            stream=streamed, faults=faults)
    dt = time.perf_counter() - t0
    obs_metrics.count("executor_lowerings_total")
    obs_metrics.observe("executor_lower_s", dt)
    tr = obs_trace.get_trace()
    if tr is not None:
        tr.complete(obs_trace.EXECUTOR_TRACK, plan.graph.name,
                    f"lower:{plan.graph.name}", "executor",
                    obs_trace.now_s() - dt, dt, route=route.mode,
                    segments=len(plan.segments), streamed=streamed)
        # remember the compile key so the first dispatch can close the
        # compile→dispatch flow arrow (ids match compile_graph's start)
        exe._flow_key = (key[0] if key is not None
                         else compiler.compile_key_for_plan(plan))
    if key is not None:
        _LOWER_CACHE[key] = exe
        while len(_LOWER_CACHE) > _LOWER_CACHE_MAX:
            _LOWER_CACHE.popitem(last=False)
    return exe
