"""Performance simulator (§4.1): latency (cycles) + peak power.

Extends the PUMA-sim / NeuroSim-style modeling the paper builds on: an
event-driven simulation over the scheduled operator stages.

Latency.  Each CIM operator chunk is a *stage* whose steady-state cycle
count comes from its placement (``stage_cycles`` = windows/dup x
t_window); CIM-unsupported operators either fuse into their producer's
epilogue (streaming ops like ReLU — their ALU cost is charged to the
producer's per-window time) or form standalone ALU stages (MatMul etc.).
With the intra-image pipeline enabled, a consumer starts once each
producer has emitted the fraction of its output the consumer's first
unit of work needs (*per-edge warmup*); the MVM-grained staggered
pipeline halves the transfer granularity and thus the warmup
(Fig. 12(d)); the VVM remap shortens the per-window time itself
(Fig. 14(d)).  Without the pipeline, consumers wait for full outputs.

Peak power.  Analog activation dominates (the paper's measured split:
crossbar activation 83%, ADC/DAC 10%, data movement 7%).  We track the
number of concurrently-activated crossbars over time; traditional
scheduling fires all crossbars of a VXB set at once, the staggered
pipeline only one row-stripe per copy (Fig. 12(c) vs (d)).  Reported
``peak_power`` is in units of one crossbar activation (incl. its
ADC/DAC + movement share).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from ..core.abstraction import CIMArch
from ..core.cg_opt import OpPlacement, SchedulePlan
from ..core.graph import Graph, Node
from ..core.mvm_opt import peak_active_xbs

XB_POWER_SHARE = 0.83
ADC_POWER_SHARE = 0.10
MOV_POWER_SHARE = 0.07


@dataclasses.dataclass
class PerfReport:
    latency_cycles: float
    compute_cycles: float          # sum of stage cycles (no overlap)
    rewrite_cycles: float
    peak_active_xbs: float
    peak_power: float              # normalized crossbar-activation units
    avg_active_xbs: float
    energy_units: float            # xb-activation-cycles
    n_segments: int
    n_stages: int
    pipeline: bool
    stagger: bool
    remap: bool
    crossbars_used: int = 0        # peak physical crossbars mapped (any segment)

    def metrics(self) -> Dict[str, float]:
        """JSON-safe flat metric bundle (DSE objectives + diagnostics).

        Every value is a plain int/float/bool so the bundle can be stored
        next to a compile-cache entry and re-read without unpickling the
        full ``CompileResult``.
        """
        d = dataclasses.asdict(self)
        return {k: (v if isinstance(v, (bool, int)) else float(v))
                for k, v in d.items()}


@dataclasses.dataclass
class _Info:
    start: float
    finish: float

    @property
    def duration(self) -> float:
        return max(self.finish - self.start, 0.0)


def _edge_frac(prod: Node, cons: Node, graph: Graph) -> float:
    """Fraction of a producer's output the consumer needs before starting
    its own first unit of work (pipeline warmup granularity)."""
    shapes = graph.shapes
    out = shapes.get(prod.outputs[0], (1,))
    t = cons.op_type
    if t == "Conv":
        k = cons.attrs["weight_shape"][2]
        h = out[1] if len(out) >= 3 else 1
        return min(1.0, k / max(h, 1))
    if t in ("MaxPool", "AveragePool"):
        k = cons.attrs.get("kernel", 2)
        h = out[1] if len(out) >= 3 else 1
        return min(1.0, k / max(h, 1))
    if t in ("Gemm", "Linear", "LayerNorm", "RMSNorm", "Softmax",
             "TopKRouter"):
        # token-wise streaming: these operate row-by-row over the leading
        # (token) dims, so they start after the first token vector arrives
        if len(out) >= 2:
            return 1.0 / max(math.prod(out[:-1]), 1)
        return 1.0              # flattened vector: needs everything
    if t in ("GlobalAveragePool", "Flatten", "MatMul"):
        return 1.0
    # elementwise & misc: stream through at element granularity
    n = max(math.prod(out), 1)
    return 1.0 / n


def _alu_stage_cycles(node: Node, graph: Graph, arch: CIMArch) -> float:
    from ..core.graph import macs
    alu = arch.chip.alu_ops_per_cycle
    if not math.isfinite(alu):
        return 0.0
    return macs(node, graph.shapes) / alu


def _is_standalone_alu(node: Node, graph: Graph) -> bool:
    """ALU nodes not fused into a CIM producer's epilogue."""
    if node.is_cim:
        return False
    if node.op_type == "MatMul":
        return True
    return not any(p.is_cim for p in graph.predecessors(node))


def estimate(plan: SchedulePlan) -> PerfReport:
    arch, graph = plan.arch, plan.graph
    stagger = plan.mvm_pipeline
    pipeline = plan.use_pipeline

    info: Dict[str, _Info] = {}
    intervals: List[Tuple[float, float, float]] = []   # start, end, active xbs
    compute = 0.0
    rewrites = 0.0
    n_stages = 0

    placements_of: Dict[str, List[OpPlacement]] = {}
    segment_of: Dict[str, int] = {}
    for si, seg in enumerate(plan.segments):
        for p in seg.placements:
            placements_of.setdefault(p.node.name, []).append(p)
            segment_of[p.node.name] = si

    def warm_edge(pred: Node, node: Node) -> float:
        pi = info[pred.name]
        if not pipeline:
            return pi.finish
        frac = _edge_frac(pred, node, graph)
        if stagger and pred.is_cim:
            frac *= 0.5          # half-tile forwarding (Fig. 12(d))
        return pi.start + pi.duration * min(1.0, frac)

    def ready_time(node: Node, floor: float) -> float:
        t = floor
        for pred in graph.predecessors(node):
            if pred.name in info:
                t = max(t, warm_edge(pred, node))
        return t

    offset = 0.0
    processed: set = set()
    ping_pong = bool(plan.notes.get("ping_pong"))
    prev_duration = 0.0
    # chunked ops may span segments: accumulate chunk intervals per node
    chunk_acc: Dict[str, List[Tuple[float, float]]] = {}
    for si, seg in enumerate(plan.segments):
        if ping_pong and si > 0:
            # double buffering: this segment's weights were programmed
            # into the idle half of the pool while the previous segment
            # computed — only the un-hidden remainder stalls the chip.
            stall = max(0.0, seg.rewrite_cycles - prev_duration)
            offset += stall
            rewrites += stall
        else:
            offset += seg.rewrite_cycles
            rewrites += seg.rewrite_cycles
        seg_start = offset
        seg_nodes = {p.node.name for p in seg.placements}
        seg_end = offset

        for node in graph.nodes:
            if node.name in processed:
                continue
            if node.is_cim:
                if node.name not in seg_nodes:
                    continue   # mapped in a later segment
            else:
                # ALU node: defer until all predecessors are scheduled
                # (a missing pred can only be a later-segment CIM node,
                # since graph.nodes and the segment list share topo order)
                if any(pr.name not in info for pr in graph.predecessors(node)):
                    continue

            if node.is_cim:
                # schedule only the chunks mapped in THIS segment
                start = ready_time(node, offset)
                acc = chunk_acc.setdefault(node.name, [])
                for p in seg.placements:
                    if p.node.name != node.name:
                        continue
                    cyc = p.stage_cycles
                    compute += cyc
                    n_stages += 1
                    acc.append((start, start + cyc))
                    ax = peak_active_xbs(p, stagger)
                    if ax > 0 and cyc > 0:
                        intervals.append((start, start + cyc, ax))
                    seg_end = max(seg_end, start + cyc)
                if len(acc) < len(placements_of[node.name]):
                    continue   # remaining chunks live in later segments
                processed.add(node.name)
                info[node.name] = _Info(start=min(s for s, _ in acc),
                                        finish=max(e for _, e in acc))
                seg_end = max(seg_end, info[node.name].finish)
                continue

            processed.add(node.name)
            start = ready_time(node, offset)
            if _is_standalone_alu(node, graph):
                cyc = _alu_stage_cycles(node, graph, arch)
                compute += cyc
                n_stages += 1
                finish = start + cyc
            else:
                # fused streaming op: completes with its slowest producer
                preds = [info[p.name].finish
                         for p in graph.predecessors(node) if p.name in info]
                finish = max(preds + [start])
            info[node.name] = _Info(start=start, finish=finish)
            seg_end = max(seg_end, finish)
        prev_duration = seg_end - seg_start
        offset = seg_end

    # trailing ALU nodes whose producers were deferred (rare)
    for node in graph.nodes:
        if node.name in processed or node.is_cim:
            continue
        if all(pr.name in info for pr in graph.predecessors(node)):
            start = ready_time(node, offset)
            if _is_standalone_alu(node, graph):
                cyc = _alu_stage_cycles(node, graph, arch)
                compute += cyc
                n_stages += 1
                finish = start + cyc
            else:
                preds = [info[p.name].finish
                         for p in graph.predecessors(node) if p.name in info]
                finish = max(preds + [start])
            info[node.name] = _Info(start=start, finish=finish)
            offset = max(offset, finish)

    latency = max(offset, *(i.finish for i in info.values()), 1e-9) \
        if info else 1e-9

    # peak power sweep
    events: List[Tuple[float, float]] = []
    energy = 0.0
    for s, e, ax in intervals:
        events.append((s, ax))
        events.append((e, -ax))
        energy += ax * (e - s)
    events.sort()
    peak = cur = 0.0
    for _, delta in events:
        cur += delta
        peak = max(peak, cur)

    # crossbars physically occupied: segments execute serially and reuse
    # (overwrite) the pool, so the footprint is the busiest segment's.
    xbs_used = max((sum(p.dup * p.mapping.n_xbs for p in seg.placements)
                    for seg in plan.segments), default=0)

    return PerfReport(
        latency_cycles=latency,
        compute_cycles=compute,
        rewrite_cycles=rewrites,
        peak_active_xbs=peak,
        peak_power=peak,
        avg_active_xbs=energy / latency,
        energy_units=energy,
        n_segments=len(plan.segments),
        n_stages=n_stages,
        pipeline=pipeline,
        stagger=stagger,
        remap=plan.vvm_remap,
        crossbars_used=xbs_used,
    )
