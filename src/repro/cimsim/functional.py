"""Functional simulator (§4.1): interprets the meta-operator flow.

The paper verifies its compiler by executing the generated meta-operator
flows in a functional simulator and comparing against a reference
framework (they use PyTorch; offline we use a pure-NumPy/JAX int8
fake-quant reference, ``reference_forward``).

The simulator walks the *expanded* Program op by op:

  * ``cim.write_xb`` / ``cim.write_row`` load quantized weight tiles into
    a crossbar store;
  * ``cim.read_xb`` / ``cim.read_row`` perform one analog activation —
    the bit-sliced, parallel-row-grouped, ADC-saturating MVM of
    kernels/cim_mvm (ref semantics; the Pallas kernel computes the same
    function and is swept against it in tests) — and accumulate partial
    sums;
  * ``cim.read_core`` executes a whole operator on a core (CM chips);
  * DCOM ops apply the digital operators; ``mov`` is bookkeeping.

Equality with the reference is bit-exact whenever the ADC does not
saturate (``CimMvmParams.exact``); with a narrow ADC the simulator
reports the (hardware-true) saturated results.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.abstraction import CIMArch
from ..core.cg_opt import OpPlacement, SchedulePlan
from ..core.graph import Graph, Node, weight_matrix_shape
from ..core.mapping import logical_cols_per_xb
from ..core.mop import MetaOp, Program
from ..kernels.cim_mvm import cim_mvm_params, CimMvmParams
from ..kernels.cim_mvm import ref as kref


# ---------------------------------------------------------------------------
# Quantization helpers (shared verbatim by simulator and reference)
# ---------------------------------------------------------------------------

def requant(y32: np.ndarray, shift: int) -> np.ndarray:
    """int32 accumulator -> int8 tensor via arithmetic right-shift."""
    return np.clip(y32 >> shift, -128, 127).astype(np.int32)


def pick_shift(y32: np.ndarray) -> int:
    m = int(np.abs(y32).max()) if y32.size else 0
    if m <= 127:
        return 0
    return max(0, int(math.ceil(math.log2((m + 1) / 127.0))))


def make_weights(graph: Graph, seed: int = 0,
                 bits: int = 8) -> Dict[str, np.ndarray]:
    """Deterministic signed int weights (R, C) per CIM node.

    Seeded with a stable digest of ``(node name, seed)`` — ``hash()`` of
    a str is salted per process, which would silently break cross-process
    reproducibility and any cache keyed on weight content.
    """
    out = {}
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1))
    for node in graph.cim_nodes:
        r, c = weight_matrix_shape(node)
        rng = np.random.default_rng(
            zlib.crc32(f"{node.name}\x00{seed}".encode()))
        out[node.name] = rng.integers(lo, hi, (r, c)).astype(np.int32)
    return out


def make_input(graph: Graph, seed: int = 0, bits: int = 8) -> Dict[str, np.ndarray]:
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1))
    rng = np.random.default_rng(seed)
    return {name: rng.integers(lo, hi, shape).astype(np.int32)
            for name, shape in graph.inputs.items()}


def im2col(x: np.ndarray, k: int, stride: int, pad: int) -> np.ndarray:
    """(C,H,W) -> (H_out*W_out, C*k*k) patch matrix (weight-matrix order)."""
    c, h, w = x.shape
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    rows = np.empty((oh * ow, c * k * k), dtype=x.dtype)
    idx = 0
    for i in range(oh):
        for j in range(ow):
            patch = xp[:, i * stride:i * stride + k, j * stride:j * stride + k]
            rows[idx] = patch.reshape(-1)
            idx += 1
    return rows


# ---------------------------------------------------------------------------
# Reference executor (int8 fake-quant, exact integer matmuls)
# ---------------------------------------------------------------------------

def _float_dcom(op_type: str, xs: List[np.ndarray],
                node: Node) -> np.ndarray:
    x = xs[0].astype(np.float64)
    if op_type == "Gelu":
        return x * 0.5 * (1.0 + np.tanh(0.7978845608 * (x + 0.044715 * x ** 3)))
    if op_type == "Silu":
        return x / (1.0 + np.exp(-x))
    if op_type == "Sigmoid":
        return 1.0 / (1.0 + np.exp(-x))
    if op_type == "Tanh":
        return np.tanh(x)
    if op_type == "Softmax":
        e = np.exp(x - x.max(axis=-1, keepdims=True))
        return e / e.sum(axis=-1, keepdims=True)
    if op_type in ("LayerNorm", "RMSNorm"):
        if op_type == "LayerNorm":
            x = x - x.mean(axis=-1, keepdims=True)
        return x / np.sqrt((x ** 2).mean(axis=-1, keepdims=True) + 1e-6)
    raise ValueError(f"no float DCOM for {op_type}")


def apply_dcom(node: Node, xs: List[np.ndarray], graph: Graph,
               shifts: Dict[str, int],
               calibrating: bool) -> np.ndarray:
    """Digital operator semantics shared by simulator and reference."""
    t = node.op_type
    if t == "Relu":
        return np.maximum(xs[0], 0)
    if t == "Add":
        y = xs[0].astype(np.int64) + xs[1].astype(np.int64)
        sh = _shift_for(node, y, shifts, calibrating)
        return requant(y.astype(np.int64) >> 0, 0) if sh == 0 \
            else np.clip(y >> sh, -128, 127).astype(np.int32)
    if t == "Mul":
        y = xs[0].astype(np.int64) * xs[1].astype(np.int64)
        sh = _shift_for(node, y, shifts, calibrating)
        return np.clip(y >> sh, -128, 127).astype(np.int32)
    if t == "MaxPool":
        return _pool(xs[0], node, np.max)
    if t in ("AveragePool", "GlobalAveragePool"):
        if t == "GlobalAveragePool":
            return (xs[0].sum(axis=(1, 2), keepdims=True)
                    // (xs[0].shape[1] * xs[0].shape[2])).astype(np.int32)
        return _pool(xs[0], node, lambda a, axis: a.sum(axis=axis)
                     // (node.attrs.get("kernel", 2) ** 2))
    if t == "Flatten":
        return xs[0].reshape(-1)
    if t == "Reshape":
        return xs[0].reshape(node.attrs["shape"])
    if t == "Identity":
        return xs[0]
    if t == "Transpose":
        return xs[0].transpose(node.attrs["perm"])
    if t == "Concat":
        return np.concatenate(xs, axis=node.attrs.get("axis", -1))
    if t == "Split":
        axis = node.attrs.get("axis", -1) % xs[0].ndim
        parts = node.attrs["parts"]
        return np.split(xs[0], np.cumsum(parts[:-1]), axis=axis)
    if t == "MatMul":
        b = xs[1].T if node.attrs.get("transpose_b") else xs[1]
        y = xs[0].astype(np.int64) @ b.astype(np.int64)
        sh = _shift_for(node, y, shifts, calibrating)
        return np.clip(y >> sh, -128, 127).astype(np.int32)
    # float fallback ops re-quantized to int8 grid
    y = _float_dcom(t, xs, node)
    return np.clip(np.round(y * 32.0), -128, 127).astype(np.int32)


def _shift_for(node: Node, y, shifts: Dict[str, int],
               calibrating: bool) -> int:
    if calibrating:
        shifts[node.name] = pick_shift(np.asarray(y))
    return shifts.get(node.name, 0)


def _pool(x: np.ndarray, node: Node, reducer) -> np.ndarray:
    k = node.attrs.get("kernel", 2)
    stride = node.attrs.get("stride", k)
    pad = node.attrs.get("pad", 0)
    c, h, w = x.shape
    if pad:
        fill = -(2 ** 31) if reducer is np.max else 0
        x = np.pad(x, ((0, 0), (pad, pad), (pad, pad)),
                   constant_values=fill)
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    out = np.empty((c, oh, ow), dtype=np.int32)
    for i in range(oh):
        for j in range(ow):
            win = x[:, i * stride:i * stride + k, j * stride:j * stride + k]
            out[:, i, j] = reducer(win.reshape(c, -1), axis=-1)
    return out


def reference_forward(graph: Graph, weights: Dict[str, np.ndarray],
                      inputs: Dict[str, np.ndarray],
                      shifts: Optional[Dict[str, int]] = None,
                      mvm=None) -> Tuple[Dict[str, np.ndarray], Dict[str, int]]:
    """Pure int8 fake-quant forward pass.

    ``mvm(x_rows, w) -> int32`` defaults to the exact integer matmul;
    passing kernels/cim_mvm's signed op makes the reference share the
    crossbar compute semantics (for saturating-ADC comparisons).
    Returns (tensors, calibrated shifts).
    """
    calibrating = shifts is None
    shifts = {} if shifts is None else dict(shifts)
    if mvm is None:
        def mvm(x_rows, w):
            return x_rows.astype(np.int64) @ w.astype(np.int64)
    tensors: Dict[str, np.ndarray] = dict(inputs)
    for node in graph.nodes:
        xs = [tensors[t] for t in node.inputs]
        if node.is_cim:
            w = weights[node.name]
            if node.op_type == "Conv":
                k = node.attrs["weight_shape"][2]
                rows = im2col(xs[0], k, node.attrs.get("stride", 1),
                              node.attrs.get("pad", 0))
                y = np.asarray(mvm(rows, w))
                sh = _shift_for(node, y, shifts, calibrating)
                y = np.clip(y >> sh, -128, 127).astype(np.int32)
                cout = node.attrs["weight_shape"][0]
                oh, ow = graph.shapes[node.outputs[0]][1:]
                y = y.T.reshape(cout, oh, ow)
            else:
                rows = xs[0][None] if xs[0].ndim == 1 else xs[0]
                y = np.asarray(mvm(rows, w))
                sh = _shift_for(node, y, shifts, calibrating)
                y = np.clip(y >> sh, -128, 127).astype(np.int32)
                y = y[0] if xs[0].ndim == 1 else y
            tensors[node.outputs[0]] = y
        else:
            _store_outputs(tensors, node,
                           apply_dcom(node, xs, graph, shifts, calibrating))
    return tensors, shifts


def _store_outputs(tensors: Dict[str, np.ndarray], node: Node, y) -> None:
    """Assign a DCOM result to the node's output tensors (Split is the
    one multi-output operator: apply_dcom returns one array per part)."""
    if node.op_type == "Split":
        for name, part in zip(node.outputs, y):
            tensors[name] = part
    else:
        tensors[node.outputs[0]] = y


# ---------------------------------------------------------------------------
# Crossbar tile geometry + signed MVM semantics, shared by the op-by-op
# interpreter (below) and the trace-lowered batched executor
# (cimsim.executor) — both must address the same weight sub-matrices.
# ---------------------------------------------------------------------------

def tile_ranges(p: OpPlacement, arch: CIMArch, rt: int, ct: int
                ) -> Tuple[int, int, int, int]:
    """Row/col index ranges of tile (rt, ct) of a chunk's sub-matrix."""
    m = p.mapping
    r0 = rt * arch.xb.rows
    r1 = min(r0 + arch.xb.rows, m.r)
    cpx = logical_cols_per_xb(m, arch)
    c0 = ct * cpx
    c1 = min(c0 + cpx, m.c)
    return r0, r1, c0, c1


def chunk_offsets(node: Node, p: OpPlacement) -> Tuple[int, int]:
    """Global (row, col) offset of a chunk inside the full matrix."""
    r, c = weight_matrix_shape(node)
    sub_r, sub_c = p.mapping.r, p.mapping.c
    cc = math.ceil(c / sub_c)
    ci, ri = p.chunk % cc, p.chunk // cc
    return ri * sub_r, ci * sub_c


def spread_slice(rows_in_tile: int, parallel_row: int, row_spread: int,
                 part: int) -> Optional[Tuple[int, int]]:
    """Row sub-span [s0, s1) of spread ``part`` under the VVM remap, or
    ``None`` when the part falls past the tile's rows."""
    n_grp = max(1, math.ceil(rows_in_tile / parallel_row))
    per = math.ceil(n_grp / row_spread) * parallel_row
    s0 = part * per
    if s0 >= rows_in_tile:
        return None
    return s0, min(s0 + per, rows_in_tile)


def signed_oracle_mvm(x_rows: np.ndarray, w: np.ndarray,
                      p: CimMvmParams) -> np.ndarray:
    """Signed MVM through the crossbar oracle via offset encoding.

    The standard CIM trick shared by the interpreter, the executor and
    the saturating-ADC reference: store ``x + 2^(ab-1)`` / ``w + 2^(wb-1)``
    unsigned, run the bit-sliced ADC-saturating oracle, subtract the
    rank-1 correction digitally.
    """
    import jax.numpy as jnp
    ox = 1 << (p.act_bits - 1)
    ow = 1 << (p.weight_bits - 1)
    x_u = x_rows.astype(np.int64) + ox
    w_u = w.astype(np.int64) + ow
    y_u = np.asarray(kref.cim_mvm_ref(
        jnp.asarray(x_u, jnp.int32), jnp.asarray(w_u, jnp.int32),
        act_bits=p.act_bits, weight_bits=p.weight_bits,
        dac_bits=p.dac_bits, cell_bits=p.cell_bits,
        parallel_row=p.parallel_row, adc_bits=p.adc_bits)).astype(np.int64)
    r = x_rows.shape[-1]
    sx = x_u.sum(axis=-1, keepdims=True)
    sw = w_u.sum(axis=0, keepdims=True)
    return y_u - ow * sx - ox * sw + r * ox * ow


def reference_mvm(params: CimMvmParams):
    """The MVM the int8 reference must use for these crossbar params:
    ``None`` (exact integer matmul) when the ADC provably never
    saturates, else the offset-encoded oracle — so calibration,
    simulation and verification all share one dispatch rule."""
    if params.exact:
        return None
    return lambda x_rows, w: signed_oracle_mvm(x_rows, w, params)


# ---------------------------------------------------------------------------
# The meta-operator flow interpreter
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimStats:
    cim_reads: int = 0
    cim_writes: int = 0
    dcom_ops: int = 0
    mov_bytes: int = 0


class FunctionalSimulator:
    """Executes an expanded meta-operator flow for one inference."""

    def __init__(self, plan: SchedulePlan, program: Program,
                 weights: Dict[str, np.ndarray],
                 shifts: Dict[str, int],
                 params: Optional[CimMvmParams] = None,
                 faults=None):
        self.plan = plan
        self.graph: Graph = plan.graph
        self.arch: CIMArch = plan.arch
        self.program = program
        self.weights = weights
        self.shifts = shifts
        self.params = params or cim_mvm_params(plan.arch)
        #: optional cimsim.faults.FaultMap — every crossbar read applies
        #: its tile's weight transform + post-MVM offset (the executor
        #: applies the identical per-span functions; see faults.py)
        self.faults = faults
        self.stats = SimStats()
        self._placement: Dict[Tuple[str, int], OpPlacement] = {}
        for p in plan.placements:
            self._placement[(p.node.name, p.chunk)] = p
        self._rows_cache: Dict[str, np.ndarray] = {}
        self._acc: Dict[str, np.ndarray] = {}       # int64 accumulators
        self._acc_pending: Dict[str, bool] = {}

    # -- crossbar-level MVM with the CIM compute semantics ---------------
    def _cim_mvm(self, x_rows: np.ndarray, w: np.ndarray,
                 parallel_row: Optional[int] = None) -> np.ndarray:
        p = self.params
        if parallel_row is not None:
            p = dataclasses.replace(p, parallel_row=parallel_row)
        return signed_oracle_mvm(x_rows, w, p)

    def _faulted(self, name: str, span: Tuple[int, int, int, int],
                 wsub: np.ndarray):
        """(effective weights, post-MVM offset or None) of one tile span
        under the active fault map — identity without one."""
        if self.faults is None:
            return wsub, None
        return (self.faults.apply_tile(name, span, wsub),
                self.faults.tile_offset(name, span))

    # -- tensor store -----------------------------------------------------
    def _tensor(self, name: str) -> np.ndarray:
        prod = self.graph.producer(name)
        if prod is not None and self._acc_pending.get(prod.name):
            self._finalize(prod)
        return self._tensors[name]

    def _finalize(self, node: Node) -> None:
        y = self._acc[node.name]
        sh = self.shifts.get(node.name, 0)
        y = np.clip(y >> sh, -128, 127).astype(np.int32)
        if node.op_type == "Conv":
            cout = node.attrs["weight_shape"][0]
            oh, ow = self.graph.shapes[node.outputs[0]][1:]
            y = y.T.reshape(cout, oh, ow)
        else:
            x_shape = self.graph.shapes[node.inputs[0]]
            if len(x_shape) == 1:
                y = y[0]
        self._tensors[node.outputs[0]] = y
        self._acc_pending[node.name] = False

    def _input_rows(self, node: Node) -> np.ndarray:
        if node.name in self._rows_cache:
            return self._rows_cache[node.name]
        x = self._tensor(node.inputs[0])
        if node.op_type == "Conv":
            k = node.attrs["weight_shape"][2]
            rows = im2col(x, k, node.attrs.get("stride", 1),
                          node.attrs.get("pad", 0))
        else:
            rows = x[None] if x.ndim == 1 else x
        self._rows_cache[node.name] = rows
        return rows

    def _tile_ranges(self, p: OpPlacement, rt: int, ct: int):
        return tile_ranges(p, self.arch, rt, ct)

    def _chunk_offsets(self, node: Node, p: OpPlacement):
        return chunk_offsets(node, p)

    # -- execution ---------------------------------------------------------
    def run(self, inputs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        self._tensors: Dict[str, np.ndarray] = dict(inputs)
        self._rows_cache.clear()
        self._acc.clear()
        for op in self.program.walk(expand_loops=True):
            self._exec(op)
        # finalize any pending accumulators and run to the graph outputs
        for node in self.graph.nodes:
            if self._acc_pending.get(node.name):
                self._finalize(node)
        return {t: self._tensor(t) for t in self.graph.outputs}

    def _exec(self, op: MetaOp) -> None:
        k = op.kind
        a = op.attrs
        if k in ("cim.write_xb", "cim.write_row"):
            self.stats.cim_writes += 1
            return                      # weights are addressed by attrs
        if k == "mov":
            self.stats.mov_bytes += int(a.get("len", 0))
            return
        if k == "cim.read_core":
            self._read_core(a)
            return
        if k in ("cim.read_xb", "cim.read_row"):
            self._read_tile(a, wlm=(k == "cim.read_row"))
            return
        # DCOM
        self.stats.dcom_ops += 1
        if k == "shift_acc":
            return                      # folded into the accumulation
        node = self.graph.node(a["node"]) if "node" in a else None
        if node is None:
            return
        xs = [self._tensor(t) for t in node.inputs]
        y = apply_dcom(node, xs, self.graph, self.shifts, calibrating=False)
        _store_outputs(self._tensors, node, y)

    def _acc_for(self, node: Node) -> np.ndarray:
        if node.name not in self._acc:
            rows = self._input_rows(node)
            r, c = weight_matrix_shape(node)
            n = rows.shape[0]
            self._acc[node.name] = np.zeros((n, c), np.int64)
        self._acc_pending[node.name] = True
        return self._acc[node.name]

    def _read_core(self, a: Dict) -> None:
        self.stats.cim_reads += 1
        node = self.graph.node(a["node"])
        p = self._placement[(node.name, a.get("chunk", 0))]
        rows = self._input_rows(node)
        acc = self._acc_for(node)
        copy, dup = a.get("copy", 0), p.dup
        idx = np.arange(copy, rows.shape[0], dup)
        if idx.size == 0:
            return
        w = self.weights[node.name]
        ro, co = self._chunk_offsets(node, p)
        wsub = w[ro:ro + p.mapping.r, co:co + p.mapping.c]
        span = (ro, ro + wsub.shape[0], co, co + wsub.shape[1])
        wsub, off = self._faulted(node.name, span, wsub)
        y = self._cim_mvm(rows[idx][:, ro:ro + p.mapping.r], wsub)
        if off is not None:
            y = y + off[None, :]
        acc[np.ix_(idx, np.arange(co, co + wsub.shape[1]))] += y

    def _read_tile(self, a: Dict, wlm: bool) -> None:
        self.stats.cim_reads += 1
        node = self.graph.node(a["op"])
        p = self._placement[(node.name, a.get("chunk", 0))]
        rows = self._input_rows(node)
        acc = self._acc_for(node)
        copy, dup = a.get("copy", 0), p.dup
        w_idx = a["window"]
        windows = np.arange(copy, rows.shape[0], dup)
        if isinstance(w_idx, int):
            if w_idx >= windows.size:
                return
            windows = windows[w_idx:w_idx + 1]
        rt, ct = a.get("row_tile", 0), a.get("col_tile", 0)
        r0, r1, c0, c1 = self._tile_ranges(p, rt, ct)
        ro, co = self._chunk_offsets(node, p)
        w = self.weights[node.name]
        wsub = w[ro + r0:ro + min(r1, p.mapping.r),
                 co + c0:co + min(c1, p.mapping.c)]
        if wsub.size == 0:
            return
        xr0, xr1 = ro + r0, ro + r0 + wsub.shape[0]
        if wlm and p.row_spread > 1:
            span = spread_slice(wsub.shape[0], self.arch.xb.parallel_row,
                                p.row_spread, a.get("spread", 0))
            if span is None:
                return
            s0, s1 = span
            wsub = wsub[s0:s1]
            xr0, xr1 = xr0 + s0, xr0 + (s1 - s0) + s0
        fspan = (xr0, xr1, co + c0, co + c0 + wsub.shape[1])
        wsub, off = self._faulted(node.name, fspan, wsub)
        y = self._cim_mvm(rows[windows][:, xr0:xr1], wsub)
        if off is not None:
            y = y + off[None, :]
        cols = np.arange(co + c0, co + c0 + wsub.shape[1])
        acc[np.ix_(windows, cols)] += y


def calibrate_shifts(graph: Graph, weights: Dict[str, np.ndarray],
                     inputs: Dict[str, np.ndarray],
                     params: CimMvmParams) -> Dict[str, int]:
    """Requantization shifts from one reference calibration pass (the
    reference shares the crossbar compute semantics when the ADC can
    saturate, so calibration sees the hardware-true dynamic range)."""
    _, shifts = reference_forward(graph, weights, inputs,
                                  mvm=reference_mvm(params))
    return shifts


def simulate(graph: Graph, arch: CIMArch, *, level=None, seed: int = 0,
             params: Optional[CimMvmParams] = None,
             use_executor: bool = False, faults=None):
    """Compile ``graph`` for ``arch``, run the reference, execute the
    meta-op flow, and return (sim_outputs, ref_outputs, stats).

    ``use_executor=True`` runs the trace-lowered batched executor
    (cimsim.executor) instead of the op-by-op interpreter — same
    semantics, one jitted dispatch (stats are then lowering stats).
    ``faults`` (a ``cimsim.faults.FaultMap``) injects device faults into
    the simulated crossbars; the reference outputs stay fault-free, so
    the pair measures fault-induced degradation.
    """
    from ..core import compiler
    weights = make_weights(graph, seed)
    inputs = make_input(graph, seed)
    p = params or cim_mvm_params(arch)

    ref_mvm = reference_mvm(p)
    _, shifts = reference_forward(graph, weights, inputs, mvm=ref_mvm)
    ref_out, _ = reference_forward(graph, weights, inputs, shifts=shifts,
                                   mvm=ref_mvm)
    if use_executor:
        from .executor import lower
        res = compiler.compile_graph(graph, arch, level=level)
        exe = lower(res.plan, res.program, params=p, faults=faults)
        sim_out = exe.run(inputs, weights, shifts)
        stats = exe.stats
    else:
        res = compiler.compile_graph(graph, arch, level=level, expand=True)
        sim = FunctionalSimulator(res.plan, res.program, weights, shifts,
                                  params=p, faults=faults)
        sim_out = sim.run(inputs)
        stats = sim.stats
    return sim_out, {t: ref_out[t] for t in graph.outputs}, stats


@dataclasses.dataclass
class VerifyReport:
    """Outcome of one functional verification (§4.1) of a compile."""

    graph: str
    arch: str
    batch: int
    max_abs_err: Dict[str, int]          # per graph output
    lower_s: float = 0.0
    run_s: float = 0.0
    #: set when verification could not run at all (compile/lowering
    #: failure) — ``max_abs_err`` is then empty and ``ok`` is False
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None and \
            all(e == 0 for e in self.max_abs_err.values())


def compile_and_verify(graph: Graph, arch: CIMArch, *, level=None,
                       seed: int = 0, batch: int = 1,
                       params: Optional[CimMvmParams] = None,
                       use_executor: bool = True, faults=None,
                       **compile_kwargs) -> VerifyReport:
    """Compile ``graph`` for ``arch`` and verify the emitted flow against
    the int8 fake-quant reference on ``batch`` random inputs.

    The fast path (default) lowers the compiled program once with the
    batched executor and verifies all inputs in a single dispatch; a
    flow the executor cannot lower bit-exactly (``LoweringError``)
    falls back to op-by-op interpretation, as does
    ``use_executor=False``.  Extra keyword arguments (``use_pipeline``,
    ``binding``, ``cache``, ...) reach ``compile_graph``, so any DSE
    design point can be verified.  With ``faults`` set the simulated
    crossbars carry the fault map while the reference stays clean, so
    ``max_abs_err`` measures fault-induced deviation (``ok`` then means
    the faults were numerically invisible).
    """
    import time
    from ..core import compiler
    weights = make_weights(graph, seed)
    p = params or cim_mvm_params(arch)
    inputs = [make_input(graph, seed + i) for i in range(batch)]
    ref_mvm = reference_mvm(p)
    _, shifts = reference_forward(graph, weights, inputs[0], mvm=ref_mvm)
    refs = [reference_forward(graph, weights, x, shifts=shifts,
                              mvm=ref_mvm)[0] for x in inputs]

    err = {t: 0 for t in graph.outputs}
    if use_executor:
        from .executor import LoweringError, lower
        res = compiler.compile_graph(graph, arch, level=level,
                                     **compile_kwargs)
        try:
            t0 = time.time()
            exe = lower(res.plan, res.program, params=p, faults=faults)
            packed = exe.pack(weights)
            t1 = time.time()
            batched = {name: np.stack([x[name] for x in inputs])
                       for name in graph.inputs}
            outs = exe.run_batch(batched, packed=packed, shifts=shifts)
            t2 = time.time()
            for i in range(batch):
                for t in graph.outputs:
                    d = np.abs(np.asarray(outs[t][i], np.int64)
                               - refs[i][t].astype(np.int64))
                    err[t] = max(err[t], int(d.max()) if d.size else 0)
            return VerifyReport(graph=graph.name, arch=arch.name,
                                batch=batch, max_abs_err=err,
                                lower_s=t1 - t0, run_s=t2 - t1)
        except LoweringError:
            pass       # fast path unavailable: verify op by op below

    res = compiler.compile_graph(graph, arch, level=level, expand=True,
                                 **compile_kwargs)
    sim = FunctionalSimulator(res.plan, res.program, weights, shifts,
                              params=p, faults=faults)
    t0 = time.time()
    for i, x in enumerate(inputs):
        out = sim.run(x)
        for t in graph.outputs:
            d = np.abs(out[t].astype(np.int64) - refs[i][t].astype(np.int64))
            err[t] = max(err[t], int(d.max()) if d.size else 0)
    return VerifyReport(graph=graph.name, arch=arch.name, batch=batch,
                        max_abs_err=err, run_s=time.time() - t0)
