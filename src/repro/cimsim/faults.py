"""Deterministic device-fault injection for CIM crossbars.

Real RRAM/SRAM crossbars ship with stuck-at cells, broken word/bit
lines, conductance drift and per-ADC offsets; this module makes those
breakable on purpose, identically in the op-by-op interpreter
(``functional.FunctionalSimulator``) and the trace-lowered executor
(``executor.LoweredExecutable``).

Fault semantics — the conformance contract
------------------------------------------

Both simulators address weights through the same *tile spans*: global
``(r0, r1, c0, c1)`` sub-rectangles of each node's weight matrix (the
interpreter per crossbar read, the executor via ``_collect_units``).  A
``FaultMap`` defines every fault as a function of ``(node name, span)``:

  * a **weight transform** ``apply_tile(name, span, w) -> w_eff`` — slice
    surgery on the offset-encoded unsigned cell values (stuck cells and
    dead lines force slices to G0/G1, drift perturbs them within the
    cell's LSB range), then decoded back to a signed matrix; and
  * a **post-MVM perturbation** ``tile_offset(name, span)`` — the folded
    integer image of the per-bitline ADC offsets, added to the tile's
    digital partial sum.

Because ``signed_oracle_mvm`` recomputes its rank-1 offset-encoding
correction from whatever weights it is given, substituting ``w_eff``
keeps the interpreter, the executor's saturating tile path *and* the
executor's exact-ADC matmul shortcut mutually bit-exact under faults —
the jitted trace stays one program (faults fold into the packed tiles
plus trace-constant offsets).

Physical model (per tile): fields are drawn over the **full physical
crossbar grid** (``xb.rows x xb.cols`` cells) with a stable per-(node,
span) seed.  Logical row ``i`` lives on physical row ``i`` and logical
column ``j``'s bit slice ``k`` on physical column ``j*S + k`` (the
``B->XBC`` layout) — unless remapping is on, in which case clean-line
selection steers rows/column-groups away from faulty lines first.
Dead lines are modeled as line-correlated stuck-at-G0 (the whole
word/bit line reads zero conductance), so every fault class is one
uniform unsigned-domain override.

Fault-aware remapping (compiler tier)
-------------------------------------

``fault_aware_compile`` retires wordlines/bitlines from the bindable
geometry (``core.mapping.retired_geometry``), recompiles — the existing
``balance_duplication`` machinery re-spreads copies over the shrunk
tiles — and verifies that every tile span can be steered onto clean
lines, iterating the retirement budget until the map is clean or
``FaultBudgetError`` says it cannot be.  Remapping assumes a *known*
fault map (post-fabrication test), so the per-column ADC offsets are
calibrated out digitally; residual faults still apply wherever clean
lines ran out.

``accuracy_under_faults`` is the executor-backed robustness metric:
top-1 agreement with the fault-free executor over a seeded input batch,
rankable by DSE campaigns (``dse.runner.evaluate_point(fault_model=)``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.abstraction import CIMArch
from ..core.mapping import FaultBudgetError, retired_geometry
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace

Span = Tuple[int, int, int, int]


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Seeded statistical description of one chip's device faults.

    Rates are per-draw probabilities; sigmas are Gaussian widths.  All
    draws are deterministic in ``seed`` (and the tile identity), so two
    ``FaultMap`` instances built from equal models materialize identical
    faults — the property every conformance test leans on.
    """

    seed: int = 0
    #: iid per-cell stuck-at probability (degradation curves; not
    #: line-retirable at realistic rates)
    stuck_cell_rate: float = 0.0
    #: per-bitline whole-column stuck-at probability (line-clustered —
    #: the retirement-friendly fault class)
    stuck_col_rate: float = 0.0
    #: fraction of stuck cells/columns stuck at G1 (max conductance);
    #: the rest stick at G0
    stuck_hi_frac: float = 0.5
    #: per-wordline open probability (line reads as all-G0)
    dead_row_rate: float = 0.0
    #: per-bitline open probability (line reads as all-G0)
    dead_col_rate: float = 0.0
    #: Gaussian conductance drift, in cell LSBs (rounded, clipped to the
    #: cell's level range)
    drift_sigma: float = 0.0
    #: Gaussian per-bitline ADC offset, in ADC counts (rounded)
    adc_offset_sigma: float = 0.0

    @property
    def any_faults(self) -> bool:
        return any((self.stuck_cell_rate, self.stuck_col_rate,
                    self.dead_row_rate, self.dead_col_rate,
                    self.drift_sigma, self.adc_offset_sigma))

    @property
    def token(self) -> str:
        """Stable content hash (executor lowering-cache key component)."""
        payload = ",".join(f"{f.name}={getattr(self, f.name)!r}"
                           for f in dataclasses.fields(self))
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


@dataclasses.dataclass
class _SpanFaults:
    """Materialized faults of one (node, span) tile, in logical layout:
    per-slice override mask/values plus drift and the folded per-column
    post-MVM offset.  ``identity`` short-circuits untouched tiles."""

    identity: bool
    mask: Optional[np.ndarray] = None     # (S, r_len, c_len) bool
    val: Optional[np.ndarray] = None      # (S, r_len, c_len) forced level
    drift: Optional[np.ndarray] = None    # (S, r_len, c_len) int
    offset: Optional[np.ndarray] = None   # (c_len,) int64 post-MVM term
    deficit_rows: int = 0                 # remap: rows left on faulty lines
    deficit_cols: int = 0                 # remap: col groups left unclean


class FaultMap:
    """Per-crossbar-tile fault materialization for one chip.

    ``arch`` supplies the *physical* grid (always the original chip —
    pass the unretired arch even when the plan was compiled against
    ``retired_geometry``).  ``remap=True`` enables clean-line selection:
    each tile's rows and column groups are steered onto fault-free
    physical lines first (and known ADC offsets are calibrated out);
    lines beyond the clean supply keep their residual faults.
    """

    def __init__(self, model: FaultModel, arch: CIMArch, *,
                 remap: bool = False):
        self.model = model
        self.remap = bool(remap)
        self.rows_phys = arch.xb.rows
        self.cols_phys = arch.xb.cols
        self.cell_bits = arch.xb.cell_precision
        self.weight_bits = arch.weight_bits
        self.slices = math.ceil(self.weight_bits / self.cell_bits)
        self._ow = 1 << (self.weight_bits - 1)
        #: per-slice bit widths / shifts (top slice may be narrower)
        self.slice_bits = tuple(
            min(self.cell_bits, self.weight_bits - k * self.cell_bits)
            for k in range(self.slices))
        self.slice_shift = tuple(k * self.cell_bits
                                 for k in range(self.slices))
        self._cache: Dict[Tuple[str, Span], _SpanFaults] = {}

    @property
    def token(self) -> str:
        """Content identity for executor lowering-cache keys."""
        return (f"{self.model.token}:{self.rows_phys}x{self.cols_phys}"
                f":{self.cell_bits}b{self.weight_bits}w"
                f":{'remap' if self.remap else 'direct'}")

    # -- per-tile field ---------------------------------------------------
    def _rng(self, name: str, span: Span) -> np.random.Generator:
        tok = f"{name}\x00{span[0]},{span[1]},{span[2]},{span[3]}" \
              f"\x00{self.model.seed}"
        return np.random.default_rng(zlib.crc32(tok.encode()))

    def _field(self, name: str, span: Span) -> Dict[str, np.ndarray]:
        """Draw the tile's physical fault field (full crossbar grid).
        Every array is drawn unconditionally in a fixed order, so the
        stream — hence every fault — is stable across rate settings of
        *other* fault classes only through the model's own values."""
        m = self.model
        rng = self._rng(name, span)
        R, C = self.rows_phys, self.cols_phys
        f = {
            "dead_row": rng.random(R) < m.dead_row_rate,
            "dead_col": rng.random(C) < m.dead_col_rate,
            "stuck_col": rng.random(C) < m.stuck_col_rate,
            "stuck_col_hi": rng.random(C) < m.stuck_hi_frac,
            "stuck_cell": rng.random((R, C)) < m.stuck_cell_rate,
            "stuck_cell_hi": rng.random((R, C)) < m.stuck_hi_frac,
        }
        f["drift"] = np.rint(rng.normal(0.0, 1.0, (R, C))
                             * m.drift_sigma).astype(np.int64)
        f["adc_off"] = np.rint(rng.normal(0.0, 1.0, C)
                               * m.adc_offset_sigma).astype(np.int64)
        return f

    # -- clean-line selection ---------------------------------------------
    def _select_lines(self, f: Dict[str, np.ndarray], r_len: int,
                      c_len: int) -> Tuple[np.ndarray, np.ndarray, int, int]:
        """(row_sel, group_sel, deficit_rows, deficit_cols): the physical
        rows and column groups holding the tile's logical lines.  Without
        remap this is the identity placement; with remap, clean lines
        come first and deficits fall back to faulty ones (in index
        order, so selection is deterministic)."""
        S = self.slices
        n_groups = self.cols_phys // S
        if not self.remap:
            return (np.arange(r_len), np.arange(c_len),
                    int(f["dead_row"][:r_len].sum()), 0)
        clean_r = ~f["dead_row"]
        order_r = np.concatenate([np.flatnonzero(clean_r),
                                  np.flatnonzero(~clean_r)])
        row_sel = order_r[:r_len]
        deficit_rows = int((~clean_r[row_sel]).sum())
        # a column group (one logical column's S slices) is clean when
        # none of its bitlines is dead/stuck and no selected row has a
        # stuck cell in it
        gcols = np.arange(n_groups * S).reshape(n_groups, S)
        line_bad = (f["dead_col"][gcols] | f["stuck_col"][gcols]).any(axis=1)
        cell_bad = f["stuck_cell"][np.ix_(row_sel, np.arange(n_groups * S))]
        cell_bad = cell_bad.reshape(r_len, n_groups, S).any(axis=(0, 2))
        clean_g = ~(line_bad | cell_bad)
        order_g = np.concatenate([np.flatnonzero(clean_g),
                                  np.flatnonzero(~clean_g)])
        group_sel = order_g[:c_len]
        deficit_cols = int((~clean_g[group_sel]).sum())
        return row_sel, group_sel, deficit_rows, deficit_cols

    # -- materialization --------------------------------------------------
    def _span(self, name: str, span: Span) -> _SpanFaults:
        key = (name, span)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        if not self.model.any_faults:
            sf = _SpanFaults(identity=True)
            self._cache[key] = sf
            return sf
        r0, r1, c0, c1 = span
        r_len, c_len = r1 - r0, c1 - c0
        S = self.slices
        if r_len > self.rows_phys or c_len * S > self.cols_phys:
            raise ValueError(
                f"{name}: tile span {span} ({r_len}x{c_len} logical) "
                f"exceeds the physical {self.rows_phys}x{self.cols_phys} "
                "crossbar — was the FaultMap built from the original "
                "(unretired) arch?")
        f = self._field(name, span)
        row_sel, group_sel, dr, dc = self._select_lines(f, r_len, c_len)
        # physical column of logical (j, slice k) after selection
        pc = (group_sel[:, None] * S
              + np.arange(S)[None, :])                     # (c_len, S)
        mask = np.zeros((S, r_len, c_len), dtype=bool)
        val = np.zeros((S, r_len, c_len), dtype=np.int64)
        drift = np.zeros((S, r_len, c_len), dtype=np.int64)
        for k in range(S):
            cols_k = pc[:, k]                              # (c_len,)
            cell = np.ix_(row_sel, cols_k)
            max_k = (1 << self.slice_bits[k]) - 1
            stuck = f["stuck_cell"][cell]
            hi = f["stuck_cell_hi"][cell]
            mask[k] = stuck
            val[k] = np.where(hi, max_k, 0) * stuck
            scol = f["stuck_col"][cols_k]
            val[k] = np.where(scol[None, :] & ~stuck,
                              np.where(f["stuck_col_hi"][cols_k][None, :],
                                       max_k, 0), val[k])
            mask[k] |= scol[None, :]
            # dead lines: line-correlated stuck-at-G0 (overrides all)
            dead = f["dead_col"][cols_k][None, :] \
                | f["dead_row"][row_sel][:, None]
            mask[k] |= dead
            val[k] = np.where(dead, 0, val[k])
            drift[k] = f["drift"][cell]
        if self.model.drift_sigma <= 0:
            drift = None
        if self.remap:
            offset = None          # known map: ADC offsets calibrated out
        else:
            off = np.zeros(c_len, dtype=np.int64)
            for k in range(S):
                off += f["adc_off"][pc[:, k]] << self.slice_shift[k]
            offset = off if off.any() else None
        identity = (not mask.any()) and drift is None and offset is None
        sf = _SpanFaults(identity=identity,
                         mask=None if identity else mask,
                         val=None if identity else val,
                         drift=drift, offset=offset,
                         deficit_rows=dr, deficit_cols=dc)
        self._cache[key] = sf
        return sf

    # -- the two runtime hooks -------------------------------------------
    def apply_tile(self, name: str, span: Span,
                   w: np.ndarray) -> np.ndarray:
        """Effective signed weights of tile ``span`` under the map.

        ``w`` is the signed (r_len, c_len) sub-matrix; the result stays
        in the signed ``weight_bits`` range, so every downstream path
        (offset-encoded oracle, exact matmul, f32 split planes) remains
        valid.  Pure and memoized per span — both simulators call this
        with identical spans, which is the bit-exactness contract.
        """
        sf = self._span(name, span)
        if sf.identity:
            return w
        r_len, c_len = span[1] - span[0], span[3] - span[2]
        if w.shape != (r_len, c_len):
            raise ValueError(f"{name}: weights {w.shape} != span "
                             f"{(r_len, c_len)}")
        w_u = w.astype(np.int64) + self._ow
        out = np.zeros_like(w_u)
        for k in range(len(self.slice_bits)):
            max_k = (1 << self.slice_bits[k]) - 1
            v = (w_u >> self.slice_shift[k]) & max_k
            if sf.drift is not None:
                v = np.clip(v + sf.drift[k], 0, max_k)
            if sf.mask is not None:
                v = np.where(sf.mask[k], sf.val[k], v)
            out += v << self.slice_shift[k]
        return (out - self._ow).astype(w.dtype)

    def tile_offset(self, name: str, span: Span) -> Optional[np.ndarray]:
        """Folded post-MVM ADC-offset term for tile ``span``: an int64
        ``(c_len,)`` vector added to the tile's digital partial sum, or
        ``None`` when the tile's offsets are all zero (or calibrated out
        by remapping)."""
        return self._span(name, span).offset

    def span_deficit(self, name: str, span: Span) -> Tuple[int, int]:
        """(rows, column groups) of the tile that could not be placed on
        clean lines — the fault-aware compile loop's retirement signal
        (always 0 when every line found a clean home)."""
        sf = self._span(name, span)
        return sf.deficit_rows, sf.deficit_cols


# ---------------------------------------------------------------------------
# Fault-aware compilation (compiler tier)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultCompileResult:
    """Outcome of ``fault_aware_compile``: the (retired-geometry)
    compile, the remapping fault map to run it under, and how much
    geometry the retirement loop gave up."""

    result: object                  # core.compiler.CompileResult
    faults: FaultMap
    retired_rows: int
    retired_cols: int               # physical bitlines retired
    attempts: int


def plan_spans(plan, program) -> Dict[str, list]:
    """Every node's crossbar-tile spans of a compiled (plan, program) —
    the same span resolution the executor lowers from, so remap
    verification and runtime fault application can never disagree."""
    from .executor import _collect_units
    seg_of = {(p.node.name, p.chunk): si
              for si, seg in enumerate(plan.segments)
              for p in seg.placements}
    placements = {(p.node.name, p.chunk): p for p in plan.placements}
    units = _collect_units(program, placements, plan.graph, plan.arch,
                           seg_of)
    return {name: [span for span, _ in tagged]
            for name, tagged in units.items()}


def fault_aware_compile(graph, arch: CIMArch, model: FaultModel, *,
                        level=None, max_rounds: int = 6,
                        **compile_kwargs) -> FaultCompileResult:
    """Compile ``graph`` so every weight line lands on fault-free
    hardware of ``arch`` under ``model``.

    Iteratively retires wordlines/bitlines from the bindable geometry
    (``retired_geometry``) — the recompile re-spreads duplication over
    the shrunk tiles via the standard ``balance_duplication`` pass —
    until the remapping ``FaultMap`` finds clean lines for every tile
    span, or raises ``FaultBudgetError`` when retirement exhausts the
    crossbar (or ``max_rounds`` budget-growth rounds were not enough).
    """
    from ..core import compiler
    retire_r, retire_c = 0, 0
    fm = FaultMap(model, arch, remap=True)
    S = fm.slices
    for attempt in range(1, max_rounds + 1):
        arch_r = retired_geometry(arch, retire_r, retire_c)
        res = compiler.compile_graph(graph, arch_r, level=level,
                                     **compile_kwargs)
        fm = FaultMap(model, arch, remap=True)
        need_r = need_c = 0
        for name, spans in plan_spans(res.plan, res.program).items():
            for span in spans:
                dr, dc = fm.span_deficit(name, span)
                need_r, need_c = max(need_r, dr), max(need_c, dc)
        if need_r == 0 and need_c == 0:
            res.plan.notes["fault_retired"] = {
                "rows": retire_r, "cols": retire_c, "attempts": attempt}
            obs_metrics.count("fault_compile_attempts_total", n=attempt,
                              workload=graph.name)
            if retire_r or retire_c:
                obs_metrics.count("fault_retired_lines_total",
                                  n=retire_r + retire_c,
                                  workload=graph.name)
            tr = obs_trace.get_trace()
            if tr is not None:
                tr.instant(obs_trace.COMPILER_TRACK, "fault_remap",
                           "faults", obs_trace.now_s(), tenant=graph.name,
                           rows=retire_r, cols=retire_c, attempts=attempt)
            return FaultCompileResult(result=res, faults=fm,
                                      retired_rows=retire_r,
                                      retired_cols=retire_c,
                                      attempts=attempt)
        obs_metrics.count("fault_retry_rounds_total", workload=graph.name)
        retire_r += need_r
        retire_c += need_c * S
    raise FaultBudgetError(
        f"no clean mapping within {max_rounds} retirement rounds "
        f"(reached {retire_r} rows / {retire_c} cols retired on "
        f"{arch.name})", retire_rows=retire_r, retire_cols=retire_c)


# ---------------------------------------------------------------------------
# Executor-backed robustness metric (DSE tier)
# ---------------------------------------------------------------------------

def accuracy_under_faults(graph, arch: CIMArch, model: FaultModel, *,
                          n_inputs: int = 8, seed: int = 0, level=None,
                          remap: bool = False, params=None,
                          **compile_kwargs) -> float:
    """Top-1 agreement with the fault-free executor under ``model``.

    Runs the trace-lowered executor twice on a seeded ``n_inputs`` batch
    — once clean, once faulted (with fault-aware remapping when
    ``remap=True``) — and returns the fraction of inputs whose argmax
    over the (flattened) first graph output agrees.  Executor-backed by
    construction, so DSE campaigns can rank design points by robustness
    at full fidelity (see ``dse.runner.evaluate_point``).
    """
    from ..core import compiler
    from ..kernels.cim_mvm import cim_mvm_params
    from .executor import lower
    from .functional import (make_input, make_weights, reference_forward,
                             reference_mvm)
    p = params or cim_mvm_params(arch)
    weights = make_weights(graph, seed)
    inputs = [make_input(graph, seed + i) for i in range(n_inputs)]
    _, shifts = reference_forward(graph, weights, inputs[0],
                                  mvm=reference_mvm(p))
    batched = {name: np.stack([x[name] for x in inputs])
               for name in graph.inputs}

    base = compiler.compile_graph(graph, arch, level=level,
                                  **compile_kwargs)
    clean_exe = lower(base.plan, base.program, params=p)
    clean = clean_exe.run_batch(batched, weights=weights, shifts=shifts)

    if remap:
        fc = fault_aware_compile(graph, arch, model, level=level,
                                 **compile_kwargs)
        faulted_exe = lower(fc.result.plan, fc.result.program, params=p,
                            faults=fc.faults)
    else:
        faulted_exe = lower(base.plan, base.program, params=p,
                            faults=FaultMap(model, arch))
    faulted = faulted_exe.run_batch(batched, weights=weights,
                                    shifts=shifts)

    out = graph.outputs[0]
    a = np.asarray(clean[out]).reshape(n_inputs, -1).argmax(axis=1)
    b = np.asarray(faulted[out]).reshape(n_inputs, -1).argmax(axis=1)
    return float((a == b).mean())
