"""Design-space exploration over the cross-tier scheduling knob space.

CIM-MLC exposes a "tractable yet effective design space" (§4.3-§4.4): the
scheduling level (CM/XBM/WLM, clamped to what the chip's computing mode
allows), the bit-dimension binding (B->XBC vs B->XB), the CG pipeline and
duplication switches, and the Abs-arch parameters themselves (crossbar
geometry, cell precision, parallel rows, core counts).  This package
turns the one-shot compiler into a search service:

  * ``space``    — enumerate valid ``DesignPoint``s of a ``DesignSpace``;
  * ``cache``    — content-addressed, disk-persisted compile cache;
  * ``runner``   — the shared job-queue evaluation primitive
                   (``EvalJob``/``run_jobs``) plus the exhaustive
                   ``sweep`` built on it;
  * ``proxy_vec``— batched structure-of-arrays proxy cost model: the
                   analytic rung for an entire array of design points in
                   one vectorized pass, bit-exact vs the scalar oracle;
  * ``search``   — multi-fidelity successive halving (batched proxy
                   metrics → graph-prefix compiles → full compiles);
  * ``adaptive`` — budgeted ask/tell searcher (TPE-style density model
                   over the categorical + arch axes) batched for the
                   vectorized proxy, promoting a model-chosen shortlist
                   up the same fidelity ladder;
  * ``campaign`` — multi-workload campaigns over one queue + cache,
                   with per-workload frontiers and robust-point summary;
  * ``pareto``   — Pareto frontier over (latency, peak power, crossbars);
  * ``report``   — lm-eval-harness-style scorecards for campaigns and
                   searches (markdown / JSON).

See docs/DSE.md for the guide.
"""
from .adaptive import AdaptiveResult, AdaptiveSearch, adaptive_search
from .cache import (CacheLockTimeout, CompileCache,
                    default_cache_dir, shared_stats)
from .campaign import (CampaignResult, RobustPoint, WorkloadOutcome,
                       robust_points, run_campaign)
from .pareto import DEFAULT_OBJECTIVES, dominates, pareto_frontier
from .proxy_vec import (BatchedProxyMetrics, NodeTensor,
                        proxy_metrics_batch)
from .report import Scorecard, campaign_scorecard, search_scorecard
from .runner import (EvalJob, SweepResult, evaluate_point, run_jobs,
                     sweep)
from .search import (DEFAULT_LADDER, HalvingSearch, Rung, RungLog,
                     SearchResult, successive_halving)
from .space import DesignPoint, DesignSpace, apply_arch_overrides

__all__ = [
    "AdaptiveResult", "AdaptiveSearch", "adaptive_search",
    "CacheLockTimeout", "CompileCache", "default_cache_dir",
    "shared_stats",
    "CampaignResult", "RobustPoint", "WorkloadOutcome",
    "robust_points", "run_campaign",
    "DEFAULT_OBJECTIVES", "dominates", "pareto_frontier",
    "BatchedProxyMetrics", "NodeTensor", "proxy_metrics_batch",
    "Scorecard", "campaign_scorecard", "search_scorecard",
    "EvalJob", "SweepResult", "evaluate_point", "run_jobs", "sweep",
    "DEFAULT_LADDER", "HalvingSearch", "Rung", "RungLog",
    "SearchResult", "successive_halving",
    "DesignPoint", "DesignSpace", "apply_arch_overrides",
]
