"""Design-space exploration over the cross-tier scheduling knob space.

CIM-MLC exposes a "tractable yet effective design space" (§4.3-§4.4): the
scheduling level (CM/XBM/WLM, clamped to what the chip's computing mode
allows), the bit-dimension binding (B->XBC vs B->XB), the CG pipeline and
duplication switches, and the Abs-arch parameters themselves (crossbar
geometry, cell precision, parallel rows, core counts).  This package
turns the one-shot compiler into a search service:

  * ``space``   — enumerate valid ``DesignPoint``s of a ``DesignSpace``;
  * ``cache``   — content-addressed, disk-persisted compile cache;
  * ``runner``  — sweep points concurrently through ``compile_graph`` +
                  ``cimsim.perf.estimate``;
  * ``pareto``  — Pareto frontier over (latency, peak power, crossbars).
"""
from .cache import CompileCache, default_cache_dir
from .pareto import DEFAULT_OBJECTIVES, dominates, pareto_frontier
from .runner import SweepResult, evaluate_point, sweep
from .space import DesignPoint, DesignSpace, apply_arch_overrides

__all__ = [
    "CompileCache", "default_cache_dir",
    "DEFAULT_OBJECTIVES", "dominates", "pareto_frontier",
    "SweepResult", "evaluate_point", "sweep",
    "DesignPoint", "DesignSpace", "apply_arch_overrides",
]
