"""Multi-fidelity successive halving over a ``DesignSpace``.

Exhaustive enumeration (``runner.sweep``) pays one full compile per
point, which stops being tractable the moment ``arch_axes`` grows past a
few values per axis — the cross product is multiplicative.  Successive
halving evaluates *every* candidate only at the cheapest fidelity and
spends full compiles on a geometrically-shrinking survivor set:

  rung 0 (``proxy``)   — the analytic proxy cost model: real cost model
                         + duplication search, no codegen, no
                         event-driven simulation.  Evaluated through the
                         *batched* structure-of-arrays path
                         (``dse.proxy_vec``): the whole rung is a few
                         vectorized NumPy passes, bit-exact against
                         per-point ``compiler.proxy_metrics``, so the
                         cheap rung stays cheap at 10^5+ points;
  rung 1 (``prefix``)  — full compile of ``Graph.prefix(frac * n)``, a
                         truncated workload that costs a fraction of the
                         full model but ranks points like it;
  rung 2 (``full``)    — full compile of the full graph.

After each rung the top ``1/eta`` of surviving points (by the scalar
``objective``, ties broken by enumeration order — fully deterministic)
are promoted.  All fidelities share one ``CompileCache``: a promoted
point's prefix and full compiles are content-addressed like any other,
so re-running a search — or following it with an exhaustive sweep — pays
nothing twice.

``HalvingSearch`` is an incremental state machine (``jobs`` →
``run_jobs`` → ``observe``) so a campaign can interleave the rungs of
many workloads into a single job queue; ``successive_halving`` is the
one-workload convenience loop.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Sequence, Tuple, Union

from ..core.abstraction import CIMArch
from ..core.graph import Graph
from .cache import CompileCache
from .runner import EvalJob, SweepResult, resolve_space, run_jobs
from .space import DesignPoint, DesignSpace


@dataclasses.dataclass(frozen=True)
class Rung:
    """One step of the fidelity ladder."""

    fidelity: str               # "proxy" | "prefix" | "full"
    frac: float = 1.0           # node fraction for "prefix"

    def __post_init__(self):
        if self.fidelity not in ("proxy", "prefix", "full"):
            raise ValueError(f"unknown fidelity {self.fidelity!r}")
        if not 0.0 < self.frac <= 1.0:
            raise ValueError("frac must be in (0, 1]")


#: proxy -> half-graph compile -> full compile
DEFAULT_LADDER: Tuple[Rung, ...] = (
    Rung("proxy"), Rung("prefix", 0.5), Rung("full"))


def rung_prefix_graph(graph: Graph, frac: float) -> Graph:
    """The prefix graph a ``frac`` rung compiles (``graph`` itself when
    the fraction rounds to the whole model).

    A prefix with no CIM node compiles to an empty plan and ranks
    nothing, so the cut is extended to cover the first CIM operator.
    """
    n = max(1, round(len(graph.nodes) * frac))
    first_cim = next((i for i, nd in enumerate(graph.nodes)
                      if nd.is_cim), None)
    if first_cim is not None:
        n = max(n, first_cim + 1)
    return graph.prefix(n)


@dataclasses.dataclass
class RungLog:
    rung: int
    fidelity: str
    evaluated: int
    promoted: int
    full_evals: int             # full-fidelity evaluations in this rung


@dataclasses.dataclass
class SearchResult:
    """Outcome of one successive-halving search."""

    results: List[SweepResult]  # full-fidelity results of the finalists
    rungs: List[RungLog]
    n_points: int               # size of the enumerated space
    full_evals: int             # total full-fidelity evaluations performed
    objective: str

    @property
    def best(self) -> Optional[SweepResult]:
        ok = [r for r in self.results if r.ok]
        if not ok:
            return None
        return min(ok, key=lambda r: (r.metrics[self.objective], r.index))


class HalvingSearch:
    """Incremental successive-halving state over one workload.

    Drive it with::

        while not search.done:
            results = run_jobs(search.jobs(), cache=cache, workers=w)
            search.observe(results)

    ``jobs(index_base=..., tag=...)`` hands out the current rung's jobs
    (survivors only, at the rung's fidelity); ``observe`` consumes that
    rung's results — in the same order — and promotes the top ``1/eta``.
    Failed points are never promoted.  ``min_keep`` floors the survivor
    count so a noisy cheap rung cannot collapse the search below a
    meaningful finalist set.
    """

    def __init__(self, graph: Graph,
                 space: Union[DesignSpace, Sequence[DesignPoint]],
                 base_arch: Optional[CIMArch] = None, *,
                 eta: int = 3,
                 ladder: Sequence[Rung] = DEFAULT_LADDER,
                 objective: str = "latency_cycles",
                 min_keep: int = 2):
        if eta < 2:
            raise ValueError("eta must be >= 2")
        self.graph = graph
        self.points, self.base_arch = resolve_space(space, base_arch)
        self.eta = eta
        self.ladder = tuple(ladder)
        if not self.ladder or self.ladder[-1].fidelity != "full":
            raise ValueError("ladder must end with a 'full' rung")
        self.objective = objective
        self.min_keep = min_keep
        self.rung = 0
        self.survivors: List[int] = list(range(len(self.points)))
        self.rung_log: List[RungLog] = []
        self.full_evals = 0
        self.results: Optional[List[SweepResult]] = None
        self._pending: Optional[List[int]] = None

    # -- state -----------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.results is not None

    def _rung_graph(self, rung: Rung) -> Graph:
        if rung.fidelity != "prefix":
            return self.graph          # proxy scores the full graph
        return rung_prefix_graph(self.graph, rung.frac)

    # -- driving ---------------------------------------------------------
    def jobs(self, index_base: int = 0, tag: Any = None) -> List[EvalJob]:
        """The current rung's job list (stable order; call once per rung)."""
        if self.done:
            return []
        rung = self.ladder[self.rung]
        graph = self._rung_graph(rung)
        self._pending = list(self.survivors)
        proxy = rung.fidelity == "proxy"
        # compile rungs are *batched*: run_jobs screens the whole rung's
        # infeasibility in one vectorized pass per (graph, arch) before
        # any point reaches the compiler (identical error strings either
        # way — see runner._screen_compile_jobs)
        return [EvalJob(index=index_base + k, graph=graph,
                        point=self.points[i], arch=self.base_arch,
                        proxy=proxy, screen=not proxy, tag=tag)
                for k, i in enumerate(self._pending)]

    def observe(self, results: Sequence[SweepResult]) -> None:
        """Consume the current rung's results (same order as ``jobs()``)."""
        if self._pending is None:
            raise RuntimeError("observe() without a preceding jobs()")
        if len(results) != len(self._pending):
            raise ValueError(f"expected {len(self._pending)} results, "
                             f"got {len(results)}")
        rung = self.ladder[self.rung]
        is_full = rung.fidelity == "full" or (
            rung.fidelity == "prefix"
            and self._rung_graph(rung) is self.graph)
        full_here = len(results) if is_full else 0
        self.full_evals += full_here
        pending, self._pending = self._pending, None

        if self.rung == len(self.ladder) - 1:
            self.rung_log.append(RungLog(self.rung, rung.fidelity,
                                         len(results), 0, full_here))
            # re-key finalists by their *enumeration* index so objective
            # ties resolve exactly like an exhaustive sweep's would
            for enum_i, r in zip(pending, results):
                r.index = enum_i
            self.results = sorted(results, key=lambda r: r.index)
            return

        scored = [(r.metrics[self.objective], i, r)
                  for i, r in zip(pending, results) if r.ok]
        scored.sort(key=lambda t: (t[0], t[1]))
        keep = min(len(scored),
                   max(self.min_keep, math.ceil(len(scored) / self.eta)))
        self.survivors = [i for _, i, _ in scored[:keep]]
        self.rung_log.append(RungLog(self.rung, rung.fidelity,
                                     len(results), keep, full_here))
        if not self.survivors:
            # every point failed at this fidelity (scored is empty —
            # otherwise keep >= 1): report the failures, nothing to promote
            for enum_i, r in zip(pending, results):
                r.index = enum_i
            self.results = sorted(results, key=lambda r: r.index)
            return
        self.rung += 1

    def search_result(self) -> SearchResult:
        if not self.done:
            raise RuntimeError("search is not finished")
        return SearchResult(results=list(self.results),
                            rungs=list(self.rung_log),
                            n_points=len(self.points),
                            full_evals=self.full_evals,
                            objective=self.objective)


def successive_halving(graph: Graph,
                       space: Union[DesignSpace, Sequence[DesignPoint]],
                       base_arch: Optional[CIMArch] = None, *,
                       eta: int = 3,
                       ladder: Sequence[Rung] = DEFAULT_LADDER,
                       objective: str = "latency_cycles",
                       min_keep: int = 2,
                       cache: Optional[CompileCache] = None,
                       workers: int = 1) -> SearchResult:
    """Run a complete successive-halving search over one workload.

    Deterministic for any ``workers`` count (rungs are synchronization
    points; within a rung, results re-order by job index).
    """
    search = HalvingSearch(graph, space, base_arch, eta=eta, ladder=ladder,
                           objective=objective, min_keep=min_keep)
    proxy_memo: dict = {}    # proxy results shared across this search's rungs
    while not search.done:
        search.observe(run_jobs(search.jobs(), cache=cache, workers=workers,
                                proxy_memo=proxy_memo))
    return search.search_result()
