"""Batched, structure-of-arrays proxy cost model.

``compiler.proxy_metrics`` — the cheap rung of the multi-fidelity DSE
searcher — evaluates one design point at a time in pure Python: one
``CostModel.placement`` object per CIM node, a Python duplication
search, a per-point latency estimate.  That is fine for dozens of
points and hopeless for the 10^5-10^6-point spaces the roadmap's
Bayesian/bandit searches need: the rung's cost scales linearly in
Python-interpreter time with space size.

``proxy_metrics_batch`` evaluates the *same analytic model* for an
entire array of design points in one vectorized NumPy pass:

  * the per-workload **node tensor** (weight-matrix shapes, MVM window
    counts, fused-epilogue element counts — everything the graph
    contributes) is computed once per graph and broadcast against the
    per-point axis;
  * per-point Abs-arch scalars (crossbar geometry, cell/DAC precision,
    core and chip counts, bandwidths) form ``(n_points, 1)`` columns, so
    every placement attribute (``n_mvm``, ``cores``, ``phases``,
    ``row_groups``, ``t_load``, ``alu_epilogue``, ``n_xbs``) becomes one
    ``(n_points, n_nodes)`` tensor (``mapping.bind_arrays``);
  * the duplication searches run as their array twins
    (``cg_opt.balance_duplication_arr`` / ``greedy_duplication_arr``),
    the WLM row-spread heuristic as a rank-ordered vector scan, and the
    latency/power/crossbar reductions as per-point columns.

**Bit-exactness contract**: for every feasible point the batched result
equals the scalar ``proxy_metrics`` dict bit for bit — same bisection
trajectory, same heap pop order, same floating-point operation order
(tests/test_proxy_vec.py anchors this, point by point, against the
scalar oracle).  Points the scalar path would *raise* on come back as
masked entries (``feasible[i] == False``) whose ``errors[i]`` string
equals ``f"{type}: {message}"`` of the scalar raise, so the searcher can
rank what survives without a single try/except.

Degenerate arch parameters (zero-sized crossbars, zero bandwidths, zero
DAC bits...) would need per-point exception replay that vectorization
cannot express; those rare points are routed through the scalar oracle
itself, keeping the contract exact everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core import compiler
from ..core.abstraction import CIMArch, ComputingMode
from ..core.cg_opt import (balance_duplication_arr,
                           estimate_segment_cycles_arr,
                           fused_epilogue_elems, greedy_duplication_arr,
                           seq_sum)
from ..core.graph import Graph, n_mvm, weight_matrix_shape
from ..core.mapping import (BitBinding, bind_arrays, bind_error_msg,
                            vxb_span_error)
from .runner import resolve_space
from .space import DesignPoint, DesignSpace


# ---------------------------------------------------------------------------
# Per-workload node tensor (arch-independent, computed once per graph)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class NodeTensor:
    """Everything one graph contributes to a placement, as arrays.

    One row per CIM node (topological order, like ``graph.cim_nodes``):
    weight-matrix shape, MVM window count, and the ordered fused-epilogue
    element counts (zero-padded — a zero contributes ``0.0 / alu = 0.0``
    to the epilogue sum, preserving the scalar summation order exactly).
    """

    names: List[str]
    r: np.ndarray               # (N,) weight-matrix rows
    c: np.ndarray               # (N,) weight-matrix cols
    windows: np.ndarray         # (N,) MVMs per inference
    epi_elems: np.ndarray       # (N, S) fused successor output elements

    @classmethod
    def from_graph(cls, graph: Graph) -> "NodeTensor":
        nodes = graph.cim_nodes
        rc = [weight_matrix_shape(nd) for nd in nodes]
        epi = [fused_epilogue_elems(nd, graph) for nd in nodes]
        width = max((len(e) for e in epi), default=0)
        return cls(
            names=[nd.name for nd in nodes],
            r=np.array([r for r, _ in rc], dtype=np.int64),
            c=np.array([c for _, c in rc], dtype=np.int64),
            windows=np.array([n_mvm(nd, graph.shapes) for nd in nodes],
                             dtype=np.int64),
            epi_elems=np.array(
                [e + [0] * (width - len(e)) for e in epi],
                dtype=np.int64).reshape(len(nodes), width),
        )

    def __len__(self) -> int:
        return len(self.names)


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchedProxyMetrics:
    """Structure-of-arrays proxy metrics for a batch of design points."""

    points: List[DesignPoint]
    feasible: np.ndarray            # (P,) bool
    latency_cycles: np.ndarray      # (P,) float64
    compute_cycles: np.ndarray      # (P,) float64
    rewrite_cycles: np.ndarray      # (P,) float64
    peak_power: np.ndarray          # (P,) float64
    crossbars_used: np.ndarray      # (P,) int64
    #: per point: ``None`` when feasible, else the scalar path's
    #: ``f"{ExceptionType}: {message}"`` string
    errors: List[Optional[str]]

    def __len__(self) -> int:
        return len(self.points)

    def metrics(self, i: int) -> Optional[Dict[str, float]]:
        """The scalar ``proxy_metrics`` dict of point ``i`` (bit-exact),
        or ``None`` if the point is masked infeasible."""
        if not self.feasible[i]:
            return None
        return {
            "latency_cycles": float(self.latency_cycles[i]),
            "compute_cycles": float(self.compute_cycles[i]),
            "rewrite_cycles": float(self.rewrite_cycles[i]),
            "peak_power": float(self.peak_power[i]),
            "crossbars_used": int(self.crossbars_used[i]),
            "fidelity": "proxy",
        }

    def metrics_list(self) -> List[Optional[Dict[str, float]]]:
        return [self.metrics(i) for i in range(len(self.points))]


# ---------------------------------------------------------------------------
# Per-point scalar extraction
# ---------------------------------------------------------------------------

#: per-point Abs-arch scalars consumed by the vector path (column order
#: of the extraction matrix)
_FIELDS = ("rows", "cols", "par_row", "dac", "slices", "act", "nxbs_core",
           "ncores", "l1", "alu", "t_write")
_RANK = {"CM": 0, "XBM": 1, "WLM": 2}


def _arch_scalars(arch: CIMArch) -> Dict[str, float]:
    return {
        "rows": arch.xb.rows, "cols": arch.xb.cols,
        "par_row": arch.xb.parallel_row, "dac": arch.xb.dac_bits,
        "slices": arch.col_slices, "act": arch.act_bits,
        "nxbs_core": arch.core.n_xbs, "ncores": arch.chip.n_cores,
        "l1": arch.core.l1_bw_bits, "alu": arch.chip.alu_ops_per_cycle,
        "t_write": arch.t_write_xb(),
    }


def _is_degenerate(s: Dict[str, float], arch: CIMArch) -> bool:
    """Parameters whose exception behaviour (zero divisions raised node
    by node) only the scalar path replays faithfully."""
    return (s["rows"] <= 0 or s["cols"] <= 0 or s["dac"] <= 0
            or s["slices"] <= 0 or s["ncores"] <= 0 or s["nxbs_core"] <= 0
            or s["act"] <= 0 or arch.weight_bits <= 0
            or s["l1"] == 0 or s["alu"] == 0)


def _extract_point(arch0: CIMArch, pt: DesignPoint, n_nodes: int) -> Tuple:
    """Per-(overrides, level) extraction record, memoized by the caller.

    Returns ``("vec", scalar_row, mode_wlm, level_xbm, level_wlm)`` for
    vector-path points, ``("fallback",)`` for degenerate arches, and two
    error kinds that preserve the scalar path's raise *order* around the
    per-point binding normalization: ``("error_pre", msg)`` for failures
    that precede it (bad override path, invalid level value) and
    ``("error_mode", msg)`` for the mode-allows rejection that follows
    it."""
    try:
        arch = pt.arch_for(arch0)
    except Exception as e:   # bad override path: per-point error, like
        return ("error_pre", f"{type(e).__name__}: {e}")   # the scalar job
    s = _arch_scalars(arch)
    if n_nodes and _is_degenerate(s, arch):
        return ("fallback",)
    # the scalar paths normalize via ComputingMode(level): accept enum
    # values, replay the exact raise for invalid ones
    lvl = pt.level.value if isinstance(pt.level, ComputingMode) else pt.level
    rank = _RANK.get(lvl)
    if rank is None:
        try:
            rank = ComputingMode(pt.level).rank
        except Exception as e:
            return ("error_pre", f"{type(e).__name__}: {e}")
    if rank > arch.mode.rank:
        return ("error_mode", "ValueError: " + compiler.mode_error(
            arch, ComputingMode(lvl)))
    return ("vec", tuple(s[f] for f in _FIELDS),
            arch.mode is ComputingMode.WLM, rank >= 1, rank >= 2)


def _scalar_oracle(graph: Graph, arch: CIMArch, point: DesignPoint,
                   ) -> Tuple[Optional[dict], Optional[str]]:
    """(metrics, error) via the scalar ``proxy_metrics`` — the fallback
    for degenerate points and the semantics net of the runner."""
    try:
        return compiler.proxy_metrics(graph, arch,
                                      **point.compile_kwargs()), None
    except Exception as e:
        return None, f"{type(e).__name__}: {e}"


# ---------------------------------------------------------------------------
# The batched evaluation
# ---------------------------------------------------------------------------

def proxy_metrics_batch(
    graph: Graph,
    space: Union[DesignSpace, Sequence[DesignPoint]],
    base_arch: Optional[CIMArch] = None, *,
    node_tensor: Optional[NodeTensor] = None,
) -> BatchedProxyMetrics:
    """Analytic proxy metrics for *every* point of ``space`` in one
    vectorized pass.

    ``space`` is a ``DesignSpace`` (its ``arch`` is the base) or an
    explicit point list plus ``base_arch`` — the same convention as
    ``dse.sweep``.  Pass ``node_tensor`` (``NodeTensor.from_graph``) to
    amortize the per-graph extraction across calls.

    Bit-exact against scalar ``compiler.proxy_metrics`` per point;
    infeasible points are masked, not raised (see module docstring).
    """
    points, arch0 = resolve_space(space, base_arch)
    nt = node_tensor if node_tensor is not None else NodeTensor.from_graph(graph)
    n_points, n_nodes = len(points), len(nt)
    if n_points == 0:
        z = np.zeros(0)
        return BatchedProxyMetrics([], np.zeros(0, dtype=bool), z, z, z, z,
                                   np.zeros(0, dtype=np.int64), [])

    feasible = np.zeros(n_points, dtype=bool)
    latency = np.zeros(n_points)
    compute = np.zeros(n_points)
    rewrite = np.zeros(n_points)
    peak = np.zeros(n_points)
    xbs_used = np.zeros(n_points, dtype=np.int64)
    errors: List[Optional[str]] = [None] * n_points

    # -- per-point scalar extraction (the only per-point Python loop).
    # The arch build and scalar bundle are memoized per distinct
    # (overrides, level) pair — a cross-product space shares a handful of
    # those across thousands of points.  The hot probe keys on the
    # *identity* of the overrides tuple (DesignSpace reuses one tuple per
    # arch variant, and the points keep them alive for the duration of
    # this call); value-equal but distinct tuples (hand-built or
    # unpickled points) fall back to a value-keyed lookup and register an
    # id alias, so memoization never silently degrades to per-point cost.
    zero_row = (0.0,) * (len(_FIELDS) + 3)
    table: List[Tuple] = []                 # distinct extraction rows
    kinds: List[int] = []                   # 0 = vec, 1 = error, 2 = fallback
    msgs: List[Optional[str]] = []
    memo_id: Dict[Tuple, int] = {}
    memo_val: Dict[Tuple, int] = {}
    rid_list: List[int] = []
    _KIND = {"vec": 0, "error_pre": 1, "fallback": 2, "error_mode": 3}
    for pt in points:
        key = (id(pt.arch_overrides), pt.level)
        rid = memo_id.get(key)
        if rid is None:
            vkey = (pt.arch_overrides, pt.level)
            try:
                rid = memo_val.get(vkey)
            except TypeError:       # unhashable override value: no value
                vkey = None         # aliasing, id memo still applies
                rid = None
            if rid is None:
                ent = _extract_point(arch0, pt, n_nodes)
                rid = len(kinds)
                kinds.append(_KIND[ent[0]])
                if ent[0] == "vec":
                    msgs.append(None)
                    table.append(ent[1] + (ent[2], ent[3], ent[4]))
                else:
                    msgs.append(ent[1] if len(ent) > 1 else None)
                    table.append(zero_row)
                if vkey is not None:
                    memo_val[vkey] = rid
            memo_id[key] = rid
        rid_list.append(rid)

    rid_arr = np.array(rid_list, dtype=np.int64)
    kind_pt = np.array(kinds, dtype=np.int64)[rid_arr]
    # binding is normalized like the scalar BitBinding(self.binding):
    # enum values accepted, invalid values replayed as the scalar raise.
    # Scalar raise order around it: override/level errors come first,
    # binding errors next, the mode-allows rejection after.
    bvals = [p.binding for p in points]
    b_to_xb = np.fromiter(
        (b == "B->XB" or b is BitBinding.B_TO_XB for b in bvals),
        dtype=bool, count=n_points)
    valid_b = np.fromiter(
        (b == "B->XB" or b == "B->XBC" or b is BitBinding.B_TO_XB
         or b is BitBinding.B_TO_XBC for b in bvals),
        dtype=bool, count=n_points)
    for k in np.flatnonzero(kind_pt == 1):            # error_pre
        errors[k] = msgs[rid_arr[k]]
    fallback = list(np.flatnonzero(kind_pt == 2))
    for k in np.flatnonzero(~valid_b & (kind_pt != 1) & (kind_pt != 2)):
        try:
            b = BitBinding(bvals[k])
        except Exception as e:
            errors[k] = f"{type(e).__name__}: {e}"
        else:                   # normalizable after all (e.g. str subclass)
            valid_b[k] = True
            b_to_xb[k] = b is BitBinding.B_TO_XB
    for k in np.flatnonzero((kind_pt == 3) & valid_b):  # error_mode
        errors[k] = msgs[rid_arr[k]]
    vec = (kind_pt == 0) & valid_b          # points on the vector path
    cols_mat = np.array(table, dtype=np.float64)[rid_arr]
    cols_f = {f: cols_mat[:, i] for i, f in enumerate(_FIELDS)}
    nf = len(_FIELDS)
    mode_wlm = cols_mat[:, nf].astype(bool)
    level_xbm = cols_mat[:, nf + 1].astype(bool)
    level_wlm = cols_mat[:, nf + 2].astype(bool)
    pipe = np.fromiter((p.use_pipeline for p in points),
                       dtype=bool, count=n_points)
    dupflag = np.fromiter((p.use_duplication for p in points),
                          dtype=bool, count=n_points)

    for k in fallback:              # degenerate arches: scalar oracle
        m, err = _scalar_oracle(graph, points[k].arch_for(arch0), points[k])
        errors[k] = err
        if m is not None:
            feasible[k] = True
            latency[k] = m["latency_cycles"]
            compute[k] = m["compute_cycles"]
            rewrite[k] = m["rewrite_cycles"]
            peak[k] = m["peak_power"]
            xbs_used[k] = m["crossbars_used"]

    if n_nodes == 0:
        # no CIM node: the scalar path skips every check but the mode one
        ok = vec
        feasible[ok] = True
        latency[ok] = 1e-9          # max(0.0, 1e-9)
        return BatchedProxyMetrics(points, feasible, latency, compute,
                                   rewrite, peak, xbs_used, errors)
    if not vec.any():
        return BatchedProxyMetrics(points, feasible, latency, compute,
                                   rewrite, peak, xbs_used, errors)

    # -- compact to the vector-path subset -------------------------------
    sub = np.flatnonzero(vec)
    P = sub.size
    fi = {f: cols_f[f][sub].astype(np.int64)[:, None] for f in
          ("rows", "cols", "par_row", "dac", "slices", "act",
           "nxbs_core", "ncores")}
    l1 = cols_f["l1"][sub][:, None]
    alu = cols_f["alu"][sub][:, None]
    t_write = cols_f["t_write"][sub]
    s_mode_wlm = mode_wlm[sub][:, None]
    s_level_xbm = level_xbm[sub]
    s_level_wlm = level_wlm[sub]
    s_b_to_xb = b_to_xb[sub][:, None]
    s_pipe = pipe[sub]
    s_dup = dupflag[sub]

    cap_xbs = (fi["ncores"] * fi["nxbs_core"])[:, 0]      # (P,)
    n_cores = fi["ncores"][:, 0]

    # -- placement attributes as (P, N) tensors --------------------------
    bound = bind_arrays(nt.r, nt.c, rows=fi["rows"], cols=fi["cols"],
                        slices=fi["slices"], b_to_xb=s_b_to_xb)
    n_xbs = bound["n_xbs"]
    grid_r = bound["grid_r"]
    cores = np.maximum(1, -(-n_xbs // fi["nxbs_core"]))
    windows = np.broadcast_to(nt.windows, (P, len(nt)))
    phases = np.maximum(1, -(-fi["act"] // fi["dac"]))
    rows_used = np.where(s_mode_wlm, np.minimum(nt.r, fi["rows"]),
                         fi["rows"])
    row_groups = np.maximum(1, -(-np.minimum(rows_used, fi["rows"])
                                 // fi["par_row"]))
    t_load = (nt.r * fi["act"]) / l1          # l1=inf -> 0.0, like scalar
    epi = np.zeros((P, len(nt)))
    for j in range(nt.epi_elems.shape[1]):    # scalar summation order
        epi = epi + nt.epi_elems[:, j] / alu
    epi = epi / np.maximum(windows, 1)
    epi = np.where(np.isfinite(alu), epi, 0.0)

    # -- infeasibility masks (same priority order as the scalar raises) --
    ok = np.ones(P, dtype=bool)
    bind_bad = ~bound["feasible"].all(axis=1)
    for i in np.flatnonzero(bind_bad):
        errors[sub[i]] = "ValueError: " + bind_error_msg(
            int(fi["cols"][i, 0]), int(fi["slices"][i, 0]))
    ok &= ~bind_bad
    span = bound["xbs_per_vxb"][:, 0]         # node-independent per point
    span_bad = ok & (span > cap_xbs)
    for i in np.flatnonzero(span_bad):
        errors[sub[i]] = "ValueError: " + vxb_span_error(
            nt.names[0], int(span[i]), int(cap_xbs[i]))
    ok &= ~span_bad

    # -- duplication (single-segment points only, like the scalar path) --
    t_mvm = phases * row_groups               # row_spread == 1 here
    t_window = np.maximum(np.maximum(t_mvm, t_load), epi)
    multi_segment = cores.sum(axis=1) > n_cores
    budget = np.where(s_level_xbm, cap_xbs, n_cores)
    cost = np.where(s_level_xbm[:, None], n_xbs, cores)
    searchable = ok & s_dup & ~multi_segment
    dup = balance_duplication_arr(windows, t_window, cost, budget,
                                  active=searchable & s_pipe)
    dup_g = greedy_duplication_arr(windows, t_window, cost, budget,
                                   active=searchable & ~s_pipe)
    dup = np.where((searchable & ~s_pipe)[:, None], dup_g, dup)

    # -- WLM row-spread heuristic (vvm_opt's remap, first order).  Only
    # rows that can actually spread (WLM level, spare crossbars, at least
    # one multi-group placement) enter the rank-ordered scan; for every
    # other row the scalar loop provably leaves row_spread at 1. --------
    row_spread = np.ones((P, len(nt)), dtype=np.int64)
    xbs_tot = (dup * n_xbs).sum(axis=1)     # dup is final: reused below
    spare0 = np.maximum(0, cap_xbs - xbs_tot)
    sp_rows = np.flatnonzero(s_level_wlm & ok & (spare0 > 0)
                             & (row_groups > 1).any(axis=1))
    if sp_rows.size:
        dup_s = dup[sp_rows]
        nx_s = n_xbs[sp_rows]
        rg_s = row_groups[sp_rows]
        stage_s = np.ceil(windows[sp_rows] / dup_s) * t_window[sp_rows]
        order = np.argsort(-stage_s, axis=1, kind="stable")
        spare = spare0[sp_rows]
        rs_s = np.ones_like(dup_s)
        pr = np.arange(sp_rows.size)
        for j in range(len(nt)):
            idx = order[:, j]
            rg = rg_s[pr, idx]
            per_spread = np.maximum(1, dup_s[pr, idx] * nx_s[pr, idx])
            k = np.minimum(rg, 1 + spare // per_spread)
            do = (rg > 1) & (k > 1)
            spare -= np.where(do, (k - 1) * per_spread, 0)
            rs_s[pr, idx] = np.where(do, k, 1)
        row_spread[sp_rows] = rs_s

    # -- latency / power / crossbar reductions ---------------------------
    t_mvm = phases * -(-row_groups // row_spread)
    t_window = np.maximum(np.maximum(t_mvm, t_load), epi)
    stage = np.ceil(windows / dup) * t_window
    lat = estimate_segment_cycles_arr(windows, dup, t_window, s_pipe)
    rew = np.where(multi_segment,
                   xbs_tot * t_write / np.maximum(n_cores, 1), 0.0)
    lat = lat + rew
    per_copy = np.where(s_level_xbm[:, None] & (grid_r > 1),
                        -(-n_xbs // grid_r), n_xbs)
    active_xbs = dup * per_copy
    pk = np.where(s_pipe, active_xbs.sum(axis=1), active_xbs.max(axis=1))
    used = np.where(multi_segment, np.minimum(xbs_tot, cap_xbs), xbs_tot)

    feasible[sub[ok]] = True
    latency[sub] = np.where(ok, np.maximum(lat, 1e-9), 0.0)
    compute[sub] = np.where(ok, seq_sum(stage), 0.0)
    rewrite[sub] = np.where(ok, rew, 0.0)
    peak[sub] = np.where(ok, pk.astype(np.float64), 0.0)
    xbs_used[sub] = np.where(ok, used, 0)
    return BatchedProxyMetrics(points, feasible, latency, compute, rewrite,
                               peak, xbs_used, errors)
