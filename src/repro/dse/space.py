"""The cross-tier knob space (§4.3-§4.4 made enumerable).

A ``DesignPoint`` is one compiler configuration: the scheduling level,
the bit-dimension binding, the two CG switches, plus a tuple of Abs-arch
parameter overrides addressed by dotted path (``"xb.cell_precision"``,
``"chip.core_number"``, ...).  ``DesignSpace.points()`` takes the cross
product of all axes and keeps only the *valid* points:

  * the level is clamped to what the (possibly overridden) chip's
    computing mode allows — a CM chip never yields XBM/WLM points — and
    duplicate clamped points collapse;
  * ``B->XBC`` binding requires the crossbar to have at least
    ``ceil(weight_bits / cell_precision)`` columns (mapping.bind raises
    otherwise), so infeasible combinations are filtered out up front.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, List, Mapping, Sequence, Tuple, Union

from ..core.abstraction import CIMArch, ComputingMode
from ..core.mapping import BitBinding

#: tier dataclass fields reachable through a dotted override path
_TIERS = ("chip", "core", "xb")


def apply_arch_overrides(arch: CIMArch,
                         overrides: Mapping[str, Any]) -> CIMArch:
    """Return ``arch`` with dotted-path parameter overrides applied.

    Top-level fields use their bare name (``"act_bits"``); tier fields
    use ``"<tier>.<field>"`` (``"xb.xb_size"``).  Shrinking ``xb.xb_size``
    below the current ``parallel_row`` clamps ``parallel_row`` to the new
    row count instead of producing an unbuildable tier.
    """
    per_tier: Dict[str, Dict[str, Any]] = {t: {} for t in _TIERS}
    top: Dict[str, Any] = {}
    for path, value in overrides.items():
        if "." in path:
            tier, field = path.split(".", 1)
            if tier not in per_tier:
                raise KeyError(f"unknown arch tier {tier!r} in {path!r}")
            per_tier[tier][field] = value
        else:
            top[path] = value
    for tier, kw in per_tier.items():
        if not kw:
            continue
        cur = getattr(arch, tier)
        if tier == "xb":
            rows = kw.get("xb_size", cur.xb_size)[0]
            pr = kw.get("parallel_row", cur.parallel_row)
            kw.setdefault("parallel_row", min(pr, rows))
        top[tier] = dataclasses.replace(cur, **kw)
    return arch.replace(**top) if top else arch


def _as_mode(level: Union[str, ComputingMode]) -> ComputingMode:
    return level if isinstance(level, ComputingMode) else ComputingMode(level)


def _as_binding(b: Union[str, BitBinding]) -> BitBinding:
    return b if isinstance(b, BitBinding) else BitBinding(b)


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One compiler configuration of the sweep (hashable, picklable)."""

    level: str                  # ComputingMode value
    binding: str                # BitBinding value
    use_pipeline: bool
    use_duplication: bool
    arch_overrides: Tuple[Tuple[str, Any], ...] = ()

    @property
    def mode(self) -> ComputingMode:
        return ComputingMode(self.level)

    @property
    def bit_binding(self) -> BitBinding:
        return BitBinding(self.binding)

    def arch_for(self, base: CIMArch) -> CIMArch:
        return apply_arch_overrides(base, dict(self.arch_overrides))

    def compile_kwargs(self) -> Dict[str, Any]:
        return dict(level=self.mode, binding=self.bit_binding,
                    use_pipeline=self.use_pipeline,
                    use_duplication=self.use_duplication)

    def label(self) -> str:
        knobs = [self.level, self.binding,
                 "pipe" if self.use_pipeline else "nopipe",
                 "dup" if self.use_duplication else "nodup"]
        knobs += [f"{k}={v}" for k, v in self.arch_overrides]
        return " ".join(str(k) for k in knobs)


@dataclasses.dataclass
class DesignSpace:
    """Cartesian knob space around a base architecture."""

    arch: CIMArch
    levels: Sequence[Union[str, ComputingMode]] = ("CM", "XBM", "WLM")
    bindings: Sequence[Union[str, BitBinding]] = (
        BitBinding.B_TO_XBC, BitBinding.B_TO_XB)
    pipeline: Sequence[bool] = (True, False)
    duplication: Sequence[bool] = (True, False)
    #: dotted arch path -> candidate values, e.g.
    #: {"xb.xb_size": [(128, 128), (256, 256)], "xb.cell_precision": [1, 2]}
    arch_axes: Mapping[str, Sequence[Any]] = dataclasses.field(
        default_factory=dict)

    def arch_variants(self) -> List[Tuple[Tuple[Tuple[str, Any], ...], CIMArch]]:
        """(overrides, concrete arch) per point of the arch sub-space."""
        axes = [(path, list(values)) for path, values in self.arch_axes.items()]
        out = []
        for combo in itertools.product(*(vals for _, vals in axes)):
            ov = tuple((path, val)
                       for (path, _), val in zip(axes, combo))
            out.append((ov, apply_arch_overrides(self.arch, dict(ov))))
        return out

    def points(self) -> List[DesignPoint]:
        """All valid points, deduplicated after mode clamping."""
        pts: List[DesignPoint] = []
        seen = set()
        for overrides, arch in self.arch_variants():
            slices = math.ceil(arch.weight_bits / arch.xb.cell_precision)
            for lvl, bnd, pipe, dup in itertools.product(
                    self.levels, self.bindings, self.pipeline,
                    self.duplication):
                mode = _as_mode(lvl)
                if mode.rank > arch.mode.rank:
                    mode = arch.mode          # clamp to the chip's mode
                binding = _as_binding(bnd)
                if binding is BitBinding.B_TO_XBC and arch.xb.cols < slices:
                    continue                  # bit slices cannot share a xb
                pt = DesignPoint(level=mode.value, binding=binding.value,
                                 use_pipeline=pipe, use_duplication=dup,
                                 arch_overrides=overrides)
                if pt in seen:
                    continue
                seen.add(pt)
                pts.append(pt)
        return pts

    def __len__(self) -> int:
        return len(self.points())
