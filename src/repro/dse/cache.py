"""Content-addressed compile cache.

Entries are keyed by ``core.compiler.compile_key`` — a SHA-256 over the
graph structure, the full Abs-arch description and every scheduling knob
— so a key can only ever map to one compilation output.  Each entry is
two files under ``<root>/v<schema>/<key[:2]>/``:

  * ``<key>.pkl``   — the pickled ``CompileResult`` (plan + program);
  * ``<key>.json``  — the small ``PerfReport.metrics()`` bundle, so sweep
    re-runs score cached points without unpickling multi-MB plans.

Writes are atomic (tempfile + ``os.replace``), which makes the cache safe
under the sweep runner's process pool.  Invalidation is by construction:
changing the graph, the arch, any knob, or ``COMPILE_KEY_SCHEMA`` (bumped
when compiler passes change behaviour) changes the key; stale entries are
simply never addressed again.  ``clear()`` removes the directory tree.

Disk growth is bounded when ``max_bytes`` is set: after each ``put`` the
current schema's entries are LRU-evicted by access time until the total
size fits (the entry just written is never evicted).  Long-running
fleets and campaign farms set the knob; the default stays unbounded so
sweep reproducibility never silently loses entries.
"""
from __future__ import annotations

import json
import os
import pickle
import tempfile
from pathlib import Path
from typing import Dict, Optional

from ..core.compiler import COMPILE_KEY_SCHEMA, CompileResult

#: environment override for the on-disk cache location
CACHE_DIR_ENV = "REPRO_COMPILE_CACHE_DIR"


def default_cache_dir() -> Path:
    env = os.environ.get(CACHE_DIR_ENV)
    if env:
        return Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME", os.path.expanduser("~/.cache"))
    return Path(xdg) / "repro-cim-mlc" / "compile"


class CompileCache:
    """Disk-backed compile cache with an in-process memory layer.

    The memory layer serves repeated compiles inside one process without
    touching disk; ``memory=False`` disables it (useful for measuring the
    disk path, and for workers that should not grow resident memory).
    """

    def __init__(self, root=None, memory: bool = True,
                 max_bytes: Optional[int] = None):
        self.root = Path(root) if root is not None else default_cache_dir()
        self._mem: Optional[Dict[str, CompileResult]] = {} if memory else None
        self._mem_metrics: Dict[str, Dict] = {}
        self.max_bytes = max_bytes   # on-disk size cap (None: unbounded)
        self._disk_total: Optional[int] = None   # running size estimate
        self._access: Dict[str, float] = {}      # per-key last hit (any layer)
        self.hits = 0           # full CompileResult hits (get)
        self.metrics_hits = 0   # metric-only hits (get_metrics, no unpickle)
        self.misses = 0         # lookups of either kind that found nothing
        self.evictions = 0      # entries removed by the size cap

    # -- paths ------------------------------------------------------------
    def _dir(self, key: str) -> Path:
        return self.root / f"v{COMPILE_KEY_SCHEMA}" / key[:2]

    def _pkl(self, key: str) -> Path:
        return self._dir(key) / f"{key}.pkl"

    def _json(self, key: str) -> Path:
        return self._dir(key) / f"{key}.json"

    # -- lookups ----------------------------------------------------------
    def _touch(self, key: str) -> None:
        """Record a hit for the size cap's LRU: memory-layer hits never
        reach the files, so disk atimes alone would rank the *hottest*
        entries oldest — this per-handle access map keeps them safe."""
        if self.max_bytes is not None:
            import time
            self._access[key] = time.time()

    def get(self, key: str) -> Optional[CompileResult]:
        """Full ``CompileResult`` for ``key``, or None."""
        if self._mem is not None and key in self._mem:
            self.hits += 1
            self._touch(key)
            return self._mem[key]
        path = self._pkl(key)
        try:
            with open(path, "rb") as f:
                result = pickle.load(f)
        except Exception:
            # missing file, truncated write, or a stale entry whose classes
            # changed shape under it (AttributeError/ImportError from
            # pickle): all degrade to a recompute, never an abort
            self.misses += 1
            return None
        self.hits += 1
        self._touch(key)
        if self._mem is not None:
            self._mem[key] = result
        return result

    def get_metrics(self, key: str) -> Optional[Dict]:
        """Metric bundle only — the cheap warm-sweep path (no unpickling)."""
        if key in self._mem_metrics:
            self.metrics_hits += 1
            self._touch(key)
            return dict(self._mem_metrics[key])
        try:
            with open(self._json(key)) as f:
                metrics = json.load(f)
        except (OSError, json.JSONDecodeError):
            self.misses += 1
            return None
        self.metrics_hits += 1
        self._touch(key)
        self._mem_metrics[key] = metrics
        return dict(metrics)

    def contains(self, key: str) -> bool:
        if self._mem is not None and key in self._mem:
            return True
        return self._pkl(key).exists()

    # -- stores -----------------------------------------------------------
    def put(self, key: str, result: CompileResult,
            metrics: Optional[Dict] = None) -> None:
        if metrics is None:
            metrics = result.metrics()
        self._dir(key).mkdir(parents=True, exist_ok=True)
        _atomic_write(self._pkl(key),
                      pickle.dumps(result, pickle.HIGHEST_PROTOCOL))
        _atomic_write(self._json(key),
                      json.dumps(metrics, sort_keys=True).encode())
        if self._mem is not None:
            self._mem[key] = result
        self._mem_metrics[key] = metrics
        if self.max_bytes is not None:
            # keep put O(1) while the cap is far away: maintain a running
            # size estimate (seeded by one full scan) and rescan/evict
            # only when it crosses the cap.  Writes by other handles are
            # invisible until a threshold scan, so the cap is enforced
            # per handle, not as a cross-process hard limit.
            if self._disk_total is None:
                self._disk_total = self.disk_bytes()
            else:
                for p in (self._pkl(key), self._json(key)):
                    try:
                        self._disk_total += p.stat().st_size
                    except OSError:
                        pass
            if self._disk_total > self.max_bytes:
                self._evict(keep=key)

    # -- maintenance ------------------------------------------------------
    def disk_bytes(self) -> int:
        """Total bytes of the current schema's on-disk entries."""
        base = self.root / f"v{COMPILE_KEY_SCHEMA}"
        if not base.exists():
            return 0
        return sum(p.stat().st_size for pat in ("*/*.pkl", "*/*.json")
                   for p in base.glob(pat))

    def _evict(self, keep: Optional[str] = None) -> None:
        """LRU-by-atime eviction down to ``max_bytes``.

        Each entry's recency is the newest of its two files' access
        times (``get`` reads the pkl, ``get_metrics`` the json) and this
        handle's in-process hit log (``_touch`` — memory-layer hits
        never touch the files, so without it the hottest entries would
        rank oldest).  On noatime/relatime mounts the on-disk component
        degrades toward write time, turning cross-handle recency into
        LRU-by-insertion — still bounded, just less precise.  The just-written ``keep`` entry is never evicted, so a
        cap smaller than one entry keeps exactly the newest.  Evicted
        keys are also dropped from the memory layer, keeping
        ``contains``/``get`` consistent with the disk state.  The scan's
        recount re-seeds the running ``_disk_total`` estimate, so drift
        from overwrites or concurrent writers self-corrects here.
        """
        base = self.root / f"v{COMPILE_KEY_SCHEMA}"
        if not base.exists():
            self._disk_total = 0
            return
        entries = []    # (recency, key, size, paths)
        total = 0
        for pkl in base.glob("*/*.pkl"):
            key = pkl.stem
            paths = [pkl, pkl.with_suffix(".json")]
            size = recency = 0
            for p in paths:
                try:
                    st = p.stat()
                except OSError:
                    continue
                size += st.st_size
                recency = max(recency, st.st_atime, st.st_mtime)
            recency = max(recency, self._access.get(key, 0.0))
            entries.append((recency, key, size, paths))
            total += size
        if total > self.max_bytes:
            entries.sort()                 # oldest access first
            for _, key, size, paths in entries:
                if total <= self.max_bytes:
                    break
                if key == keep:
                    continue
                for p in paths:
                    try:
                        os.unlink(p)
                    except OSError:
                        pass
                if self._mem is not None:
                    self._mem.pop(key, None)
                self._mem_metrics.pop(key, None)
                self._access.pop(key, None)
                total -= size
                self.evictions += 1
        self._disk_total = total

    def drop_memory(self) -> None:
        """Forget the in-process layer (keeps disk entries)."""
        if self._mem is not None:
            self._mem.clear()
        self._mem_metrics.clear()

    def clear(self) -> None:
        """Delete every entry of the current schema from disk + memory."""
        import shutil
        self.drop_memory()
        self._disk_total = None
        shutil.rmtree(self.root / f"v{COMPILE_KEY_SCHEMA}",
                      ignore_errors=True)

    def stats(self) -> Dict[str, int]:
        """Hit/miss counters for this handle plus the on-disk entry count.

        ``hits`` are full ``CompileResult`` lookups served, and
        ``metrics_hits`` the metric-only lookups that answered without
        unpickling a plan (the warm-sweep fast path); ``misses`` counts
        lookups of either kind that found nothing.  Campaign summaries
        surface this bundle (``CampaignResult.cache_stats``)."""
        disk = 0
        base = self.root / f"v{COMPILE_KEY_SCHEMA}"
        if base.exists():
            disk = sum(1 for _ in base.glob("*/*.pkl"))
        return {"hits": self.hits, "metrics_hits": self.metrics_hits,
                "misses": self.misses, "disk_entries": disk,
                "evictions": self.evictions}


def _atomic_write(path: Path, data: bytes) -> None:
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
