"""Content-addressed compile cache — a shared artifact store.

Entries are keyed by ``core.compiler.compile_key`` — a SHA-256 over the
graph structure, the full Abs-arch description and every scheduling knob
— so a key can only ever map to one compilation output.  Each entry is
three files under ``<root>/v<schema>/<key[:2]>/``:

  * ``<key>.pkl``   — the pickled ``CompileResult`` (plan + program);
  * ``<key>.json``  — the small ``PerfReport.metrics()`` bundle, so sweep
    re-runs score cached points without unpickling multi-MB plans;
  * ``<key>.src``   — the short ``owner`` token of the handle that
    published the entry, so a hit can be attributed to the campaign (or
    fleet) that paid the compile.

Writes are atomic (tempfile + ``os.replace``), which makes *publication*
safe under any number of concurrent writers — sweep-runner process
pools, simultaneous campaigns, serving fleets warm-loading from the same
root.  Invalidation is by construction: changing the graph, the arch,
any knob, or ``COMPILE_KEY_SCHEMA`` (bumped when compiler passes change
behaviour) changes the key; stale entries are simply never addressed
again.  ``clear()`` removes the directory tree.

Disk growth is bounded when ``max_bytes`` is set: after each ``put`` the
current schema's entries are LRU-evicted by access time until the total
size fits (the entry just written is never evicted).  Eviction holds an
exclusive **lock file** (``<root>/v<schema>/.lock``, ``flock`` where
available, an ``O_EXCL`` spin lock elsewhere), so two handles — or two
processes — capping the same store serialize their scans instead of
deleting each other's in-flight entries; ``evict_grace_s`` additionally
exempts entries younger than the grace window.  Long-running fleets and
campaign farms set the cap; the default stays unbounded so sweep
reproducibility never silently loses entries.

Cross-process accounting: every handle carries an ``owner`` token; disk
hits on entries another handle published count as ``foreign_hits``
(the cross-campaign reuse the shared store exists for), and
``publish_stats()`` / ``shared_stats()`` aggregate per-handle counter
bundles across processes through the store itself.
"""
from __future__ import annotations

import contextlib
import json
import os
import pickle
import tempfile
import time
import uuid
from pathlib import Path
from typing import Dict, Iterator, Optional

from ..core.compiler import COMPILE_KEY_SCHEMA, CompileResult
from ..obs import metrics as obs_metrics

#: environment override for the on-disk cache location
CACHE_DIR_ENV = "REPRO_COMPILE_CACHE_DIR"

#: spin-lock parameters for the no-``fcntl`` fallback (seconds): poll
#: backoff doubles deterministically from _LOCK_POLL_S up to
#: _LOCK_POLL_MAX_S (no jitter — retry schedules must replay exactly),
#: and markers older than _LOCK_STALE_S are presumed abandoned by a
#: dead process and broken
_LOCK_POLL_S = 0.005
_LOCK_POLL_MAX_S = 0.25
_LOCK_STALE_S = 30.0


class CacheLockTimeout(TimeoutError):
    """The store lock could not be acquired within ``timeout_s``.

    Raised instead of blocking forever when a caller bounds the wait —
    a holder that is alive but slow (not stale) keeps the lock, and the
    caller decides whether to retry, skip maintenance, or surface the
    contention."""


def default_cache_dir() -> Path:
    env = os.environ.get(CACHE_DIR_ENV)
    if env:
        return Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME", os.path.expanduser("~/.cache"))
    return Path(xdg) / "repro-cim-mlc" / "compile"


class CompileCache:
    """Disk-backed compile cache with an in-process memory layer.

    The memory layer serves repeated compiles inside one process without
    touching disk; ``memory=False`` disables it (useful for measuring the
    disk path, and for workers that should not grow resident memory).

    ``owner`` names this handle in the shared store (default: a random
    token per handle).  Two campaigns sharing one root pass distinct
    owners (or accept the default) and read ``stats()["foreign_hits"]``
    to see how many artifacts the *other* campaign paid for.
    """

    def __init__(self, root=None, memory: bool = True,
                 max_bytes: Optional[int] = None,
                 owner: Optional[str] = None,
                 evict_grace_s: float = 0.0):
        self.root = Path(root) if root is not None else default_cache_dir()
        self._mem: Optional[Dict[str, CompileResult]] = {} if memory else None
        self._mem_metrics: Dict[str, Dict] = {}
        self.max_bytes = max_bytes   # on-disk size cap (None: unbounded)
        self.evict_grace_s = float(evict_grace_s)
        self._disk_total: Optional[int] = None   # running size estimate
        self._access: Dict[str, float] = {}      # per-key last hit (any layer)
        self.owner = owner if owner else uuid.uuid4().hex[:12]
        self._origin_seen: set = set()  # keys whose disk origin was counted
        self.hits = 0           # full CompileResult hits (get)
        self.metrics_hits = 0   # metric-only hits (get_metrics, no unpickle)
        self.misses = 0         # lookups of either kind that found nothing
        self.evictions = 0      # entries removed by the size cap
        self.foreign_hits = 0   # disk hits on entries another owner wrote

    # -- paths ------------------------------------------------------------
    @property
    def _base(self) -> Path:
        return self.root / f"v{COMPILE_KEY_SCHEMA}"

    def _dir(self, key: str) -> Path:
        return self._base / key[:2]

    def _pkl(self, key: str) -> Path:
        return self._dir(key) / f"{key}.pkl"

    def _json(self, key: str) -> Path:
        return self._dir(key) / f"{key}.json"

    def _src(self, key: str) -> Path:
        return self._dir(key) / f"{key}.src"

    # -- locking ----------------------------------------------------------
    @contextlib.contextmanager
    def lock(self, timeout_s: Optional[float] = None,
             stale_s: Optional[float] = None,
             force_spin: bool = False) -> Iterator[None]:
        """Exclusive store-wide lock.

        Guards multi-file maintenance — eviction uses it internally.
        Prefer ``flock`` (kernel-released on process death); fall back to
        an ``O_EXCL`` spin lock where ``fcntl`` is missing.  Publication
        (``put``) does *not* take the lock: atomic renames are already
        safe under concurrency.

        ``timeout_s`` bounds the wait on either path — ``None`` blocks
        until acquired, otherwise :class:`CacheLockTimeout` is raised
        once the deadline passes.  The spin path polls with a
        deterministic exponential backoff (``_LOCK_POLL_S`` doubling to
        ``_LOCK_POLL_MAX_S``, no jitter) and breaks markers older than
        ``stale_s`` (default ``_LOCK_STALE_S``) — a crashed holder never
        wedges the store, unlike a naive O_EXCL loop.  ``force_spin``
        selects the marker path even when ``fcntl`` exists, so the
        fallback is testable on platforms that have ``flock``.
        """
        self._base.mkdir(parents=True, exist_ok=True)
        fcntl = None
        if not force_spin:
            try:
                import fcntl
            except ImportError:
                fcntl = None
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        if fcntl is not None:
            with open(self._base / ".lock", "a+b") as f:
                if deadline is None:
                    fcntl.flock(f.fileno(), fcntl.LOCK_EX)
                else:
                    poll = _LOCK_POLL_S
                    while True:
                        try:
                            fcntl.flock(f.fileno(),
                                        fcntl.LOCK_EX | fcntl.LOCK_NB)
                            break
                        except OSError:
                            if time.monotonic() >= deadline:
                                raise CacheLockTimeout(
                                    f"store lock at {self._base} not "
                                    f"acquired within {timeout_s}s") from None
                            time.sleep(poll)
                            poll = min(poll * 2, _LOCK_POLL_MAX_S)
                try:
                    yield
                finally:
                    fcntl.flock(f.fileno(), fcntl.LOCK_UN)
            return
        # portable fallback: spin on an exclusive-create marker
        marker = self._base / ".lock.excl"
        stale = _LOCK_STALE_S if stale_s is None else stale_s
        poll = _LOCK_POLL_S
        while True:
            try:
                fd = os.open(marker, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                try:     # holder identity, for post-mortem diagnostics
                    os.write(fd, f"{self.owner} pid={os.getpid()}".encode())
                finally:
                    os.close(fd)
                break
            except FileExistsError:
                try:   # break locks abandoned by a dead process
                    if time.time() - marker.stat().st_mtime > stale:
                        marker.unlink(missing_ok=True)
                        continue
                except OSError:
                    continue   # holder released between open and stat
                if deadline is not None and time.monotonic() >= deadline:
                    raise CacheLockTimeout(
                        f"store lock at {marker} not acquired within "
                        f"{timeout_s}s") from None
                time.sleep(poll)
                poll = min(poll * 2, _LOCK_POLL_MAX_S)
        try:
            yield
        finally:
            try:
                marker.unlink()
            except OSError:
                pass

    # -- lookups ----------------------------------------------------------
    def _touch(self, key: str) -> None:
        """Record a hit for the size cap's LRU: memory-layer hits never
        reach the files, so disk atimes alone would rank the *hottest*
        entries oldest — this per-handle access map keeps them safe."""
        if self.max_bytes is not None:
            self._access[key] = time.time()

    def _count_origin(self, key: str) -> None:
        """Attribute a *disk* hit to the handle that published the entry.

        Counted once per key per handle (the first disk load; memory-layer
        re-hits are this handle's own amortization, not cross-handle
        reuse).  Entries without a ``.src`` sidecar (pre-upgrade stores)
        stay unattributed.
        """
        if key in self._origin_seen:
            return
        self._origin_seen.add(key)
        try:
            writer = self._src(key).read_text(encoding="utf-8").strip()
        except OSError:
            return
        if writer and writer != self.owner:
            self.foreign_hits += 1
            obs_metrics.count("compile_cache_foreign_hits_total")

    def get(self, key: str) -> Optional[CompileResult]:
        """Full ``CompileResult`` for ``key``, or None."""
        if self._mem is not None and key in self._mem:
            self.hits += 1
            obs_metrics.count("compile_cache_hits_total", layer="memory")
            self._touch(key)
            return self._mem[key]
        path = self._pkl(key)
        try:
            with open(path, "rb") as f:
                result = pickle.load(f)
        except Exception:
            # missing file, truncated write, or a stale entry whose classes
            # changed shape under it (AttributeError/ImportError from
            # pickle): all degrade to a recompute, never an abort
            self.misses += 1
            obs_metrics.count("compile_cache_misses_total")
            return None
        self.hits += 1
        obs_metrics.count("compile_cache_hits_total", layer="disk")
        self._touch(key)
        self._count_origin(key)
        if self._mem is not None:
            self._mem[key] = result
        return result

    def get_metrics(self, key: str) -> Optional[Dict]:
        """Metric bundle only — the cheap warm-sweep path (no unpickling)."""
        if key in self._mem_metrics:
            self.metrics_hits += 1
            obs_metrics.count("compile_cache_metrics_hits_total",
                              layer="memory")
            self._touch(key)
            return dict(self._mem_metrics[key])
        try:
            with open(self._json(key)) as f:
                metrics = json.load(f)
        except (OSError, json.JSONDecodeError):
            self.misses += 1
            obs_metrics.count("compile_cache_misses_total")
            return None
        self.metrics_hits += 1
        obs_metrics.count("compile_cache_metrics_hits_total", layer="disk")
        self._touch(key)
        self._count_origin(key)
        self._mem_metrics[key] = metrics
        return dict(metrics)

    def contains(self, key: str) -> bool:
        if self._mem is not None and key in self._mem:
            return True
        return self._pkl(key).exists()

    # -- stores -----------------------------------------------------------
    def put(self, key: str, result: CompileResult,
            metrics: Optional[Dict] = None) -> None:
        if metrics is None:
            metrics = result.metrics()
        self._dir(key).mkdir(parents=True, exist_ok=True)
        _atomic_write(self._pkl(key),
                      pickle.dumps(result, pickle.HIGHEST_PROTOCOL))
        _atomic_write(self._json(key),
                      json.dumps(metrics, sort_keys=True).encode())
        _atomic_write(self._src(key), self.owner.encode())
        self._origin_seen.add(key)        # own entry: never a foreign hit
        if self._mem is not None:
            self._mem[key] = result
        self._mem_metrics[key] = metrics
        if self.max_bytes is not None:
            # keep put O(1) while the cap is far away: maintain a running
            # size estimate (seeded by one full scan) and rescan/evict
            # only when it crosses the cap.  Writes by other handles are
            # invisible until a threshold scan, so the cap is enforced
            # per handle, not as a cross-process hard limit.
            if self._disk_total is None:
                self._disk_total = self.disk_bytes()
            else:
                for p in (self._pkl(key), self._json(key), self._src(key)):
                    try:
                        self._disk_total += p.stat().st_size
                    except OSError:
                        pass
            if self._disk_total > self.max_bytes:
                self._evict(keep=key)

    # -- maintenance ------------------------------------------------------
    def _entry_paths(self, pkl: Path):
        return [pkl, pkl.with_suffix(".json"), pkl.with_suffix(".src")]

    def disk_bytes(self) -> int:
        """Total bytes of the current schema's on-disk entries."""
        if not self._base.exists():
            return 0
        total = 0
        for pkl in self._base.glob("*/*.pkl"):
            for p in self._entry_paths(pkl):
                try:
                    total += p.stat().st_size
                except OSError:
                    pass
        return total

    def _evict(self, keep: Optional[str] = None) -> None:
        """Lock-guarded LRU-by-atime eviction down to ``max_bytes``.

        The whole scan-and-delete runs under the store lock (``lock()``),
        so concurrent cappers — another campaign, a serving fleet — never
        interleave their scans and evict each other's in-flight entries;
        each waits its turn and re-measures the store it actually sees.
        Entries younger than ``evict_grace_s`` are exempt, so a writer's
        freshly published artifacts survive a neighbour's eviction pass
        even before that writer reads them back.

        Each entry's recency is the newest of its files' access times
        (``get`` reads the pkl, ``get_metrics`` the json) and this
        handle's in-process hit log (``_touch`` — memory-layer hits
        never touch the files, so without it the hottest entries would
        rank oldest).  On noatime/relatime mounts the on-disk component
        degrades toward write time, turning cross-handle recency into
        LRU-by-insertion — still bounded, just less precise.  The
        just-written ``keep`` entry is never evicted, so a cap smaller
        than one entry keeps exactly the newest.  Evicted keys are also
        dropped from the memory layer, keeping ``contains``/``get``
        consistent with the disk state.  The scan's recount re-seeds the
        running ``_disk_total`` estimate, so drift from overwrites or
        concurrent writers self-corrects here.
        """
        if not self._base.exists():
            self._disk_total = 0
            return
        with self.lock():
            self._evict_locked(keep)

    def _evict_locked(self, keep: Optional[str]) -> None:
        now = time.time()
        entries = []    # (recency, key, size, paths, fresh)
        total = 0
        for pkl in self._base.glob("*/*.pkl"):
            key = pkl.stem
            paths = self._entry_paths(pkl)
            size = recency = 0
            for p in paths:
                try:
                    st = p.stat()
                except OSError:
                    continue
                size += st.st_size
                recency = max(recency, st.st_atime, st.st_mtime)
            fresh = now - recency < self.evict_grace_s
            recency = max(recency, self._access.get(key, 0.0))
            entries.append((recency, key, size, paths, fresh))
            total += size
        if total > self.max_bytes:
            entries.sort(key=lambda e: (e[0], e[1]))   # oldest access first
            for _, key, size, paths, fresh in entries:
                if total <= self.max_bytes:
                    break
                if key == keep or fresh:
                    continue
                for p in paths:
                    try:
                        os.unlink(p)
                    except OSError:
                        pass
                if self._mem is not None:
                    self._mem.pop(key, None)
                self._mem_metrics.pop(key, None)
                self._access.pop(key, None)
                total -= size
                self.evictions += 1
                obs_metrics.count("compile_cache_evictions_total")
        self._disk_total = total

    def drop_memory(self) -> None:
        """Forget the in-process layer (keeps disk entries)."""
        if self._mem is not None:
            self._mem.clear()
        self._mem_metrics.clear()

    def clear(self) -> None:
        """Delete every entry of the current schema from disk + memory."""
        import shutil
        self.drop_memory()
        self._disk_total = None
        shutil.rmtree(self._base, ignore_errors=True)

    # -- accounting -------------------------------------------------------
    def _counters(self) -> Dict[str, int]:
        return {"hits": self.hits, "metrics_hits": self.metrics_hits,
                "misses": self.misses, "evictions": self.evictions,
                "foreign_hits": self.foreign_hits}

    def stats(self) -> Dict[str, int]:
        """Hit/miss counters for this handle plus the on-disk entry count.

        ``hits`` are full ``CompileResult`` lookups served, and
        ``metrics_hits`` the metric-only lookups that answered without
        unpickling a plan (the warm-sweep fast path); ``misses`` counts
        lookups of either kind that found nothing.  ``foreign_hits``
        counts disk hits on entries *another* owner published — the
        cross-campaign reuse a shared store exists for.  Campaign
        summaries surface this bundle (``CampaignResult.cache_stats``)."""
        disk = 0
        if self._base.exists():
            disk = sum(1 for _ in self._base.glob("*/*.pkl"))
        out = self._counters()
        out["disk_entries"] = disk
        return out

    def publish_stats(self) -> Path:
        """Publish this handle's counters into the shared store.

        Writes ``<root>/v<schema>/_stats/<owner>.json`` atomically
        (cumulative counters — re-publishing overwrites, it never double
        counts), so ``shared_stats`` can aggregate every participating
        campaign/fleet without any of them talking to each other.
        """
        d = self._base / "_stats"
        d.mkdir(parents=True, exist_ok=True)
        payload = dict(self._counters(), owner=self.owner, time=time.time())
        path = d / f"{self.owner}.json"
        _atomic_write(path, json.dumps(payload, sort_keys=True).encode())
        return path

    def shared_stats(self) -> Dict[str, int]:
        """Aggregate counters across every handle that published.

        This handle's *live* counters are included even if it has not
        published yet; ``owners`` counts the distinct participants.
        """
        return shared_stats(self.root, extra=[dict(self._counters(),
                                                   owner=self.owner)])

    def __repr__(self) -> str:
        return (f"CompileCache(root={str(self.root)!r}, "
                f"owner={self.owner!r}, max_bytes={self.max_bytes})")


def shared_stats(root, extra=None) -> Dict[str, int]:
    """Sum the per-owner counter bundles published under ``root``.

    ``extra`` (internal) merges live, not-yet-published handle counters;
    a published bundle for the same owner is superseded by its live one.
    """
    base = Path(root) / f"v{COMPILE_KEY_SCHEMA}" / "_stats"
    by_owner: Dict[str, Dict] = {}
    if base.exists():
        for p in sorted(base.glob("*.json")):
            try:
                with open(p) as f:
                    by_owner[p.stem] = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
    for bundle in (extra or []):
        by_owner[bundle["owner"]] = bundle
    keys = ("hits", "metrics_hits", "misses", "evictions", "foreign_hits")
    out = {k: 0 for k in keys}
    for bundle in by_owner.values():
        for k in keys:
            out[k] += int(bundle.get(k, 0))
    out["owners"] = len(by_owner)
    return out


def _atomic_write(path: Path, data: bytes) -> None:
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
