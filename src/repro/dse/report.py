"""Campaign scorecards — lm-eval-harness-style result tables.

A DSE campaign produces a pile of nested dataclasses; comparing two
campaigns (halving vs adaptive, last week's space vs this week's) means
diffing them by hand.  This module flattens a campaign — or a single
search — into a ``Scorecard``: a named table with typed rows, a metadata
header, and two serializations:

  * ``to_markdown()`` — the pipe-table format eval harnesses print, so a
    scorecard drops into a PR description or a benchmark log verbatim;
  * ``to_json()``     — a stable machine-readable form (sorted keys) for
    committing next to ``BENCH_dse.json`` or diffing across runs.

Every row carries the *spend* (full-fidelity compiles paid, against the
exhaustive price) next to the *outcome* (best objective, frontier size),
so "same best point, 40x cheaper" is one line, not an archaeology
session.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from ..obs import metrics as obs_metrics

__all__ = ["Scorecard", "campaign_scorecard", "search_scorecard"]


def _obs_meta(meta: Dict[str, Any]) -> Dict[str, Any]:
    """Fold the live metrics registry's compile-cache and DSE series
    into a scorecard's metadata (``obs_<series>`` keys) — when telemetry
    is enabled, every scorecard shows what the process actually paid."""
    reg = obs_metrics.active()
    if reg is not None:
        for series, v in reg.flat(prefix=("compile_cache_", "dse_")).items():
            meta[f"obs_{series}"] = v
    return meta


def _fmt(v: Any) -> str:
    if isinstance(v, bool) or v is None:
        return str(v)
    if isinstance(v, float):
        if v != v:                      # nan
            return "-"
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
        return f"{v:.4g}"
    return str(v)


@dataclasses.dataclass
class Scorecard:
    """A named result table (rows are column->value mappings)."""

    title: str
    columns: List[str]
    rows: List[Dict[str, Any]]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps({"title": self.title, "meta": self.meta,
                           "columns": self.columns, "rows": self.rows},
                          sort_keys=True, indent=indent)

    def to_markdown(self) -> str:
        """Pipe table plus a ``key: value`` metadata header."""
        lines = [f"### {self.title}"]
        for k in sorted(self.meta):
            lines.append(f"{k}: {_fmt(self.meta[k])}")
        if self.meta:
            lines.append("")
        widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in self.rows))
                  if self.rows else len(c) for c in self.columns}
        lines.append("|" + "|".join(c.ljust(widths[c])
                                    for c in self.columns) + "|")
        lines.append("|" + "|".join("-" * widths[c]
                                    for c in self.columns) + "|")
        for r in self.rows:
            lines.append("|" + "|".join(
                _fmt(r.get(c)).ljust(widths[c]) for c in self.columns) + "|")
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.to_markdown()


def campaign_scorecard(campaign, title: str = "DSE campaign") -> Scorecard:
    """One row per workload of a ``CampaignResult``.

    Works for every campaign mode; adaptive campaigns additionally
    surface the per-workload proxy spend when the outcome's rung log
    carries it.  ``meta`` records the campaign shape, the cache counters
    (including cross-campaign ``foreign_hits`` when the store is
    shared), and the robust-point count.
    """
    columns = ["workload", "points", "feasible", "frontier",
               "full_evals", "exhaustive", "reduction",
               "best_cost", "best_point"]
    rows: List[Dict[str, Any]] = []
    for name, w in campaign.workloads.items():
        b = w.best
        n_points = campaign.n_points
        rows.append({
            "workload": name,
            "points": n_points,
            "feasible": sum(r.ok for r in w.results),
            "frontier": len(w.frontier),
            "full_evals": w.full_evals,
            "exhaustive": n_points,
            "reduction": (f"{n_points / w.full_evals:.1f}x"
                          if w.full_evals else "-"),
            "best_cost": (b.metrics[w.objective] if b else None),
            "best_point": (b.point.label() if b else "infeasible"),
        })
    meta: Dict[str, Any] = {
        "mode": campaign.mode,
        "workloads": len(campaign.workloads),
        "n_points": campaign.n_points,
        "full_evals": campaign.full_evals,
        "exhaustive_evals": campaign.exhaustive_evals,
        "robust_points": len(campaign.robust),
        "robust_tol": campaign.robust_tol,
    }
    if campaign.cache_stats is not None:
        for k, v in sorted(campaign.cache_stats.items()):
            meta[f"cache_{k}"] = v
    return Scorecard(title=title, columns=columns, rows=rows,
                     meta=_obs_meta(meta))


def search_scorecard(result, name: str = "search",
                     title: Optional[str] = None) -> Scorecard:
    """One row per rung of a ``SearchResult`` / ``AdaptiveResult``."""
    columns = ["rung", "fidelity", "evaluated", "promoted", "full_evals"]
    rows = [{"rung": r.rung, "fidelity": r.fidelity,
             "evaluated": r.evaluated, "promoted": r.promoted,
             "full_evals": r.full_evals} for r in result.rungs]
    b = result.best
    meta: Dict[str, Any] = {
        "workload": name,
        "n_points": result.n_points,
        "objective": result.objective,
        "full_evals": result.full_evals,
        "best_cost": (b.metrics[result.objective] if b else None),
        "best_point": (b.point.label() if b else "infeasible"),
    }
    for extra in ("proxy_evals", "prefix_evals", "ask_rounds"):
        v = getattr(result, extra, None)
        if v is not None:
            meta[extra] = v
    return Scorecard(title=title or f"{name} search", columns=columns,
                     rows=rows, meta=_obs_meta(meta))
