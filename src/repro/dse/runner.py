"""Sweep executor: compile + score every design point, cached, parallel.

Each point is independent, so the runner farms them out to a process
pool (``workers > 1``); results are re-ordered by point index, so the
outcome is bit-identical for any worker count.  Scoring a point:

  1. compute its ``compile_key``;
  2. warm path — the cache's *metrics* file answers without unpickling;
  3. cold path — ``compile_graph`` (which itself consults the cache for
     the full result) then ``perf.estimate``; the entry is persisted.

A point whose compilation raises (e.g. an arch override too small to
hold any chunk of the model) is reported with ``error`` set rather than
aborting the sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..core import compiler
from ..core.abstraction import CIMArch
from ..core.graph import Graph
from .cache import CompileCache
from .space import DesignPoint, DesignSpace


@dataclasses.dataclass
class SweepResult:
    index: int
    point: DesignPoint
    metrics: Optional[Dict[str, float]]
    cached: bool = False
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None and self.metrics is not None


def evaluate_point(graph: Graph, base_arch: CIMArch, point: DesignPoint,
                   cache: Optional[CompileCache] = None,
                   ) -> Tuple[Dict[str, float], bool]:
    """(metrics, was_cached) for one design point."""
    arch = point.arch_for(base_arch)
    kwargs = point.compile_kwargs()
    if cache is not None:
        key = compiler.compile_key(graph, arch, **kwargs)
        metrics = cache.get_metrics(key)
        if metrics is not None:
            return metrics, True
    result = compiler.compile_graph(graph, arch, cache=cache, **kwargs)
    return result.metrics(), False


def _eval_one(args) -> SweepResult:
    index, graph, base_arch, point, cache_dir = args
    cache = CompileCache(cache_dir, memory=False) if cache_dir else None
    try:
        metrics, cached = evaluate_point(graph, base_arch, point, cache)
        return SweepResult(index=index, point=point, metrics=metrics,
                           cached=cached)
    except Exception as e:  # infeasible point: report, don't abort the sweep
        return SweepResult(index=index, point=point, metrics=None,
                           error=f"{type(e).__name__}: {e}")


def sweep(graph: Graph,
          space: Union[DesignSpace, Sequence[DesignPoint]],
          base_arch: Optional[CIMArch] = None,
          cache: Optional[CompileCache] = None,
          workers: int = 1) -> List[SweepResult]:
    """Evaluate every point of ``space`` on ``graph``.

    ``space`` is a ``DesignSpace`` (its ``arch`` is the base) or an
    explicit point list plus ``base_arch``.  ``cache=None`` disables
    caching; ``workers`` > 1 uses a process pool (each worker re-opens
    the cache directory; entries are written atomically).
    """
    if isinstance(space, DesignSpace):
        points = space.points()
        base_arch = base_arch or space.arch
    else:
        points = list(space)
        if base_arch is None:
            raise ValueError("base_arch is required with an explicit "
                             "point list")

    if workers <= 1 or len(points) <= 1:
        return [_eval_one((i, graph, base_arch, p, None))
                if cache is None else _eval_one_local(i, graph, base_arch,
                                                      p, cache)
                for i, p in enumerate(points)]

    cache_dir = str(cache.root) if cache is not None else None
    jobs = [(i, graph, base_arch, p, cache_dir)
            for i, p in enumerate(points)]
    try:
        from concurrent.futures import ProcessPoolExecutor
        with ProcessPoolExecutor(max_workers=workers) as pool:
            results = list(pool.map(_eval_one, jobs, chunksize=1))
    except (OSError, ImportError):   # no process support: degrade serially
        results = [_eval_one(j) for j in jobs]
    results.sort(key=lambda r: r.index)
    if cache is not None:
        # surface freshly-written entries to the caller's cache layer
        cache.drop_memory()
    return results


def _eval_one_local(index: int, graph: Graph, base_arch: CIMArch,
                    point: DesignPoint, cache: CompileCache) -> SweepResult:
    """Serial path reusing the caller's cache object (memory layer live)."""
    try:
        metrics, cached = evaluate_point(graph, base_arch, point, cache)
        return SweepResult(index=index, point=point, metrics=metrics,
                           cached=cached)
    except Exception as e:
        return SweepResult(index=index, point=point, metrics=None,
                           error=f"{type(e).__name__}: {e}")
