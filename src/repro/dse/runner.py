"""Job-queue evaluation primitive shared by sweeps, searches, campaigns.

An ``EvalJob`` is one (graph, design point) evaluation at some fidelity:
a full compile + perf estimate by default, or an analytic proxy when
``proxy=True``.  ``run_jobs`` executes any job list — one workload's
exhaustive sweep, one rung of a successive-halving search, or a whole
campaign round interleaving many workloads — through a single queue, so
wall-clock scales with total work rather than with the number of
callers.

Execution model:

  * proxy jobs never reach the pool: they are grouped per (graph, base
    arch) and evaluated through the **batched proxy cost model**
    (``dse.proxy_vec.proxy_metrics_batch``) — one vectorized pass per
    group, bit-exact against per-job scalar ``compiler.proxy_metrics``
    (infeasible points come back as ``error`` results carrying the
    scalar raise's message);
  * compile jobs with ``screen=True`` first pass through the same
    batched proxy, grouped per (graph, arch): points the proxy proves
    infeasible come back as ``error`` results carrying the exact string
    the compiler would have raised, and only feasible points reach the
    compile path — this is how search rungs evaluate a whole promotion
    batch per (graph, arch) instead of compiling one point at a time;
  * compile jobs with ``workers <= 1`` (or a single job) run in-process,
    reusing the caller's cache object so its memory layer stays live;
  * compile jobs with ``workers > 1`` are farmed to a process pool; each
    worker re-opens the cache directory (``memory=False`` — workers must
    not grow resident memory) and entries are written atomically.  If
    the host cannot fork, the pool degrades to the same per-job code
    path serially.  Either way the caller's cache memory layer is
    dropped afterwards so freshly-written disk entries become visible.

Results come back ordered by job index, so outcomes are bit-identical
for any worker count.  A job whose compilation raises (e.g. an arch
override too small to hold any chunk of the model) is reported with
``error`` set rather than aborting the queue.

Scoring a full-fidelity job:

  1. compute its ``compile_key``;
  2. warm path — the cache's *metrics* file answers without unpickling;
  3. cold path — ``compile_graph`` (which itself consults the cache for
     the full result) then ``perf.estimate``; the entry is persisted.

Proxy jobs are analytic and never touch the disk cache, but they are
memoized per ``(graph, base arch, point)`` within one ``run_jobs``
invocation — and across invocations when the caller threads its own
``proxy_memo`` dict through (``successive_halving`` keeps one per
search, ``run_campaign`` one per campaign, so identical proxy jobs are
never recomputed across rungs or rounds).  Memo keys use object
identity of the graph/arch; the memo pins every pair it has keyed, so
entries stay valid for as long as the dict itself lives.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Dict, Iterable, List, Optional, Sequence, Tuple,
                    Union)

from ..core import compiler
from ..core.abstraction import CIMArch
from ..core.graph import Graph
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .cache import CompileCache
from .space import DesignPoint, DesignSpace


@dataclasses.dataclass
class EvalJob:
    """One (graph, point) evaluation queued through ``run_jobs``."""

    index: int                   # global order key (results are re-sorted)
    graph: Graph
    point: DesignPoint
    arch: CIMArch                # base arch the point's overrides apply to
    proxy: bool = False          # analytic proxy_metrics instead of compile
    screen: bool = False         # batch-screen infeasibility before compiling
    tag: Any = None              # caller routing key (e.g. workload name)


@dataclasses.dataclass
class SweepResult:
    index: int
    point: DesignPoint
    metrics: Optional[Dict[str, float]]
    cached: bool = False
    error: Optional[str] = None
    tag: Any = None

    @property
    def ok(self) -> bool:
        return self.error is None and self.metrics is not None


def evaluate_point(graph: Graph, base_arch: CIMArch, point: DesignPoint,
                   cache: Optional[CompileCache] = None,
                   fault_model=None,
                   ) -> Tuple[Dict[str, float], bool]:
    """(metrics, was_cached) for one design point at full fidelity.

    With ``fault_model`` (a ``cimsim.faults.FaultModel``) set, the
    metrics gain ``fault_top1``: executor-backed top-1 agreement with
    the fault-free executor under that model (see
    ``cimsim.faults.accuracy_under_faults``) — so campaigns can rank
    points by robustness, not just latency.  Robustness is a property
    of the realized arch, so it is computed fresh (never answered from
    the metrics cache) and appended to whatever the cache returned.
    """
    arch = point.arch_for(base_arch)
    kwargs = point.compile_kwargs()
    metrics = cached = None
    if cache is not None:
        key = compiler.compile_key(graph, arch, **kwargs)
        metrics = cache.get_metrics(key)
        cached = metrics is not None
    if metrics is None:
        result = compiler.compile_graph(graph, arch, cache=cache, **kwargs)
        metrics, cached = result.metrics(), False
    if fault_model is not None:
        from ..cimsim.faults import accuracy_under_faults
        metrics = dict(metrics)
        metrics["fault_top1"] = accuracy_under_faults(
            graph, arch, fault_model, **kwargs)
    return metrics, cached


def _eval_job(job: EvalJob, cache: Optional[CompileCache]) -> SweepResult:
    """The one evaluation code path every execution mode shares."""
    try:
        if job.proxy:
            arch = job.point.arch_for(job.arch)
            kwargs = job.point.compile_kwargs()
            kwargs.pop("expand", None)
            metrics = compiler.proxy_metrics(job.graph, arch, **kwargs)
            return SweepResult(index=job.index, point=job.point,
                               metrics=metrics, tag=job.tag)
        metrics, cached = evaluate_point(job.graph, job.arch, job.point,
                                         cache)
        return SweepResult(index=job.index, point=job.point, metrics=metrics,
                           cached=cached, tag=job.tag)
    except Exception as e:  # infeasible point: report, don't abort the queue
        return SweepResult(index=job.index, point=job.point, metrics=None,
                           error=f"{type(e).__name__}: {e}", tag=job.tag)


def _eval_job_worker(args: Tuple[EvalJob, Optional[str]]) -> SweepResult:
    """Pool entry: re-open the cache directory, then the shared path."""
    job, cache_dir = args
    cache = CompileCache(cache_dir, memory=False) if cache_dir else None
    return _eval_job(job, cache)


def _fill_proxy_memo(jobs: Sequence[EvalJob],
                     memo: Dict[Any, Tuple[Optional[Dict], Optional[str]]],
                     ) -> None:
    """Score every job's point through the batched proxy cost model.

    Jobs are grouped per (graph, base arch); each group's unmemoized
    points go through one ``proxy_metrics_batch`` pass.  ``memo`` maps
    ``(id(graph), id(arch), point)`` to ``(metrics, error)`` — reused
    duplicates (within a group, across groups, or across invocations
    when the caller threads the dict through) cost a dict lookup.  The
    memo also pins each (graph, arch) pair it has keyed, so the ids can
    never be recycled onto different objects while the dict lives.  If
    the batched path itself fails unexpectedly, the group's points fall
    back to the scalar oracle one by one, so a proxy job can never be
    *worse* off than before batching.
    """
    from .proxy_vec import NodeTensor, proxy_metrics_batch, _scalar_oracle

    groups: Dict[Tuple[int, int], List[EvalJob]] = {}
    for j in jobs:
        groups.setdefault((id(j.graph), id(j.arch)), []).append(j)

    for gkey, grp in groups.items():
        graph, arch = grp[0].graph, grp[0].arch
        memo[("__pin__", *gkey)] = (graph, arch)
        todo: List[DesignPoint] = []
        keys: List[Tuple] = []
        seen = set()
        for j in grp:
            key = (*gkey, j.point)
            if key not in memo and key not in seen:
                seen.add(key)
                todo.append(j.point)
                keys.append(key)
        if todo:
            try:
                batch = proxy_metrics_batch(
                    graph, todo, arch,
                    node_tensor=NodeTensor.from_graph(graph))
                for i, key in enumerate(keys):
                    memo[key] = (batch.metrics(i), batch.errors[i])
            except Exception:    # semantics net: replay through the oracle
                for key, pt in zip(keys, todo):
                    try:
                        arch_pt = pt.arch_for(arch)
                    except Exception as e:
                        memo[key] = (None, f"{type(e).__name__}: {e}")
                        continue
                    memo[key] = _scalar_oracle(graph, arch_pt, pt)


def _eval_proxy_jobs(jobs: Sequence[EvalJob],
                     memo: Dict[Any, Tuple[Optional[Dict], Optional[str]]],
                     ) -> List[SweepResult]:
    """Evaluate proxy jobs through the batched proxy cost model."""
    _fill_proxy_memo(jobs, memo)
    return [SweepResult(
        index=j.index, point=j.point,
        metrics=(dict(m) if (m := memo[(id(j.graph), id(j.arch),
                                        j.point)][0]) is not None else None),
        error=memo[(id(j.graph), id(j.arch), j.point)][1], tag=j.tag)
        for j in jobs]


def _screen_compile_jobs(jobs: Sequence[EvalJob],
                         memo: Dict[Any, Tuple[Optional[Dict],
                                               Optional[str]]],
                         ) -> Tuple[List[EvalJob], List[SweepResult]]:
    """Partition compile jobs by batched infeasibility screening.

    Runs the whole job list through one vectorized proxy pass per
    (graph, arch) group and splits it into (feasible jobs, infeasible
    results).  The proxy's infeasibility conditions — mode/level
    mismatch, binding below core granularity, virtual-crossbar span over
    the per-core budget — are raised by ``compile_graph`` with the
    *identical* message strings (they share ``compiler.mode_error``, the
    same ``CostModel.placement`` and the same span cap), so a screened
    rung reports the same errors the one-at-a-time compile path would,
    without paying a compile attempt per infeasible point.  Feasible
    jobs still go through the real compiler: screening changes where
    infeasibility is *detected*, never what a feasible point scores.
    """
    _fill_proxy_memo(jobs, memo)
    passed: List[EvalJob] = []
    failed: List[SweepResult] = []
    for j in jobs:
        error = memo[(id(j.graph), id(j.arch), j.point)][1]
        if error is None:
            passed.append(j)
        else:
            failed.append(SweepResult(index=j.index, point=j.point,
                                      metrics=None, error=error, tag=j.tag))
    return passed, failed


def run_jobs(jobs: Iterable[EvalJob],
             cache: Optional[CompileCache] = None,
             workers: int = 1,
             proxy_memo: Optional[Dict] = None) -> List[SweepResult]:
    """Evaluate ``jobs`` and return results sorted by job index.

    ``proxy_memo`` (optional) is a dict threaded through by callers that
    issue proxy jobs repeatedly for the same (graph, arch, point)
    triples; by default memoization is scoped to this invocation.
    """
    import time as _time
    jobs = list(jobs)
    t0 = _time.perf_counter()
    proxy_jobs = [j for j in jobs if j.proxy]
    compile_jobs = [j for j in jobs if not j.proxy]
    results: List[SweepResult] = []
    memo = proxy_memo if proxy_memo is not None else {}
    if proxy_jobs:
        results.extend(_eval_proxy_jobs(proxy_jobs, memo))

    screened = [j for j in compile_jobs if j.screen]
    if screened:
        # batched rung: one vectorized infeasibility pass per (graph,
        # arch) group, then only the survivors reach the compiler
        passed, failed = _screen_compile_jobs(screened, memo)
        results.extend(failed)
        compile_jobs = [j for j in compile_jobs if not j.screen] + passed

    if compile_jobs:
        if workers <= 1 or len(compile_jobs) <= 1:
            results.extend(_eval_job(j, cache) for j in compile_jobs)
        else:
            cache_dir = str(cache.root) if cache is not None else None
            args = [(j, cache_dir) for j in compile_jobs]
            try:
                from concurrent.futures import ProcessPoolExecutor
                with ProcessPoolExecutor(max_workers=workers) as pool:
                    results.extend(pool.map(_eval_job_worker, args,
                                            chunksize=1))
            except (OSError, ImportError):  # no processes: degrade serially
                results.extend(_eval_job_worker(a) for a in args)
            if cache is not None:
                # the caller's memory layer predates the workers' writes
                # (pool and fallback alike use private cache handles):
                # resync it from disk
                cache.drop_memory()
    results.sort(key=lambda r: r.index)
    obs_metrics.count("dse_jobs_total", n=len(jobs))
    tr = obs_trace.get_trace()
    if tr is not None and jobs:
        dt = _time.perf_counter() - t0
        graph = jobs[0].graph.name
        tr.complete(obs_trace.DSE_TRACK, graph, f"rung:{graph}", "dse",
                    obs_trace.now_s() - dt, dt, jobs=len(jobs),
                    proxy=len(proxy_jobs), ok=sum(r.ok for r in results))
    return results


def resolve_space(space: Union[DesignSpace, Sequence[DesignPoint]],
                  base_arch: Optional[CIMArch] = None,
                  ) -> Tuple[List[DesignPoint], CIMArch]:
    """(points, base arch) from a ``DesignSpace`` or explicit point list."""
    if isinstance(space, DesignSpace):
        return space.points(), base_arch or space.arch
    points = list(space)
    if base_arch is None:
        raise ValueError("base_arch is required with an explicit point list")
    return points, base_arch


def sweep(graph: Graph,
          space: Union[DesignSpace, Sequence[DesignPoint]],
          base_arch: Optional[CIMArch] = None,
          cache: Optional[CompileCache] = None,
          workers: int = 1) -> List[SweepResult]:
    """Exhaustively evaluate every point of ``space`` on ``graph``.

    ``space`` is a ``DesignSpace`` (its ``arch`` is the base) or an
    explicit point list plus ``base_arch``.  ``cache=None`` disables
    caching.  Thin wrapper over ``run_jobs`` — see module docstring for
    the execution model.
    """
    points, base_arch = resolve_space(space, base_arch)
    return run_jobs((EvalJob(index=i, graph=graph, point=p, arch=base_arch)
                     for i, p in enumerate(points)),
                    cache=cache, workers=workers)
