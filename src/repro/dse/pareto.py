"""Pareto frontier over sweep results (all objectives minimized)."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

#: default objective vector: runtime, thermal envelope, silicon footprint
DEFAULT_OBJECTIVES: Tuple[str, ...] = (
    "latency_cycles", "peak_power", "crossbars_used")


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True if objective vector ``a`` Pareto-dominates ``b`` (minimize)."""
    return all(x <= y for x, y in zip(a, b)) and \
        any(x < y for x, y in zip(a, b))


def _vector(metrics: Dict[str, float],
            objectives: Sequence[str]) -> Tuple[float, ...]:
    return tuple(float(metrics[o]) for o in objectives)


def pareto_frontier(results: Sequence,
                    objectives: Sequence[str] = DEFAULT_OBJECTIVES) -> List:
    """Non-dominated subset of ``results``, sorted by the first objective.

    ``results`` may be ``runner.SweepResult``s (failed points are ignored)
    or plain metric dicts.  A point dominated by any other — or an exact
    duplicate of an earlier kept point — is dropped.
    """
    rows = []
    for r in results:
        metrics = r if isinstance(r, dict) else r.metrics
        if metrics is None:
            continue
        rows.append((_vector(metrics, objectives), r))

    front: List[Tuple[Tuple[float, ...], object]] = []
    for vec, r in rows:
        if any(dominates(fv, vec) or fv == vec for fv, _ in front):
            continue
        front = [(fv, fr) for fv, fr in front if not dominates(vec, fv)]
        front.append((vec, r))
    front.sort(key=lambda t: t[0])
    return [r for _, r in front]
