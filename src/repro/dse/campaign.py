"""Multi-workload DSE campaigns over one shared cache and job queue.

A campaign sweeps *many* workloads (ResNets, VGGs, ViT, LM blocks, …)
against one design space.  Instead of running ``sum(per-workload
sweeps)`` back to back, every round's (workload, point) jobs are
interleaved into a single ``runner.run_jobs`` queue over one process
pool and one compile cache, so wall-clock scales with total work and a
point compiled for one workload's rung is a cache hit everywhere else it
appears.

Three modes:

  * ``"halving"`` (default) — one ``HalvingSearch`` per workload, driven
    in lockstep: each round gathers the current rung's jobs from every
    unfinished search into one queue, then routes results back.  The
    opening round screens the cross-product of all workloads x all
    points through the batched proxy cost model (one vectorized
    ``dse.proxy_vec`` pass per workload — see runner); full compiles are
    paid only for each workload's survivor set.
  * ``"adaptive"`` — one ``AdaptiveSearch`` per workload through the
    same lockstep loop: every round interleaves each workload's ask
    batch (or screened compile rung) into the shared queue.  Each
    workload's searcher gets its own ``numpy`` Generator derived from
    ``seed`` and the workload's position, so campaigns are reproducible
    end to end; extra searcher knobs pass through ``adaptive=...``.
  * ``"exhaustive"`` — every (workload, point) pair at full fidelity in
    one round-robin-interleaved queue; the reference baseline.

The result carries, per workload, the full-fidelity results, the Pareto
frontier, and the best point by the scalar objective — plus a
cross-workload *robust points* summary: points evaluated at full
fidelity on every workload whose objective is within ``robust_tol`` of
that workload's best, every time.  Those are the configurations worth
building hardware for when the deployment mix is uncertain.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, List, Mapping, Optional, Sequence, Tuple, Union)

from ..core.abstraction import CIMArch
from ..core.graph import Graph
from .cache import CompileCache
from .pareto import DEFAULT_OBJECTIVES, pareto_frontier
from .runner import EvalJob, SweepResult, resolve_space, run_jobs
from .search import DEFAULT_LADDER, HalvingSearch, Rung, RungLog
from .space import DesignPoint, DesignSpace


@dataclasses.dataclass
class WorkloadOutcome:
    """One workload's view of the campaign."""

    name: str
    results: List[SweepResult]          # full-fidelity results
    frontier: List[SweepResult]
    full_evals: int
    rungs: List[RungLog] = dataclasses.field(default_factory=list)
    objective: str = "latency_cycles"
    #: functional verification of the best point (``verify_best=True``):
    #: a cimsim.VerifyReport from the trace-lowered batched executor
    verify: Optional[object] = None

    @property
    def best(self) -> Optional[SweepResult]:
        ok = [r for r in self.results if r.ok]
        if not ok:
            return None
        return min(ok, key=lambda r: (r.metrics[self.objective], r.index))


@dataclasses.dataclass
class RobustPoint:
    """A point near-optimal on every workload of the campaign."""

    point: DesignPoint
    max_regret: float                    # worst relative gap to a best
    regret: Dict[str, float]             # per-workload relative gap


@dataclasses.dataclass
class CampaignResult:
    workloads: Dict[str, WorkloadOutcome]
    robust: List[RobustPoint]
    n_points: int
    mode: str
    robust_tol: float
    #: ``CompileCache.stats()`` snapshot taken when the campaign finished
    #: (None when the campaign ran uncached)
    cache_stats: Optional[Dict[str, int]] = None

    @property
    def full_evals(self) -> int:
        return sum(w.full_evals for w in self.workloads.values())

    @property
    def exhaustive_evals(self) -> int:
        """Full-fidelity evaluations an exhaustive campaign would pay."""
        return self.n_points * len(self.workloads)

    def summary(self) -> str:
        lines = [f"campaign: {len(self.workloads)} workloads x "
                 f"{self.n_points} points ({self.mode}); "
                 f"{self.full_evals} full-fidelity evals "
                 f"(exhaustive: {self.exhaustive_evals})"]
        for name, w in self.workloads.items():
            b = w.best
            best = (f"{b.point.label()} -> {b.metrics[w.objective]:.0f}"
                    if b else "no feasible point")
            lines.append(f"  {name}: frontier {len(w.frontier)} / "
                         f"{sum(r.ok for r in w.results)} feasible; "
                         f"best {best}")
        lines.append(f"  robust points (<= {self.robust_tol:.0%} off best "
                     f"everywhere): {len(self.robust)}")
        for rp in self.robust[:5]:
            lines.append(f"    {rp.point.label()}  "
                         f"(max regret {rp.max_regret:.1%})")
        if self.cache_stats is not None:
            s = self.cache_stats
            lines.append(f"  compile cache: {s['hits']} hits, "
                         f"{s['metrics_hits']} metric-only hits, "
                         f"{s['misses']} misses "
                         f"({s['disk_entries']} disk entries)")
        return "\n".join(lines)


def _as_workloads(workloads) -> List[Tuple[str, Graph]]:
    if isinstance(workloads, Mapping):
        return list(workloads.items())
    out = []
    for item in workloads:
        if isinstance(item, Graph):
            out.append((item.name, item))
        else:
            name, graph = item
            out.append((name, graph))
    if len({n for n, _ in out}) != len(out):
        raise ValueError("workload names must be unique")
    return out


def robust_points(outcomes: Mapping[str, WorkloadOutcome],
                  tol: float = 0.10,
                  objective: str = "latency_cycles") -> List[RobustPoint]:
    """Points near-optimal on *every* workload.

    Only points with a feasible full-fidelity result on every workload
    are comparable (under halving that is the survivor intersection);
    regret is ``obj / workload_best - 1``.  Sorted by worst-case regret,
    ties by point enumeration order.
    """
    per_point: Dict[DesignPoint, Dict[str, float]] = {}
    order: Dict[DesignPoint, int] = {}
    for name, w in outcomes.items():
        best = w.best
        if best is None:
            return []
        floor = best.metrics[objective]
        for r in w.results:
            if not r.ok:
                continue
            per_point.setdefault(r.point, {})[name] = \
                r.metrics[objective] / max(floor, 1e-12) - 1.0
            order.setdefault(r.point, r.index)
    out = []
    for point, regret in per_point.items():
        if len(regret) != len(outcomes):
            continue                     # not evaluated everywhere
        worst = max(regret.values())
        if worst <= tol:
            out.append(RobustPoint(point=point, max_regret=worst,
                                   regret=dict(regret)))
    out.sort(key=lambda rp: (rp.max_regret, order[rp.point]))
    return out


def run_campaign(workloads, space: Union[DesignSpace, Sequence[DesignPoint]],
                 base_arch: Optional[CIMArch] = None, *,
                 mode: str = "halving",
                 eta: int = 3,
                 ladder: Sequence[Rung] = DEFAULT_LADDER,
                 objective: str = "latency_cycles",
                 objectives: Sequence[str] = DEFAULT_OBJECTIVES,
                 min_keep: int = 2,
                 robust_tol: float = 0.10,
                 cache: Optional[CompileCache] = None,
                 workers: int = 1,
                 seed: int = 0,
                 adaptive: Optional[Mapping] = None,
                 verify_best: bool = False,
                 verify_batch: int = 2) -> CampaignResult:
    """Sweep every workload against ``space`` through one shared queue.

    ``workloads`` is a mapping ``name -> Graph``, a sequence of
    ``(name, graph)`` pairs, or a sequence of graphs (named by
    ``graph.name``).  Results are deterministic for any ``workers``
    count.

    ``verify_best=True`` closes the loop the paper closes by hand
    (§4.1): each workload's winning design point is functionally
    verified against the int8 fake-quant reference — via the
    trace-lowered batched executor, so the check costs one lowering plus
    one batched dispatch of ``verify_batch`` inputs.  The report lands
    on ``WorkloadOutcome.verify``.
    """
    wls = _as_workloads(workloads)
    points, base = resolve_space(space, base_arch)
    if mode not in ("halving", "adaptive", "exhaustive"):
        raise ValueError(f"unknown campaign mode {mode!r}")

    outcomes: Dict[str, WorkloadOutcome] = {}
    if mode == "exhaustive":
        # round-robin across workloads so the single queue mixes cheap and
        # expensive graphs instead of draining them workload-by-workload
        jobs = [EvalJob(index=k, graph=g, point=p, arch=base, tag=name)
                for k, (p, (name, g)) in enumerate(
                    (p, wl) for p in points for wl in wls)]
        results = run_jobs(jobs, cache=cache, workers=workers)
        by_wl: Dict[str, List[SweepResult]] = {name: [] for name, _ in wls}
        for r in results:
            by_wl[r.tag].append(r)
        for name, _ in wls:
            rs = by_wl[name]
            outcomes[name] = WorkloadOutcome(
                name=name, results=rs,
                frontier=pareto_frontier([r for r in rs if r.ok], objectives),
                full_evals=len(rs), objective=objective)
    else:
        if mode == "adaptive":
            from .adaptive import AdaptiveSearch
            knobs = dict(adaptive or {})
            # every workload derives its own generator from one root
            # seed (the knobs' seed wins if both are given) and its
            # stable position, so campaigns replay end to end
            root_seed = knobs.pop("seed", seed)
            knobs.setdefault("objective", objective)
            knobs.setdefault("min_keep", min_keep)
            searches = {name: AdaptiveSearch(g, points, base,
                                             seed=(root_seed, k), **knobs)
                        for k, (name, g) in enumerate(wls)}
        else:
            searches = {name: HalvingSearch(g, points, base, eta=eta,
                                            ladder=ladder,
                                            objective=objective,
                                            min_keep=min_keep)
                        for name, g in wls}
        # one memo for the whole campaign: identical proxy jobs recurring
        # across rungs or rounds (multi-proxy ladders, repeated points)
        # cost a dict lookup instead of a recompute
        proxy_memo: Dict = {}
        while any(not s.done for s in searches.values()):
            jobs: List[EvalJob] = []
            slices: List[Tuple[str, int]] = []
            for name, _ in wls:           # stable workload order
                s = searches[name]
                if s.done:
                    continue
                batch = s.jobs(index_base=len(jobs), tag=name)
                jobs.extend(batch)
                slices.append((name, len(batch)))
            results = run_jobs(jobs, cache=cache, workers=workers,
                               proxy_memo=proxy_memo)
            off = 0
            for name, count in slices:
                searches[name].observe(results[off:off + count])
                off += count
        for name, _ in wls:
            sr = searches[name].search_result()
            ok = [r for r in sr.results if r.ok]
            outcomes[name] = WorkloadOutcome(
                name=name, results=sr.results,
                frontier=pareto_frontier(ok, objectives),
                full_evals=sr.full_evals, rungs=sr.rungs,
                objective=objective)

    if verify_best:
        from ..cimsim import VerifyReport, compile_and_verify
        graphs = dict(wls)
        for name, w in outcomes.items():
            b = w.best
            if b is None:
                continue
            arch_pt = b.point.arch_for(base)
            try:
                w.verify = compile_and_verify(
                    graphs[name], arch_pt, batch=verify_batch,
                    cache=cache,       # the winning compile is already here
                    **b.point.compile_kwargs())
            except Exception as e:   # fail-soft, like sweep evaluation
                w.verify = VerifyReport(
                    graph=name, arch=arch_pt.name, batch=verify_batch,
                    max_abs_err={}, error=f"{type(e).__name__}: {e}")

    return CampaignResult(
        workloads=outcomes,
        robust=robust_points(outcomes, robust_tol, objective),
        n_points=len(points), mode=mode, robust_tol=robust_tol,
        cache_stats=cache.stats() if cache is not None else None)
