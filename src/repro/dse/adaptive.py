"""Budgeted ask/tell search over a ``DesignSpace`` (learned halving).

Successive halving (``search.py``) is exhaustive at the cheap rung: it
scores *every* enumerated point through the proxy, then promotes a fixed
``1/eta`` fraction rung by rung — on an 11664-point space that is
thousands of prefix/full compiles regardless of how quickly the good
region is identified.  ``AdaptiveSearch`` replaces the fixed grid with a
model-guided loop sized for the vectorized proxy:

  ask   — propose a *batch* of unevaluated points.  Categorical axes
          (scheduling level, bit binding, the CG switches) and the
          enumerated arch axes (crossbar size, cell precision, DAC bits,
          core/chip counts, ...) are scored by a TPE-style density
          model: observed points are split at the ``gamma`` quantile of
          the proxy objective into *good* and *bad* sets, each axis gets
          Laplace-smoothed categorical densities ``l`` (good) / ``g``
          (bad), and candidates rank by ``sum_axis log(l/g)`` — the
          classic Bergstra et al. acquisition, vectorized over the whole
          space with NumPy.  An ``explore`` fraction of every batch is
          drawn uniformly so the model can never paint itself into a
          corner; all randomness flows from one seeded
          ``numpy.random.Generator``, so a seed fixes the entire ask
          sequence.
  tell  — the batch comes back from the **batched proxy cost model**
          (``runner`` routes proxy jobs through ``dse.proxy_vec``, so a
          512-point ask is one structure-of-arrays pass, not 512 scalar
          proxies).  Infeasible points score ``+inf`` and teach the
          density model which axis values to avoid.

The ask/tell loop stops on any of: proxy budget exhausted, space fully
evaluated, ``max_rounds`` reached, or ``patience`` consecutive rounds
without improving the best proxy score.  The top ``prefix_keep``
feasible points then climb the same fidelity ladder halving uses —
one *batched prefix rung* (a single screened ``run_jobs`` batch of
``Graph.prefix`` compiles per (graph, arch)) and one full rung — so the
expensive fidelities are paid for a model-chosen shortlist instead of a
fixed fraction of the whole space.

``AdaptiveSearch`` exposes the same incremental driving interface as
``HalvingSearch`` (``jobs()`` → ``run_jobs`` → ``observe``; ``done``;
``search_result()``), so ``run_campaign(mode="adaptive")`` interleaves
many workloads' rounds through one job queue and one shared compile
cache, and ``points_from_campaign`` hands the winners to the serving
fleet unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.abstraction import CIMArch
from ..core.graph import Graph
from ..obs import metrics as obs_metrics
from .cache import CompileCache
from .runner import EvalJob, SweepResult, resolve_space, run_jobs
from .search import RungLog, SearchResult, rung_prefix_graph
from .space import DesignPoint, DesignSpace


@dataclasses.dataclass
class AdaptiveResult(SearchResult):
    """Outcome of one adaptive search (a ``SearchResult`` plus the
    ask/tell accounting the scorecard reports)."""

    proxy_evals: int            # proxy evaluations actually paid
    prefix_evals: int           # prefix-fidelity compiles paid
    ask_rounds: int             # ask/tell rounds before promotion
    ask_log: List[Tuple[int, ...]]   # enumeration indices asked per round


def _feature_matrix(points: Sequence[DesignPoint],
                    ) -> Tuple[np.ndarray, List[int], List[str]]:
    """Integer-coded categorical features, one row per design point.

    Axes are the four scheduling knobs plus one axis per distinct
    ``arch_overrides`` path (absent paths code as their own category).
    Codes follow first appearance in enumeration order, so the encoding
    is deterministic for a given point list.
    """
    paths = sorted({path for pt in points for path, _ in pt.arch_overrides})
    names = ["level", "binding", "pipeline", "duplication", *paths]
    rows = []
    for pt in points:
        ov = dict(pt.arch_overrides)
        rows.append((pt.level, pt.binding, pt.use_pipeline,
                     pt.use_duplication, *(ov.get(p) for p in paths)))
    feats = np.empty((len(points), len(names)), dtype=np.int64)
    n_cats: List[int] = []
    for a in range(len(names)):
        code: Dict[Any, int] = {}
        for i, row in enumerate(rows):
            v = row[a]
            if v not in code:
                code[v] = len(code)
            feats[i, a] = code[v]
        n_cats.append(len(code))
    return feats, n_cats, names


class AdaptiveSearch:
    """Incremental ask/tell state over one workload.

    Drive it exactly like ``HalvingSearch``::

        while not search.done:
            results = run_jobs(search.jobs(), cache=cache, workers=w)
            search.observe(results)

    Proxy rounds issue ``batch``-sized ask batches; once the loop
    stops, one screened prefix batch and one screened full batch
    finish the ladder.  Determinism: a fixed ``seed`` fixes the ask
    sequence, hence every downstream promotion and the final best
    point, for any ``workers`` count.
    """

    def __init__(self, graph: Graph,
                 space: Union[DesignSpace, Sequence[DesignPoint]],
                 base_arch: Optional[CIMArch] = None, *,
                 seed=0,
                 objective: str = "latency_cycles",
                 batch: int = 512,
                 max_rounds: int = 16,
                 proxy_budget: Optional[int] = None,
                 gamma: float = 0.2,
                 explore: float = 0.1,
                 patience: int = 3,
                 prefix_keep: int = 32,
                 prefix_frac: float = 0.5,
                 full_keep: int = 8,
                 min_keep: int = 2):
        if not 0.0 < gamma < 1.0:
            raise ValueError("gamma must be in (0, 1)")
        if not 0.0 <= explore <= 1.0:
            raise ValueError("explore must be in [0, 1]")
        if full_keep > prefix_keep:
            raise ValueError("full_keep cannot exceed prefix_keep")
        self.graph = graph
        self.points, self.base_arch = resolve_space(space, base_arch)
        n = len(self.points)
        self.objective = objective
        self.batch = max(1, min(batch, n)) if n else 1
        self.max_rounds = max_rounds
        self.proxy_budget = n if proxy_budget is None else min(
            max(proxy_budget, self.batch), n)
        self.gamma = gamma
        self.explore = explore
        self.patience = patience
        self.prefix_keep = prefix_keep
        self.prefix_frac = prefix_frac
        self.full_keep = full_keep
        self.min_keep = min_keep
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._feats, self._n_cats, self.axes = _feature_matrix(self.points)
        #: nan = unevaluated, +inf = proxy-infeasible, else proxy objective
        self._scores = np.full(n, np.nan)
        self._proxy_results: Dict[int, SweepResult] = {}
        self._prefix_cache: Optional[Graph] = None
        self.phase = "proxy"             # "proxy" -> "prefix" -> "full"
        self.survivors: List[int] = []
        self.rung_log: List[RungLog] = []
        self.ask_log: List[Tuple[int, ...]] = []
        self.full_evals = 0
        self.proxy_evals = 0
        self.prefix_evals = 0
        self._stall = 0
        self._best = math.inf
        self.results: Optional[List[SweepResult]] = None
        self._pending: Optional[List[int]] = None

    # -- state -----------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.results is not None

    def _prefix_graph(self) -> Graph:
        # one prefix graph object per search, so batched screening and
        # the proxy memo key every prefix job to the same (graph, arch)
        if self._prefix_cache is None:
            self._prefix_cache = rung_prefix_graph(self.graph,
                                                   self.prefix_frac)
        return self._prefix_cache

    # -- the ask side ----------------------------------------------------
    def _ask(self) -> List[int]:
        """Next batch of enumeration indices to score through the proxy."""
        unev = np.flatnonzero(np.isnan(self._scores))
        k = min(self.batch, len(unev),
                max(0, self.proxy_budget - self.proxy_evals))
        if k <= 0:
            return []
        obs = np.flatnonzero(~np.isnan(self._scores))
        feas = obs[np.isfinite(self._scores[obs])]
        if len(feas) < max(4, 2 * self.min_keep):
            # cold start (or a hostile space): uniform coverage
            asked = sorted(int(i) for i in
                           self.rng.choice(unev, size=k, replace=False))
        else:
            n_good = max(1, math.ceil(self.gamma * len(feas)))
            by_score = feas[np.lexsort((feas, self._scores[feas]))]
            good = by_score[:n_good]
            bad = np.setdiff1d(obs, good)
            dens = np.zeros(len(unev))
            for a in range(self._feats.shape[1]):
                cats = self._n_cats[a]
                if cats < 2:
                    continue
                lo = np.bincount(self._feats[good, a], minlength=cats) + 1.0
                hi = np.bincount(self._feats[bad, a], minlength=cats) + 1.0
                ratio = np.log(lo / lo.sum()) - np.log(hi / hi.sum())
                dens += ratio[self._feats[unev, a]]
            n_explore = int((self.rng.random(k) < self.explore).sum())
            order = np.lexsort((unev, -dens))   # best ratio, ties by index
            exploit = [int(i) for i in unev[order[:k - n_explore]]]
            rest = np.setdiff1d(unev, np.asarray(exploit, dtype=unev.dtype))
            explore: List[int] = []
            if n_explore and len(rest):
                explore = [int(i) for i in self.rng.choice(
                    rest, size=min(n_explore, len(rest)), replace=False)]
            asked = sorted(exploit + explore)
        self.ask_log.append(tuple(asked))
        obs_metrics.count("dse_ask_rounds_total", workload=self.graph.name)
        return asked

    # -- driving ---------------------------------------------------------
    def jobs(self, index_base: int = 0, tag: Any = None) -> List[EvalJob]:
        """The next batch of jobs (proxy ask, or a screened compile rung)."""
        if self.done:
            return []
        if self.phase == "proxy":
            asked = self._ask()
            if asked:
                self._pending = list(asked)
                return [EvalJob(index=index_base + k, graph=self.graph,
                                point=self.points[i], arch=self.base_arch,
                                proxy=True, tag=tag)
                        for k, i in enumerate(asked)]
            # budget exhausted before a round could be issued
            self._promote_from_proxy()
            if self.done:
                return []
        graph = self.graph if self.phase == "full" else self._prefix_graph()
        self._pending = list(self.survivors)
        return [EvalJob(index=index_base + k, graph=graph,
                        point=self.points[i], arch=self.base_arch,
                        screen=True, tag=tag)
                for k, i in enumerate(self._pending)]

    def observe(self, results: Sequence[SweepResult]) -> None:
        """Consume the batch issued by the last ``jobs()`` (same order)."""
        if self._pending is None:
            if self.done and not results:
                return      # a driver handing back an empty final slice
            raise RuntimeError("observe() without a preceding jobs()")
        if len(results) != len(self._pending):
            raise ValueError(f"expected {len(self._pending)} results, "
                             f"got {len(results)}")
        pending, self._pending = self._pending, None
        if self.phase == "proxy":
            self._tell(pending, results)
            return
        if self.phase == "prefix":
            is_full = self._prefix_graph() is self.graph
            self.prefix_evals += len(results)
            full_here = len(results) if is_full else 0
            self.full_evals += full_here
            scored = [(r.metrics[self.objective], i, r)
                      for i, r in zip(pending, results) if r.ok]
            scored.sort(key=lambda t: (t[0], t[1]))
            keep = min(len(scored), max(self.min_keep, self.full_keep))
            self.survivors = [i for _, i, _ in scored[:keep]]
            self.rung_log.append(RungLog(len(self.rung_log), "prefix",
                                         len(results), keep, full_here))
            if not self.survivors:
                self._finalize(pending, results)
                return
            self.phase = "full"
            return
        self.full_evals += len(results)
        self.rung_log.append(RungLog(len(self.rung_log), "full",
                                     len(results), 0, len(results)))
        self._finalize(pending, results)

    def _tell(self, pending: List[int],
              results: Sequence[SweepResult]) -> None:
        self.proxy_evals += len(results)
        for i, r in zip(pending, results):
            self._proxy_results[i] = r
            self._scores[i] = (r.metrics[self.objective] if r.ok
                               else math.inf)
        feasible = int(np.isfinite(self._scores).sum())
        best = float(np.min(self._scores[~np.isnan(self._scores)])) \
            if feasible else math.inf
        if best < self._best:
            self._best, self._stall = best, 0
        else:
            self._stall += 1
        exhausted = (self.proxy_evals >= self.proxy_budget
                     or not np.isnan(self._scores).any()
                     or len(self.ask_log) >= self.max_rounds)
        converged = self._stall >= self.patience and feasible >= self.min_keep
        if exhausted or converged:
            self._promote_from_proxy()

    def _promote_from_proxy(self) -> None:
        feas = np.flatnonzero(np.isfinite(self._scores))
        by_score = feas[np.lexsort((feas, self._scores[feas]))]
        keep = min(len(feas), max(self.min_keep, self.prefix_keep))
        self.survivors = [int(i) for i in by_score[:keep]]
        if self.survivors:
            obs_metrics.count("dse_promotions_total", n=len(self.survivors),
                              workload=self.graph.name)
        self.rung_log.append(RungLog(len(self.rung_log), "proxy",
                                     self.proxy_evals,
                                     len(self.survivors), 0))
        if not self.survivors:
            # nothing feasible anywhere the model looked: report the
            # evaluated failures, exactly like an all-failed halving rung
            evaluated = sorted(self._proxy_results)
            self._finalize(evaluated,
                           [self._proxy_results[i] for i in evaluated])
            return
        self.phase = "prefix"

    def _finalize(self, pending: Sequence[int],
                  results: Sequence[SweepResult]) -> None:
        # re-key finalists by their *enumeration* index so objective ties
        # resolve exactly like an exhaustive sweep's would
        for enum_i, r in zip(pending, results):
            r.index = enum_i
        self.results = sorted(results, key=lambda r: r.index)

    def search_result(self) -> AdaptiveResult:
        if not self.done:
            raise RuntimeError("search is not finished")
        return AdaptiveResult(results=list(self.results),
                              rungs=list(self.rung_log),
                              n_points=len(self.points),
                              full_evals=self.full_evals,
                              objective=self.objective,
                              proxy_evals=self.proxy_evals,
                              prefix_evals=self.prefix_evals,
                              ask_rounds=len(self.ask_log),
                              ask_log=list(self.ask_log))


def adaptive_search(graph: Graph,
                    space: Union[DesignSpace, Sequence[DesignPoint]],
                    base_arch: Optional[CIMArch] = None, *,
                    cache: Optional[CompileCache] = None,
                    workers: int = 1,
                    **knobs) -> AdaptiveResult:
    """Run a complete adaptive search over one workload.

    ``knobs`` are ``AdaptiveSearch`` parameters (``seed``, ``batch``,
    ``prefix_keep``, ...).  Deterministic for any ``workers`` count —
    rounds are synchronization points, and the ask sequence depends only
    on the seed and the told scores.
    """
    search = AdaptiveSearch(graph, space, base_arch, **knobs)
    proxy_memo: dict = {}   # proxy results shared across this search's rounds
    while not search.done:
        batch = search.jobs()
        if not batch and search.done:
            break
        search.observe(run_jobs(batch, cache=cache, workers=workers,
                                proxy_memo=proxy_memo))
    return search.search_result()
