"""Logical-axis sharding rules (MaxText-style), with divisibility
fallback so odd dimensions (vocab 50280, 25 SSM heads, batch 1) degrade
to replication instead of erroring.

Train:   FSDP x TP — reduction dims shard on "data", model dims on
         "model"; batch on ("pod","data"); optimizer state follows
         params (ZeRO-3-like memory).
Serve:   params shard on "model" only (fit in HBM without FSDP
         gathers); batch on ("pod","data"); KV-cache sequence on
         "model" (distributed flash-decoding softmax via SPMD partial
         reductions).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

Rules = Dict[str, Optional[Tuple[str, ...]]]


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_rules(cfg: ModelConfig, mesh: Mesh, kind: str) -> Rules:
    """logical axis name -> mesh axes (or None = replicate)."""
    model_size = mesh.shape["model"]
    # experts: expert-parallel when the expert count fills the axis,
    # otherwise tensor-parallel inside each expert
    if cfg.n_experts and cfg.n_experts % model_size == 0:
        expert, mlp_e = ("model",), None
    else:
        expert, mlp_e = None, ("model",)
    rules: Rules = {
        "vocab": ("model",),
        "embed": ("data",) if kind == "train" else None,
        "heads": ("model",),
        "kv": ("model",),
        "mlp": ("model",),
        "inner": ("model",),
        "expert": expert,
        "mlp_e": mlp_e,
        "layers": None,
        None: None,
    }
    return rules


def cache_rules(cfg: ModelConfig, mesh: Mesh, kind: str) -> Rules:
    return {
        "batch": _batch_axes(mesh),
        "kvseq": ("model",),
        "ssm_heads": ("model",),
        "layers": None,
        None: None,
    }


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             mesh: Mesh, rules: Rules) -> P:
    """Build a PartitionSpec, dropping assignments that don't divide."""
    assert len(shape) == len(axes), (shape, axes)
    used = set()
    parts = []
    for dim, ax in zip(shape, axes):
        mesh_axes = rules.get(ax)
        if not mesh_axes:
            parts.append(None)
            continue
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        total = math.prod(mesh.shape[a] for a in mesh_axes) if mesh_axes else 1
        if not mesh_axes or dim % total != 0:
            parts.append(None)
            continue
        used.update(mesh_axes)
        parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(specs_tree: Any, axes_tree: Any, mesh: Mesh,
                   rules: Rules) -> Any:
    """NamedSharding tree matching a ShapeDtypeStruct tree."""
    def build(spec, axes):
        return NamedSharding(mesh, spec_for(tuple(spec.shape), tuple(axes),
                                            mesh, rules))
    return jax.tree.map(build, specs_tree, axes_tree)


def batch_sharding(mesh: Mesh, ndim: int, batch_dim: int = 0) -> NamedSharding:
    parts = [None] * ndim
    ax = _batch_axes(mesh)
    parts[batch_dim] = ax if len(ax) > 1 else ax[0]
    return NamedSharding(mesh, P(*parts))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
