"""Step builders: assemble (fn, input specs, shardings) for every
(architecture x workload-shape) cell — consumed by the dry-run, the
trainer, and the server.

Cells:
  train_*   -> ``train_step``  (loss + grads + AdamW update, remat'd)
  prefill_* -> ``prefill_step`` (prompt -> last logits + decode cache)
  decode_* / long_* -> ``serve_step`` (one new token against the cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..configs.base import ModelConfig, ShapeSpec
from ..models import lm
from ..optim import adamw
from . import sharding as shd

ENC_LEN_CAP = 4096        # encoder context for enc-dec decode shapes


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _enc_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    if shape.kind == "train":
        return shape.seq_len
    return min(shape.seq_len, ENC_LEN_CAP)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        specs = {"tokens": _sds((b, 1), jnp.int32)}
        if cfg.mrope:
            specs["positions3"] = _sds((3, b, 1), jnp.int32)
        return specs
    specs = {"tokens": _sds((b, s), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = _sds((b, s), jnp.int32)
    if cfg.vision_stub:
        specs["vision_embeds"] = _sds((b, cfg.n_vision_tokens, cfg.d_model),
                                      cfg.dtype)
        specs["positions3"] = _sds((3, b, s), jnp.int32)
    if cfg.enc_dec:
        specs["enc_embeds"] = _sds((b, _enc_len(cfg, shape), cfg.d_model),
                                   cfg.dtype)
    return specs


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    specs: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in specs.items():
        bdim = 1 if k == "positions3" else 0
        out[k] = shd.batch_sharding(mesh, len(v.shape), bdim)
        if v.shape[bdim] % _batch_div(mesh) != 0:
            out[k] = shd.replicated(mesh)
    return out


def _batch_div(mesh: Mesh) -> int:
    d = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        d *= mesh.shape["pod"]
    return d


@dataclasses.dataclass
class Cell:
    name: str
    fn: Any                      # jit-able callable
    args: Tuple[Any, ...]        # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.args)


# wider models need deeper accumulation to fit 16 GB HBM (measured on
# the dry-run memory_analysis; see EXPERIMENTS.md §Dry-run)
MICROBATCH_OVERRIDES = {
    "mixtral-8x7b": 16,
    "starcoder2-15b": 16,
    "deepseek-v2-lite-16b": 16,
    "minitron-4b": 16,
}


def default_microbatches(cfg: ModelConfig, shape: ShapeSpec,
                         mesh: Mesh) -> int:
    """Gradient-accumulation depth: keep per-microbatch activation
    footprint bounded.  The global batch divides evenly by construction
    (global batches are powers of two)."""
    local_batch = max(1, shape.global_batch // _batch_div(mesh))
    return min(MICROBATCH_OVERRIDES.get(cfg.name, 8), local_batch)


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               lr: float = 3e-4,
               microbatches: Optional[int] = None,
               perf: Optional["PerfOpts"] = None) -> Cell:
    from ..models.perfopts import PerfOpts, use_perf_opts
    if perf is None:
        perf = PerfOpts()
    perf = dataclasses.replace(
        perf, mesh=mesh,
        batch_axes=("pod", "data") if "pod" in mesh.axis_names
        else ("data",))
    p_specs = lm.param_specs(cfg)
    p_axes = lm.logical_axes(cfg)
    kind = "train" if shape.kind == "train" else "serve"
    p_rules = shd.param_rules(cfg, mesh, "train" if kind == "train" else "serve")
    p_shard = shd.tree_shardings(p_specs, p_axes, mesh, p_rules)
    b_specs = batch_specs(cfg, shape)
    b_shard = batch_shardings(cfg, shape, mesh, b_specs)

    if shape.kind == "train":
        o_specs = adamw.adamw_state_specs(p_specs)
        o_shard = adamw.AdamWState(
            count=shd.replicated(mesh),
            mu=shd.tree_shardings(o_specs.mu, p_axes, mesh, p_rules),
            nu=shd.tree_shardings(o_specs.nu, p_axes, mesh, p_rules))

        mb = microbatches or default_microbatches(cfg, shape, mesh)

        def train_step(params, opt_state, batch):
            ctx = use_perf_opts(perf)
            ctx.__enter__()      # active during tracing of this body
            def micro(batch_i):
                return jax.value_and_grad(
                    lambda p: lm.lm_loss(p, cfg, batch_i))(params)

            if mb > 1:
                # gradient accumulation: scan over microbatches along the
                # batch dim; grads accumulate in fp32 param-sharded buffers
                def split(name, v):
                    if name == "positions3":     # (3, B, S): batch at dim 1
                        return v.reshape(3, mb, v.shape[1] // mb,
                                         *v.shape[2:]).transpose(1, 0, 2, 3)
                    return v.reshape(mb, v.shape[0] // mb, *v.shape[1:])

                mbatch = {k: split(k, v) for k, v in batch.items()}

                def acc_step(carry, batch_i):
                    tot_loss, grads = carry
                    li, gi = micro(batch_i)
                    grads = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), grads, gi)
                    return (tot_loss + li, grads), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    acc_step, (jnp.float32(0.0), zeros), mbatch)
                loss = loss / mb
                grads = jax.tree.map(lambda g: g / mb, grads)
            else:
                loss, grads = micro(batch)

            grads, gnorm = adamw.clip_by_global_norm(grads, 1.0)
            params, opt_state = adamw.adamw_update(grads, opt_state, params,
                                                   lr=lr)
            metrics = {"loss": loss, "grad_norm": gnorm}
            ctx.__exit__(None, None, None)
            return params, opt_state, metrics

        return Cell(
            name=f"{cfg.name}:{shape.name}",
            fn=train_step,
            args=(p_specs, o_specs, b_specs),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard,
                           {"loss": shd.replicated(mesh),
                            "grad_norm": shd.replicated(mesh)}),
            donate_argnums=(0, 1),
        )

    c_rules = shd.cache_rules(cfg, mesh, kind)
    c_specs, c_axes = lm.cache_specs(cfg, shape.global_batch, shape.seq_len,
                                     enc_len=_enc_len(cfg, shape))
    c_shard = shd.tree_shardings(c_specs, c_axes, mesh, c_rules)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            with use_perf_opts(perf):
                return lm.prefill(params, cfg, batch)

        logits_shard = shd.batch_sharding(mesh, 3)
        if shape.global_batch % _batch_div(mesh) != 0:
            logits_shard = shd.replicated(mesh)
        return Cell(
            name=f"{cfg.name}:{shape.name}",
            fn=prefill_step,
            args=(p_specs, b_specs),
            in_shardings=(p_shard, b_shard),
            out_shardings=(logits_shard, c_shard),
        )

    # decode
    def serve_step(params, cache, batch, pos):
        with use_perf_opts(perf):
            return lm.decode_step(params, cfg, cache, batch, pos)

    logits_shard = shd.batch_sharding(mesh, 3)
    if shape.global_batch % _batch_div(mesh) != 0:
        logits_shard = shd.replicated(mesh)
    pos_spec = _sds((), jnp.int32)
    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=serve_step,
        args=(p_specs, c_specs, b_specs, pos_spec),
        in_shardings=(p_shard, c_shard, b_shard, shd.replicated(mesh)),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,),
    )
