"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16x16 = 256 chips ("data",
"model"); multi-pod: 2 pods x 256 = 512 chips ("pod", "data", "model").
The pod axis carries pure data parallelism (params replicated across
pods; gradient all-reduce is the only cross-pod collective — it rides
the data-center interconnect, not ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over the real host devices (tests / smoke runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
