import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e).

``.lower().compile()`` every (architecture x input shape) cell on the
production meshes — 16x16 single-pod and 2x16x16 multi-pod — and record
memory_analysis / cost_analysis / collective bytes for the roofline.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init), which is why it precedes the docstring's
siblings.  Do not set that flag globally: smoke tests and benches run on
the single real CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 cells x 2 meshes
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""
import argparse
import json
import time
import traceback
from pathlib import Path


from ..configs import ARCHS, get_config
from ..configs.base import SHAPES, shapes_for
from .mesh import make_production_mesh
from .steps import build_cell
from ..analysis import roofline


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, optimized: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_chips": int(n_chips), "status": "ok",
           "variant": "optimized" if optimized else "baseline"}
    perf_opts = None
    if optimized:
        from ..models.perfopts import OPTIMIZED
        perf_opts = OPTIMIZED
    t0 = time.time()
    try:
        with mesh:
            cell = build_cell(cfg, shape, mesh, perf=perf_opts)
            lowered = cell.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            if verbose:
                print(f"  memory_analysis: {ma}")
                print(f"  cost_analysis: flops={ca.get('flops')} "
                      f"bytes={ca.get('bytes accessed')}")
            coll = roofline.parse_collectives(compiled.as_text(),
                                              n_partitions=n_chips)
            rec.update(
                lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
                flops=float(ca.get("flops", 0.0)),
                bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                walked_flops=coll["walked_flops"],
                walked_hbm_bytes=coll["walked_hbm_bytes"],
                temp_bytes=int(ma.temp_size_in_bytes),
                arg_bytes=int(ma.argument_size_in_bytes),
                out_bytes=int(ma.output_size_in_bytes),
                collective_bytes=coll["total_bytes"],
                collective_count=coll["count"],
                collectives=coll["by_kind"],
            )
            rec.update(roofline.terms(rec, cfg, shape, n_chips))
    except Exception as e:  # a failing cell is a bug — record and surface
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape) cell")
    ap.add_argument("--opt", action="store_true",
                    help="enable the optimized PerfOpts set (§Perf)")
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, cfg in ARCHS.items():
            for shape in shapes_for(cfg):
                cells.append((arch, shape.name))
    else:
        if not args.arch:
            ap.error("--arch required unless --all")
        cfg = get_config(args.arch)
        shapes = ([SHAPES[args.shape]] if args.shape
                  else shapes_for(cfg))
        cells = [(args.arch, s.name) for s in shapes]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    records = []
    if out_path.exists():
        records = json.loads(out_path.read_text())
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records
            if r.get("status") == "ok"}

    n_fail = 0
    for arch, shape in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            if (arch, shape, mesh_name) in done:
                print(f"[skip] {arch} x {shape} on {mesh_name} (cached)")
                continue
            print(f"[dryrun] {arch} x {shape} on {mesh_name} ...", flush=True)
            rec = run_cell(arch, shape, multi, optimized=args.opt)
            records = [r for r in records
                       if not (r["arch"] == arch and r["shape"] == shape
                               and r["mesh"] == mesh_name)]
            records.append(rec)
            out_path.write_text(json.dumps(records, indent=1))
            status = rec["status"]
            if status != "ok":
                n_fail += 1
                print(f"  FAIL: {rec['error']}")
            else:
                print(f"  ok in {rec['total_s']}s  "
                      f"flops={rec['flops']:.3g} "
                      f"coll={rec['collective_bytes']:.3g}B "
                      f"temp={rec['temp_bytes']/2**30:.2f}GiB/device")
    print(f"\n{len(records)} records in {out_path}; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
