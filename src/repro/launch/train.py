"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
      --reduced --steps 200 --workdir /tmp/run1

``--reduced`` trains the CPU-sized config of the same family (the
end-to-end example path); without it, the full config is launched on
the production mesh (real pod only).
"""
from __future__ import annotations

import argparse


from ..configs import ARCHS, get_config, reduced
from ..configs.base import ShapeSpec
from ..data import TokenStream, make_batch_iterator
from ..launch.mesh import make_host_mesh, make_production_mesh
from ..train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
        mesh = make_host_mesh()
        shape = ShapeSpec("custom", "train", args.seq_len, args.batch)
    else:
        mesh = make_production_mesh()
        from ..configs.base import SHAPES
        shape = SHAPES["train_4k"]

    stream = TokenStream(cfg.vocab, shape.global_batch, shape.seq_len,
                         seed=args.seed)
    extra = {}
    import numpy as np
    if cfg.enc_dec:
        extra["enc_embeds"] = np.ones(
            (shape.global_batch, shape.seq_len, cfg.d_model), np.float32)
    if cfg.vision_stub:
        nv = min(cfg.n_vision_tokens, shape.seq_len)
        extra["vision_embeds"] = np.ones(
            (shape.global_batch, nv, cfg.d_model), np.float32)
        extra["positions3"] = np.broadcast_to(
            np.arange(shape.seq_len, dtype=np.int32)[None, None],
            (3, shape.global_batch, shape.seq_len)).copy()
    data = make_batch_iterator(stream, extra)

    tcfg = TrainerConfig(workdir=args.workdir, num_steps=args.steps,
                         save_every=args.save_every, lr=args.lr)
    trainer = Trainer(cfg, shape, mesh, tcfg, data, data_state=stream.state)
    result = trainer.train(seed=args.seed)
    print("final:", result)


if __name__ == "__main__":
    main()
