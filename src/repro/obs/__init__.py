"""Stack-wide observability: metrics registry, Chrome trace, provenance.

Three pieces, one enablement story:

  * :mod:`repro.obs.metrics` — the process-wide ``MetricsRegistry``
    (counters/gauges/histograms, Prometheus + stable-JSON exposition).
    ``metrics.enable()`` turns accounting on; disabled, every
    instrumented path is one ``is None`` check.
  * :mod:`repro.obs.trace` — the Chrome-trace ``TraceRecorder`` (grown
    out of ``serving.trace``, which re-exports it).  ``trace.install()``
    makes it the process-wide sink the compiler, executor and DSE
    drivers emit spans to, each on its own Perfetto process row; hand
    the same recorder to a fleet's ``trace=`` for one merged timeline.
  * :mod:`repro.obs.explain` — per-node compile provenance
    (``ExplainReport`` / ``explain_compile``; CLI in
    ``tools/explain.py``), fed by the :mod:`repro.obs.hooks` events
    the compiler tiers emit.

See ``docs/OBSERVABILITY.md`` for the operator guide.
"""
from . import hooks, metrics, trace                              # noqa: F401
from .metrics import MetricsRegistry                             # noqa: F401
from .trace import (TraceRecorder, load_trace,                   # noqa: F401
                    validate_chrome_trace)

__all__ = [
    "hooks", "metrics", "trace",
    "MetricsRegistry", "TraceRecorder",
    "load_trace", "validate_chrome_trace",
    "ExplainReport", "explain_compile",
]


def __getattr__(name):
    # ``explain`` imports the compiler (which imports this package), so
    # it loads lazily to keep the package import acyclic and light.
    if name in ("ExplainReport", "explain_compile", "explain"):
        import importlib
        explain = importlib.import_module(".explain", __name__)
        if name == "explain":
            return explain
        return getattr(explain, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
