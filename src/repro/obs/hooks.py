"""Lightweight structured-event hooks for compile provenance.

The compiler tiers (``core.compiler`` / ``core.cg_opt`` /
``core.mapping``) emit small ``(kind, payload)`` events through this
module while they make scheduling decisions — which placement a node
got, how the graph was segmented, whether the compile was served from
cache.  ``obs.explain`` subscribes during a compile to capture
provenance; nothing else in the stack depends on a subscriber being
present.

The design constraint is the emitter's cost when nobody listens: the
compiler's inner loops (``CostModel.placement`` runs once per node per
design point in DSE sweeps) call :func:`emit` unconditionally, so the
disabled path must be one truthiness check on a module-level list —
no allocation, no formatting.  Callers therefore pass cheap payloads
(scalars, short strings) and build anything expensive only when
:func:`subscribed` is true.

Subscribers must not raise: an exception from a hook propagates into
the compile that emitted it (deliberate — silent telemetry loss is
worse during debugging, and subscribers are trusted in-repo code).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List

Subscriber = Callable[[str, Dict[str, Any]], None]

#: live subscribers; module-level so ``emit`` is one truthiness check
#: away from free when telemetry is off
_SUBS: List[Subscriber] = []


def subscribe(fn: Subscriber) -> Callable[[], None]:
    """Register ``fn(kind, payload)``; returns an unsubscribe closure."""
    _SUBS.append(fn)

    def unsubscribe() -> None:
        try:
            _SUBS.remove(fn)
        except ValueError:
            pass
    return unsubscribe


def subscribed() -> bool:
    """True when at least one subscriber is live — emitters gate any
    payload construction that is not free on this."""
    return bool(_SUBS)


def emit(kind: str, **payload: Any) -> None:
    """Deliver one event to every subscriber (no-op when none)."""
    if not _SUBS:
        return
    for fn in list(_SUBS):
        fn(kind, payload)
