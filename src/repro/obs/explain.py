"""Compile provenance — "why does my network run the way it runs".

CIM-MLC's output is a pile of cross-tier decisions: which scheduling
tier each operator was compiled under, how its weight matrix was bound
onto crossbars, how many copies the duplication search paid for, which
schedule segment it landed in, whether the pipeline or the ping-pong
rebuild won, and (under faults) how many lines were retired.  All of
it is recorded on the ``SchedulePlan`` — but scattered over
placements, ``node.sched`` annotations and ``plan.notes``.

``ExplainReport`` flattens one compile into a per-node provenance
table (every graph node gets a row — DCOM nodes show as the digital
tier) plus a metadata header with the plan-level decisions, rendered
as a markdown pipe table (via the DSE :class:`~repro.dse.report.
Scorecard`) or stable JSON.  ``explain_compile`` runs a compile with
the :mod:`repro.obs.hooks` provenance events captured, so the report
also carries what only the compile *driver* knows — wall time, cache
provenance, the ping-pong decision.

``tools/explain.py`` is the CLI over this module.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from . import hooks

__all__ = ["ExplainReport", "explain_compile"]

#: per-node provenance columns, in render order
COLUMNS = ["node", "op", "tier", "segment", "chunks", "dup", "cores",
           "xbs", "grid", "binding", "row_spread", "vxb_slots", "windows"]


@dataclasses.dataclass
class ExplainReport:
    """Per-node compile provenance for one compiled plan."""

    rows: List[Dict[str, Any]]
    meta: Dict[str, Any]
    columns: List[str] = dataclasses.field(
        default_factory=lambda: list(COLUMNS))

    # -- constructors -----------------------------------------------------
    @classmethod
    def from_result(cls, result) -> "ExplainReport":
        """Build from a ``compiler.CompileResult`` (adds the cache key)."""
        report = cls.from_plan(result.plan)
        report.meta["key"] = result.key
        return report

    @classmethod
    def from_plan(cls, plan) -> "ExplainReport":
        """Build from a ``SchedulePlan``: one row per graph node.

        CIM nodes aggregate over their chunks (an operator split across
        segments keeps one row; ``segment`` lists every segment it
        touches); DCOM nodes report the digital tier.
        """
        graph, arch = plan.graph, plan.arch
        by_node: Dict[str, List] = {}
        seg_of: Dict[str, List[int]] = {}
        for si, seg in enumerate(plan.segments):
            for p in seg.placements:
                by_node.setdefault(p.node.name, []).append(p)
                seg_of.setdefault(p.node.name, []).append(si)

        level = plan.notes.get("level")
        level_v = getattr(level, "value", level) or arch.mode.value
        rows: List[Dict[str, Any]] = []
        for node in graph.nodes:
            if node.is_cim:
                pls = by_node.get(node.name, [])
                if not pls:          # defensive: a CIM node must be placed
                    raise ValueError(f"{node.name}: CIM node has no "
                                     f"placement in the plan")
                m = pls[0].mapping
                segs = sorted(set(seg_of[node.name]))
                rows.append({
                    "node": node.name, "op": node.op_type, "tier": level_v,
                    "segment": "+".join(str(s) for s in segs),
                    "chunks": len(pls),
                    "dup": max(p.dup for p in pls),
                    "cores": sum(p.dup * p.cores for p in pls),
                    "xbs": sum(p.dup * p.mapping.n_xbs for p in pls),
                    "grid": f"{m.grid_r}x{m.grid_c}",
                    "binding": m.binding.value,
                    "row_spread": max(p.row_spread for p in pls),
                    "vxb_slots": max(p.vxb_slots for p in pls),
                    "windows": max(p.n_mvm for p in pls),
                })
            else:
                rows.append({
                    "node": node.name, "op": node.op_type, "tier": "digital",
                    "segment": "-", "chunks": 0, "dup": 0, "cores": 0,
                    "xbs": 0, "grid": "-", "binding": "-",
                    "row_spread": 0, "vxb_slots": 0, "windows": 0,
                })

        meta: Dict[str, Any] = {
            "workload": graph.name,
            "arch": arch.name,
            "arch_mode": arch.mode.value,
            "level": level_v,
            "use_pipeline": plan.use_pipeline,
            "use_duplication": plan.use_duplication,
            "ping_pong": bool(plan.notes.get("ping_pong", False)),
            "mvm_pipeline": plan.mvm_pipeline,
            "vvm_remap": plan.vvm_remap,
            "segments": len(plan.segments),
            "nodes": len(graph.nodes),
            "cim_nodes": len(graph.cim_nodes),
            "crossbars_used": sum(p.dup * p.mapping.n_xbs
                                  for p in plan.placements),
        }
        policy = plan.notes.get("policy")
        if policy:
            meta["policy"] = policy
        retired = plan.notes.get("fault_retired")
        if retired:
            meta["fault_retired_rows"] = retired.get("rows", 0)
            meta["fault_retired_cols"] = retired.get("cols", 0)
            meta["fault_retire_attempts"] = retired.get("attempts", 0)
        return cls(rows=rows, meta=meta)

    # -- accessors --------------------------------------------------------
    @property
    def coverage(self) -> float:
        """Fraction of the compiled graph's nodes carrying a row (the
        acceptance bar is 1.0 — every node explained)."""
        nodes = self.meta.get("nodes", 0)
        return len(self.rows) / nodes if nodes else 0.0

    # -- renderings -------------------------------------------------------
    def scorecard(self):
        """The report as a ``dse.report.Scorecard`` (markdown/JSON)."""
        from ..dse.report import Scorecard
        title = (f"explain {self.meta.get('workload', '?')} on "
                 f"{self.meta.get('arch', '?')}")
        return Scorecard(title=title, columns=list(self.columns),
                         rows=self.rows, meta=dict(self.meta))

    def to_markdown(self) -> str:
        return self.scorecard().to_markdown()

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps({"meta": self.meta, "columns": self.columns,
                           "rows": self.rows},
                          sort_keys=True, indent=indent)

    def __str__(self) -> str:
        return self.to_markdown()


def explain_compile(graph, arch, *, fault_model=None,
                    **compile_kwargs) -> ExplainReport:
    """Compile ``graph`` for ``arch`` and return its provenance report.

    Runs the real compiler with the provenance hooks captured, so the
    report's metadata carries the driver-side decisions (wall seconds,
    whether the artifact came from cache) on top of everything the plan
    records.  ``fault_model`` (a ``cimsim.faults.FaultModel``) routes
    through ``fault_aware_compile`` instead, adding the retired-line
    provenance.  Remaining keyword arguments are ``compile_graph``
    knobs (``level=``, ``binding=``, ``use_pipeline=``, ``cache=``...).
    """
    from ..core import compiler

    captured: Dict[str, Any] = {}

    def _capture(kind: str, payload: Dict[str, Any]) -> None:
        if kind == "compile.done":
            captured.update(payload)

    unsubscribe = hooks.subscribe(_capture)
    try:
        if fault_model is not None:
            from ..cimsim.faults import fault_aware_compile
            result = fault_aware_compile(graph, arch, fault_model,
                                         **compile_kwargs).result
        else:
            result = compiler.compile_graph(graph, arch, **compile_kwargs)
    finally:
        unsubscribe()

    report = ExplainReport.from_result(result)
    if "wall_s" in captured:
        report.meta["compile_wall_s"] = round(captured["wall_s"], 6)
    if "cached" in captured:
        report.meta["cache_hit"] = bool(captured["cached"])
    return report
