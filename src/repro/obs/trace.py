"""Chrome-trace recording for every tier — one Perfetto timeline.

``TraceRecorder`` collects Chrome Trace Event Format events (the JSON
consumed by ``chrome://tracing`` and https://ui.perfetto.dev).  It grew
up in the serving tier (fleet batcher waits, engine dispatches,
migrations — ``repro.serving.trace`` still re-exports it from here),
and is now the stack-wide sink: the compiler, the trace-lowered
executor and the DSE drivers emit onto the same recorder under
**reserved track names**, so a DSE campaign, its compiles and the
fleet run they feed land in one timeline with distinct process rows.

Mapping onto the trace model:

  * **process (pid)** = one *track*: a CIM chip for serving events, or
    one of the reserved tracks ``compiler`` / ``executor`` / ``dse``
    for the other tiers (``register_chip`` assigns pids and emits the
    ``process_name`` metadata either way);
  * **thread (tid)**  = one tenant on that chip — or, on the reserved
    tracks, one workload — plus tid 0 for track-level control events;
  * **complete events (``ph: "X"``)** = spans: queue waits, engine
    dispatches, compiles, executor dispatches, DSE rung batches;
  * **instant events (``ph: "i"``)** = points: admission rejections,
    re-plan triggers, searcher rounds;
  * **counter events (``ph: "C"``)** = sampled series (utilization,
    queue depth) — ``args`` values must be numbers;
  * **flow events (``ph: "s"/"t"/"f"``)** = cross-track arrows sharing
    an ``id``: a compile's flow start binds to the executor dispatch
    that first runs the artifact.

Units and clocks: the recorder's timeline is whatever clock the caller
drives — the serving tier passes its service clock (wall time in
production, synthetic in tests); the compiler/executor/DSE hooks use
the process clock started by :func:`install` (``now_s``).  Under a
wall clock all tiers coincide; under a synthetic service clock the
serving rows show the model's own accounting next to the host-side
rows.  Event ``ts``/``dur`` are emitted in **microseconds** as the
format requires.

Thread-safety: a recorder is plain mutable state owned by one thread;
share one recorder across the tiers of one run, not across concurrent
runs.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Optional, Union

#: event phases this layer emits (subset of the trace format):
#: complete, instant, counter, metadata, flow start/step/end
_PHASES = ("X", "i", "C", "M", "s", "t", "f")

#: the flow-event subset (requires an ``id`` binding the arrow's ends)
_FLOW_PHASES = ("s", "t", "f")

#: fields every emitted event carries (the format's required core)
_REQUIRED = ("name", "ph", "ts", "pid", "tid")

#: reserved track (pseudo-chip) names the non-serving tiers emit under
COMPILER_TRACK = "compiler"
EXECUTOR_TRACK = "executor"
DSE_TRACK = "dse"


def _us(t_s: float) -> float:
    """Clock seconds -> trace microseconds (float is allowed)."""
    return round(t_s * 1e6, 3)


class TraceRecorder:
    """Accumulates Chrome-trace events for one run.

    All ``*_s`` arguments are clock seconds (see module docstring);
    ``args`` values must be JSON-serializable.  Not thread-safe — one
    recorder per driving thread.
    """

    def __init__(self):
        self.events: List[dict] = []
        self._pids: Dict[str, int] = {}          # track name -> pid
        self._tids: Dict[tuple, int] = {}        # (pid, tenant) -> tid

    # -- registry --------------------------------------------------------
    def register_chip(self, chip: str) -> int:
        """Assign (or return) the pid for track ``chip``; emits
        process_name metadata on first registration."""
        if chip not in self._pids:
            pid = len(self._pids) + 1
            self._pids[chip] = pid
            label = chip if chip in (COMPILER_TRACK, EXECUTOR_TRACK,
                                     DSE_TRACK) else f"chip:{chip}"
            self.events.append({"name": "process_name", "ph": "M",
                                "ts": 0, "pid": pid, "tid": 0,
                                "args": {"name": label}})
        return self._pids[chip]

    def register_tenant(self, chip: str, tenant: str) -> int:
        """Assign (or return) the tid for ``tenant`` on ``chip``; emits
        thread_name metadata on first registration (tid 0 is reserved
        for track-level control events)."""
        pid = self.register_chip(chip)
        key = (pid, tenant)
        if key not in self._tids:
            tid = 1 + sum(1 for (p, _) in self._tids if p == pid)
            self._tids[key] = tid
            self.events.append({"name": "thread_name", "ph": "M",
                                "ts": 0, "pid": pid, "tid": tid,
                                "args": {"name": f"tenant:{tenant}"}})
        return self._tids[key]

    # -- emitters --------------------------------------------------------
    def complete(self, chip: str, tenant: str, name: str, cat: str,
                 ts_s: float, dur_s: float, **args) -> None:
        """One span (``ph: "X"``): starts at ``ts_s``, lasts ``dur_s``
        (clock seconds; negative durations are clamped to 0)."""
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": _us(ts_s), "dur": _us(max(0.0, dur_s)),
            "pid": self.register_chip(chip),
            "tid": self.register_tenant(chip, tenant),
            "args": args})

    def instant(self, chip: str, name: str, cat: str, ts_s: float,
                tenant: Optional[str] = None, **args) -> None:
        """One point event (``ph: "i"``, thread scope); track-level when
        ``tenant`` is None (tid 0)."""
        tid = (self.register_tenant(chip, tenant) if tenant is not None
               else (self.register_chip(chip), 0)[1])
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": _us(ts_s), "pid": self.register_chip(chip),
            "tid": tid, "args": args})

    def counter(self, chip: str, name: str, ts_s: float,
                values: Dict[str, float]) -> None:
        """One counter sample (``ph: "C"``): ``values`` maps series name
        to a number (e.g. ``{"utilization": 0.73}``)."""
        self.events.append({
            "name": name, "cat": "counter", "ph": "C",
            "ts": _us(ts_s), "pid": self.register_chip(chip),
            "tid": 0, "args": dict(values)})

    def flow(self, phase: str, chip: str, tenant: str, name: str,
             cat: str, ts_s: float, flow_id: int, **args) -> None:
        """One flow event (``ph: "s"/"t"/"f"``) — the cross-track arrow
        primitive.  All ends sharing ``flow_id`` are drawn as one flow;
        the end event binds to its enclosing slice (``bp: "e"``)."""
        if phase not in _FLOW_PHASES:
            raise ValueError(f"flow phase must be one of {_FLOW_PHASES}, "
                             f"got {phase!r}")
        ev = {"name": name, "cat": cat, "ph": phase,
              "ts": _us(ts_s), "pid": self.register_chip(chip),
              "tid": self.register_tenant(chip, tenant),
              "id": int(flow_id), "args": args}
        if phase == "f":
            ev["bp"] = "e"
        self.events.append(ev)

    def flow_start(self, chip: str, tenant: str, name: str, cat: str,
                   ts_s: float, flow_id: int, **args) -> None:
        self.flow("s", chip, tenant, name, cat, ts_s, flow_id, **args)

    def flow_end(self, chip: str, tenant: str, name: str, cat: str,
                 ts_s: float, flow_id: int, **args) -> None:
        self.flow("f", chip, tenant, name, cat, ts_s, flow_id, **args)

    # -- output ----------------------------------------------------------
    def to_dict(self) -> dict:
        """The JSON-object trace (``traceEvents`` array form) — the shape
        both ``chrome://tracing`` and Perfetto load directly."""
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: Union[str, Path]) -> Path:
        """Write the trace as JSON **atomically** (write-temp-then-rename,
        same directory so the rename never crosses filesystems): a
        killed benchmark leaves either the previous trace or the new
        one, never a truncated file Perfetto rejects.  Returns the
        path; load it in https://ui.perfetto.dev ("Open trace file") or
        chrome://tracing."""
        path = Path(path)
        data = (json.dumps(self.to_dict()) + "\n").encode("utf-8")
        fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                                   prefix=path.name + ".", suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    def __len__(self) -> int:
        return len(self.events)


def validate_chrome_trace(trace: dict) -> None:
    """Validate ``trace`` against the Chrome Trace Event Format subset
    this layer emits; raises ``ValueError`` with the first violation.

    Checks the JSON-object form (``traceEvents`` array), per-event
    required fields, known phases, numeric non-negative timestamps,
    ``dur`` on complete events, counter ``args`` being non-empty
    number-valued objects, flow events carrying an ``id``, and ``args``
    being JSON objects — the properties Perfetto's importer actually
    relies on.
    """
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a JSON object with 'traceEvents'")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be an array")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        for field in _REQUIRED:
            if field not in ev:
                raise ValueError(f"event {i}: missing field {field!r}")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"event {i}: unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i}: bad ts {ev['ts']!r}")
        for field in ("pid", "tid"):
            if not isinstance(ev[field], int):
                raise ValueError(f"event {i}: {field} must be an int")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i}: complete event needs dur >= 0")
        if ev["ph"] == "C":
            args = ev.get("args")
            if not args or not isinstance(args, dict):
                raise ValueError(f"event {i}: counter event needs args "
                                 f"values")
            for k, v in args.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    raise ValueError(
                        f"event {i}: counter series {k!r} must be a "
                        f"number, got {v!r}")
        if ev["ph"] in _FLOW_PHASES:
            if not isinstance(ev.get("id"), (int, str)):
                raise ValueError(f"event {i}: flow event needs an 'id'")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i}: args must be an object")
    # one timeline: metadata aside, events must carry registered pids
    pids = {ev["pid"] for ev in events if ev["ph"] == "M"}
    for i, ev in enumerate(events):
        if ev["ph"] != "M" and pids and ev["pid"] not in pids:
            raise ValueError(f"event {i}: pid {ev['pid']} never registered")


def load_trace(path: Union[str, Path]) -> dict:
    """Read a trace JSON file and validate it; returns the trace dict."""
    trace = json.loads(Path(path).read_text(encoding="utf-8"))
    validate_chrome_trace(trace)
    return trace


# ---------------------------------------------------------------------------
# Process-wide recorder (the compiler/executor/DSE hook sink)
# ---------------------------------------------------------------------------

_TRACE: Optional[TraceRecorder] = None
_T0: float = 0.0


def install(recorder: Optional[TraceRecorder] = None) -> TraceRecorder:
    """Install ``recorder`` (or a fresh one) as the process-wide sink
    the compiler/executor/DSE hooks emit to, and start the process
    clock ``now_s`` runs on; returns the installed recorder.  Pass the
    same recorder to a fleet/cluster's ``trace=`` to merge serving
    events into the identical timeline."""
    global _TRACE, _T0
    _TRACE = recorder if recorder is not None else TraceRecorder()
    _T0 = time.perf_counter()
    return _TRACE


def uninstall() -> Optional[TraceRecorder]:
    """Remove the process-wide recorder (tracing off); returns it."""
    global _TRACE
    prev, _TRACE = _TRACE, None
    return prev


def get_trace() -> Optional[TraceRecorder]:
    """The installed recorder, or ``None`` when tracing is disabled —
    hot paths gate all emission on this single check."""
    return _TRACE


def now_s() -> float:
    """Seconds on the process clock started by :func:`install`."""
    return time.perf_counter() - _T0
