"""Process-wide metrics registry — the stack's one place for counters.

Every tier of the stack keeps numbers today: ``CompileCache.stats()``,
the executor's ``ExecutorStats``, the serving ``ServiceStats``, the DSE
searchers' round logs.  They are all *pull* bundles with their own
shapes, so "how many compiles did this campaign pay, how many cache
hits did the fleet get, how many kernel dispatches ran" is N different
accessors.  ``MetricsRegistry`` is the *push* side that unifies them:

  * three instrument kinds — :class:`Counter` (monotone),
    :class:`Gauge` (set-to-current), :class:`Histogram` (bucketed
    observations with sum/count) — each identified by a metric name
    plus a sorted label set, Prometheus-style;
  * **deterministic snapshots**: ``snapshot()`` / ``flat()`` sort by
    (name, labels) so two runs with the same event sequence serialize
    byte-identically — committed benchmark JSON can diff them;
  * two expositions: ``to_prometheus()`` (the text format scrapers
    ingest) and ``to_json()`` (stable, sorted keys);
  * ``absorb()`` pulls any of today's scattered stats dicts
    (``CompileCache.stats()``, ``dataclasses.asdict(ExecutorStats)``,
    a ``ServiceStats`` summary) into gauges under one prefix, so
    legacy bundles surface through the same exposition.

Enablement contract: telemetry is **off by default** — ``active()``
returns ``None`` and every instrumented hot path (executor dispatch,
cache lookups, compile driver) reduces to one ``is None`` check, so
disabled runs are bit-identical and effectively free.  ``enable()``
installs a process-wide registry (optionally your own instance);
``disable()`` removes it and returns it for inspection.  The module
helpers ``count`` / ``set_gauge`` / ``observe`` are the no-op-when-
disabled entry points call sites use.

Thread-safety: like the serving stats bundles, a registry is plain
mutable state owned by one driving thread; counters are not atomic
across threads.  Process pools (DSE sweep workers) do not share the
parent's registry — absorb their returned stats instead.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple, Union

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "enable", "disable", "active",
    "count", "set_gauge", "observe",
]

#: default histogram bucket upper bounds (seconds-flavoured: the stack's
#: histograms time dispatches and packs; callers pass their own bounds
#: for anything else)
DEFAULT_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)

LabelValue = Union[str, int, float, bool]


def _label_key(labels: Mapping[str, LabelValue]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _series_name(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


@dataclasses.dataclass
class Counter:
    """Monotone event count for one labeled series."""

    name: str
    labels: Tuple[Tuple[str, str], ...] = ()
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({n})")
        self.value += n


@dataclasses.dataclass
class Gauge:
    """Set-to-current value for one labeled series."""

    name: str
    labels: Tuple[Tuple[str, str], ...] = ()
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


@dataclasses.dataclass
class Histogram:
    """Bucketed observations (cumulative buckets + sum + count)."""

    name: str
    labels: Tuple[Tuple[str, str], ...] = ()
    bounds: Tuple[float, ...] = DEFAULT_BUCKETS
    counts: List[int] = dataclasses.field(default_factory=list)
    sum: float = 0.0
    count: int = 0

    def __post_init__(self):
        self.bounds = tuple(sorted(float(b) for b in self.bounds))
        if not self.counts:
            self.counts = [0] * (len(self.bounds) + 1)   # + the +Inf bucket

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> Dict[str, int]:
        """``{le: cumulative count}`` including the ``+Inf`` bucket —
        the Prometheus histogram shape."""
        out: Dict[str, int] = {}
        running = 0
        for b, c in zip(self.bounds, self.counts):
            running += c
            out[repr(b)] = running
        out["+Inf"] = self.count
        return out


class MetricsRegistry:
    """Deterministic counter/gauge/histogram store for one process."""

    def __init__(self):
        self._counters: Dict[Tuple, Counter] = {}
        self._gauges: Dict[Tuple, Gauge] = {}
        self._histograms: Dict[Tuple, Histogram] = {}

    # -- instruments -----------------------------------------------------
    def counter(self, name: str, **labels: LabelValue) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter(name, key[1])
        return c

    def gauge(self, name: str, **labels: LabelValue) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge(name, key[1])
        return g

    def histogram(self, name: str,
                  bounds: Iterable[float] = DEFAULT_BUCKETS,
                  **labels: LabelValue) -> Histogram:
        key = (name, _label_key(labels))
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(name, key[1],
                                                  tuple(bounds))
        return h

    # -- absorption of legacy stat bundles -------------------------------
    def absorb(self, prefix: str, stats: Mapping[str, Any],
               **labels: LabelValue) -> None:
        """Mirror the numeric entries of a legacy stats mapping
        (``CompileCache.stats()``, ``dataclasses.asdict`` of
        ``ExecutorStats``/``ServiceStats``) as ``<prefix>_<key>``
        gauges, so pull-style bundles ride the same exposition.
        Non-numeric values are skipped; booleans become 0/1."""
        for k in sorted(stats):
            v = stats[k]
            if isinstance(v, bool):
                v = int(v)
            if isinstance(v, (int, float)):
                self.gauge(f"{prefix}_{k}", **labels).set(float(v))

    # -- snapshots --------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Deterministic nested snapshot (sorted by name, then labels)."""
        def series(d):
            return {_series_name(m.name, m.labels): m.value
                    for _, m in sorted(d.items())}
        hists = {}
        for _, h in sorted(self._histograms.items()):
            hists[_series_name(h.name, h.labels)] = {
                "buckets": h.cumulative(), "sum": h.sum, "count": h.count}
        return {"counters": series(self._counters),
                "gauges": series(self._gauges),
                "histograms": hists}

    def flat(self, prefix: Union[str, Tuple[str, ...], None] = None
             ) -> Dict[str, float]:
        """Counters and gauges as one sorted ``{series: value}`` map,
        optionally filtered to metric-name ``prefix`` (str or tuple)."""
        out: Dict[str, float] = {}
        for store in (self._counters, self._gauges):
            for _, m in sorted(store.items()):
                if prefix is not None and not m.name.startswith(prefix):
                    continue
                out[_series_name(m.name, m.labels)] = m.value
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, indent=indent)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (``# TYPE`` per metric family)."""
        lines: List[str] = []

        def fmt(v: float) -> str:
            return str(int(v)) if float(v) == int(v) else repr(float(v))

        for kind, store in (("counter", self._counters),
                            ("gauge", self._gauges)):
            seen: set = set()
            for _, m in sorted(store.items()):
                if m.name not in seen:
                    seen.add(m.name)
                    lines.append(f"# TYPE {m.name} {kind}")
                lines.append(f"{_series_name(m.name, m.labels)} "
                             f"{fmt(m.value)}")
        seen = set()
        for _, h in sorted(self._histograms.items()):
            if h.name not in seen:
                seen.add(h.name)
                lines.append(f"# TYPE {h.name} histogram")
            for le, c in h.cumulative().items():
                labels = h.labels + (("le", le),)
                lines.append(f"{_series_name(h.name + '_bucket', labels)} "
                             f"{c}")
            lines.append(f"{_series_name(h.name + '_sum', h.labels)} "
                         f"{fmt(h.sum)}")
            lines.append(f"{_series_name(h.name + '_count', h.labels)} "
                         f"{h.count}")
        return "\n".join(lines) + "\n"

    def __len__(self) -> int:
        return (len(self._counters) + len(self._gauges)
                + len(self._histograms))


# ---------------------------------------------------------------------------
# Process-wide enablement
# ---------------------------------------------------------------------------

_REGISTRY: Optional[MetricsRegistry] = None


def enable(registry: Optional[MetricsRegistry] = None) -> MetricsRegistry:
    """Install ``registry`` (or a fresh one) process-wide; returns it."""
    global _REGISTRY
    _REGISTRY = registry if registry is not None else MetricsRegistry()
    return _REGISTRY


def disable() -> Optional[MetricsRegistry]:
    """Remove the installed registry (telemetry off); returns it."""
    global _REGISTRY
    prev, _REGISTRY = _REGISTRY, None
    return prev


def active() -> Optional[MetricsRegistry]:
    """The installed registry, or ``None`` when telemetry is disabled —
    hot paths gate all accounting on this single check."""
    return _REGISTRY


def count(name: str, n: float = 1.0, **labels: LabelValue) -> None:
    """Increment a counter on the installed registry (no-op if none)."""
    if _REGISTRY is not None:
        _REGISTRY.counter(name, **labels).inc(n)


def set_gauge(name: str, v: float, **labels: LabelValue) -> None:
    """Set a gauge on the installed registry (no-op if none)."""
    if _REGISTRY is not None:
        _REGISTRY.gauge(name, **labels).set(v)


def observe(name: str, v: float, **labels: LabelValue) -> None:
    """Observe into a histogram on the installed registry (no-op)."""
    if _REGISTRY is not None:
        _REGISTRY.histogram(name, **labels).observe(v)
