from .checkpoint import (save_checkpoint, restore_checkpoint,  # noqa
                         restore_resharded, latest_checkpoint,
                         CheckpointManager)
