"""Sharding-aware checkpointing with atomic writes and elastic restore.

Layout (one directory per step):

    <dir>/step_000042.tmp-*/       # staged, then atomically renamed to:
    <dir>/step_000042/
        manifest.json              # tree structure, shapes, dtypes, extra
        arrays_p0.npz              # this process's addressable leaf data

Properties required at scale and honored here:
  * atomic publish (tmp dir + rename) — a crashed writer never leaves a
    half-checkpoint that restore would pick up;
  * per-process shard files (``_p{process_index}``) — on a multi-host pod
    every host writes only its addressable shards;
  * restore is *elastic*: arrays are saved unsharded-logical (single
    process: full value; manifest records logical shapes), and
    ``restore_resharded`` re-lays them onto any new mesh/sharding — a
    restart may use a different device count;
  * data-iterator state and arbitrary metadata ride in the manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = {f"leaf_{i:05d}": np.asarray(x) for i, x in enumerate(leaves)}
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Atomically write a checkpoint; returns the final path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    staging = Path(tempfile.mkdtemp(prefix=final.name + ".tmp-",
                                    dir=directory))
    try:
        flat, treedef = _flatten(tree)
        pidx = jax.process_index()
        np.savez(staging / f"arrays_p{pidx}.npz", **flat)
        manifest = {
            "step": int(step),
            "time": time.time(),
            "n_leaves": len(flat),
            "process_count": jax.process_count(),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "extra": extra or {},
        }
        (staging / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(staging, final)
        return str(final)
    except BaseException:
        shutil.rmtree(staging, ignore_errors=True)
        raise


def latest_checkpoint(directory: str) -> Optional[str]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = sorted(p for p in d.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and ".tmp-" not in p.name
                   and (p / "manifest.json").exists())
    return str(steps[-1]) if steps else None


def _load_flat(path: Path) -> Tuple[Dict[str, np.ndarray], Dict]:
    manifest = json.loads((path / "manifest.json").read_text())
    dtypes = manifest.get("dtypes", {})
    flat: Dict[str, np.ndarray] = {}
    for f in sorted(path.glob("arrays_p*.npz")):
        with np.load(f) as z:
            for k in z.files:
                arr = z[k]
                want = dtypes.get(k)
                if want and str(arr.dtype) != want:
                    # npz stores ml_dtypes (bfloat16 etc.) as raw void —
                    # reinterpret with the manifest dtype
                    arr = arr.view(np.dtype(want)) if arr.dtype.kind == "V" \
                        else arr.astype(np.dtype(want))
                flat[k] = arr
    return flat, manifest


def restore_checkpoint(path: str, like: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a matching pytree)."""
    flat, manifest = _load_flat(Path(path))
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == manifest["n_leaves"], \
        (len(leaves), manifest["n_leaves"])
    vals = [flat[f"leaf_{i:05d}"] for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, vals), manifest["extra"]


def restore_resharded(path: str, like: Any, shardings: Any
                      ) -> Tuple[Any, Dict]:
    """Elastic restore: place each leaf with the given shardings (which
    may target a different mesh/device count than the writer used)."""
    tree, extra = restore_checkpoint(path, like)
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
    return placed, extra


class CheckpointManager:
    """save-every-N with retention, resumable via latest()."""

    def __init__(self, directory: str, save_every: int = 100,
                 keep: int = 3):
        self.directory = Path(directory)
        self.save_every = save_every
        self.keep = keep

    def maybe_save(self, step: int, tree: Any,
                   extra: Optional[Dict] = None) -> Optional[str]:
        if step % self.save_every:
            return None
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def _gc(self) -> None:
        steps = sorted(p for p in self.directory.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and ".tmp-" not in p.name)
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    def latest(self) -> Optional[str]:
        return latest_checkpoint(self.directory)
