"""CG-grained optimization (§3.3.2, Figure 9).

Operates on the computation graph under the chip-tier abstraction:

  * **operator duplication** — a dynamic-programming / dual search for the
    per-operator duplication count under the ``core_number`` budget
    (Figure 9(b): "use dynamic programming to search for all operators'
    duplication numbers under the core_number constraint");
  * **inter-operator pipeline** — adjacent operators stream tiles;
  * **dynamic balancing** — duplication numbers adjusted so adjacent
    stages' compute/data rates match (avoiding pipeline stalls), under
    ``core_noc_cost`` / ``L0 BW`` / ``ALU`` constraints;
  * **resource-adaptive graph segmentation** — when CIM capacity cannot
    hold the whole DNN, maximal subgraphs are constructed iteratively and
    boundaries refined by popping trailing nodes while latency improves.

The pass attaches its results to ``node.sched`` (the paper annotates the
ONNX nodes) and returns a ``SchedulePlan`` consumed by the finer-grained
passes and by the performance simulator.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from ..obs import hooks as obs_hooks
from .abstraction import CIMArch, ComputingMode
from .graph import Graph, Node, n_mvm, out_elems, weight_matrix_shape
from .mapping import (BitBinding, VXBMapping, bind, cores_per_copy,
                      logical_cols_per_xb, vxb_span_error)


# ---------------------------------------------------------------------------
# Placement records
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpPlacement:
    """One CIM operator's (possibly column-tiled chunk's) placement."""

    node: Node
    chunk: int                   # chunk id when an op is split across segments
    n_chunks: int
    mapping: VXBMapping
    n_mvm: int                   # MVMs (windows) this chunk must execute
    cores: int                   # cores per copy
    dup: int = 1                 # duplication count (copies)
    phases: int = 1              # DAC input-bit phases per activation
    row_groups: int = 1          # serial parallel-row groups per activation
    t_load: float = 0.0          # cycles to stream one MVM input
    alu_epilogue: float = 0.0    # ALU cycles per window (fused successors)
    # filled by finer passes:
    vxb_slots: int = 0           # MVM-grained: VXB slots backing this op
    row_spread: int = 1          # VVM-grained: parallel-row remap factor

    @property
    def t_mvm(self) -> float:
        """Cycles per crossbar-set activation after VVM row-spreading."""
        return self.phases * math.ceil(self.row_groups / self.row_spread)

    @property
    def t_window(self) -> float:
        """Steady-state cycles between consecutive windows of one copy."""
        return max(self.t_mvm, self.t_load, self.alu_epilogue)

    @property
    def stage_cycles(self) -> float:
        """Total cycles for this op chunk at its current duplication."""
        return math.ceil(self.n_mvm / self.dup) * self.t_window

    @property
    def n_xbs_total(self) -> int:
        return self.dup * self.mapping.n_xbs


@dataclasses.dataclass
class Segment:
    placements: List[OpPlacement]
    rewrite_cycles: float = 0.0  # weight (re)programming before this segment

    @property
    def cores_used(self) -> int:
        return sum(p.dup * p.cores for p in self.placements)


@dataclasses.dataclass
class SchedulePlan:
    graph: Graph
    arch: CIMArch
    segments: List[Segment]
    use_pipeline: bool = True
    use_duplication: bool = True
    mvm_pipeline: bool = False   # set by mvm_opt (staggered activation)
    vvm_remap: bool = False      # set by vvm_opt (row remapping)
    notes: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def placements(self) -> List[OpPlacement]:
        return [p for s in self.segments for p in s.placements]


# ---------------------------------------------------------------------------
# Cost model shared by the passes
# ---------------------------------------------------------------------------

class CostModel:
    """Analytic per-operator costs under a CIMArch (cycles)."""

    def __init__(self, arch: CIMArch, binding: BitBinding = BitBinding.B_TO_XBC):
        self.arch = arch
        self.binding = binding

    def placement(self, node: Node, graph: Graph, chunk: int = 0,
                  n_chunks: int = 1,
                  sub_rc: Optional[Tuple[int, int]] = None) -> OpPlacement:
        r, c = weight_matrix_shape(node)
        if sub_rc is not None:
            r, c = sub_rc
        mapping = bind((r, c), self.arch, self.binding)
        windows = n_mvm(node, graph.shapes)
        xb = self.arch.xb
        phases = xb.input_phases(self.arch.act_bits)
        if self.arch.mode == ComputingMode.WLM:
            groups = xb.row_groups(min(r, xb.rows))
        else:
            groups = xb.row_groups(xb.rows)
        in_bits = r * self.arch.act_bits
        l1 = self.arch.core.l1_bw_bits
        t_load = in_bits / l1 if math.isfinite(l1) else 0.0
        p = OpPlacement(
            node=node, chunk=chunk, n_chunks=n_chunks, mapping=mapping,
            n_mvm=windows, cores=cores_per_copy(self.arch, mapping),
            phases=phases, row_groups=groups, t_load=t_load,
            alu_epilogue=self._epilogue(node, graph, windows),
        )
        # provenance event, gated at the call site: this method runs once
        # per node per design point inside DSE sweeps, so even the
        # payload-dict construction must be skipped when nobody listens
        if obs_hooks.subscribed():
            obs_hooks.emit("mapping.place", node=node.name, chunk=chunk,
                           n_chunks=n_chunks,
                           grid=f"{mapping.grid_r}x{mapping.grid_c}",
                           xbs=mapping.n_xbs, cores=p.cores,
                           windows=windows)
        return p

    def _epilogue(self, node: Node, graph: Graph, windows: int) -> float:
        """ALU cycles per window for directly-fused successor DCOM ops.

        §3.3.2: "Once the CIM-unsupported node, like Relu, follows the
        operator, we will also update the duplication number under the
        constraint of ALU" — we charge the ALU work to the producing CIM
        stage so duplication past the ALU rate is not rewarded.
        """
        alu = self.arch.chip.alu_ops_per_cycle
        if not math.isfinite(alu):
            return 0.0
        cyc = 0.0
        for elems in fused_epilogue_elems(node, graph):
            cyc += elems / alu
        return cyc / max(windows, 1)

    def alu_cycles(self, node: Node, graph: Graph) -> float:
        """Standalone cost of a CIM-unsupported operator on the chip ALU."""
        from .graph import macs
        alu = self.arch.chip.alu_ops_per_cycle
        if not math.isfinite(alu):
            return 0.0
        return macs(node, graph.shapes) / alu

    def weight_xbs(self, node: Node) -> int:
        return bind(node, self.arch, self.binding).n_xbs


def fused_epilogue_elems(node: Node, graph: Graph) -> List[int]:
    """Output element counts of the DCOM successors fused into ``node``'s
    CIM stage, in graph order.

    This is the single source of the §3.3.2 fusion rule (which successor
    ops ride the producing stage's ALU budget): ``CostModel._epilogue``
    sums ``elems / alu`` over it, and the batched proxy (dse.proxy_vec)
    bakes the same ordered counts into its per-graph node tensor so the
    two paths can never disagree on what is fused.
    """
    return [out_elems(succ, graph.shapes) for succ in graph.successors(node)
            if not succ.is_cim and succ.op_type not in ("Flatten", "Reshape",
                                                        "Identity")]


# ---------------------------------------------------------------------------
# Duplication search
# ---------------------------------------------------------------------------

def _copy_cost(p: OpPlacement, unit: str) -> int:
    """Resource cost of one copy: whole cores (CM granularity) or
    crossbar slots (XBM granularity — Eq. (1) packing)."""
    return p.cores if unit == "cores" else p.mapping.n_xbs


def _feasible_bottleneck(placements: List[OpPlacement], budget: int,
                         target: float, unit: str) -> Optional[List[int]]:
    """Duplications achieving stage_cycles <= target within the budget."""
    dups = []
    total = 0
    for p in placements:
        work = p.n_mvm * p.t_window
        d = max(1, math.ceil(work / max(target, 1e-9)))
        d = min(d, p.n_mvm)  # no point duplicating past one window per copy
        if math.ceil(p.n_mvm / d) * p.t_window > target:
            return None
        dups.append(d)
        total += d * _copy_cost(p, unit)
        if total > budget:
            return None
    return dups


def balance_duplication(placements: List[OpPlacement], budget: int,
                        unit: str = "cores") -> None:
    """Min-bottleneck duplication under the resource budget (pipelined
    objective).

    Lagrangian-dual binary search over the bottleneck latency T: each op
    needs ceil(work/T) copies; feasibility is monotone in T, so the search
    is exact for the bottleneck objective (equivalent to the paper's DP on
    this objective, but O(n log W)).  Leftover resources then go greedily
    to the slowest stages (the paper's "intra-segment dynamic balancing").
    """
    base = sum(_copy_cost(p, unit) for p in placements)
    if base > budget:
        for p in placements:
            p.dup = 1
        return
    lo, hi = 0.0, max(p.n_mvm * p.t_window for p in placements)
    best = [1] * len(placements)
    for _ in range(60):
        mid = (lo + hi) / 2
        cand = _feasible_bottleneck(placements, budget, mid, unit)
        if cand is not None:
            best, hi = cand, mid
        else:
            lo = mid
    for p, d in zip(placements, best):
        p.dup = d
    _spend_leftover(placements, budget, unit)


def greedy_duplication(placements: List[OpPlacement], budget: int,
                       unit: str = "cores") -> None:
    """Min-sum duplication (non-pipelined objective): greedy marginal gain.

    Optimal for the convex per-op cost work/d; this is the 'CG-Duplication'
    ablation arm and also the Poly-Schedule-style baseline policy.
    """
    import heapq
    for p in placements:
        p.dup = 1
    used = sum(_copy_cost(p, unit) for p in placements)
    if used > budget:
        return

    def gain(p: OpPlacement) -> float:
        cur = math.ceil(p.n_mvm / p.dup) * p.t_window
        nxt = math.ceil(p.n_mvm / (p.dup + 1)) * p.t_window
        return (cur - nxt) / _copy_cost(p, unit)

    heap = [(-gain(p), i) for i, p in enumerate(placements)]
    heapq.heapify(heap)
    while heap:
        g, i = heapq.heappop(heap)
        p = placements[i]
        if -g <= 0 or used + _copy_cost(p, unit) > budget or p.dup >= p.n_mvm:
            continue
        p.dup += 1
        used += _copy_cost(p, unit)
        heapq.heappush(heap, (-gain(p), i))


def _spend_leftover(placements: List[OpPlacement], budget: int,
                    unit: str) -> None:
    import heapq
    used = sum(p.dup * _copy_cost(p, unit) for p in placements)
    heap = [(-p.stage_cycles, i) for i, p in enumerate(placements)]
    heapq.heapify(heap)
    guard = 0
    while heap and guard < 100000:
        guard += 1
        neg, i = heapq.heappop(heap)
        p = placements[i]
        if p.dup >= p.n_mvm or used + _copy_cost(p, unit) > budget:
            continue
        p.dup += 1
        used += _copy_cost(p, unit)
        heapq.heappush(heap, (-p.stage_cycles, i))
        if all(used + _copy_cost(q, unit) > budget or q.dup >= q.n_mvm
               for q in placements):
            break


# ---------------------------------------------------------------------------
# Segment latency estimate (used during segmentation search)
# ---------------------------------------------------------------------------

def estimate_segment_cycles(placements: List[OpPlacement],
                            use_pipeline: bool) -> float:
    if not placements:
        return 0.0
    if use_pipeline:
        fill = sum(p.t_window for p in placements)
        return fill + max(p.stage_cycles for p in placements)
    return sum(p.stage_cycles for p in placements)


# ---------------------------------------------------------------------------
# Array-shaped twins of the duplication searches.
#
# The batched proxy cost model (dse.proxy_vec) evaluates the analytic
# rung for a whole array of design points at once: every search below
# operates on (n_points, n_nodes) tensors and is bit-exact against its
# scalar namesake above — same bisection trajectory, same heap pop order
# (ties resolve to the lowest node index, exactly like heapq on a
# ``(-key, index)`` tuple), same floating-point operation order.  The
# scalar implementations stay the oracle; tests/test_proxy_vec.py anchors
# the equivalence point by point.
# ---------------------------------------------------------------------------

def seq_sum(a):
    """Left-to-right float sum along the node axis — the same operation
    order as Python's ``sum()`` over a placement list, so pipelined fill
    and stage totals match the scalar estimate bit for bit."""
    import numpy as np
    out = np.zeros(a.shape[0], dtype=np.float64)
    for j in range(a.shape[1]):
        out = out + a[:, j]
    return out


def _unique_search_rows(arrays):
    """(unique_index, inverse) over the rows of the stacked ``arrays``.

    The duplication searches are pure functions of their per-point rows,
    and large cross-product spaces repeat rows heavily (e.g. XBM and WLM
    points of one arch variant pose the *same* search problem), so each
    distinct row is searched once and the result broadcast back.
    Bitwise row identity (a void view over the packed bytes) is used, so
    merged rows are exactly-equal inputs — a pure deduplication, never
    an approximation."""
    import numpy as np
    key = np.ascontiguousarray(np.concatenate(
        [np.asarray(a, dtype=np.float64).reshape(a.shape[0], -1)
         for a in arrays], axis=1))
    view = key.view([("", np.void, key.shape[1] * 8)]).ravel()
    _, first, inverse = np.unique(view, return_index=True,
                                  return_inverse=True)
    return first, inverse


def _spend_leftover_arr(dup, n_mvm, t_window, cost, budget):
    """Vectorized ``_spend_leftover``: per point, repeatedly give one more
    copy to the placement with the largest current ``stage_cycles``.
    Dense form — every row of the ``(rows, nodes)`` arrays is active.

    Mirrors the heap semantics exactly: a popped placement that cannot
    take another copy is discarded for good (both ineligibility
    conditions are monotone — ``used`` never decreases, ``dup`` never
    decreases — so the discard loses nothing), and ties select the
    lowest node index.  Two pure-performance accelerations keep the
    sequential character out of the hot path without changing a single
    pop outcome:

      * **run-length batching** — while the selected placement's heap
        key ``(-stage, index)`` stays the smallest, the scalar heap
        would keep popping it; the whole run is applied in one step.
        Against the runner-up key ``(-s2, j2)`` that means popping while
        ``stage > s2``, or while ``stage >= s2`` when ``index < j2``
        (ties go to the lower index).  The run length comes from
        inverting the stage step function and is then *verified* against
        the exact float comparison the scalar code performs
        (monotonicity of ``ceil(n/d) * t`` in ``d`` makes one check at
        the run's last step sufficient); on any doubt the run degrades
        to a single pop, which is always exact.
      * **row compaction** — points whose heap has drained are dropped
        from the working set, so late iterations only touch the few
        long-running points.

    Mutates and returns ``dup``.
    """
    import numpy as np
    n_points, n_nodes = dup.shape
    if n_nodes == 0 or n_points == 0:
        return dup
    out = dup
    sub = np.arange(n_points)
    d = out
    nm, tw, cs, bud = n_mvm, t_window, cost, budget
    used = (d * cs).sum(axis=1)
    # masked stage: -inf marks discarded placements (popped ineligible)
    ms = np.ceil(nm / d) * tw
    neg_inf = np.full(sub.size, -np.inf)
    pt = np.arange(sub.size)
    # per-point pop budget: the scalar guard truncates after 100000 heap
    # pops, and a batched run of m increments is m pops — count them the
    # same way so even guard-truncated spends stay bit-exact
    pops = np.zeros(sub.size, dtype=np.int64)
    with np.errstate(invalid="ignore", divide="ignore"):
        while sub.size:
            sel = ms.argmax(axis=1)             # ties: lowest index, like
            flat = pt * n_nodes + sel           # heapq on (-stage, i)
            msel = ms.ravel()[flat]             # == per-row max
            live = (msel > -np.inf) & (pops < 100000)
            if not live.all():
                keep = np.flatnonzero(live)
                out[sub] = d                    # write back finished rows
                sub, d, nm, tw, cs, bud, used, ms, pops = (
                    sub[keep], d[keep], nm[keep], tw[keep], cs[keep],
                    bud[keep], used[keep], ms[keep], pops[keep])
                neg_inf = neg_inf[:sub.size]
                pt = pt[:sub.size]
                continue
            d_s = d.ravel()[flat]
            nm_s = nm.ravel()[flat]
            tw_s = tw.ravel()[flat]
            cs_s = cs.ravel()[flat]
            # runner-up heap key (-s2, j2) among the other live placements
            if n_nodes > 1:
                ms.ravel()[flat] = -np.inf
                j2 = ms.argmax(axis=1)
                s2 = ms.ravel()[pt * n_nodes + j2]
                ms.ravel()[flat] = msel
            else:
                j2, s2 = sel, neg_inf
            m_cap = np.minimum(nm_s - d_s, (bud - used) // cs_s)
            m_cap = np.minimum(m_cap, 100000 - pops)
            # run length: sel keeps popping while stage > s2 — or while
            # stage >= s2 when it wins ties (sel < j2).  Invert the stage
            # step function:
            # stage(d') > s2  <=> ceil(nm/d') > floor(s2/t) = q
            #                 <=> d' <= ceil(nm/q) - 1        (q >= 1)
            # stage(d') >= s2 <=> ceil(nm/d') >= ceil(s2/t) = q2
            #                 <=> d' <= ceil(nm/(q2 - 1)) - 1 (q2 >= 2)
            # then verify the last step with the exact float comparison
            # the scalar code performs (stage is non-increasing in d, so
            # one check suffices); degrade to a single pop on any doubt.
            wins_tie = sel < j2
            qq = np.where(wins_tie, np.ceil(s2 / tw_s) - 1.0,
                          np.floor(s2 / tw_s))
            tgt = np.ceil(nm_s / np.maximum(qq, 1.0)) - d_s
            m = np.where(qq >= 1, np.clip(tgt, 1, m_cap), m_cap)
            m = np.where(m_cap >= 1, m, 0).astype(np.int64)
            last_stage = np.ceil(nm_s / np.maximum(d_s + m - 1, 1)) * tw_s
            exact = (m <= 1) | (last_stage > s2) | \
                (wins_tie & (last_stage == s2))
            m = np.where(exact, m, np.minimum(m, 1))
            d.ravel()[flat] = d_s + m
            used += m * cs_s
            pops += np.maximum(m, 1)            # a failed pop still counts
            new_stage = np.ceil(nm_s / np.maximum(d_s + m, 1)) * tw_s
            ms.ravel()[flat] = np.where(m_cap >= 1, new_stage, -np.inf)
    if sub.size:
        out[sub] = d
    return out


def balance_duplication_arr(n_mvm, t_window, cost, budget, active=None):
    """(points x nodes) twin of ``balance_duplication``.

    ``n_mvm``/``t_window``/``cost`` are ``(P, N)`` arrays (``cost`` is the
    per-copy resource cost in the caller's unit), ``budget`` is ``(P,)``;
    ``active`` masks the points to search (inactive points keep dup=1).
    Returns the ``(P, N)`` int64 duplication array: 60-step bisection over
    the bottleneck target, then the leftover-spending greedy — both run
    once per *distinct* search row (``_unique_search_rows``) and the
    results broadcast back.
    """
    import numpy as np
    n_points, n_nodes = t_window.shape
    dup = np.ones((n_points, n_nodes), dtype=np.int64)
    if n_nodes == 0 or n_points == 0:
        return dup
    if active is None:
        active = np.ones(n_points, dtype=bool)
    nm_full = np.broadcast_to(n_mvm, t_window.shape)
    rows = active & (cost.sum(axis=1) <= budget)   # over budget: dup = 1
    if not rows.any():
        return dup
    sub = np.flatnonzero(rows)               # bisect the active subset only
    uniq, inv = _unique_search_rows([nm_full[sub], t_window[sub],
                                     cost[sub], budget[sub]])
    ui = sub[uniq]
    nm = np.ascontiguousarray(nm_full[ui])
    tw = np.ascontiguousarray(t_window[ui])
    cs = np.ascontiguousarray(cost[ui])
    bud = budget[ui]
    work = nm * tw
    lo = np.zeros(ui.size)
    hi = work.max(axis=1)
    best = np.ones((ui.size, n_nodes), dtype=np.int64)
    for _ in range(60):
        mid = (lo + hi) / 2
        tgt = np.maximum(mid, 1e-9)[:, None]
        d = np.minimum(np.maximum(1.0, np.ceil(work / tgt)), nm)
        ok = (np.ceil(nm / d) * tw <= mid[:, None]).all(axis=1)
        d = d.astype(np.int64)
        feas = ok & ((d * cs).sum(axis=1) <= bud)
        best = np.where(feas[:, None], d, best)
        hi = np.where(feas, mid, hi)
        lo = np.where(feas, lo, mid)
    best = _spend_leftover_arr(best, nm, tw, cs, bud)
    dup[sub] = best[inv]
    return dup


def greedy_duplication_arr(n_mvm, t_window, cost, budget, active=None):
    """(points x nodes) twin of ``greedy_duplication`` (min-sum objective,
    marginal-gain heap).  Same shapes/semantics as the balanced twin;
    replays the exact pop sequence, including the scalar quirk that a
    zero-gain pop discards the placement even if a later increment would
    have turned its gain positive again (ceil steps are not convex).
    Like the balanced twin, each distinct search row is solved once."""
    import numpy as np
    n_points, n_nodes = t_window.shape
    dup = np.ones((n_points, n_nodes), dtype=np.int64)
    if n_nodes == 0 or n_points == 0:
        return dup
    if active is None:
        active = np.ones(n_points, dtype=bool)
    nm_full = np.broadcast_to(n_mvm, t_window.shape)
    rows = active & (cost.sum(axis=1) <= budget)   # over budget: dup = 1
    if not rows.any():
        return dup

    def _gain_at(d, nm, tw, cs):
        cur = np.ceil(nm / d) * tw
        nxt = np.ceil(nm / (d + 1)) * tw
        return (cur - nxt) / cs

    osub = np.flatnonzero(rows)
    uniq, inv = _unique_search_rows([nm_full[osub], t_window[osub],
                                     cost[osub], budget[osub]])
    ui = osub[uniq]
    nm = np.ascontiguousarray(nm_full[ui])
    tw = np.ascontiguousarray(t_window[ui])
    cs = np.ascontiguousarray(cost[ui])
    bud = budget[ui]
    out = np.ones((ui.size, n_nodes), dtype=np.int64)
    sub = np.arange(ui.size)
    d = out
    used = cs.sum(axis=1)
    # masked gain: -inf marks discarded placements (popped with gain <= 0
    # or over budget — discarded for good, like the scalar heap)
    mg = _gain_at(d, nm, tw, cs)
    while sub.size:
        live = mg.max(axis=1) > -np.inf
        if not live.all():
            keep = np.flatnonzero(live)
            out[sub] = d                   # write back finished rows
            sub, d, nm, tw, cs, bud, used, mg = (
                sub[keep], d[keep], nm[keep], tw[keep], cs[keep],
                bud[keep], used[keep], mg[keep])
            if not sub.size:
                break
        pt = np.arange(sub.size)
        sel = mg.argmax(axis=1)
        flat = pt * n_nodes + sel
        g_s = mg.ravel()[flat]
        cs_s = cs.ravel()[flat]
        d_s = d.ravel()[flat]
        nm_s = nm.ravel()[flat]
        elig = (g_s > 0) & (used + cs_s <= bud) & (d_s < nm_s)
        d.ravel()[flat] = d_s + elig
        used += np.where(elig, cs_s, 0)
        new_gain = _gain_at(d_s + 1, nm_s, tw.ravel()[flat], cs_s)
        mg.ravel()[flat] = np.where(elig, new_gain, -np.inf)
    if sub.size:
        out[sub] = d
    dup[osub] = out[inv]
    return dup


def estimate_segment_cycles_arr(n_mvm, dup, t_window, use_pipeline):
    """(points,) twin of ``estimate_segment_cycles`` over (P, N) arrays;
    ``use_pipeline`` is a per-point boolean column."""
    import numpy as np
    if t_window.shape[1] == 0:
        return np.zeros(t_window.shape[0])
    stage = np.ceil(n_mvm / dup) * t_window
    pipelined = seq_sum(t_window) + stage.max(axis=1)
    return np.where(use_pipeline, pipelined, seq_sum(stage))


# ---------------------------------------------------------------------------
# The CG pass
# ---------------------------------------------------------------------------

def run(graph: Graph, arch: CIMArch, *, use_pipeline: bool = True,
        use_duplication: bool = True,
        binding: BitBinding = BitBinding.B_TO_XBC,
        ping_pong: bool = False,
        naive_chunking: bool = False) -> SchedulePlan:
    """CG-grained pass.

    ``ping_pong=True`` schedules segments onto half the core pool so the
    other half can be (re)programmed concurrently — weight-rewrite
    latency hides behind compute (double buffering).  The compiler tries
    both variants for multi-segment schedules and keeps the faster
    (compiler.compile_graph); on weight-frozen single-segment ReRAM
    deployments it is never chosen.
    """
    if not arch.mode.allows(ComputingMode.CM):
        raise ValueError("architecture exposes no core-level interface")
    cm = CostModel(arch, binding)
    budget = arch.chip.n_cores
    if ping_pong:
        budget = max(1, budget // 2)

    # 1. placements for every CIM node; ops whose single copy exceeds the
    # whole chip are tiled into (row x col) chunks that each fit.  Row
    # chunks produce partial sums accumulated by the chip ALU; column
    # chunks produce disjoint output slices.
    pls: List[OpPlacement] = []
    for node in graph.cim_nodes:
        p0 = cm.placement(node, graph)
        if p0.cores <= budget:
            pls.append(p0)
            continue
        r, c = weight_matrix_shape(node)
        slot_cap = budget * arch.core.n_xbs      # crossbars on the chip
        full = bind((r, c), arch, binding)
        grid_r_full = full.grid_r
        # Column capacity is counted in VXB column *units* so a chunk
        # boundary never splits the bit slices of one logical column
        # (B->XB: one unit = col_slices crossbars; B->XBC: one crossbar).
        xbs_per_unit = full.xbs_per_vxb
        cols_per_unit = logical_cols_per_xb(full, arch)
        units_c_full = math.ceil(c / cols_per_unit)
        if slot_cap < xbs_per_unit:
            raise ValueError(vxb_span_error(node.name, xbs_per_unit,
                                            slot_cap))
        # search the (row-chunks x col-chunks) grid minimizing the total
        # chunk count (serial reload generations), subject to one chunk
        # fitting the chip; ties prefer bigger chunks (better packing)
        best = None
        rc_lo = max(1, math.ceil(grid_r_full / (slot_cap // xbs_per_unit)))
        rc_hi = rc_lo if naive_chunking else grid_r_full
        for rc in range(rc_lo, rc_hi + 1):
            grid_r_chunk = math.ceil(grid_r_full / rc)
            col_cap = slot_cap // (grid_r_chunk * xbs_per_unit)
            if col_cap < 1:
                continue
            units_c_chunk = min(col_cap, units_c_full)
            cc = math.ceil(units_c_full / units_c_chunk)
            chunk_xbs = grid_r_chunk * units_c_chunk * xbs_per_unit
            cores = math.ceil(chunk_xbs / arch.core.n_xbs)
            if cores > budget:
                continue
            key = (rc * cc, -chunk_xbs)
            if best is None or key < best[0]:
                best = (key, rc, cc, units_c_chunk)
            if grid_r_chunk == 1:
                break   # further row splits cannot reduce the chunk count
        assert best is not None, f"no feasible chunking for {node.name}"
        _, rc, cc, units_c_chunk = best
        sub_r = math.ceil(r / rc)
        sub_c = min(c, units_c_chunk * cols_per_unit)
        n_chunks = rc * cc
        for ch in range(n_chunks):
            pls.append(cm.placement(node, graph, chunk=ch, n_chunks=n_chunks,
                                    sub_rc=(sub_r, sub_c)))
        # safety: the construction above guarantees fit, but guard anyway
        assert pls[-1].cores <= budget, (
            f"chunking failed for {node.name}: {pls[-1].cores} > {budget}")

    # 2. resource-adaptive segmentation + per-segment duplication
    segments = segment_graph(pls, arch, budget, use_pipeline, use_duplication)

    # 3. annotate nodes (paper: attributes on the ONNX graph)
    for si, seg in enumerate(segments):
        for p in seg.placements:
            p.node.sched.update({
                "segment": si, "dup": p.dup, "cores_per_copy": p.cores,
                "n_vxb": p.mapping.n_xbs,
            })

    plan = SchedulePlan(graph=graph, arch=arch, segments=segments,
                        use_pipeline=use_pipeline,
                        use_duplication=use_duplication)
    plan.notes["cg_budget"] = budget
    plan.notes["ping_pong"] = ping_pong
    if obs_hooks.subscribed():
        obs_hooks.emit("cg.plan", graph=graph.name, arch=arch.name,
                       segments=len(segments), budget=budget,
                       ping_pong=ping_pong,
                       placements=len(plan.placements))
    return plan


def _rewrite_cycles(seg_pls: List[OpPlacement], arch: CIMArch) -> float:
    """Per-inference cycles to (re)program a segment's crossbars.

    Cores program their crossbars in parallel; rows within a crossbar are
    written serially at the memory cell's write cost (§2.1's device
    diversity — ReRAM/FLASH writes are ~100-1000x an SRAM write)."""
    n_xbs = sum(p.dup * p.mapping.n_xbs for p in seg_pls)
    return n_xbs * arch.t_write_xb() / max(arch.chip.n_cores, 1)


def _duplicate_segment(seg_pls: List[OpPlacement], arch: CIMArch,
                       budget: int, use_pipeline: bool, use_duplication: bool,
                       charge_rewrite: bool) -> float:
    """Assign duplications for one segment; returns estimated cycles.

    When the segment must be reprogrammed per inference (multi-segment
    schedules), duplication inflates the rewrite cost, so the budget
    actually spent on duplication is searched (the paper's
    resource-*adaptive* allocation): fractions of the core budget are
    tried and the best rewrite+compute total wins.  On SRAM chips writes
    are cheap and the full budget survives the search.
    """
    def apply(frac: float) -> float:
        for p in seg_pls:
            p.dup = 1
        if use_duplication and frac > 0:
            b = max(sum(p.cores for p in seg_pls), int(budget * frac))
            if use_pipeline:
                balance_duplication(seg_pls, b)
            else:
                greedy_duplication(seg_pls, b)
        cost = estimate_segment_cycles(seg_pls, use_pipeline)
        if charge_rewrite:
            cost += _rewrite_cycles(seg_pls, arch)
        return cost

    if not use_duplication:
        return apply(0.0)
    if not charge_rewrite:
        return apply(1.0)
    best_cost, best_frac = None, 1.0
    for frac in (1.0, 0.5, 0.25, 0.125, 0.0625, 0.0):
        cost = apply(frac)
        if best_cost is None or cost < best_cost - 1e-9:
            best_cost, best_frac = cost, frac
    return apply(best_frac)


def segment_graph(pls: List[OpPlacement], arch: CIMArch, budget: int,
                  use_pipeline: bool, use_duplication: bool,
                  pop_window: int = 4) -> List[Segment]:
    """Figure 9(b)'s resource-adaptive segmentation.

    Grow a maximal prefix that fits (one copy per op), then refine the
    boundary: pop trailing nodes while the estimated latency of the
    segment (after duplication DP) improves.  Weight-rewrite cost between
    segments is charged per the memory-cell write cost — this is where
    ReRAM's expensive writes penalize segmentation (§1, §2.1).
    """
    # Does the whole model fit at one copy per op?  If so, weights are
    # programmed once and amortized over the inference stream (ReRAM
    # weight-frozen operation); otherwise EVERY segment is reprogrammed
    # on every inference (segment N+1 overwrites segment N's crossbars).
    multi_segment = sum(p.cores for p in pls) > budget
    segments: List[Segment] = []
    i = 0
    while i < len(pls):
        j = i
        used = 0
        while j < len(pls) and used + pls[j].cores <= budget:
            used += pls[j].cores
            j += 1
        j = max(j, i + 1)  # always make progress

        # boundary refinement: try popping up to pop_window trailing nodes
        best_j, best_cost = j, None
        if j < len(pls):  # popping only matters when a tail remains
            for jj in range(j, max(i + 1, j - pop_window) - 1, -1):
                seg_pls = pls[i:jj]
                cost = _duplicate_segment(seg_pls, arch, budget, use_pipeline,
                                          use_duplication, multi_segment)
                # remaining nodes at 1 copy + their rewrite as tail estimate
                tail = sum(p.n_mvm * p.t_window for p in pls[jj:])
                if multi_segment:
                    tail += _rewrite_cycles(pls[jj:], arch)
                cost += tail
                if best_cost is None or cost < best_cost - 1e-9:
                    best_cost, best_j = cost, jj
        j = best_j

        seg_pls = pls[i:j]
        _duplicate_segment(seg_pls, arch, budget, use_pipeline,
                           use_duplication, multi_segment)
        rewrite = _rewrite_cycles(seg_pls, arch) if multi_segment else 0.0
        segments.append(Segment(placements=seg_pls, rewrite_cycles=rewrite))
        i = j
    return segments
