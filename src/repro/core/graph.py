"""ONNX-isomorphic computation-graph IR.

The paper ingests DNNs "in ONNX format ... nodes correspond to operators,
and edges denote the data dependency between each operator" (§3.3.1) and
annotates optimization results as node attributes.  This module provides
the same representation without the onnx dependency (offline build):
``Node`` = operator with attrs, tensors are named edges, ``Graph`` keeps a
topological view plus shape inference, and scheduling passes attach their
results to ``node.sched`` (mirroring the paper's "adding attributes to the
nodes in the ONNX graph").

A loader for ONNX-shaped dicts (``Graph.from_dict``) accepts the schema
{"nodes": [{"name","op_type","inputs","outputs","attrs"}], "inputs": ...}
so externally-exported graphs can be ingested.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

# Operator taxonomy ---------------------------------------------------------
# CIM-supported operators are weight-stationary matmul-family ops that map
# onto crossbars (§3.2: cores/crossbars execute conv / MVM).  Everything
# else executes on the tier ALU (DCOM) — including activation x activation
# matmuls (attention QK^T / AV), which cannot be weight-stationary.
CIM_OPS = {"Conv", "Gemm", "Linear"}
ALU_OPS = {
    "Relu", "Gelu", "Silu", "Sigmoid", "Tanh", "Softmax", "LayerNorm",
    "RMSNorm", "BatchNorm", "Add", "Mul", "MaxPool", "AveragePool",
    "GlobalAveragePool", "Flatten", "Reshape", "Concat", "Split",
    "MatMul", "Embedding", "SSMScan", "RoPE", "TopKRouter", "Softcap",
    "Identity", "Transpose",
}
KNOWN_OPS = CIM_OPS | ALU_OPS


@dataclasses.dataclass
class Node:
    name: str
    op_type: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # Scheduling results attached by compiler passes (paper: node attributes).
    sched: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.op_type not in KNOWN_OPS:
            raise ValueError(f"unknown op_type {self.op_type!r} in node {self.name!r}")

    @property
    def is_cim(self) -> bool:
        return self.op_type in CIM_OPS

    def __repr__(self) -> str:  # keep pytest output short
        return f"Node({self.name}:{self.op_type})"


@dataclasses.dataclass
class Graph:
    name: str
    nodes: List[Node]
    inputs: Dict[str, Tuple[int, ...]]          # tensor name -> shape
    outputs: List[str]
    shapes: Dict[str, Tuple[int, ...]] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self._producer: Dict[str, Node] = {}
        for n in self.nodes:
            for t in n.outputs:
                if t in self._producer:
                    raise ValueError(f"tensor {t!r} produced twice")
                self._producer[t] = n
        self._toposort()
        if not self.shapes:
            self.infer_shapes()

    # -- structure -------------------------------------------------------
    def _toposort(self) -> None:
        order: List[Node] = []
        seen: set = set()
        temp: set = set()

        def visit(n: Node):
            if n.name in seen:
                return
            if n.name in temp:
                raise ValueError(f"cycle through {n.name}")
            temp.add(n.name)
            for t in n.inputs:
                p = self._producer.get(t)
                if p is not None:
                    visit(p)
            temp.discard(n.name)
            seen.add(n.name)
            order.append(n)

        for n in self.nodes:
            visit(n)
        self.nodes = order

    def producer(self, tensor: str) -> Optional[Node]:
        return self._producer.get(tensor)

    def consumers(self, tensor: str) -> List[Node]:
        return [n for n in self.nodes if tensor in n.inputs]

    def predecessors(self, node: Node) -> List[Node]:
        out, seen = [], set()
        for t in node.inputs:
            p = self._producer.get(t)
            if p is not None and p.name not in seen:
                seen.add(p.name)
                out.append(p)
        return out

    def successors(self, node: Node) -> List[Node]:
        outs = set(node.outputs)
        result, seen = [], set()
        for n in self.nodes:
            if n.name not in seen and outs & set(n.inputs):
                seen.add(n.name)
                result.append(n)
        return result

    @property
    def cim_nodes(self) -> List[Node]:
        return [n for n in self.nodes if n.is_cim]

    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    # -- shape inference ---------------------------------------------------
    def infer_shapes(self) -> Dict[str, Tuple[int, ...]]:
        sh: Dict[str, Tuple[int, ...]] = dict(self.inputs)
        for n in self.nodes:
            try:
                infer_node_shape(n, sh)
            except KeyError as e:
                raise ValueError(f"missing shape for input {e} of {n}") from None
        self.shapes = sh
        return sh

    # -- fidelity slicing --------------------------------------------------
    def prefix(self, n_nodes: int) -> "Graph":
        """First ``n_nodes`` nodes (topological order) as a standalone graph.

        The reduced-fidelity proxy of the DSE searcher (dse.search): a
        prefix compiles and simulates like any graph, at a fraction of the
        cost, and its latency ranks design points like the full model does
        (the dropped suffix is built from the same operator population).
        Tensors whose consumers were all dropped become graph outputs, so
        no kept node dangles.  Nodes are copied — compiling a prefix never
        touches this graph's ``sched`` annotations.  ``n_nodes`` at or
        above ``len(self.nodes)`` returns ``self`` unchanged, so full-
        fidelity requests share compile-cache entries with direct compiles.
        """
        if n_nodes < 1:
            raise ValueError("prefix needs at least one node")
        if n_nodes >= len(self.nodes):
            return self
        kept = self.nodes[:n_nodes]
        kept_names = {n.name for n in kept}
        outputs = []
        for n in kept:
            for t in n.outputs:
                consumers = [c for c in self.consumers(t)
                             if c.name in kept_names]
                if t in self.outputs or not consumers:
                    outputs.append(t)
        nodes = [Node(n.name, n.op_type, list(n.inputs), list(n.outputs),
                      dict(n.attrs)) for n in kept]
        consumed = {t for n in kept for t in n.inputs}
        inputs = {t: shp for t, shp in self.inputs.items() if t in consumed}
        return Graph(f"{self.name}.prefix{n_nodes}", nodes, inputs, outputs)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "nodes": [
                {"name": n.name, "op_type": n.op_type, "inputs": n.inputs,
                 "outputs": n.outputs, "attrs": n.attrs}
                for n in self.nodes
            ],
            "inputs": {k: list(v) for k, v in self.inputs.items()},
            "outputs": self.outputs,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Graph":
        nodes = [Node(x["name"], x["op_type"], list(x["inputs"]),
                      list(x["outputs"]), dict(x.get("attrs", {})))
                 for x in d["nodes"]]
        return cls(d["name"], nodes,
                   {k: tuple(v) for k, v in d["inputs"].items()},
                   list(d["outputs"]))


# ---------------------------------------------------------------------------
# Shape inference (batch=1 inference graphs; conv tensors are CHW).
# ---------------------------------------------------------------------------

def _conv_out_hw(h: int, w: int, k: int, stride: int, pad: int) -> Tuple[int, int]:
    return ((h + 2 * pad - k) // stride + 1, (w + 2 * pad - k) // stride + 1)


def infer_node_shape(n: Node, sh: Dict[str, Tuple[int, ...]]) -> None:
    t = n.op_type
    x = sh[n.inputs[0]]
    if t == "Conv":
        cout, _, k, _ = n.attrs["weight_shape"]        # (Cout,Cin,k,k)
        stride, pad = n.attrs.get("stride", 1), n.attrs.get("pad", 0)
        oh, ow = _conv_out_hw(x[1], x[2], k, stride, pad)
        sh[n.outputs[0]] = (cout, oh, ow)
    elif t in ("Gemm", "Linear"):
        cin, cout = n.attrs["weight_shape"][-2:]        # (in,out)
        sh[n.outputs[0]] = tuple(x[:-1]) + (cout,)
    elif t == "MatMul":                                 # act x act
        y = sh[n.inputs[1]]
        last = y[-2] if n.attrs.get("transpose_b") else y[-1]
        sh[n.outputs[0]] = tuple(x[:-1]) + (last,)
    elif t in ("MaxPool", "AveragePool"):
        k = n.attrs.get("kernel", 2)
        stride = n.attrs.get("stride", k)
        pad = n.attrs.get("pad", 0)
        oh, ow = _conv_out_hw(x[1], x[2], k, stride, pad)
        sh[n.outputs[0]] = (x[0], oh, ow)
    elif t == "GlobalAveragePool":
        sh[n.outputs[0]] = (x[0], 1, 1)
    elif t == "Flatten":
        sh[n.outputs[0]] = (int(math.prod(x)),)
    elif t == "Reshape":
        sh[n.outputs[0]] = tuple(n.attrs["shape"])
    elif t == "Transpose":
        perm = n.attrs["perm"]
        sh[n.outputs[0]] = tuple(x[p] for p in perm)
    elif t == "Concat":
        axis = n.attrs.get("axis", -1)
        shapes = [sh[i] for i in n.inputs]
        axis = axis % len(x)
        out = list(x)
        out[axis] = sum(s[axis] for s in shapes)
        sh[n.outputs[0]] = tuple(out)
    elif t == "Split":
        axis = n.attrs.get("axis", -1) % len(x)
        parts = n.attrs["parts"]
        base = list(x)
        for o, p in zip(n.outputs, parts):
            base[axis] = p
            sh[o] = tuple(base)
    elif t == "Embedding":
        sh[n.outputs[0]] = tuple(x) + (n.attrs["weight_shape"][1],)
    elif t == "TopKRouter":
        sh[n.outputs[0]] = tuple(x[:-1]) + (n.attrs["n_experts"],)
    else:  # elementwise / normalization / misc keep shape of first input
        for o in n.outputs:
            sh[o] = x


# ---------------------------------------------------------------------------
# Workload-side queries used by the scheduler & perf model.
# ---------------------------------------------------------------------------

def weight_matrix_shape(n: Node) -> Tuple[int, int]:
    """(R, C): the logical weight matrix a crossbar mapping must hold.

    Conv (Cout,Cin,k,k) unrolls to R = Cin*k*k input rows, C = Cout
    columns (Figure 7's matrix-dimension view); Gemm is (in, out).
    """
    if n.op_type == "Conv":
        cout, cin, k, _ = n.attrs["weight_shape"]
        return cin * k * k, cout
    if n.op_type in ("Gemm", "Linear"):
        cin, cout = n.attrs["weight_shape"][-2:]
        return cin, cout
    raise ValueError(f"{n} has no crossbar weight matrix")


def n_mvm(n: Node, shapes: Dict[str, Tuple[int, ...]]) -> int:
    """Number of MVMs (sliding windows / token rows) one inference needs."""
    if n.op_type == "Conv":
        out = shapes[n.outputs[0]]
        return out[1] * out[2]
    if n.op_type in ("Gemm", "Linear"):
        x = shapes[n.inputs[0]]
        return int(math.prod(x[:-1])) if len(x) > 1 else 1
    raise ValueError(f"{n} is not an MVM-decomposable operator")


def macs(n: Node, shapes: Dict[str, Tuple[int, ...]]) -> int:
    """Multiply-accumulate count of a node (ALU cost for unsupported ops)."""
    if n.is_cim:
        r, c = weight_matrix_shape(n)
        return r * c * n_mvm(n, shapes)
    if n.op_type == "MatMul":
        x = shapes[n.inputs[0]]
        out = shapes[n.outputs[0]]
        return int(math.prod(x)) * out[-1]
    return out_elems(n, shapes)


def out_elems(n: Node, shapes: Dict[str, Tuple[int, ...]]) -> int:
    return int(math.prod(shapes[n.outputs[0]]))


def weight_bits(n: Node, bits: int) -> int:
    if not n.is_cim:
        return 0
    r, c = weight_matrix_shape(n)
    return r * c * bits
