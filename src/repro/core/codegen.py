"""Meta-operator flow generation (§3.3.2-3.3.4 "Meta-operator Flow
Generation" paragraphs; worked example §3.4 / Figure 16).

Translates a ``SchedulePlan`` into the meta-operator ``Program``:

  * CM  — ``parallel { cim.read_core(...) }`` per duplicated copy, DCOM
    ops for CIM-unsupported operators, ``mov`` for explicit transfers.
  * XBM — ``cim.write_xb`` weight programming, then per window:
    ``mov(L0->L1)``; ``parallel { cim.read_xb ... }``; shift-accumulate;
    ``mov(L1->L0)``.
  * WLM — ``cim.write_row`` programming honoring the VVM remap, then
    ``parallel { cim.read_row(row_addr, len=parallel_row) ... }``.

Large flows are Loop-compressed (the paper's "256 similar code segments");
``expand=True`` materializes every window with concrete indices so the
functional simulator can interpret the flow.
"""
from __future__ import annotations

import math
from typing import Dict, List

from .abstraction import CIMArch, ComputingMode
from .cg_opt import OpPlacement, SchedulePlan
from .graph import Graph, Node, out_elems
from . import mop
from .mop import Loop, MetaOp, Parallel, Program, Stmt

# DCOM kind for each CIM-unsupported graph op
_DCOM_OF = {
    "Relu": "relu", "Gelu": "gelu", "Silu": "silu", "Sigmoid": "sigmoid",
    "Tanh": "tanh", "Add": "add", "Mul": "mul", "MaxPool": "maxpool",
    "AveragePool": "avgpool", "GlobalAveragePool": "avgpool",
    "Softmax": "softmax", "LayerNorm": "layernorm", "RMSNorm": "rmsnorm",
    "MatMul": "matmul", "Embedding": "embedding", "SSMScan": "ssm_scan",
    "RoPE": "rope", "TopKRouter": "topk_router", "Softcap": "softcap",
    "Flatten": "flatten", "Reshape": "reshape", "Concat": "concat",
    "Split": "split", "Identity": "identity", "Transpose": "transpose",
}

MAX_EXPANDED_OPS = 500_000


class _BufferAllocator:
    """Bump allocator assigning L0 byte offsets to graph tensors."""

    def __init__(self, graph: Graph, act_bits: int):
        self.graph = graph
        self.act_bits = act_bits
        self.offsets: Dict[str, int] = {}
        self.top = 0

    def addr(self, tensor: str) -> int:
        if tensor not in self.offsets:
            self.offsets[tensor] = self.top
            nbytes = out_bytes(self.graph, tensor, self.act_bits)
            self.top += nbytes
        return self.offsets[tensor]


def out_bytes(graph: Graph, tensor: str, act_bits: int) -> int:
    shape = graph.shapes.get(tensor, (1,))
    return max(1, math.prod(shape) * act_bits // 8)


def emit(plan: SchedulePlan, expand: bool = False) -> Program:
    arch, graph = plan.arch, plan.graph
    alloc = _BufferAllocator(graph, arch.act_bits)
    stmts: List[Stmt] = []
    level = plan.notes.get("level", arch.mode)

    # map node name -> its placements (chunks) for quick lookup
    by_node: Dict[str, List[OpPlacement]] = {}
    seg_of: Dict[str, int] = {}
    for si, seg in enumerate(plan.segments):
        for p in seg.placements:
            by_node.setdefault(p.node.name, []).append(p)
            seg_of[p.node.name] = si

    core_cursor = 0

    def assign_cores(p: OpPlacement) -> int:
        nonlocal core_cursor
        base = core_cursor
        core_cursor += p.dup * p.cores
        if core_cursor > arch.chip.n_cores:  # wrap (segments reuse cores)
            core_cursor = p.dup * p.cores
            base = 0
        return base

    emitted_nodes = set()
    for si, seg in enumerate(plan.segments):
        core_cursor = 0
        seg_nodes = {p.node.name for p in seg.placements}
        # 1. weight programming for XBM/WLM-visible levels
        if level.allows(ComputingMode.XBM):
            init: List[Stmt] = []
            for p in seg.placements:
                base = assign_cores(p)
                p.node.sched["core_base"] = base
                init.extend(_emit_writes(p, arch, level, base))
            if init:
                stmts.append(Loop(init, 1, note=f"segment {si}: program weights"))
        else:
            for p in seg.placements:
                p.node.sched["core_base"] = assign_cores(p)

        # 2. compute flow in topological order
        for node in graph.nodes:
            if node.name in emitted_nodes:
                continue
            if node.is_cim:
                if node.name not in seg_nodes:
                    continue
                emitted_nodes.add(node.name)
                for p in by_node[node.name]:
                    stmts.extend(_emit_cim_compute(p, plan, alloc, level, expand))
            else:
                # emit an ALU node once ALL its producers are emitted
                # (a missing one lives in a later segment — retry there)
                preds = plan.graph.predecessors(node)
                if any(pr.name not in emitted_nodes for pr in preds):
                    continue
                emitted_nodes.add(node.name)
                stmts.append(_emit_dcom(node, graph, alloc))

    # trailing ALU nodes whose producers landed in the final segment
    for node in graph.nodes:
        if node.name in emitted_nodes or node.is_cim:
            continue
        if all(pr.name in emitted_nodes for pr in graph.predecessors(node)):
            emitted_nodes.add(node.name)
            stmts.append(_emit_dcom(node, graph, alloc))

    prog = Program(name=f"{graph.name}@{arch.name}:{level.value}", stmts=stmts,
                   meta={"arch": arch.name, "graph": graph.name,
                         "level": level.value,
                         "segments": len(plan.segments)})
    if expand:
        prog = prog.expand()
        n = sum(prog.op_counts().values())
        if n > MAX_EXPANDED_OPS:
            raise ValueError(f"expanded flow too large ({n} ops); "
                             "use expand=False for this graph")
    return prog


def _emit_writes(p: OpPlacement, arch: CIMArch, level: ComputingMode,
                 core_base: int) -> List[Stmt]:
    """cim.write_xb / cim.write_row programming ops for one placement."""
    out: List[Stmt] = []
    m = p.mapping
    wlm = level.allows(ComputingMode.WLM)
    for copy in range(p.dup):
        xb_idx = 0
        for rt in range(m.grid_r):
            for ct in range(m.grid_c):
                core = core_base + (copy * p.cores +
                                    xb_idx // arch.core.n_xbs)
                xb = xb_idx % arch.core.n_xbs
                if wlm and p.row_spread > 1:
                    rows = arch.xb.rows if rt < m.grid_r - 1 else m.rows_used_last
                    grp = arch.xb.parallel_row
                    n_grp = max(1, math.ceil(rows / grp))
                    for part in range(min(p.row_spread, n_grp)):
                        out.append(mop.write_row(
                            row_addr=(core, xb, part, 0),
                            value=f"{p.node.name}.w[r{rt},c{ct},s{part}]",
                            op=p.node.name, copy=copy, row_tile=rt,
                            col_tile=ct, spread=part, chunk=p.chunk))
                else:
                    out.append(mop.write_xb(
                        xb_addr=(core, xb), mat=f"{p.node.name}.w[r{rt},c{ct}]",
                        op=p.node.name, copy=copy, row_tile=rt, col_tile=ct,
                        chunk=p.chunk))
                xb_idx += 1
    return out


def _emit_cim_compute(p: OpPlacement, plan: SchedulePlan,
                      alloc: _BufferAllocator, level: ComputingMode,
                      expand: bool) -> List[Stmt]:
    arch = plan.arch
    node = p.node
    src = alloc.addr(node.inputs[0])
    dst = alloc.addr(node.outputs[0])
    core_base = node.sched.get("core_base", 0)

    if level == ComputingMode.CM:
        block = Parallel([
            mop.read_core(op=node.op_type.lower(), core_addr=core_base + c,
                          src=src, dst=dst, node=node.name, copy=c,
                          chunk=p.chunk)
            for c in range(p.dup)
        ]) if p.dup > 1 else mop.read_core(
            op=node.op_type.lower(), core_addr=core_base, src=src, dst=dst,
            node=node.name, copy=0, chunk=p.chunk)
        return [block]

    m = p.mapping
    windows_per_copy = math.ceil(p.n_mvm / p.dup)
    wlm = level.allows(ComputingMode.WLM)

    def window_block(w) -> List[Stmt]:
        reads: List[Stmt] = []
        for copy in range(p.dup):
            xb_idx = 0
            for rt in range(m.grid_r):
                for ct in range(m.grid_c):
                    core = core_base + (copy * p.cores +
                                        xb_idx // arch.core.n_xbs)
                    xb = xb_idx % arch.core.n_xbs
                    common = dict(op=node.name, copy=copy, window=w,
                                  row_tile=rt, col_tile=ct, chunk=p.chunk)
                    if wlm:
                        rows = arch.xb.rows if rt < m.grid_r - 1 else m.rows_used_last
                        k = p.row_spread
                        n_grp = max(1, math.ceil(rows / arch.xb.parallel_row))
                        for part in range(min(k, n_grp)):
                            reads.append(mop.read_row(
                                row_addr=(core, xb, part, 0),
                                length=arch.xb.parallel_row,
                                spread=part, **common))
                    else:
                        reads.append(mop.read_xb(xb_addr=(core, xb),
                                                 length=1, **common))
                    xb_idx += 1
        body: List[Stmt] = [mop.mov(src=f"L0+{src}", dst="L1", length=m.r,
                                    op=node.name, window=w)]
        body.append(Parallel(reads) if len(reads) > 1 else reads[0])
        if m.grid_r > 1:
            body.append(mop.dcom("shift_acc", op=node.name, window=w,
                                 parts=m.grid_r))
        body.append(mop.mov(src="L1", dst=f"L0+{dst}", length=m.c,
                            op=node.name, window=w))
        return body

    if expand:
        out: List[Stmt] = []
        for w in range(windows_per_copy):
            out.extend(window_block(w))
        return out
    return [Loop(window_block("w"), windows_per_copy,
                 note=f"{node.name}: {windows_per_copy} windows x "
                      f"{p.dup} copies")]


def _emit_dcom(node: Node, graph: Graph, alloc: _BufferAllocator) -> MetaOp:
    kind = _DCOM_OF.get(node.op_type)
    if kind is None:
        raise ValueError(f"no DCOM lowering for {node.op_type}")
    attrs = dict(node=node.name)
    srcs = [alloc.addr(t) for t in node.inputs]
    if kind == "add" and len(srcs) >= 2:
        attrs.update(src1=srcs[0], src2=srcs[1])
    else:
        attrs.update(src=srcs[0])
    attrs["dst"] = alloc.addr(node.outputs[0])
    attrs["len"] = out_elems(node, graph.shapes)
    return mop.dcom(kind, **attrs)
