"""CIM hardware abstraction (Abs-arch) and computing modes (Abs-com).

Reproduces §3.2 of CIM-MLC (ASPLOS'24): a three-tier architecture
abstraction — chip / core / crossbar — each tier carrying the parameter
table of Figures 5, 6 and 8, plus the three computing-mode abstractions
(CM / XBM / WLM) that determine which scheduling levels the compiler may
exercise (§3.2.1-3.2.3).

All presets from the paper's evaluation are provided:
  * ``isaac_baseline``  — Table 3 (ISAAC-like ReRAM chip, XBM+WLM capable)
  * ``jia_cm``          — Figure 17 (Jia et al. ISSCC'21 SRAM chip, CM)
  * ``puma_xbm``        — Figure 18 (PUMA ReRAM chip, XBM)
  * ``jain_wlm``        — Figure 19 (Jain et al. JSSC'21 SRAM macro, WLM)
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Tuple


class ComputingMode(enum.Enum):
    """Abs-com: the scheduling granularity the chip exposes (§3.2).

    CM  — core mode:      whole cores execute whole DNN operators.
    XBM — crossbar mode:   individual crossbars execute MVMs.
    WLM — wordline mode:   individual row groups can be activated.

    The modes are ordered coarse→fine; a chip exposing WLM also allows the
    scheduler to use the coarser levels (the paper's multi-level joint
    scheduling inherits coarse results into finer passes).
    """

    CM = "CM"
    XBM = "XBM"
    WLM = "WLM"

    @property
    def rank(self) -> int:
        return {"CM": 0, "XBM": 1, "WLM": 2}[self.value]

    def allows(self, other: "ComputingMode") -> bool:
        """True if a chip in mode ``self`` permits scheduling level ``other``."""
        return other.rank <= self.rank


class CellType(enum.Enum):
    SRAM = "SRAM"
    RERAM = "ReRAM"
    FLASH = "FLASH"
    PCM = "PCM"

    @property
    def write_cost_per_row(self) -> float:
        """Relative cycles to (re)program one crossbar row.

        Captures the paper's §1 observation: SRAM supports flexible
        updates while ReRAM/FLASH writes are expensive, so schedulers for
        those devices avoid weight rewrites (this is what penalises graph
        segmentation on ReRAM chips — see cg_opt.segment_graph).
        """
        return {
            "SRAM": 1.0,
            "ReRAM": 100.0,
            "FLASH": 1000.0,
            "PCM": 150.0,
        }[self.value]


@dataclasses.dataclass(frozen=True)
class ChipTier:
    """Figure 5 — chip-tier architecture abstraction parameters."""

    core_number: Tuple[int, int]        # cores per row * cores per column
    alu_ops_per_cycle: float = math.inf  # "ALU": digital compute capacity
    core_noc: str = "mesh"               # NoC type
    core_noc_cost: float = 0.0           # cycles per bit between adjacent cores
    l0_size_kb: float = math.inf         # global buffer capacity
    l0_bw_bits: float = math.inf         # global buffer bandwidth, bits/cycle

    @property
    def n_cores(self) -> int:
        return self.core_number[0] * self.core_number[1]


@dataclasses.dataclass(frozen=True)
class CoreTier:
    """Figure 6 — core-tier architecture abstraction parameters."""

    xb_number: Tuple[int, int]           # crossbars per row * per column
    alu_ops_per_cycle: float = math.inf
    xb_noc: str = "shared-bus"
    xb_noc_cost: float = 0.0
    l1_size_kb: float = math.inf
    l1_bw_bits: float = math.inf

    @property
    def n_xbs(self) -> int:
        return self.xb_number[0] * self.xb_number[1]


@dataclasses.dataclass(frozen=True)
class CrossbarTier:
    """Figure 8 — crossbar-tier architecture abstraction parameters."""

    xb_size: Tuple[int, int]             # rows (wordlines) * columns (bitlines)
    dac_bits: int = 1                    # DAC precision
    adc_bits: int = 8                    # ADC precision
    cell_type: CellType = CellType.RERAM
    cell_precision: int = 2              # bits stored per cell
    parallel_row: Optional[int] = None   # max simultaneously-activated rows

    def __post_init__(self):
        if self.parallel_row is None:
            object.__setattr__(self, "parallel_row", self.xb_size[0])
        if self.parallel_row <= 0:
            raise ValueError("parallel_row must be positive")
        if self.cell_precision <= 0:
            raise ValueError("cell_precision must be positive")

    @property
    def rows(self) -> int:
        return self.xb_size[0]

    @property
    def cols(self) -> int:
        return self.xb_size[1]

    @property
    def cells(self) -> int:
        return self.rows * self.cols

    def row_groups(self, rows_used: int) -> int:
        """Serial activation groups needed to read ``rows_used`` wordlines."""
        rows_used = min(rows_used, self.rows)
        return max(1, math.ceil(rows_used / self.parallel_row))

    def input_phases(self, act_bits: int) -> int:
        """Bit-serial DAC phases to present an ``act_bits`` input."""
        return max(1, math.ceil(act_bits / self.dac_bits))


@dataclasses.dataclass(frozen=True)
class CIMArch:
    """A complete Abs-arch + Abs-com description of one CIM accelerator."""

    name: str
    mode: ComputingMode
    chip: ChipTier
    core: CoreTier
    xb: CrossbarTier
    act_bits: int = 8                    # activation precision of the workload
    weight_bits: int = 8                 # weight precision of the workload

    # ---- derived capacities --------------------------------------------
    @property
    def col_slices(self) -> int:
        """Columns per logical weight (bit-slicing B -> adjacent XBC)."""
        return math.ceil(self.weight_bits / self.xb.cell_precision)

    @property
    def core_weight_capacity_bits(self) -> float:
        """Weight bits one core can hold across its crossbars."""
        return self.core.n_xbs * self.xb.cells * self.xb.cell_precision

    @property
    def chip_weight_capacity_bits(self) -> float:
        return self.chip.n_cores * self.core_weight_capacity_bits

    # ---- elementary latencies (cycles) ---------------------------------
    def t_xb_read(self, rows_used: Optional[int] = None) -> int:
        """Cycles for one crossbar activation (one analog MVM read).

        = input-bit phases x serial row groups. In XBM (no wordline
        control) the whole array is activated, so rows_used is the full
        row count unless the arch exposes WLM.
        """
        if rows_used is None or not self.mode.allows(ComputingMode.WLM):
            rows_used = self.xb.rows
        return self.xb.input_phases(self.act_bits) * self.xb.row_groups(rows_used)

    def t_write_xb(self) -> float:
        """Cycles to program one full crossbar (row-by-row write)."""
        return self.xb.rows * self.xb.cell_type.write_cost_per_row

    def replace(self, **kw) -> "CIMArch":
        return dataclasses.replace(self, **kw)

    def subarch(self, n_cores: int, name: Optional[str] = None) -> "CIMArch":
        """A crossbar-budget *view* of this chip: the same core and
        crossbar tiers, but only ``n_cores`` of the chip's cores.

        This is how the multi-tenant tenancy planner
        (``serving.placement``) hands each co-resident model a feasible
        slice of the physical crossbar pool: every compiler pass and the
        executor see an ordinary ``CIMArch`` whose capacity is the
        tenant's partition, so per-tenant compiles can never place
        weights outside their budget.  Chip-shared resources (ALU rate,
        L0 bandwidth, NoC cost) are intentionally left at chip scale —
        partitioning them is traffic-dependent, not capacity-dependent.
        """
        if not 1 <= n_cores <= self.chip.n_cores:
            raise ValueError(
                f"subarch needs 1 <= n_cores <= {self.chip.n_cores}, "
                f"got {n_cores}")
        chip = dataclasses.replace(self.chip, core_number=(n_cores, 1))
        return self.replace(chip=chip,
                            name=name or f"{self.name}[{n_cores}c]")

    # ---- stable serialization (compile-cache keys, sweep manifests) ----
    def to_dict(self) -> dict:
        """JSON-safe, order-stable description of the full Abs-arch +
        Abs-com configuration.  Two archs with equal ``to_dict()`` compile
        identically, so this is the arch half of a compile-cache key."""
        d = dataclasses.asdict(self)
        d["mode"] = self.mode.value
        d["xb"]["cell_type"] = self.xb.cell_type.value
        return d

    def fingerprint(self) -> str:
        """Stable hex digest of ``to_dict()`` (content-addressed caching)."""
        import hashlib
        import json
        blob = json.dumps(self.to_dict(), sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()


# ---------------------------------------------------------------------------
# Presets from the paper's evaluation section.
# ---------------------------------------------------------------------------

def isaac_baseline(**overrides) -> CIMArch:
    """Table 3 — ISAAC-like ReRAM baseline used in §4.2-§4.4.

    1024 cores, 8 crossbars per core (ISAAC: 8 arrays per IMA), 128x128
    ReRAM arrays with 2-bit cells, 1-bit DAC / 8-bit ADC, 8 parallel rows.
    """
    arch = CIMArch(
        name="isaac-baseline",
        mode=ComputingMode.WLM,
        chip=ChipTier(core_number=(32, 32), alu_ops_per_cycle=1024,
                      l0_bw_bits=8192),
        core=CoreTier(xb_number=(2, 4), alu_ops_per_cycle=1024,
                      l1_bw_bits=8192),
        xb=CrossbarTier(xb_size=(128, 128), dac_bits=1, adc_bits=8,
                        cell_type=CellType.RERAM, cell_precision=2,
                        parallel_row=8),
    )
    return arch.replace(**overrides) if overrides else arch


def jia_cm(**overrides) -> CIMArch:
    """Figure 17 — Jia et al. ISSCC'21: 16 CIMUs of 1152x256 SRAM, CM mode.

    High-precision ADC allows all 1152 rows in parallel; the chip only
    exposes core-granularity activation -> the compiler may use CG-grained
    scheduling only.
    """
    arch = CIMArch(
        name="jia-issc21",
        mode=ComputingMode.CM,
        chip=ChipTier(core_number=(4, 4), core_noc="disjoint-buffer-switch"),
        core=CoreTier(xb_number=(1, 1)),
        xb=CrossbarTier(xb_size=(1152, 256), dac_bits=1, adc_bits=8,
                        cell_type=CellType.SRAM, cell_precision=1,
                        parallel_row=1152),
    )
    return arch.replace(**overrides) if overrides else arch


def puma_xbm(**overrides) -> CIMArch:
    """Figure 18 — PUMA: 138 cores x 2 crossbars of 128x128 ReRAM, XBM mode."""
    arch = CIMArch(
        name="puma",
        mode=ComputingMode.XBM,
        chip=ChipTier(core_number=(138, 1), core_noc="mesh",
                      l0_size_kb=96, l0_bw_bits=384),
        core=CoreTier(xb_number=(2, 1), l1_size_kb=1),
        xb=CrossbarTier(xb_size=(128, 128), dac_bits=8, adc_bits=1,
                        cell_type=CellType.RERAM, cell_precision=2,
                        parallel_row=128),
    )
    return arch.replace(**overrides) if overrides else arch


def jain_wlm(**overrides) -> CIMArch:
    """Figure 19 — Jain et al. JSSC'21 SRAM macro: 4 cores x 2 crossbars of
    256x64, only <=32 rows active at once -> WLM mode."""
    arch = CIMArch(
        name="jain-jssc21",
        mode=ComputingMode.WLM,
        chip=ChipTier(core_number=(4, 1)),
        core=CoreTier(xb_number=(2, 1)),
        xb=CrossbarTier(xb_size=(256, 64), dac_bits=1, adc_bits=6,
                        cell_type=CellType.SRAM, cell_precision=1,
                        parallel_row=32),
    )
    return arch.replace(**overrides) if overrides else arch


def toy_example(**overrides) -> CIMArch:
    """Table 2 — the §3.4 walk-through architecture: 2 cores x 2 crossbars
    of 32x128 with 2-bit cells, 16 parallel rows."""
    arch = CIMArch(
        name="toy-section-3.4",
        mode=ComputingMode.WLM,
        chip=ChipTier(core_number=(2, 1)),
        core=CoreTier(xb_number=(2, 1)),
        xb=CrossbarTier(xb_size=(32, 128), dac_bits=8, adc_bits=8,
                        cell_type=CellType.SRAM, cell_precision=2,
                        parallel_row=16),
    )
    return arch.replace(**overrides) if overrides else arch


PRESETS = {
    "isaac-baseline": isaac_baseline,
    "jia-issc21": jia_cm,
    "puma": puma_xbm,
    "jain-jssc21": jain_wlm,
    "toy": toy_example,
}


def get_arch(name: str, **overrides) -> CIMArch:
    if name not in PRESETS:
        raise KeyError(f"unknown CIM arch preset {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name](**overrides)
