"""VVM-grained optimization (§3.3.4, Figure 14).

Wordline-mode chips can only activate ``parallel_row`` wordlines per
cycle, so reading one crossbar whose mapped rows exceed that limit takes
``g = ceil(rows_used / parallel_row)`` serial sub-cycles, and a consumer
operator cannot start until the serial accumulation finishes.

The *data remapping* strategy spreads the row groups that contribute to
the same accumulation across ``k`` different crossbars: all groups then
activate in the same cycle (their partial sums are combined by the ALU
shift-accumulate), so the activation takes ``ceil(g/k)`` sub-cycles and
the consumer starts earlier — converting serial accumulation into
parallel computation (Figure 14(c)/(d)).

The remap consumes spare crossbars left over after MVM-grained
duplication; the pass chooses, per operator, between spending leftovers
on further duplication or on row-spreading, keeping whichever minimizes
the stage bottleneck (the paper applies remapping where MVM-grained
duplication is ineffective, e.g. Jain et al.'s small-core macro).
"""
from __future__ import annotations

import math

from .abstraction import ComputingMode
from .cg_opt import SchedulePlan


def run(plan: SchedulePlan) -> SchedulePlan:
    arch = plan.arch
    if not arch.mode.allows(ComputingMode.WLM):
        raise ValueError(f"{arch.name} exposes no wordline-level interface "
                         f"(mode={arch.mode.value})")

    total_xbs = arch.chip.n_cores * arch.core.n_xbs
    for seg in plan.segments:
        used = sum(p.dup * p.mapping.n_xbs for p in seg.placements)
        spare = max(0, total_xbs - used)
        # 1. spend spare crossbars on the ops with the worst bottleneck first
        for p in sorted(seg.placements, key=lambda q: -q.stage_cycles):
            g = p.row_groups
            if g <= 1:
                p.node.sched["row_spread"] = 1
                continue
            # spreading one copy's row groups k-ways costs (k-1) extra
            # crossbar sets of the same column footprint
            per_spread = max(1, p.dup * p.mapping.n_xbs)
            k_max = 1 + (spare // per_spread)
            k = min(g, k_max)
            if k > 1:
                spare -= (k - 1) * per_spread
                p.row_spread = k

        # 2. duplication <-> spreading conversion: turning two copies into
        # one double-spread copy keeps the crossbar cost and the stage
        # throughput but halves t_window — a strictly finer pipeline
        # granularity (Fig. 14(d)'s earlier consumer start).
        if plan.use_pipeline:
            for p in seg.placements:
                while (p.dup >= 2 and p.row_spread * 2 <=
                       max(1, math.ceil(p.row_groups / 1))):
                    if p.row_spread >= p.row_groups:
                        break
                    old_stage = p.stage_cycles
                    old_dup, old_spread = p.dup, p.row_spread
                    p.dup = old_dup // 2
                    p.row_spread = min(p.row_groups, old_spread * 2)
                    if p.stage_cycles > old_stage + 1e-9:
                        p.dup, p.row_spread = old_dup, old_spread
                        break
        for p in seg.placements:
            p.node.sched["row_spread"] = p.row_spread

    plan.vvm_remap = True
    plan.notes["vvm_remap"] = True
    return plan
