"""VXB (Virtual Crossbar) construction and dimension binding (§3.3.3, Fig. 7).

A *VXB* is the set of physical crossbars that collaborate to perform a
single MVM: the logical weight matrix (R rows x C cols x B weight bits)
is bound onto the physical crossbar grid.  The paper's dimension-binding
scheme offers two placements for the bit dimension:

  * ``B -> XBC`` (default): weight bits spread to *adjacent columns* of the
    same crossbar, so a logical column consumes ``ceil(B/cell_precision)``
    physical columns.
  * ``B -> XB``: bit slices live on *different crossbars*, each crossbar
    holding one slice of the full R x C matrix.

R always binds to XBR (wordlines) and C to XBC (bitlines).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import List

from ..obs import hooks as obs_hooks
from .abstraction import CIMArch
from .graph import Node, weight_matrix_shape


class BitBinding(enum.Enum):
    B_TO_XBC = "B->XBC"     # bits to adjacent columns (Figure 7 default)
    B_TO_XB = "B->XB"       # bits to separate crossbars


def bind_error_msg(cols: int, slices: int) -> str:
    """The ``bind`` infeasibility message for B->XBC with too few columns.

    Single-sourced so the batched proxy's masked-infeasibility reasons
    (dse.proxy_vec) can never drift from the scalar raise."""
    return (f"crossbar has {cols} columns < {slices} bit slices; "
            "use BitBinding.B_TO_XB for this cell precision")


def vxb_span_error(name: str, span: int, cap: int) -> str:
    """The over-capacity message for a VXB column unit spanning more
    crossbars than the chip offers (cg_opt chunking, proxy screening)."""
    return (f"{name}: one VXB column unit spans {span} crossbars but the "
            f"chip offers only {cap}")


@dataclasses.dataclass(frozen=True)
class VXBMapping:
    """How one operator copy's weight matrix occupies physical crossbars."""

    r: int                      # logical rows of weight matrix
    c: int                      # logical cols
    binding: BitBinding
    col_slices: int             # physical columns per logical weight
    grid_r: int                 # crossbars stacked along R
    grid_c: int                 # crossbars stacked along C (incl. bit slices)
    rows_used_last: int         # wordlines used in the last row-tile
    cols_used_last: int         # bitlines used in the last col-tile

    @property
    def n_xbs(self) -> int:
        """Physical crossbars holding one full copy of the weight matrix."""
        return self.grid_r * self.grid_c

    @property
    def xbs_per_vxb(self) -> int:
        """Crossbars composing one VXB (the unit computing one sub-MVM tile
        at full weight precision).  With ``B->XBC`` the bit slices share a
        crossbar, so a VXB is a single crossbar; with ``B->XB`` one VXB
        spans ``col_slices`` crossbars."""
        return self.col_slices if self.binding is BitBinding.B_TO_XB else 1

    @property
    def n_vxb(self) -> int:
        """VXB tiles needed to cover the whole weight matrix (``num_VXB``
        of Eq. 1)."""
        return self.n_xbs // self.xbs_per_vxb


def bind(node_or_rc, arch: CIMArch,
         binding: BitBinding = BitBinding.B_TO_XBC) -> VXBMapping:
    """Bind a weight matrix to the crossbar grid of ``arch``."""
    if isinstance(node_or_rc, Node):
        r, c = weight_matrix_shape(node_or_rc)
    else:
        r, c = node_or_rc
    slices = math.ceil(arch.weight_bits / arch.xb.cell_precision)
    xr, xc = arch.xb.rows, arch.xb.cols

    grid_r = math.ceil(r / xr)
    if binding is BitBinding.B_TO_XBC:
        # a logical column's bit slices live in adjacent physical columns
        # of the same crossbar (never straddling two crossbars), so each
        # crossbar holds floor(cols / slices) logical columns
        if xc < slices:
            raise ValueError(bind_error_msg(xc, slices))
        cols_per_xb = xc // slices
        grid_c = math.ceil(c / cols_per_xb)
        cols_last = (c - (grid_c - 1) * cols_per_xb) * slices
    else:
        per_slice_grid_c = math.ceil(c / xc)
        grid_c = per_slice_grid_c * slices
        cols_last = c - (per_slice_grid_c - 1) * xc

    rows_last = r - (grid_r - 1) * xr
    m = VXBMapping(r=r, c=c, binding=binding, col_slices=slices,
                   grid_r=grid_r, grid_c=grid_c,
                   rows_used_last=rows_last, cols_used_last=cols_last)
    # gated at the call site: bind runs in DSE inner loops, so the
    # payload must not be built unless a provenance subscriber is live
    if obs_hooks.subscribed():
        obs_hooks.emit("mapping.bind", r=r, c=c, binding=binding.value,
                       col_slices=slices, grid_r=grid_r, grid_c=grid_c,
                       n_xbs=m.n_xbs)
    return m


def bind_arrays(r, c, *, rows, cols, slices, b_to_xb):
    """Array-shaped twin of ``bind`` over a (points x nodes) broadcast.

    ``r``/``c`` are per-node integer arrays (shape ``(N,)`` or ``(P, N)``)
    and ``rows``/``cols``/``slices``/``b_to_xb`` per-point columns (shape
    ``(P, 1)``); everything broadcasts to ``(P, N)``.  Returns a dict of
    int64 arrays ``grid_r``/``grid_c``/``n_xbs``/``xbs_per_vxb`` plus the
    boolean ``feasible`` mask (False exactly where scalar ``bind`` raises:
    B->XBC with fewer physical columns than bit slices).  Entries of
    infeasible points are computed with guarded denominators and carry no
    meaning — mask before use.

    Bit-exact against ``bind``: every quantity is the same integer
    ceiling/floor arithmetic, just broadcast.  The scalar path stays the
    oracle (tests/test_proxy_vec.py anchors the equivalence).
    """
    import numpy as np

    r = np.asarray(r, dtype=np.int64)
    c = np.asarray(c, dtype=np.int64)
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    slices = np.asarray(slices, dtype=np.int64)
    b_to_xb = np.asarray(b_to_xb, dtype=bool)

    feasible = b_to_xb | (cols >= slices)
    grid_r = -(-r // np.maximum(rows, 1))
    # B->XBC: bit slices share a crossbar -> floor(cols/slices) logical
    # columns per crossbar; B->XB: one slice per crossbar, full columns
    cols_per_xb = np.maximum(cols // np.maximum(slices, 1), 1)
    grid_c_xbc = -(-c // cols_per_xb)
    grid_c_xb = -(-c // np.maximum(cols, 1)) * slices
    grid_c = np.where(b_to_xb, grid_c_xb, grid_c_xbc)
    n_xbs = grid_r * grid_c
    xbs_per_vxb = np.where(b_to_xb, slices, 1)
    out = np.broadcast_arrays(grid_r, grid_c, n_xbs, xbs_per_vxb,
                              feasible | np.zeros_like(grid_r, dtype=bool))
    return {"grid_r": out[0], "grid_c": out[1], "n_xbs": out[2],
            "xbs_per_vxb": out[3], "feasible": out[4]}


class FaultBudgetError(ValueError):
    """Fault retirement exceeds the crossbar's capacity: after retiring
    the requested faulty wordlines/bitlines the remaining geometry cannot
    bind any weight tile (or the fault-aware compile loop could not find
    enough clean lines within its retirement budget).  Carries
    ``retire_rows``/``retire_cols`` so callers can report how far the
    retirement climbed before giving up."""

    def __init__(self, msg: str, *, retire_rows: int = 0,
                 retire_cols: int = 0):
        self.retire_rows = retire_rows
        self.retire_cols = retire_cols
        super().__init__(msg)


def retired_geometry(arch: CIMArch, retire_rows: int = 0,
                     retire_cols: int = 0) -> CIMArch:
    """``arch`` with ``retire_rows`` wordlines and ``retire_cols``
    bitlines removed from every crossbar's bindable geometry.

    This is the compiler half of fault-aware remapping: compiling
    against the shrunk crossbar leaves each physical tile spare lines,
    which the runtime fault map's clean-line selection then uses to
    steer every weight row/column group away from faulty hardware
    (``cimsim.faults.FaultMap(remap=True)``).  ``parallel_row`` is
    clamped to the surviving rows.  Raises ``FaultBudgetError`` when the
    retirement leaves no bindable geometry (no rows, or fewer columns
    than one logical weight's bit slices).
    """
    rows = arch.xb.rows - int(retire_rows)
    cols = arch.xb.cols - int(retire_cols)
    slices = math.ceil(arch.weight_bits / arch.xb.cell_precision)
    if rows < 1 or cols < slices:
        raise FaultBudgetError(
            f"retiring {retire_rows} rows / {retire_cols} cols of a "
            f"{arch.xb.rows}x{arch.xb.cols} crossbar leaves {rows}x{cols} "
            f"— below the {max(1, slices)}-column minimum for "
            f"{arch.weight_bits}-bit weights",
            retire_rows=retire_rows, retire_cols=retire_cols)
    xb = dataclasses.replace(
        arch.xb, xb_size=(rows, cols),
        parallel_row=min(arch.xb.parallel_row, rows))
    name = arch.name
    if retire_rows or retire_cols:
        name = f"{arch.name}-ret{retire_rows}r{retire_cols}c"
    return arch.replace(xb=xb, name=name)


def vxbs_per_core(arch: CIMArch, mapping: VXBMapping) -> int:
    """``Core_VXB`` of Eq. (1): VXBs that fit in one core."""
    return arch.core.n_xbs // mapping.xbs_per_vxb


def cores_per_copy(arch: CIMArch, mapping: VXBMapping) -> int:
    """Cores one operator copy occupies (CG-grained granularity)."""
    return max(1, math.ceil(mapping.n_xbs / arch.core.n_xbs))


def row_tile_rows(mapping: VXBMapping, arch: CIMArch) -> List[int]:
    """Wordlines used by each row tile of the VXB."""
    full = arch.xb.rows
    return [full] * (mapping.grid_r - 1) + [mapping.rows_used_last]


def logical_cols_per_xb(mapping: VXBMapping, arch: CIMArch) -> int:
    """Logical (full-precision) weight columns held by one crossbar."""
    if mapping.binding is BitBinding.B_TO_XBC:
        return max(1, arch.xb.cols // mapping.col_slices)
    return arch.xb.cols
