"""Multi-level scheduling driver (§3.3.1, Figure 3).

The computing mode exposed by the target chip selects the pass stack:

    CM  chip:  CG-grained only
    XBM chip:  CG-grained -> MVM-grained
    WLM chip:  CG-grained -> MVM-grained -> VVM-grained

Finer passes inherit the coarser results (the paper's "multi-level joint
scheduling").  ``level`` may be clamped below the chip's mode for the
ablation arms of §4.3 (e.g. evaluate CG-only on a WLM-capable chip).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Optional, Union

from ..obs import hooks as obs_hooks
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from . import cg_opt, codegen, mvm_opt, vvm_opt
from .abstraction import CIMArch, ComputingMode
from .cg_opt import SchedulePlan
from .graph import Graph
from .mapping import BitBinding
from .mop import Program


@dataclasses.dataclass
class CompileResult:
    plan: SchedulePlan
    program: Program
    #: content hash of the (graph, arch, knobs) config that produced this
    #: result, as stored in the compile cache.  Note the executor cache
    #: derives its own key via ``compile_key_for_plan`` (normalized over
    #: expansion and salted by baseline policy) — this field is identity
    #: metadata, not that anchor.
    key: Optional[str] = None

    @property
    def text(self) -> str:
        return self.program.to_text()

    def report(self) -> dict:
        from ..cimsim import perf
        return dataclasses.asdict(perf.estimate(self.plan))

    def metrics(self) -> dict:
        """JSON-safe metric bundle (the DSE objective vector lives here)."""
        from ..cimsim import perf
        return perf.estimate(self.plan).metrics()


# ---------------------------------------------------------------------------
# Compile cache hook.
#
# ``compile_graph`` consults an (optional) cache object with the duck-typed
# interface ``get(key) -> Optional[CompileResult]`` / ``put(key, result)``
# (dse.cache.CompileCache is the disk-backed implementation).  The key is a
# content hash of everything that determines the output: the graph structure,
# the full Abs-arch description and every scheduling knob.
# ---------------------------------------------------------------------------

#: bump when compiler passes change in ways that alter emitted programs
#: (or when CompileResult's pickled layout changes), so stale cache
#: entries from older code can never be returned.
COMPILE_KEY_SCHEMA = 2

_COMPILE_CACHE = None


def set_compile_cache(cache):
    """Install a process-wide default compile cache; returns the previous
    one (``None`` to disable).  Explicit ``compile_graph(..., cache=...)``
    arguments take precedence."""
    global _COMPILE_CACHE
    prev, _COMPILE_CACHE = _COMPILE_CACHE, cache
    return prev


def get_compile_cache():
    return _COMPILE_CACHE


def compile_key(
    graph: Graph,
    arch: CIMArch,
    *,
    level: Optional[Union[str, ComputingMode]] = None,
    use_pipeline: bool = True,
    use_duplication: bool = True,
    binding: BitBinding = BitBinding.B_TO_XBC,
    expand: bool = False,
) -> str:
    """Stable content hash of one (graph, arch, knobs) compile config."""
    if isinstance(level, str):
        level = ComputingMode(level)
    level = level or arch.mode
    payload = {
        "schema": COMPILE_KEY_SCHEMA,
        "graph": graph.to_dict(),
        "arch": arch.to_dict(),
        "level": level.value,
        "use_pipeline": bool(use_pipeline),
        "use_duplication": bool(use_duplication),
        "binding": binding.value,
        "expand": bool(expand),
    }
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


def compile_key_for_plan(plan: SchedulePlan) -> str:
    """Content key of the config a ``SchedulePlan`` was built under.

    Reconstructs the knobs from the plan itself (the binding lives on the
    placements' mappings), normalized to ``expand=False`` — program
    expansion changes neither the schedule nor the lowered semantics, so
    executor caches built on this key are shared across expansion modes.
    Plans not produced by ``compile_graph`` (the §4.2 baseline policies
    in ``core.baselines`` tag ``notes["policy"]``) get a distinct suffix:
    their placements differ from the compiler's for the same knobs, and
    under a saturating ADC different tilings compute different values.
    """
    binding = (plan.placements[0].mapping.binding if plan.placements
               else BitBinding.B_TO_XBC)
    key = compile_key(plan.graph, plan.arch,
                      level=plan.notes.get("level"),
                      use_pipeline=plan.use_pipeline,
                      use_duplication=plan.use_duplication,
                      binding=binding, expand=False)
    policy = plan.notes.get("policy")
    return f"{key}:{policy}" if policy else key


def mode_error(arch: CIMArch, level: ComputingMode) -> str:
    """Message for a scheduling level the chip's computing mode does not
    expose.  Single-sourced so the batched proxy's masked-infeasibility
    reasons (dse.proxy_vec) match the scalar raises verbatim."""
    return (f"chip {arch.name} (mode {arch.mode.value}) does not expose "
            f"the {level.value} interface")


def proxy_metrics(
    graph: Graph,
    arch: CIMArch,
    *,
    level: Optional[Union[str, ComputingMode]] = None,
    use_pipeline: bool = True,
    use_duplication: bool = True,
    binding: BitBinding = BitBinding.B_TO_XBC,
) -> dict:
    """Analytic proxy for ``compile_graph(...).metrics()`` — no codegen,
    no segmentation search, no event-driven simulation.

    The cheap rung of the multi-fidelity DSE searcher (dse.search): build
    one placement per CIM node with the real ``CostModel``, run the real
    duplication search over one flat segment, approximate the VVM row
    spread, and read latency off ``estimate_segment_cycles``.  The bundle
    carries the sweep objective keys (``latency_cycles``, ``peak_power``,
    ``crossbars_used``) so a proxy score ranks points the same way a full
    compile would be ranked — absolute values are *not* comparable across
    fidelities, and proxies are never cached on disk.

    Raises like ``compile_graph`` for configurations no compile could
    serve (level above the chip's mode, bit slices that fit no crossbar).

    This scalar path is the *oracle*: ``dse.proxy_vec.proxy_metrics_batch``
    evaluates the same model for an entire array of design points in one
    vectorized pass, bit-exact against this function (infeasible points
    come back masked instead of raising).
    """
    from .cg_opt import (CostModel, balance_duplication,
                         estimate_segment_cycles, greedy_duplication)
    from .mapping import vxb_span_error
    from .mvm_opt import peak_active_xbs

    if isinstance(level, str):
        level = ComputingMode(level)
    level = level or arch.mode
    if not arch.mode.allows(level):
        raise ValueError(mode_error(arch, level))

    cm = CostModel(arch, binding)
    cap_xbs = arch.chip.n_cores * arch.core.n_xbs
    pls = []
    for node in graph.cim_nodes:
        p = cm.placement(node, graph)
        if p.mapping.xbs_per_vxb > cap_xbs:
            raise ValueError(vxb_span_error(node.name, p.mapping.xbs_per_vxb,
                                            cap_xbs))
        pls.append(p)

    budget = arch.chip.n_cores
    multi_segment = sum(p.cores for p in pls) > budget
    if use_duplication and not multi_segment and pls:
        dup = balance_duplication if use_pipeline else greedy_duplication
        if level.allows(ComputingMode.XBM):
            dup(pls, cap_xbs, unit="xbs")
        else:
            dup(pls, budget, unit="cores")

    if level.allows(ComputingMode.WLM):
        # vvm_opt's remap, first-order: spend spare crossbars spreading the
        # worst bottlenecks' row groups
        spare = max(0, cap_xbs - sum(p.dup * p.mapping.n_xbs for p in pls))
        for p in sorted(pls, key=lambda q: -q.stage_cycles):
            if p.row_groups <= 1:
                continue
            per_spread = max(1, p.dup * p.mapping.n_xbs)
            k = min(p.row_groups, 1 + spare // per_spread)
            if k > 1:
                spare -= (k - 1) * per_spread
                p.row_spread = k

    latency = estimate_segment_cycles(pls, use_pipeline)
    rewrite = 0.0
    if multi_segment:
        # every crossbar is reprogrammed per inference; cores write in
        # parallel (cg_opt._rewrite_cycles on the whole placement list)
        n_xbs = sum(p.dup * p.mapping.n_xbs for p in pls)
        rewrite = n_xbs * arch.t_write_xb() / max(arch.chip.n_cores, 1)
        latency += rewrite
    stagger = level.allows(ComputingMode.XBM)
    active = [peak_active_xbs(p, stagger) for p in pls]
    peak = float((sum if use_pipeline else max)(active)) if active else 0.0
    xbs_used = sum(p.dup * p.mapping.n_xbs for p in pls)
    if multi_segment:
        xbs_used = min(xbs_used, cap_xbs)   # segments reuse the pool
    return {
        "latency_cycles": float(max(latency, 1e-9)),
        "compute_cycles": float(sum(p.stage_cycles for p in pls)),
        "rewrite_cycles": float(rewrite),
        "peak_power": peak,
        "crossbars_used": int(xbs_used),
        "fidelity": "proxy",
    }


def compile_graph(
    graph: Graph,
    arch: CIMArch,
    *,
    level: Optional[Union[str, ComputingMode]] = None,
    use_pipeline: bool = True,
    use_duplication: bool = True,
    binding: BitBinding = BitBinding.B_TO_XBC,
    expand: bool = False,
    cache=None,
) -> CompileResult:
    """Compile ``graph`` for ``arch`` and emit the meta-operator flow.

    ``cache`` (or a process-wide default installed via
    ``set_compile_cache``) short-circuits recompiles of identical
    configurations; a hit returns the cached ``CompileResult`` — note its
    ``plan.graph`` is the cache's own copy, not the ``graph`` argument.
    """
    if isinstance(level, str):
        level = ComputingMode(level)
    level = level or arch.mode
    if not arch.mode.allows(level):
        raise ValueError(mode_error(arch, level))

    t0 = time.perf_counter()
    cache = cache if cache is not None else _COMPILE_CACHE
    key = compile_key(graph, arch, level=level, use_pipeline=use_pipeline,
                      use_duplication=use_duplication, binding=binding,
                      expand=expand)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:    # schema-2 entries are stored with key set
            _note_compile(graph, arch, level, key, cached=True,
                          wall_s=time.perf_counter() - t0, plan=hit.plan)
            return hit

    def build(ping_pong: bool) -> SchedulePlan:
        plan = cg_opt.run(graph, arch, use_pipeline=use_pipeline,
                          use_duplication=use_duplication, binding=binding,
                          ping_pong=ping_pong)
        plan.notes["level"] = level
        if level.allows(ComputingMode.XBM):
            mvm_opt.run(plan)
        if level.allows(ComputingMode.WLM):
            vvm_opt.run(plan)
        return plan

    plan = build(ping_pong=False)
    if len(plan.segments) > 1:
        # weight reloads are on the critical path: consider double-buffered
        # (ping-pong) scheduling that hides rewrites behind compute at the
        # price of half the compute pool per segment.
        from ..cimsim import perf
        try:
            alt = build(ping_pong=True)
        except ValueError:
            alt = None   # half the pool cannot hold one placement chunk
        if alt is not None and \
                perf.estimate(alt).latency_cycles < perf.estimate(plan).latency_cycles:
            plan = alt
        else:  # rebuild to restore node.sched annotations of the winner
            plan = build(ping_pong=False)

    program = codegen.emit(plan, expand=expand)
    program.validate()
    result = CompileResult(plan=plan, program=program, key=key)
    if cache is not None:
        cache.put(key, result)
    _note_compile(graph, arch, level, key, cached=False,
                  wall_s=time.perf_counter() - t0, plan=plan)
    return result


def _note_compile(graph, arch, level, key, *, cached, wall_s, plan) -> None:
    """Telemetry for one ``compile_graph`` return (hit or fresh build).

    Disabled telemetry costs two ``is None`` checks and one list
    truthiness test; the span is drawn back from "now" so the compile
    occupies its real wall interval on the compiler track.  The flow
    start seeds the compile→dispatch arrow the executor's first
    dispatch of this artifact closes (ids derive from the compile key
    prefix on both sides — see ``cimsim.executor.lower``).
    """
    reg = obs_metrics.active()
    if reg is not None:
        reg.counter("compiles_total", workload=graph.name,
                    cached=cached).inc()
        reg.histogram("compile_wall_s", cached=cached).observe(wall_s)
    tr = obs_trace.get_trace()
    if tr is not None:
        now = obs_trace.now_s()
        tr.complete(obs_trace.COMPILER_TRACK, graph.name,
                    f"compile:{graph.name}", "compile",
                    now - wall_s, wall_s, level=level.value, cached=cached,
                    segments=len(plan.segments), key=key[:12])
        tr.flow_start(obs_trace.COMPILER_TRACK, graph.name,
                      "artifact", "flow", now - wall_s / 2,
                      flow_id=int(key[:12], 16), key=key[:12])
    obs_hooks.emit("compile.done", graph=graph.name, arch=arch.name,
                   key=key, cached=cached, wall_s=wall_s,
                   level=level.value, segments=len(plan.segments),
                   ping_pong=bool(plan.notes.get("ping_pong", False)))
