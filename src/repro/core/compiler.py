"""Multi-level scheduling driver (§3.3.1, Figure 3).

The computing mode exposed by the target chip selects the pass stack:

    CM  chip:  CG-grained only
    XBM chip:  CG-grained -> MVM-grained
    WLM chip:  CG-grained -> MVM-grained -> VVM-grained

Finer passes inherit the coarser results (the paper's "multi-level joint
scheduling").  ``level`` may be clamped below the chip's mode for the
ablation arms of §4.3 (e.g. evaluate CG-only on a WLM-capable chip).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional, Union

from . import cg_opt, codegen, mvm_opt, vvm_opt
from .abstraction import CIMArch, ComputingMode
from .cg_opt import SchedulePlan
from .graph import Graph
from .mapping import BitBinding
from .mop import Program


@dataclasses.dataclass
class CompileResult:
    plan: SchedulePlan
    program: Program

    @property
    def text(self) -> str:
        return self.program.to_text()

    def report(self) -> dict:
        from ..cimsim import perf
        return dataclasses.asdict(perf.estimate(self.plan))

    def metrics(self) -> dict:
        """JSON-safe metric bundle (the DSE objective vector lives here)."""
        from ..cimsim import perf
        return perf.estimate(self.plan).metrics()


# ---------------------------------------------------------------------------
# Compile cache hook.
#
# ``compile_graph`` consults an (optional) cache object with the duck-typed
# interface ``get(key) -> Optional[CompileResult]`` / ``put(key, result)``
# (dse.cache.CompileCache is the disk-backed implementation).  The key is a
# content hash of everything that determines the output: the graph structure,
# the full Abs-arch description and every scheduling knob.
# ---------------------------------------------------------------------------

#: bump when compiler passes change in ways that alter emitted programs,
#: so stale cache entries from older code can never be returned.
COMPILE_KEY_SCHEMA = 1

_COMPILE_CACHE = None


def set_compile_cache(cache):
    """Install a process-wide default compile cache; returns the previous
    one (``None`` to disable).  Explicit ``compile_graph(..., cache=...)``
    arguments take precedence."""
    global _COMPILE_CACHE
    prev, _COMPILE_CACHE = _COMPILE_CACHE, cache
    return prev


def get_compile_cache():
    return _COMPILE_CACHE


def compile_key(
    graph: Graph,
    arch: CIMArch,
    *,
    level: Optional[Union[str, ComputingMode]] = None,
    use_pipeline: bool = True,
    use_duplication: bool = True,
    binding: BitBinding = BitBinding.B_TO_XBC,
    expand: bool = False,
) -> str:
    """Stable content hash of one (graph, arch, knobs) compile config."""
    if isinstance(level, str):
        level = ComputingMode(level)
    level = level or arch.mode
    payload = {
        "schema": COMPILE_KEY_SCHEMA,
        "graph": graph.to_dict(),
        "arch": arch.to_dict(),
        "level": level.value,
        "use_pipeline": bool(use_pipeline),
        "use_duplication": bool(use_duplication),
        "binding": binding.value,
        "expand": bool(expand),
    }
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


def compile_graph(
    graph: Graph,
    arch: CIMArch,
    *,
    level: Optional[Union[str, ComputingMode]] = None,
    use_pipeline: bool = True,
    use_duplication: bool = True,
    binding: BitBinding = BitBinding.B_TO_XBC,
    expand: bool = False,
    cache=None,
) -> CompileResult:
    """Compile ``graph`` for ``arch`` and emit the meta-operator flow.

    ``cache`` (or a process-wide default installed via
    ``set_compile_cache``) short-circuits recompiles of identical
    configurations; a hit returns the cached ``CompileResult`` — note its
    ``plan.graph`` is the cache's own copy, not the ``graph`` argument.
    """
    if isinstance(level, str):
        level = ComputingMode(level)
    level = level or arch.mode
    if not arch.mode.allows(level):
        raise ValueError(
            f"chip {arch.name} (mode {arch.mode.value}) does not expose the "
            f"{level.value} interface")

    cache = cache if cache is not None else _COMPILE_CACHE
    key = None
    if cache is not None:
        key = compile_key(graph, arch, level=level, use_pipeline=use_pipeline,
                          use_duplication=use_duplication, binding=binding,
                          expand=expand)
        hit = cache.get(key)
        if hit is not None:
            return hit

    def build(ping_pong: bool) -> SchedulePlan:
        plan = cg_opt.run(graph, arch, use_pipeline=use_pipeline,
                          use_duplication=use_duplication, binding=binding,
                          ping_pong=ping_pong)
        plan.notes["level"] = level
        if level.allows(ComputingMode.XBM):
            mvm_opt.run(plan)
        if level.allows(ComputingMode.WLM):
            vvm_opt.run(plan)
        return plan

    plan = build(ping_pong=False)
    if len(plan.segments) > 1:
        # weight reloads are on the critical path: consider double-buffered
        # (ping-pong) scheduling that hides rewrites behind compute at the
        # price of half the compute pool per segment.
        from ..cimsim import perf
        try:
            alt = build(ping_pong=True)
        except ValueError:
            alt = None   # half the pool cannot hold one placement chunk
        if alt is not None and \
                perf.estimate(alt).latency_cycles < perf.estimate(plan).latency_cycles:
            plan = alt
        else:  # rebuild to restore node.sched annotations of the winner
            plan = build(ping_pong=False)

    program = codegen.emit(plan, expand=expand)
    program.validate()
    result = CompileResult(plan=plan, program=program)
    if cache is not None:
        cache.put(key, result)
    return result
