"""Multi-level scheduling driver (§3.3.1, Figure 3).

The computing mode exposed by the target chip selects the pass stack:

    CM  chip:  CG-grained only
    XBM chip:  CG-grained -> MVM-grained
    WLM chip:  CG-grained -> MVM-grained -> VVM-grained

Finer passes inherit the coarser results (the paper's "multi-level joint
scheduling").  ``level`` may be clamped below the chip's mode for the
ablation arms of §4.3 (e.g. evaluate CG-only on a WLM-capable chip).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from . import cg_opt, codegen, mvm_opt, vvm_opt
from .abstraction import CIMArch, ComputingMode
from .cg_opt import SchedulePlan
from .graph import Graph
from .mapping import BitBinding
from .mop import Program


@dataclasses.dataclass
class CompileResult:
    plan: SchedulePlan
    program: Program

    @property
    def text(self) -> str:
        return self.program.to_text()

    def report(self) -> dict:
        from ..cimsim import perf
        return dataclasses.asdict(perf.estimate(self.plan))


def compile_graph(
    graph: Graph,
    arch: CIMArch,
    *,
    level: Optional[Union[str, ComputingMode]] = None,
    use_pipeline: bool = True,
    use_duplication: bool = True,
    binding: BitBinding = BitBinding.B_TO_XBC,
    expand: bool = False,
) -> CompileResult:
    """Compile ``graph`` for ``arch`` and emit the meta-operator flow."""
    if isinstance(level, str):
        level = ComputingMode(level)
    level = level or arch.mode
    if not arch.mode.allows(level):
        raise ValueError(
            f"chip {arch.name} (mode {arch.mode.value}) does not expose the "
            f"{level.value} interface")

    def build(ping_pong: bool) -> SchedulePlan:
        plan = cg_opt.run(graph, arch, use_pipeline=use_pipeline,
                          use_duplication=use_duplication, binding=binding,
                          ping_pong=ping_pong)
        plan.notes["level"] = level
        if level.allows(ComputingMode.XBM):
            mvm_opt.run(plan)
        if level.allows(ComputingMode.WLM):
            vvm_opt.run(plan)
        return plan

    plan = build(ping_pong=False)
    if len(plan.segments) > 1:
        # weight reloads are on the critical path: consider double-buffered
        # (ping-pong) scheduling that hides rewrites behind compute at the
        # price of half the compute pool per segment.
        from ..cimsim import perf
        alt = build(ping_pong=True)
        if perf.estimate(alt).latency_cycles < perf.estimate(plan).latency_cycles:
            plan = alt
        else:  # rebuild to restore node.sched annotations of the winner
            plan = build(ping_pong=False)

    program = codegen.emit(plan, expand=expand)
    program.validate()
    return CompileResult(plan=plan, program=program)
