"""Baseline scheduling policies the paper compares against (§4.2).

Every baseline is expressed as a restriction of the same ``SchedulePlan``
machinery, so latency / power numbers are produced by the *same*
performance simulator as CIM-MLC's own schedules — only the policy
differs (matching the paper's "same CIM architecture abstracted in
Table 3" methodology).

  * ``no_opt``        — serial layer-by-layer execution, one copy per op.
  * ``native``        — the chip's own scheduling: dup=1, no intra-image
                        pipeline, traditional full-VXB activation.
  * ``poly_schedule`` — Poly-Schedule [22]-style: greedy operator
                        duplication + inter-layer (batch) pipeline; no
                        MVM-grained stagger, no VVM remap, and no
                        intra-image pipeline (its pipeline overlaps
                        *different* inputs, which does not cut
                        single-image latency).
"""
from __future__ import annotations

from . import cg_opt
from .abstraction import CIMArch, ComputingMode
from .cg_opt import SchedulePlan
from .graph import Graph
from .mapping import BitBinding


def no_opt(graph: Graph, arch: CIMArch,
           binding: BitBinding = BitBinding.B_TO_XBC) -> SchedulePlan:
    plan = cg_opt.run(graph, arch, use_pipeline=False, use_duplication=False,
                      binding=binding, naive_chunking=True)
    plan.notes["policy"] = "no-opt"
    plan.notes["level"] = ComputingMode.CM
    return plan


def native(graph: Graph, arch: CIMArch,
           binding: BitBinding = BitBinding.B_TO_XBC) -> SchedulePlan:
    """The accelerator's as-published schedule: weights mapped once,
    operators execute in order, all crossbars of an operator fire
    together (traditional Fig.12(c) activation)."""
    plan = no_opt(graph, arch, binding)
    plan.notes["policy"] = "native"
    plan.notes["level"] = arch.mode  # uses the chip's full interface width
    return plan


def poly_schedule(graph: Graph, arch: CIMArch,
                  binding: BitBinding = BitBinding.B_TO_XBC) -> SchedulePlan:
    plan = cg_opt.run(graph, arch, use_pipeline=False, use_duplication=True,
                      binding=binding, naive_chunking=True)
    # greedy (min-sum) duplication instead of the balanced pipelined DP
    for seg in plan.segments:
        for p in seg.placements:
            p.dup = 1
        cg_opt.greedy_duplication(seg.placements, arch.chip.n_cores)
    plan.notes["policy"] = "poly-schedule"
    plan.notes["level"] = (ComputingMode.XBM
                           if arch.mode.allows(ComputingMode.XBM)
                           else ComputingMode.CM)
    return plan
